"""--suite dist: feature-table → analysis sessions, fused vs materialized.

``PYTHONPATH=src python -m benchmarks.run --suite dist``

The canonical workload is Sfiligoi-et-al.'s personal-device pipeline one
step upstream of ``--suite api``: an (n, d) abundance table becomes
Bray–Curtis distances and immediately feeds PCoA + PERMANOVA. Two modes:

* **fused** — ``Workspace.from_features``: the ``repro.dist`` driver
  emits CONDENSED distances tile-by-tile with the operator means
  accumulated during the sweep; PCoA runs matrix-free off the condensed
  operator and PERMANOVA streams ``op.matvec`` strips. No n×n square
  matrix is ever allocated.
* **materialized baseline** — build the square matrix
  (``pairwise_distances(..., out="square")``), then run the same session
  through a square-backed Workspace (which additionally hoists the
  square Gower matrix for PERMANOVA) — exactly what a pdist→squareform→
  analyze pipeline does.

Per the container-noise rule the tracked quantities are **analytic**:
peak matrix bytes per mode (condensed m·4 vs square n²·4 + gram n²·4)
and the n²-pass hoist accounting from the ``HoistCache`` miss counters;
``bytes_avoided`` — the n×n allocations the fused path never makes — is
the acceptance artifact. Wall time is recorded but informational (±40%).
"""

import json
import time

import jax
import numpy as np

from repro.api.config import ExecConfig
from repro.api.workspace import Workspace
from repro.dist import condensed_size, pairwise_distances
from repro.obs.ledger import FEATURE_HOIST_PASSES, HOIST_PASSES

_NUM_GROUPS = 8
_DIMS = 10
_FEATURES = 128

# The audited pass tables live in ONE place now — ``repro.obs.ledger``
# (the feature-backed vs square-backed columns the instrumented
# HoistCache charges live). The production's O(n·d) feature reads stay
# out of the pass accounting since they are identical in both modes
# (``repro.obs.ledger.production_floats`` is their own op).
_PASSES_FUSED = FEATURE_HOIST_PASSES
_PASSES_BASE = HOIST_PASSES


def _artifact(key):
    return key if isinstance(key, str) else key[0]


def _accounting(cache, n, table):
    builds = {}
    for k, c in cache.misses.items():
        a = _artifact(k)
        builds[a] = builds.get(a, 0) + c
    passes = sum(table[a] * c for a, c in builds.items())
    return {"builds": builds, "d_passes": passes,
            "analytic_bytes": passes * n * n * 4}


def run(sizes=(2048, 4096), d=_FEATURES, permutations=199,
        metric="braycurtis", out_json="BENCH_dist.json"):
    print(f"\n# --suite dist — feature table (n, {d}) → {metric} → "
          f"pcoa k={_DIMS} + permanova K={permutations}: "
          f"fused condensed production vs materialize-then-analyze")
    key = jax.random.PRNGKey(7)
    results = {}
    for n in sizes:
        x = np.abs(np.asarray(
            jax.random.normal(jax.random.PRNGKey(n), (n, d)))).astype(
                np.float32)
        grouping = np.arange(n) % _NUM_GROUPS
        m = condensed_size(n)

        # -- fused: from_features, square-free ----------------------------
        ws = Workspace.from_features(x, metric=metric, config=ExecConfig())
        t0 = time.perf_counter()
        ws.pcoa(dimensions=_DIMS)
        ws.permanova(grouping, permutations=permutations, key=key)
        t_fused = time.perf_counter() - t0
        assert "square" not in ws.cache, "fused path materialized a square!"
        fused = _accounting(ws.cache, n, _PASSES_FUSED)
        fused["peak_matrix_bytes"] = m * 4
        fused["seconds"] = t_fused

        # -- baseline: square matrix, then the session --------------------
        t0 = time.perf_counter()
        square = pairwise_distances(x, metric, out="square")
        jax.block_until_ready(square)
        ws2 = Workspace(square, config=ExecConfig(), validate=False)
        ws2.cache.get("square", lambda: square)   # count the n² build
        ws2.pcoa(dimensions=_DIMS)
        ws2.permanova(grouping, permutations=permutations, key=key)
        t_base = time.perf_counter() - t0
        base = _accounting(ws2.cache, n, _PASSES_BASE)
        # square D stays live for the session + the hoisted square Gower
        base["peak_matrix_bytes"] = 2 * n * n * 4
        base["seconds"] = t_base

        bytes_avoided = base["peak_matrix_bytes"] - fused["peak_matrix_bytes"]
        results[n] = {
            "fused": fused, "baseline": base,
            "square_bytes": n * n * 4, "condensed_bytes": m * 4,
            "bytes_avoided": bytes_avoided,
            "peak_ratio": base["peak_matrix_bytes"]
            / fused["peak_matrix_bytes"],
            "traffic_ratio": base["d_passes"] / max(fused["d_passes"],
                                                    1e-9),
        }
        r = results[n]
        print(f"dist n={n:<6d} fused peak {fused['peak_matrix_bytes'] / 1e6:8.1f} MB"
              f" ({fused['d_passes']:4.1f} n²-passes)  baseline "
              f"{base['peak_matrix_bytes'] / 1e6:8.1f} MB "
              f"({base['d_passes']:4.1f})  -> {r['bytes_avoided'] / 1e6:8.1f} MB"
              f" of n×n avoided ({r['peak_ratio']:.2f}x peak, "
              f"{r['traffic_ratio']:.2f}x traffic); wall {t_fused:.2f}s vs "
              f"{t_base:.2f}s (informational)")

    if out_json:
        artifact = {
            "suite": "dist",
            "metric": metric,
            "features": d,
            "dimensions": _DIMS,
            "permutations": permutations,
            "num_groups": _NUM_GROUPS,
            "pass_table_fused": _PASSES_FUSED,
            "pass_table_baseline": _PASSES_BASE,
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "results": {str(n): r for n, r in results.items()},
        }
        with open(out_json, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"# wrote {out_json}")
    return results


if __name__ == "__main__":
    run()
