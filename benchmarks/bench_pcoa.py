"""Paper §4.1 end-to-end: pcoa with original vs fused centering, plus the
validation-caching effect (pcoa internally copies its DistanceMatrix —
paper §4.3 last paragraph)."""

import jax

from benchmarks.common import row, time_fn
from repro.core.distance_matrix import DistanceMatrix, random_distance_matrix
from repro.core.pcoa import pcoa


def run(sizes=(2048, 4096)):
    print("\n# §4.1 — pcoa end-to-end (fsvd, k=10)")
    results = {}
    for n in sizes:
        dm = random_distance_matrix(jax.random.PRNGKey(n), n, dim=8)
        # PCoAResults is not a pytree — block on the coordinates explicitly
        t_ref = time_fn(
            lambda d: pcoa(d, centering_impl="ref").coordinates, dm,
            repeats=2)
        row("pcoa", "pcoa_fsvd", "orig-ctr", n, t_ref)
        t_fused = time_fn(
            lambda d: pcoa(d, centering_impl="fused").coordinates, dm,
            repeats=2)
        row("pcoa", "pcoa_fsvd", "fused-ctr", n, t_fused, baseline=t_ref)
        results[n] = {"original": t_ref, "fused": t_fused}

    # validation caching: constructing from a validated copy is ~free
    n = sizes[-1]
    dm = random_distance_matrix(jax.random.PRNGKey(0), n)
    t_reval = time_fn(lambda: DistanceMatrix(dm.data), repeats=2)
    row("pcoa", "construct", "revalidate", n, t_reval)
    t_copy = time_fn(lambda: dm.copy(), repeats=2)
    row("pcoa", "construct", "cached", n, t_copy, baseline=t_reval)
    results["validation_caching"] = {"revalidate": t_reval, "copy": t_copy}
    return results


if __name__ == "__main__":
    run()
