"""Paper §4.1 end-to-end: pcoa benchmarks.

``run``       — the paper-suite rows: materialized fsvd with original vs
                fused centering, plus the validation-caching effect (pcoa
                internally copies its DistanceMatrix — paper §4.3).
``run_suite`` — the PR 2 ordination sweep (``--suite pcoa``): ref-centred
                vs fused-centred (both materialize-then-solve) vs the
                matrix-free operator path, recording wall time and peak
                matrix bytes to ``BENCH_pcoa.json`` so the perf trajectory
                has a PCoA artifact alongside ``BENCH_stats.json``.
"""

import json

import jax

from benchmarks.common import row, time_fn
from repro.core.distance_matrix import DistanceMatrix, random_distance_matrix
from repro.core.pcoa import pcoa

_MATVEC_BLOCK = 256


def _live_bytes() -> int:
    """Bytes held by live jax arrays right now (committed buffers only)."""
    return sum(int(a.nbytes) for a in jax.live_arrays())


def _device_peak_bytes():
    """Allocator high-water mark where the backend exposes one (TPU/GPU);
    None on this container's CPU backend."""
    stats = jax.devices()[0].memory_stats() or {}
    return stats.get("peak_bytes_in_use")


def run(sizes=(2048, 4096)):
    print("\n# §4.1 — pcoa end-to-end (fsvd, k=10, materialized baseline)")
    results = {}
    for n in sizes:
        dm = random_distance_matrix(jax.random.PRNGKey(n), n, dim=8)
        # PCoAResults is not a pytree — block on the coordinates explicitly
        t_ref = time_fn(
            lambda d: pcoa(d, centering_impl="ref",
                           materialize=True).coordinates, dm, repeats=2)
        row("pcoa", "pcoa_fsvd", "orig-ctr", n, t_ref)
        t_fused = time_fn(
            lambda d: pcoa(d, centering_impl="fused",
                           materialize=True).coordinates, dm, repeats=2)
        row("pcoa", "pcoa_fsvd", "fused-ctr", n, t_fused, baseline=t_ref)
        results[n] = {"original": t_ref, "fused": t_fused}

    # validation caching: constructing from a validated copy is ~free
    n = sizes[-1]
    dm = random_distance_matrix(jax.random.PRNGKey(0), n)
    t_reval = time_fn(lambda: DistanceMatrix(dm.data), repeats=2)
    row("pcoa", "construct", "revalidate", n, t_reval)
    t_copy = time_fn(lambda: dm.copy(), repeats=2)
    row("pcoa", "construct", "cached", n, t_copy, baseline=t_reval)
    results["validation_caching"] = {"revalidate": t_reval, "copy": t_copy}
    return results


def run_suite(sizes=(2048, 4096), dimensions=10,
              out_json="BENCH_pcoa.json"):
    """ref vs fused vs matrix-free ordination at each n.

    ``peak_matrix_bytes`` is the analytic high-water of matrix-sized
    buffers each path holds at once (fp32): the materialized paths keep D
    *and* the centered F (the ref centering adds a full E intermediate on
    top); the operator path keeps D plus one (block, n) row strip — the
    whole point of the refactor. ``live_bytes`` / ``device_peak_bytes``
    record the measured counterparts where the runtime exposes them.
    """
    print(f"\n# --suite pcoa — ordination: materialized vs matrix-free "
          f"(fsvd, k={dimensions})")
    results = {}
    for n in sizes:
        dm = random_distance_matrix(jax.random.PRNGKey(n), n, dim=8)
        nn = 4 * n * n                       # one fp32 n×n matrix
        strip = 4 * min(_MATVEC_BLOCK, n) * n
        cases = {
            # eager centering materializes E and F on top of D
            "ref": (dict(centering_impl="ref", materialize=True), 3 * nn),
            # fused centering writes F once; D + F coexist for the solve
            "fused": (dict(centering_impl="fused", materialize=True),
                      2 * nn),
            # operator path: D plus one (block, n) strip, never F
            "matrix-free": (dict(materialize=False, block=_MATVEC_BLOCK),
                            nn + strip),
        }
        results[n] = {}
        base = None
        for name, (kw, peak) in cases.items():
            t = time_fn(lambda: pcoa(dm, dimensions=dimensions,
                                     **kw).coordinates, repeats=3)
            row("pcoa", f"pcoa_k{dimensions}", name, n, t, baseline=base)
            base = base or t
            results[n][name] = {
                "seconds": t,
                "peak_matrix_bytes": peak,
                "live_bytes": _live_bytes(),
                "device_peak_bytes": _device_peak_bytes(),
            }
        r = results[n]
        r["matrix-free"]["speedup_vs_fused"] = \
            r["fused"]["seconds"] / r["matrix-free"]["seconds"]
        r["matrix-free"]["matrix_bytes_vs_fused"] = \
            r["matrix-free"]["peak_matrix_bytes"] / r["fused"]["peak_matrix_bytes"]

    if out_json:
        artifact = {
            "suite": "pcoa",
            "dimensions": dimensions,
            "matvec_block": _MATVEC_BLOCK,
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "results": {str(n): r for n, r in results.items()},
        }
        with open(out_json, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"# wrote {out_json}")
    return results


if __name__ == "__main__":
    run()
    run_suite()
