"""Paper Table 2: mantel runtimes.

Baseline = the paper's literal original (Algorithm 3): per permutation,
NumPy row+column fancy-indexing to materialize the permuted matrix,
condense to the upper triangle, and call black-box
``scipy.stats.pearsonr`` (which re-derives mean/norm from scratch).
Optimized = Algorithm 5: hoisted invariants + one fused gather-multiply-
reduce per permutation. K=199 (paper: 999 — the ratio is K-independent,
both paths are linear in K).
"""

import numpy as np
from scipy.stats import pearsonr

import jax

from benchmarks.common import row, time_fn
from repro.core.distance_matrix import random_distance_matrix
from repro.core.mantel import mantel


def mantel_numpy_original(x: np.ndarray, y: np.ndarray, permutations: int,
                          seed: int = 0):
    """Algorithm 3+4 verbatim."""
    n = x.shape[0]
    iu = np.triu_indices(n, k=1)
    x_flat = x[iu]
    y_flat = y[iu]
    orig_stat = pearsonr(x_flat, y_flat).statistic
    rng = np.random.default_rng(seed)
    permuted_stats = np.empty(permutations)
    for p in range(permutations):
        perm = rng.permutation(n)
        x_perm_flat = x[perm][:, perm][iu]
        permuted_stats[p] = pearsonr(x_perm_flat, y_flat).statistic
    count = (np.abs(permuted_stats) >= abs(orig_stat)).sum()
    return orig_stat, (count + 1) / (permutations + 1)


def run(sizes=(512, 1024, 2048), permutations=199):
    print("\n# Table 2 — mantel (NumPy+scipy original vs hoisted+fused), "
          f"K={permutations}")
    results = {}
    for n in sizes:
        x = random_distance_matrix(jax.random.PRNGKey(n), n)
        y = random_distance_matrix(jax.random.PRNGKey(n + 1), n)
        x_np, y_np = np.asarray(x.data, np.float64), np.asarray(y.data,
                                                                np.float64)
        t_ref = time_fn(mantel_numpy_original, x_np, y_np, permutations,
                        repeats=1, warmup=0)
        row("table2", f"mantel_k{permutations}", "original", n, t_ref)
        key = jax.random.PRNGKey(7)
        t_opt = time_fn(mantel, x, y, permutations, key, repeats=2)
        row("table2", f"mantel_k{permutations}", "fused", n, t_opt,
            baseline=t_ref)
        results[n] = {"original": t_ref, "fused": t_opt}
    return results


if __name__ == "__main__":
    run()
