"""Paper Table 2: mantel runtimes — plus ``--suite mantel``, the analytic
per-permutation traffic accounting of the condensed batch-fused loop.

Baseline = the paper's literal original (Algorithm 3): per permutation,
NumPy row+column fancy-indexing to materialize the permuted matrix,
condense to the upper triangle, and call black-box
``scipy.stats.pearsonr`` (which re-derives mean/norm from scratch).
Optimized = Algorithm 5: hoisted invariants + one fused gather-multiply-
reduce per permutation. K=199 (paper: 999 — the ratio is K-independent,
both paths are linear in K).

``run_suite`` (→ ``BENCH_mantel.json``) records the tracked quantity per
the container-noise rule: **analytic fp32 traffic per permutation**, not
wall-clock (±40% noisy). Three audited models of the Mantel hot loop:

* ``original`` (Algorithm 3, eager): the two materializing square
  gathers (4 n²-passes), the triangle condense (2m), and black-box
  pearsonr's multi-pass mean/center/norm/dot over both m-vectors (~8m)
  ⇒ 4n² + 10m ≈ 9n² floats.
* ``square_gather`` (the PR-4 engine loop): per permutation,
  ``x[order][:, order]`` lowers to two materialized n² gathers (read +
  write each) and the fused reduce reads the gathered Xp plus the square
  hoisted Ŷ ⇒ 6n² floats.
* ``condensed_fused`` (this PR): one closed-form condensed gather (m)
  plus the per-permutation share of the tile streams — ŷ_c, and the
  ii/jj triangle map, each fetched once per B-permutation tile (3m/B) —
  plus the (n,) order row ⇒ m(1 + 3/B) + n ≈ n²/2 floats at B=32.
"""

import json

import numpy as np
from scipy.stats import pearsonr

import jax

from benchmarks.common import row, time_fn
from repro.core.distance_matrix import random_distance_matrix
from repro.core.mantel import mantel
# the audited per-permutation traffic models live in ONE place now —
# the same registry the instrumented engine charges live; a parity test
# in tests/test_obs.py pins the published 10.97x headline against it
from repro.obs.ledger import perm_traffic_floats

__all__ = ["mantel_numpy_original", "perm_traffic_floats", "run_suite",
           "run"]


def mantel_numpy_original(x: np.ndarray, y: np.ndarray, permutations: int,
                          seed: int = 0):
    """Algorithm 3+4 verbatim."""
    n = x.shape[0]
    iu = np.triu_indices(n, k=1)
    x_flat = x[iu]
    y_flat = y[iu]
    orig_stat = pearsonr(x_flat, y_flat).statistic
    rng = np.random.default_rng(seed)
    permuted_stats = np.empty(permutations)
    for p in range(permutations):
        perm = rng.permutation(n)
        x_perm_flat = x[perm][:, perm][iu]
        permuted_stats[p] = pearsonr(x_perm_flat, y_flat).statistic
    count = (np.abs(permuted_stats) >= abs(orig_stat)).sum()
    return orig_stat, (count + 1) / (permutations + 1)


def run_suite(sizes=(2048, 4096), permutations=999, batch=32,
              out_json="BENCH_mantel.json"):
    """--suite mantel: the tracked per-permutation traffic artifact.

    Acceptance gate: ``condensed_fused`` must move ≥ 8x fewer analytic
    bytes per permutation than ``square_gather`` at n=2048, K=999. Wall
    time of the live fused path is recorded but informational only."""
    print(f"\n# --suite mantel — analytic per-permutation traffic, "
          f"K={permutations}, batch B={batch} "
          f"(square-gather loop vs condensed batch-fused)")
    results = {}
    for n in sizes:
        floats = perm_traffic_floats(n, batch)
        bytes_per_perm = {k: 4.0 * v for k, v in floats.items()}
        ratio_sq = bytes_per_perm["square_gather"] / \
            bytes_per_perm["condensed_fused"]
        ratio_orig = bytes_per_perm["original"] / \
            bytes_per_perm["condensed_fused"]

        x = random_distance_matrix(jax.random.PRNGKey(n), n)
        y = random_distance_matrix(jax.random.PRNGKey(n + 1), n)
        key = jax.random.PRNGKey(7)
        t_fused = time_fn(mantel, x, y, permutations, key, repeats=1)

        # the gate is enforced, not just printed: a traffic-model or
        # kernel regression must fail the suite (CI runs this via --smoke)
        assert ratio_sq >= 8.0, (
            f"condensed_fused moves only {ratio_sq:.2f}x fewer bytes than "
            f"square_gather at n={n} (acceptance floor: 8x)")

        results[n] = {
            "bytes_per_perm": bytes_per_perm,
            "total_bytes": {k: v * permutations
                            for k, v in bytes_per_perm.items()},
            "ratio_vs_square_gather": ratio_sq,
            "ratio_vs_original": ratio_orig,
            "wall_fused_seconds": t_fused,       # informational (±40%)
        }
        print(f"mantel-traffic  n={n:<6d} square-gather "
              f"{bytes_per_perm['square_gather'] / 1e6:8.2f} MB/perm  "
              f"condensed-fused {bytes_per_perm['condensed_fused'] / 1e6:6.2f}"
              f" MB/perm  -> {ratio_sq:5.2f}x less "
              f"({ratio_orig:5.2f}x vs the eager original); "
              f"fused wall {t_fused:.2f}s (informational)")

    if out_json:
        artifact = {
            "suite": "mantel",
            "permutations": permutations,
            "batch": batch,
            "traffic_models": {
                "original": "4n² square gathers + 2m condense + 8m "
                            "multi-pass pearsonr",
                "square_gather": "2 materialized n² gathers (r+w) + "
                                 "fused reduce reading Xp and square Y",
                "condensed_fused": "m xc gather + (ynorm,ii,jj) streamed "
                                   "once per B-tile (3m/B) + n order row",
            },
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "results": {str(n): r for n, r in results.items()},
        }
        with open(out_json, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"# wrote {out_json}")
    return results


def run(sizes=(512, 1024, 2048), permutations=199):
    print("\n# Table 2 — mantel (NumPy+scipy original vs hoisted+fused), "
          f"K={permutations}")
    results = {}
    for n in sizes:
        x = random_distance_matrix(jax.random.PRNGKey(n), n)
        y = random_distance_matrix(jax.random.PRNGKey(n + 1), n)
        x_np, y_np = np.asarray(x.data, np.float64), np.asarray(y.data,
                                                                np.float64)
        t_ref = time_fn(mantel_numpy_original, x_np, y_np, permutations,
                        repeats=1, warmup=0)
        row("table2", f"mantel_k{permutations}", "original", n, t_ref)
        key = jax.random.PRNGKey(7)
        t_opt = time_fn(mantel, x, y, permutations, key, repeats=2)
        row("table2", f"mantel_k{permutations}", "fused", n, t_opt,
            baseline=t_ref)
        results[n] = {"original": t_ref, "fused": t_opt}
    return results


if __name__ == "__main__":
    run()
