"""repro.stats sweep: eager scikit-bio-style oracles vs the hoisted+fused
engine paths, for PERMANOVA, ANOSIM and the partial Mantel test.

``PYTHONPATH=src python -m benchmarks.run --suite stats``

Emits ``BENCH_stats.json`` so the perf trajectory of the subsystem is
recorded per PR. The measured quantity is the ref/fused wall-clock RATIO
at n ∈ {512, 2048}, K=999 (the acceptance gate is ≥5x at n=2048); refs
are timed once (no warmup — eager paths have nothing to compile)."""

import json

import jax
import numpy as np

from benchmarks.common import row, time_fn
from repro.core.distance_matrix import random_distance_matrix
from repro.stats import (anosim, anosim_ref, partial_mantel,
                         partial_mantel_ref, permanova, permanova_ref)

_NUM_GROUPS = 8


def _inputs(n):
    x = random_distance_matrix(jax.random.PRNGKey(n), n)
    y = random_distance_matrix(jax.random.PRNGKey(n + 1), n)
    z = random_distance_matrix(jax.random.PRNGKey(n + 2), n)
    grouping = np.arange(n) % _NUM_GROUPS
    return x, y, z, grouping


def run(sizes=(512, 2048), permutations=999, out_json="BENCH_stats.json"):
    print(f"\n# repro.stats — ref (eager multi-pass) vs fused engine, "
          f"K={permutations}, {_NUM_GROUPS} groups")
    key = jax.random.PRNGKey(7)
    results = {}
    for n in sizes:
        x, y, z, grouping = _inputs(n)
        cases = {
            "permanova": (lambda: permanova_ref(x, grouping, permutations, key),
                          lambda: permanova(x, grouping, permutations, key)),
            "anosim": (lambda: anosim_ref(x, grouping, permutations, key),
                       lambda: anosim(x, grouping, permutations, key)),
            "partial_mantel": (
                lambda: partial_mantel_ref(x, y, z, permutations, key),
                lambda: partial_mantel(x, y, z, permutations, key)),
        }
        results[n] = {}
        for name, (ref_fn, fused_fn) in cases.items():
            t_ref = time_fn(lambda: ref_fn().p_value, repeats=1, warmup=0)
            row("stats", f"{name}_k{permutations}", "original", n, t_ref)
            t_fused = time_fn(lambda: fused_fn().p_value, repeats=2, warmup=1)
            row("stats", f"{name}_k{permutations}", "fused", n, t_fused,
                baseline=t_ref)
            results[n][name] = {"ref": t_ref, "fused": t_fused,
                                "speedup": t_ref / t_fused}

    if out_json:
        artifact = {
            "suite": "stats",
            "permutations": permutations,
            "num_groups": _NUM_GROUPS,
            "jax": jax.__version__,
            "device_count": jax.device_count(),
            "results": {str(n): r for n, r in results.items()},
        }
        with open(out_json, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"# wrote {out_json}")
    return results


if __name__ == "__main__":
    run()
