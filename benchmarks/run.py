"""Benchmark harness — one section per paper table (deliverable (d)).

``PYTHONPATH=src python -m benchmarks.run [--fast|--smoke]
[--suite paper|stats|pcoa|api|dist]``

Suites:
  paper (default) — the paper's tables:
    Table 1 — centering (original vs fused)
    Table 2 — mantel (original vs hoisted+fused)
    Table 3 — validation (original vs fused)
    §4.1    — pcoa end-to-end + validation caching
    summary — measured speedups vs the paper's claimed ranges
  stats — the repro.stats subsystem (PERMANOVA / ANOSIM / partial Mantel,
    ref vs fused at n ∈ {512, 2048}, K=999); writes BENCH_stats.json.
  pcoa — ordination: ref/fused materialize-then-solve vs the matrix-free
    operator path at n ∈ {2048, 4096}; writes BENCH_pcoa.json with wall
    time and peak matrix bytes.
  api — hoist-once sessions: analytic O(n²)-pass counts (bytes of D read)
    for the 4-analysis study battery, one shared Workspace vs standalone
    per-call hoists; writes BENCH_api.json. The gate is the analytic
    traffic ratio, not wall-clock (container timing is ±40% noisy).
  dist — feature-table sessions: the fused repro.dist condensed
    production (Workspace.from_features, square-free) vs the
    materialize-then-analyze baseline at n ∈ {2048, 4096}; writes
    BENCH_dist.json with the analytic n×n bytes avoided.
  mantel — the condensed batch-fused permutation loop: analytic
    per-permutation bytes moved (square-gather loop vs condensed
    batch-fused, at n ∈ {2048, 4096}, K=999); writes BENCH_mantel.json.
    Acceptance gate: ≥ 8x less traffic than the square-gather loop.
  tune — the repro.tune solver: modeled effective traffic of
    solver-chosen tiles vs the hand-picked constants, across every
    suite's workload at n ∈ {2048, 4096}; writes BENCH_tune.json plus
    the container's calibration profile (tune_profile.json). Gate:
    tuned never models worse than the constants.
  serve — the repro.serve front door: R concurrent mixed-K mantel
    requests against one pooled study, gated on the coalescing bound
    (tiles == ceil(ΣK/B)), hoists charged once per study, and the
    session ledger's perm traffic matching perm_traffic_floats; writes
    BENCH_serve.json at n ∈ {512, 2048}, with the chaos sweep's
    receipts in its "chaos" section. With --chaos, runs ONLY the
    seeded fault-injection soak (repro.faults): all requests must
    terminate, completed p-values must be bitwise-equal to the
    fault-free run, retry amplification stays capped, and journal
    recovery runs exactly the remaining tiles with zero re-hoists.

``--smoke`` runs the dist + api + mantel suites at tiny sizes with NO
BENCH artifact written — the CI guard that the benchmark entry points
can't silently rot (exercises the same code paths; the tracked
BENCH_*.json files are only ever written by full-size runs). It then
runs the full 6-analysis battery on an observability-enabled
feature-backed Workspace under the recompile sentinel — the padded
``per_batch`` path must compile exactly ONE ``kernels.permute_reduce``
program per invariant-stack shape across different K values — and
writes the session's ``RunReport`` JSON (``--report``, default
``RunReport_smoke.json``; CI uploads it as a workflow artifact).

Every suite (and the smoke) finishes through the perf-trajectory gate
(``benchmarks/trajectory.py``): its analytic ratios — plus, in smoke,
the ``obs.probe`` compile-time byte measurements — append to
``BENCH_trajectory.jsonl`` and are compared against the committed
``benchmarks/trajectory_baseline.json``; a regression past tolerance
exits nonzero. Wall-clock never gates (±40% container noise).
"""

import argparse
import platform

import jax

from benchmarks import bench_api, bench_center, bench_dist, bench_mantel, \
    bench_pcoa, bench_serve, bench_stats, bench_tune, bench_validation, \
    trajectory


def _smoke_report(path: str) -> None:
    """The observability acceptance battery: every analysis spanned,
    every hoist/batch charged, the recompile sentinel gating."""
    import numpy as np

    from repro.api.config import ExecConfig
    from repro.api.workspace import Workspace
    from repro.obs import ObsConfig, sentinel

    rng = np.random.default_rng(0)
    cfg = ExecConfig(obs=ObsConfig(enabled=True))
    ws = Workspace.from_features(rng.random((64, 16), dtype=np.float32) + .01,
                                 config=cfg)
    wsy = Workspace.from_features(rng.random((64, 16), dtype=np.float32) + .01,
                                  config=cfg)
    wsz = Workspace.from_features(rng.random((64, 16), dtype=np.float32) + .01,
                                  config=cfg)
    grouping = rng.integers(0, 4, 64)

    # the gate: the battery below runs the batched condensed loop for
    # three statistics (Mantel S=1 / ANOSIM S=1 — same program — and
    # partial Mantel S=2) at TWO different K values each path; more than
    # 2 distinct kernels.permute_reduce programs means a shape leaked
    # back into the trace signature (the pre-PR-5 trailing-block bug)
    with sentinel.expect("kernels.permute_reduce", max_programs=2):
        ws.pcoa(dimensions=8)
        ws.permanova(grouping, permutations=49)
        ws.permdisp(grouping, permutations=49, dimensions=8)
        ws.anosim(grouping, permutations=49)
        ws.mantel(wsy, permutations=49)
        ws.mantel(wsy, permutations=17)      # second K: same program
        ws.partial_mantel(wsy, wsz, permutations=49)

    report = ws.report(meta={"suite": "smoke"})
    report.save(path)
    led = report.ledger
    print(f"\n# smoke RunReport -> {path}")
    print(f"#   hoist passes {led['hoist_passes']:.1f}  "
          f"total {led['total_bytes'] / 1e6:.2f} MB analytic  "
          f"ops {sorted(led['by_op'])}")
    print(f"#   compile window: "
          f"{ {k: v['programs'] for k, v in report.compile.items()} }")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller sizes / fewer repeats")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: dist+api+mantel at tiny sizes (no "
                         "BENCH artifacts) + the obs-instrumented battery "
                         "under the recompile sentinel")
    ap.add_argument("--report", default="RunReport_smoke.json",
                    help="where --smoke writes the RunReport JSON "
                         "(uploaded by CI as a workflow artifact)")
    ap.add_argument("--chaos", action="store_true",
                    help="with --suite serve: run ONLY the seeded "
                         "chaos-soak sweep (bounded seeds, no BENCH "
                         "artifacts) — gates on termination, bitwise-"
                         "equal completed p-values, retry amplification, "
                         "and journal-recovery tile counts; never "
                         "wall-clock")
    ap.add_argument("--suite", default="paper",
                    choices=("paper", "stats", "pcoa", "api", "dist",
                             "mantel", "tune", "serve"),
                    help="paper tables (default), the repro.stats sweep, "
                         "the matrix-free ordination sweep, the hoist-once "
                         "Workspace session accounting, the fused "
                         "feature-table distance production, the "
                         "condensed Mantel permutation-traffic accounting, "
                         "the repro.tune solved-vs-default tile pricing, "
                         "or the repro.serve coalescing gates")
    args, _ = ap.parse_known_args()

    print(f"# repro benchmarks — {platform.processor() or 'cpu'} · "
          f"jax {jax.__version__} · devices={jax.device_count()}")
    print("# paper: Sfiligoi/McDonald/Knight PEARC'21 — sizes scaled to "
          "one CPU core; the measured quantity is the fused-vs-multipass "
          "RATIO (see EXPERIMENTS.md §Benchmarks)")

    if args.smoke:
        smoke = {}
        smoke["dist"] = bench_dist.run(sizes=(128, 256), d=32,
                                       permutations=49, out_json=None)
        smoke["api"] = bench_api.run(sizes=(128,), permutations=49,
                                     out_json=None)
        smoke["mantel"] = bench_mantel.run_suite(sizes=(64,),
                                                 permutations=19, batch=8,
                                                 out_json=None)
        # the tune gate: solver tiles never price worse than the
        # hand-picked constants in the analytic model (asserted inside)
        smoke["tune"] = bench_tune.run(sizes=(64, 256), d=32,
                                       out_json=None, profile_json=None)
        # the serve gates: coalesced tiles == ceil(ΣK/B), hoists once
        # per study, ledger traffic == the audited model (asserted
        # inside bench_serve._workload)
        smoke["serve"] = bench_serve.run(sizes=(64,), permutations=99,
                                         batch=16, requests=6,
                                         out_json=None, chaos=False)
        _smoke_report(args.report)
        # the perf-trajectory gate: every suite's analytic ratios plus
        # the compile-time probe measurements, appended to the JSONL
        # ledger and compared against the committed baseline. A
        # regression past tolerance exits nonzero (wall-clock is never
        # gated — see benchmarks/trajectory.py).
        metrics = {}
        for suite, results in smoke.items():
            metrics.update(trajectory.flatten(suite, results))
        metrics.update(trajectory.probe_metrics())
        trajectory.check("smoke", metrics)
        print("\n# smoke OK — dist + api + mantel + tune + serve suites "
              "ran end-to-end (no BENCH artifacts written) + obs battery "
              "passed the recompile gate + trajectory gate green")
        return

    if args.suite == "tune":
        if args.fast:
            # separate artifact: fast-mode numbers must not clobber the
            # tracked full-size trajectory file
            s = bench_tune.run(sizes=(256, 512), d=64,
                               out_json="BENCH_tune_fast.json",
                               profile_json="tune_profile.json")
        else:
            s = bench_tune.run()
        print("\n# summary — modeled effective traffic, default / tuned")
        for n, r in s.items():
            worst = min(o["ratio"] for su in r["suites"].values()
                        for o in su.values())
            print(f"tune            n={n:<6d} worst suite ratio "
                  f"{worst:6.2f}x (>= 1.00 required)")
        trajectory.check("tune", s)
        return

    if args.suite == "serve":
        if args.chaos:
            # the chaos-soak job: every gate is asserted inside
            # run_chaos (termination, bitwise-equal completed results,
            # amplification cap, recovery tile counts) — reaching the
            # summary print IS the pass
            c = bench_serve.run_chaos()
            bench_serve.print_chaos(c)
            print("\n# chaos OK — all requests terminated under every "
                  "seed, completed p-values bitwise-equal to the "
                  "fault-free run, amplification bounded, recovery "
                  "resumed without re-hoisting")
            return
        if args.fast:
            # separate artifact: fast-mode numbers must not clobber the
            # tracked full-size trajectory file
            # chaos is skipped here: the dedicated --chaos CI job owns
            # the soak, and fast mode should stay fast
            s = bench_serve.run(sizes=(128, 256), permutations=199,
                                batch=16, requests=8,
                                out_json="BENCH_serve_fast.json",
                                chaos=False)
        else:
            s = bench_serve.run()
        print("\n# summary — coalesced serving vs per-request tiles "
              "(ledger-verified)")
        for n, r in s.items():
            if not isinstance(n, int):     # the chaos receipts
                continue
            print(f"serve           n={n:<6d} {r['tile_ratio']:6.2f}x "
                  f"fewer tiles, {r['traffic_ratio']:6.2f}x less perm "
                  f"traffic, hoists once per study")
        trajectory.check("serve", s)
        return

    if args.suite == "mantel":
        if args.fast:
            # separate artifact: fast-mode numbers must not clobber the
            # tracked full-size trajectory file
            s = bench_mantel.run_suite(sizes=(256, 512), permutations=99,
                                       out_json="BENCH_mantel_fast.json")
        else:
            s = bench_mantel.run_suite()
        print("\n# summary — per-permutation traffic, square-gather / "
              "condensed batch-fused (analytic)")
        for n, r in s.items():
            print(f"mantel-traffic  n={n:<6d} "
                  f"{r['ratio_vs_square_gather']:6.2f}x less traffic "
                  f"({r['ratio_vs_original']:.2f}x vs eager original)")
        trajectory.check("mantel", s)
        return

    if args.suite == "dist":
        if args.fast:
            # separate artifact: fast-mode numbers must not clobber the
            # tracked full-size trajectory file
            s = bench_dist.run(sizes=(256, 512), d=64, permutations=99,
                               out_json="BENCH_dist_fast.json")
        else:
            s = bench_dist.run()
        print("\n# summary — n×n bytes avoided, fused / materialized")
        for n, r in s.items():
            print(f"dist-session    n={n:<6d} {r['bytes_avoided'] / 1e6:8.1f}"
                  f" MB avoided ({r['peak_ratio']:.2f}x peak matrix bytes,"
                  f" {r['traffic_ratio']:.2f}x hoist traffic, analytic)")
        trajectory.check("dist", s)
        return

    if args.suite == "api":
        if args.fast:
            # separate artifact: fast-mode numbers must not clobber the
            # tracked full-size trajectory file
            s = bench_api.run(sizes=(256, 512), permutations=199,
                              out_json="BENCH_api_fast.json")
        else:
            s = bench_api.run()
        print("\n# summary — O(n²) traffic, standalone / one Workspace")
        for n, r in s.items():
            print(f"api-session     n={n:<6d} {r['traffic_ratio']:6.2f}x "
                  f"less matrix traffic (analytic)")
        trajectory.check("api", s)
        return

    if args.suite == "pcoa":
        if args.fast:
            # separate artifact: fast-mode numbers must not clobber the
            # tracked full-size trajectory file
            s = bench_pcoa.run_suite(sizes=(512, 1024),
                                     out_json="BENCH_pcoa_fast.json")
        else:
            s = bench_pcoa.run_suite()
        print("\n# summary — matrix-free vs materialize-then-solve (fused)")
        for n, per_impl in s.items():
            mf = per_impl["matrix-free"]
            print(f"pcoa            n={n:<6d} {mf['speedup_vs_fused']:6.2f}x "
                  f"wall, {mf['matrix_bytes_vs_fused']:.2f}x matrix bytes")
        return

    if args.suite == "stats":
        if args.fast:
            # separate artifact: fast-mode numbers must not clobber the
            # tracked full-size (n=2048, K=999) trajectory file
            s = bench_stats.run(sizes=(256, 512), permutations=199,
                                out_json="BENCH_stats_fast.json")
        else:
            s = bench_stats.run()
        print("\n# summary — speedup (original / fused), repro.stats engine")
        for n, per_stat in s.items():
            for name, r in per_stat.items():
                print(f"{name:15s} n={n:<6d} {r['speedup']:6.1f}x")
        return

    if args.fast:
        c = bench_center.run(sizes=(2048, 4096))
        m = bench_mantel.run(sizes=(256, 512), permutations=49)
        v = bench_validation.run(sizes=(2048, 4096))
        p = bench_pcoa.run(sizes=(1024,))
    else:
        c = bench_center.run()
        m = bench_mantel.run()
        v = bench_validation.run()
        p = bench_pcoa.run()

    print("\n# summary — speedup (original / optimized) vs the paper's")
    print("# SINGLE-CORE rows (this container is 1 core; the paper's")
    print("# headline 10-200x additionally includes its multicore scaling,")
    print("# reproduced here structurally by the shard_map paths)")
    biggest = max(k for k in c if isinstance(k, int))
    print(f"centering   {c[biggest]['original'] / c[biggest]['fused']:6.1f}x"
          f"   [paper Table 1, 1 core: 2.0-3.3x; 16 cores: 24-30x]")
    biggest = max(k for k in m if isinstance(k, int))
    print(f"mantel      {m[biggest]['original'] / m[biggest]['fused']:6.1f}x"
          f"   [paper Table 2, 1 core: 14.5-24.7x; 16 cores: 90-162x]")
    biggest = max(k for k in v if isinstance(k, int))
    print(f"validation  {v[biggest]['original'] / v[biggest]['fused']:6.1f}x"
          f"   [paper Table 3, 1 core: 0.7-2.8x; 16 cores: 4.5-39x]")
    vc = p["validation_caching"]
    print(f"valid-cache {vc['revalidate'] / vc['copy']:6.1f}x"
          f"   [paper §4.3: 'avoid unnecessary validations']")


if __name__ == "__main__":
    main()
