"""Perf-trajectory ledger + regression gate (analytic + probed, never wall).

Every benchmark suite ends by calling :func:`check`: the suite's gated
ratios (analytic traffic/peak ratios — the numbers the paper's argument
rests on) plus ahead-of-time probe measurements (``obs.probe`` compiled
byte counts) are

1. **appended** to ``BENCH_trajectory.jsonl`` — one JSON object per
   suite run, so the repo accumulates a perf trajectory across commits
   and CI uploads the file as an artifact; and
2. **gated** against ``benchmarks/trajectory_baseline.json`` — the
   committed snapshot of where the numbers stood when the baseline was
   seeded. A metric that regresses past its tolerance raises
   ``SystemExit`` (CI goes red).

Wall-clock is deliberately NOT a trajectory metric: this container's
timings are ±40% noisy, and a gate that flakes teaches everyone to
ignore it. Every gated quantity is either closed-form analytic (ledger
ratios) or a compile-time observable (probe bytes — deterministic for a
fixed jax/XLA version, so its tolerance band only needs to absorb
compiler-version drift, not scheduler noise).

Baseline schema — ``{metric: {"value": v, "direction": d, "tolerance": t}}``:

* ``direction: "min"`` — the metric is a *win* (bigger is better, e.g.
  a traffic-reduction ratio); fail when ``value < base * (1 - t)``;
* ``direction: "max"`` — the metric is a *cost* (smaller is better,
  e.g. probed bytes); fail when ``value > base * (1 + t)``.

Metrics present in a run but absent from the baseline pass (new metrics
are legal until the next reseed); baseline metrics absent from a run are
ignored (suites gate only what they measured). Reseed with::

    PYTHONPATH=src python -m benchmarks.trajectory --rebaseline

which folds the newest value of every metric in the JSONL ledger into
the baseline with the default direction/tolerance rules below.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax

#: the append-only ledger (repo root; CI uploads it as an artifact)
TRAJECTORY_PATH = "BENCH_trajectory.jsonl"
#: the committed gate baseline (lives beside this module)
BASELINE_PATH = os.path.join(os.path.dirname(__file__),
                             "trajectory_baseline.json")

#: default (direction, tolerance) when seeding a baseline entry.
#: ``probe.*`` metrics are measured costs (compiled bytes / peak) and
#: move only when the compiler does — but a jax upgrade can re-fuse
#: entire loop bodies, so they get a wide band. Everything else is a
#: win ratio from an exact closed form; 5% covers only size-rounding
#: drift (padding, tile clamps) from tuning changes.
_PROBE_RULE = ("max", 0.35)
_DEFAULT_RULE = ("min", 0.05)


def default_rule(metric: str):
    """(direction, tolerance) for a metric name, by the rules above."""
    return _PROBE_RULE if metric.startswith("probe.") else _DEFAULT_RULE


# --------------------------------------------------------------------------
# Flattening suite results into metric dicts
# --------------------------------------------------------------------------
def flatten(suite: str, results: dict) -> dict:
    """Extract the gated scalars from a suite's return dict, keyed
    ``<suite>.<metric>.n<size>`` so every geometry gates separately."""
    out = {}
    sized = {n: r for n, r in results.items() if isinstance(n, int)}
    if suite == "mantel":
        for n, r in sized.items():
            out[f"mantel.ratio_vs_square_gather.n{n}"] = \
                r["ratio_vs_square_gather"]
            out[f"mantel.ratio_vs_original.n{n}"] = r["ratio_vs_original"]
    elif suite == "api":
        for n, r in sized.items():
            out[f"api.traffic_ratio.n{n}"] = r["traffic_ratio"]
    elif suite == "dist":
        for n, r in sized.items():
            out[f"dist.traffic_ratio.n{n}"] = r["traffic_ratio"]
            out[f"dist.peak_ratio.n{n}"] = r["peak_ratio"]
    elif suite == "tune":
        for n, r in sized.items():
            out[f"tune.worst_ratio.n{n}"] = min(
                o["ratio"] for su in r["suites"].values()
                for o in su.values())
    elif suite == "serve":
        for n, r in sized.items():
            out[f"serve.tile_ratio.n{n}"] = r["tile_ratio"]
            out[f"serve.traffic_ratio.n{n}"] = r["traffic_ratio"]
    else:
        raise ValueError(f"no trajectory extraction for suite {suite!r}")
    return {k: float(v) for k, v in out.items()}


def probe_metrics(n: int = 256, batch: int = 32, d: int = 32) -> dict:
    """Compile-time measurements of the production entry points at one
    fixed geometry — the measured half of the trajectory. Deterministic
    per jax version (AOT compile, no execution)."""
    from repro.obs.probe import (probe_panel_stats, probe_permute_reduce,
                                 probe_stream_pass)

    pr = probe_permute_reduce(n, batch=batch)
    pan = probe_panel_stats(n, d)
    stream = probe_stream_pass(1 << 22)
    return {
        f"probe.permute_reduce.bytes.n{n}": float(pr.bytes_corrected),
        f"probe.permute_reduce.peak.n{n}": float(pr.peak_bytes),
        f"probe.panel_stats.bytes.n{n}": float(pan.bytes_corrected),
        f"probe.panel_stats.peak.n{n}": float(pan.peak_bytes),
        "probe.stream_pass.bytes.n4194304": float(stream.bytes_corrected),
    }


# --------------------------------------------------------------------------
# Ledger + gate
# --------------------------------------------------------------------------
def record(suite: str, metrics: dict, path: str = TRAJECTORY_PATH) -> dict:
    """Append one trajectory entry; returns what was written."""
    entry = {"suite": suite, "t": time.time(),
             "jax": jax.__version__, "backend": jax.default_backend(),
             "metrics": metrics}
    with open(path, "a") as f:
        f.write(json.dumps(entry) + "\n")
    return entry


def load_baseline(path: str = BASELINE_PATH) -> dict:
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)


def gate(metrics: dict, baseline: dict) -> list:
    """Regressions as human-readable strings (empty == green)."""
    failures = []
    for name, value in metrics.items():
        base = baseline.get(name)
        if base is None:
            continue
        bv, tol = base["value"], base["tolerance"]
        if base["direction"] == "min":
            if value < bv * (1.0 - tol):
                failures.append(
                    f"{name}: {value:.6g} fell below baseline "
                    f"{bv:.6g} - {tol:.0%} = {bv * (1 - tol):.6g}")
        elif value > bv * (1.0 + tol):
            failures.append(
                f"{name}: {value:.6g} exceeded baseline "
                f"{bv:.6g} + {tol:.0%} = {bv * (1 + tol):.6g}")
    return failures


def check(suite: str, results_or_metrics: dict, *,
          path: str = TRAJECTORY_PATH,
          baseline_path: str = BASELINE_PATH,
          raise_on_failure: bool = True) -> list:
    """Record + gate one suite run. ``results_or_metrics`` is either a
    suite return dict (flattened here) or an already-flat metric dict
    (every key contains a dot). Raises ``SystemExit`` on regression."""
    if all("." in str(k) for k in results_or_metrics):
        metrics = dict(results_or_metrics)
    else:
        metrics = flatten(suite, results_or_metrics)
    record(suite, metrics, path=path)
    failures = gate(metrics, load_baseline(baseline_path))
    for f in failures:
        print(f"# TRAJECTORY REGRESSION: {f}")
    if failures and raise_on_failure:
        raise SystemExit(
            f"trajectory gate: {len(failures)} metric(s) regressed past "
            f"tolerance (see above; reseed with "
            f"`python -m benchmarks.trajectory --rebaseline` only if the "
            f"change is intended)")
    if not failures:
        gated = sum(1 for k in metrics if k in load_baseline(baseline_path))
        print(f"# trajectory: {suite} appended {len(metrics)} metric(s), "
              f"{gated} gated against baseline — green")
    return failures


def rebaseline(path: str = TRAJECTORY_PATH,
               baseline_path: str = BASELINE_PATH) -> dict:
    """Fold the newest value of every metric in the JSONL ledger into
    the baseline (defaults for direction/tolerance; existing entries
    keep their direction/tolerance and only refresh the value)."""
    old = load_baseline(baseline_path)
    latest = {}
    with open(path) as f:
        for line in f:
            if line.strip():
                latest.update(json.loads(line)["metrics"])
    base = {}
    for name, value in sorted(latest.items()):
        direction, tol = default_rule(name)
        prev = old.get(name, {})
        base[name] = {"value": value,
                      "direction": prev.get("direction", direction),
                      "tolerance": prev.get("tolerance", tol)}
    with open(baseline_path, "w") as f:
        json.dump(base, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# baseline reseeded: {len(base)} metric(s) -> {baseline_path}")
    return base


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rebaseline", action="store_true",
                    help="fold the newest JSONL values into the baseline")
    ap.add_argument("--trajectory", default=TRAJECTORY_PATH)
    ap.add_argument("--baseline", default=BASELINE_PATH)
    args = ap.parse_args()
    if args.rebaseline:
        rebaseline(args.trajectory, args.baseline)
        return
    ap.error("nothing to do (pass --rebaseline)")


if __name__ == "__main__":
    main()
