"""Paper Table 1: center_distance_matrix runtimes.

The baseline is the paper's LITERAL original: NumPy, one op at a time
(Algorithm 1 — 8 matrix reads + 5 writes of DRAM traffic). The optimized
path is the fused JAX implementation (Algorithm 2's fusion; jit plays
Cython's role — DESIGN §2). Paper sizes are 25k–100k on 8–16 cores; this
container is one core, so sizes scale to 4k–12k (≥64 MB fp32, beyond
LLC, so both paths are DRAM-bound like the paper's).
"""

import numpy as np

import jax

from benchmarks.common import row, time_fn
from repro.core.centering import (center_distance_matrix,
                                  center_distance_matrix_blocked)
from repro.core.distance_matrix import random_distance_matrix


def center_numpy_original(d: np.ndarray) -> np.ndarray:
    """Algorithm 1 verbatim (scikit-bio original)."""
    e = d * d / -2.0
    row_means = e.mean(axis=1, keepdims=True)
    col_means = e.mean(axis=0, keepdims=True)
    matrix_mean = e.mean()
    return e - row_means - col_means + matrix_mean


def run(sizes=(4096, 8192, 12288)):
    print("\n# Table 1 — center_distance_matrix (NumPy original vs fused)")
    results = {}
    for n in sizes:
        dm = random_distance_matrix(jax.random.PRNGKey(n), n).data
        dm_np = np.asarray(dm)
        t_ref = time_fn(center_numpy_original, dm_np, repeats=2)
        row("table1", "center", "original", n, t_ref)
        t_fused = time_fn(center_distance_matrix, dm)
        row("table1", "center", "fused", n, t_fused, baseline=t_ref)
        t_blk = time_fn(center_distance_matrix_blocked, dm, block=1024)
        row("table1", "center", "blocked", n, t_blk, baseline=t_ref)
        results[n] = {"original": t_ref, "fused": t_fused, "blocked": t_blk}
    return results


if __name__ == "__main__":
    run()
