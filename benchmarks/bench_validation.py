"""Paper Table 3: distance-matrix validation.

Baseline = Algorithm 6 verbatim in NumPy: ``(mat.T != mat).any()``
materializes a full boolean matrix (plus the lazy transpose forcing a
strided second pass), and ``np.trace`` is yet another pass. Optimized =
the fused single-pass jit (Algorithm 7 semantics).
"""

import numpy as np

import jax

from benchmarks.common import row, time_fn
from repro.core.distance_matrix import random_distance_matrix
from repro.core.validation import (is_symmetric_and_hollow,
                                   is_symmetric_and_hollow_blocked)


def validation_numpy_original(mat: np.ndarray):
    not_sym = (mat.T != mat).any()
    not_hollow = np.trace(mat) != 0
    return (not not_sym), (not not_hollow)


def run(sizes=(4096, 8192, 12288)):
    print("\n# Table 3 — is_symmetric_and_hollow (NumPy original vs fused)")
    results = {}
    for n in sizes:
        dm = random_distance_matrix(jax.random.PRNGKey(n), n).data
        dm_np = np.asarray(dm)
        t_ref = time_fn(validation_numpy_original, dm_np, repeats=2)
        row("table3", "validation", "original", n, t_ref)
        t_fused = time_fn(is_symmetric_and_hollow, dm)
        row("table3", "validation", "fused", n, t_fused, baseline=t_ref)
        t_blk = time_fn(is_symmetric_and_hollow_blocked, dm, block=1024)
        row("table3", "validation", "blocked", n, t_blk, baseline=t_ref)
        results[n] = {"original": t_ref, "fused": t_fused}
    return results


if __name__ == "__main__":
    run()
