"""Benchmark timing utilities."""

import time

import jax


def time_fn(fn, *args, repeats: int = 3, warmup: int = 1, **kw):
    """Best-of-N wall time with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        best = min(best, time.perf_counter() - t0)
    return best


def row(table: str, workload: str, impl: str, n: int, seconds: float,
        baseline: float = None):
    speed = f"{baseline / seconds:8.1f}x" if baseline else "        "
    print(f"{table:12s} {workload:22s} {impl:10s} n={n:<7d} "
          f"{seconds * 1e3:10.1f} ms {speed}")
    return seconds
