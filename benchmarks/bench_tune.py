"""--suite tune: modeled traffic of solver-chosen tiles vs the
hand-picked constants, across every suite's workload.

``PYTHONPATH=src python -m benchmarks.run --suite tune``

The measured quantity is **analytic effective traffic** from the
``repro.tune`` cost model (itself the audited ``obs.ledger`` registry —
the same terms every other BENCH artifact accounts with). For each
size the solver picks block / feature_block / batch / chunk against
the backend budget, and both the solved and the default tiles are
priced at their budget-clamped EFFECTIVE reuse — so the comparison is
what each geometry actually realizes, not what its label promises.

The gate (also run under ``--smoke``): the solved tiles never model
MORE traffic than the hand-picked constants on any suite's workload —
guaranteed by construction (the defaults are in the solver's candidate
set) and asserted here so a solver regression cannot ship silently.

Also writes the calibration profile (``tune_profile.json``): the
two-point bandwidth/latency fit for this container, which CI uploads
so later runs can ``ExecConfig(tune_profile=...)`` instead of
re-probing.
"""

import json

import jax

from repro.tune import calibrate, detect_budget, save_profile, solve_tiles

# which cost-model ops each BENCH suite's workload exercises, and which
# backing (feature-backed workloads add the production sweep)
_SUITE_OPS = {
    "mantel": {"ops": ("perm_batch",), "feature_backed": False},
    "stats": {"ops": ("perm_batch",), "feature_backed": False},
    "pcoa": {"ops": ("matvec",), "feature_backed": False},
    "api": {"ops": ("matvec", "perm_batch"), "feature_backed": False},
    "dist": {"ops": ("production", "perm_batch", "matvec"),
             "feature_backed": True},
}


def _price(tiles, op):
    t = tiles.to_dict()
    return (t["modeled"][op]["traffic_floats"],
            t["modeled_default"][op]["traffic_floats"])


def run(sizes=(2048, 4096), d=256, out_json="BENCH_tune.json",
        profile_json="tune_profile.json"):
    print(f"\n# --suite tune — solver-chosen tiles vs hand-picked "
          f"constants (analytic effective traffic, d={d} feature-backed)")
    budget = detect_budget()
    results = {}
    for n in sizes:
        dm_tiles = solve_tiles(n, budget=budget)
        ft_tiles = solve_tiles(n, d, budget=budget)
        per_suite = {}
        for suite, spec in _SUITE_OPS.items():
            tiles = ft_tiles if spec["feature_backed"] else dm_tiles
            ops = {}
            for op in spec["ops"]:
                tuned, default = _price(tiles, op)
                # THE gate: solver tiles never model worse than the
                # constants they replace, on any suite's workload
                assert tuned <= default, (
                    f"tune regression: {suite}/{op} at n={n}: solved "
                    f"tiles model {tuned} floats vs default {default}")
                ops[op] = {"tuned_floats": tuned, "default_floats": default,
                           "ratio": default / tuned if tuned else 1.0}
            per_suite[suite] = ops
        results[n] = {
            "tiles": {"dm": {k: getattr(dm_tiles, k) for k in
                             ("block", "feature_block", "batch_size",
                              "chunk")},
                      "features": {k: getattr(ft_tiles, k) for k in
                                   ("block", "feature_block", "batch_size",
                                    "chunk")}},
            "suites": per_suite,
        }
        worst = min(o["ratio"] for s in per_suite.values()
                    for o in s.values())
        best = max(o["ratio"] for s in per_suite.values()
                   for o in s.values())
        print(f"tune n={n:<6d} tiles(dm) block={dm_tiles.block:<5d}"
              f" B={dm_tiles.batch_size:<4d} chunk={dm_tiles.chunk:<7d}"
              f" -> tuned/default traffic ratios {1/best:.3f}..{1/worst:.3f}"
              f" (<= 1 on all {len(per_suite)} suites)")

    if profile_json:
        prof = calibrate(budget)
        save_profile(prof, profile_json)
        print(f"# calibrated {prof.backend}: "
              f"{prof.bandwidth / 1e9:.1f} GB/s, "
              f"{prof.latency * 1e6:.1f} us -> {profile_json}")

    if out_json:
        artifact = {
            "suite": "tune",
            "d": d,
            "budget": budget.to_dict(),
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "results": {str(n): r for n, r in results.items()},
        }
        with open(out_json, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"# wrote {out_json}")
    return results


if __name__ == "__main__":
    run()
