"""--suite api: analytic O(n²) traffic of a 4-analysis session,
Workspace vs standalone.

``PYTHONPATH=src python -m benchmarks.run --suite api``

The measured quantity is **analytic matrix traffic**, not wall-clock:
container timing is ±40% noisy, while the number of O(n²) hoist passes is
exact — each `HoistCache` build maps to a documented number of n²-sized
passes over D (or a derived n² matrix), so bytes = passes · n² · 4 (fp32).
The canonical session is the Sfiligoi-et-al. study battery — PCoA,
PERMANOVA, PERMDISP, ANOSIM on one matrix. "standalone" runs each
analysis on its own one-shot Workspace (exactly what the legacy free
functions do); "workspace" shares one session. Emits ``BENCH_api.json``
so the traffic ratio is the tracked artifact (wall time is recorded but
informational only).
"""

import json
import time

import jax
import numpy as np

from repro.api.config import ExecConfig
from repro.api.workspace import Workspace
from repro.core.distance_matrix import random_distance_matrix
from repro.obs.ledger import HOIST_PASSES

_NUM_GROUPS = 8
_DIMS = 10

# The audited n²-pass cost table lives in ONE place now —
# ``repro.obs.ledger.HOIST_PASSES`` (the same registry the instrumented
# runtime charges live, so a ``Workspace.report()``'s hoist totals and
# this benchmark's accounting can never drift apart). A parity test in
# tests/test_obs.py pins the published 11-vs-16 session passes against
# the registry.
_PASSES = HOIST_PASSES


def _artifact(key):
    return key if isinstance(key, str) else key[0]


def _session(ws, grouping, permutations, key):
    ws.pcoa(dimensions=_DIMS)
    ws.permanova(grouping, permutations=permutations, key=key)
    ws.permdisp(grouping, permutations=permutations, key=key,
                dimensions=_DIMS)
    ws.anosim(grouping, permutations=permutations, key=key)


def _accounting(caches, n):
    builds = {}
    for cache in caches:
        for k, c in cache.misses.items():
            a = _artifact(k)
            builds[a] = builds.get(a, 0) + c
    passes = sum(_PASSES[a] * c for a, c in builds.items())
    return {"builds": builds, "d_passes": passes,
            "analytic_bytes": passes * n * n * 4}


def run(sizes=(512, 2048), permutations=999, out_json="BENCH_api.json"):
    print(f"\n# --suite api — 4-analysis session "
          f"(pcoa k={_DIMS} / permanova / permdisp / anosim), "
          f"K={permutations}: one Workspace vs per-call hoists")
    key = jax.random.PRNGKey(7)
    results = {}
    for n in sizes:
        dm = random_distance_matrix(jax.random.PRNGKey(n), n)
        grouping = np.arange(n) % _NUM_GROUPS

        # -- workspace mode: one session, shared HoistCache ---------------
        ws = Workspace(dm, config=ExecConfig())
        t0 = time.perf_counter()
        _session(ws, grouping, permutations, key)
        t_ws = time.perf_counter() - t0
        shared = _accounting([ws.cache], n)

        # -- standalone mode: a fresh one-shot Workspace per analysis -----
        # (exactly the legacy free-function behaviour, instrumented)
        t0 = time.perf_counter()
        solos = []
        for analysis in ("pcoa", "permanova", "permdisp", "anosim"):
            solo = Workspace(dm, config=ExecConfig())
            if analysis == "pcoa":
                solo.pcoa(dimensions=_DIMS)
            elif analysis == "permanova":
                solo.permanova(grouping, permutations=permutations, key=key)
            elif analysis == "permdisp":
                solo.permdisp(grouping, permutations=permutations, key=key,
                              dimensions=_DIMS)
            else:
                solo.anosim(grouping, permutations=permutations, key=key)
            solos.append(solo.cache)
        t_solo = time.perf_counter() - t0
        standalone = _accounting(solos, n)

        ratio = standalone["d_passes"] / shared["d_passes"]
        shared["seconds"] = t_ws
        standalone["seconds"] = t_solo
        results[n] = {"workspace": shared, "standalone": standalone,
                      "traffic_ratio": ratio}
        print(f"api  n={n:<6d} workspace {shared['d_passes']:5.1f} n²-passes"
              f" ({shared['analytic_bytes'] / 1e6:8.1f} MB)  standalone "
              f"{standalone['d_passes']:5.1f} ({standalone['analytic_bytes'] / 1e6:8.1f} MB)"
              f"  -> {ratio:.2f}x less traffic; wall {t_ws:.2f}s vs "
              f"{t_solo:.2f}s (informational)")

    if out_json:
        artifact = {
            "suite": "api",
            "analyses": ["pcoa", "permanova", "permdisp", "anosim"],
            "dimensions": _DIMS,
            "permutations": permutations,
            "num_groups": _NUM_GROUPS,
            "pass_table": _PASSES,
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "results": {str(n): r for n, r in results.items()},
        }
        with open(out_json, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"# wrote {out_json}")
    return results


if __name__ == "__main__":
    run()
