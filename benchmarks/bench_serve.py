"""``--suite serve``: the front door's coalescing economics, gated.

The serving claim is the continuous-batching one, restated for
permutation tiles: R concurrent requests against the same study must
cost ONE set of hoists and ``ceil(ΣK_r / B)`` padded tiles — not R sets
of hoists and ``Σ ceil(K_r / B)`` tiles, which is what R independent
library calls (or a slot-per-request scheduler that can't share tiles)
would pay. Both quantities are analytic (the container-noise rule:
wall-clock is ±40% noisy, structure isn't), priced by the SAME audited
registry the live engine charges — ``obs.ledger.perm_traffic_floats``
for tile traffic, the session ledger's hoist entries for the hoists —
and the run's own per-study ``Ledger`` is the witness: the gates read
the charges the serve path actually recorded, not a model of what it
should have recorded.

Gates (asserted, not just reported):
* tiles executed == ceil(ΣK_r / B) per lane (the coalescing bound from
  the acceptance criteria);
* every hoist artifact charged exactly once per study, independent of R;
* the ledger's recorded perm traffic == tiles × B × condensed_fused(n,B).

``run()`` writes ``BENCH_serve.json`` (full sizes); ``--fast`` and
``--smoke`` run smaller without touching the tracked artifact.
"""

import json
import math
import time

import numpy as np

from repro.obs.ledger import perm_traffic_floats
from repro.serve import AnalysisService, ServeConfig

#: mixed per-request K — deliberately not multiples of B, so the
#: coalescing bound is exercised with ragged tails
REQUEST_KS = (999, 499, 249, 99, 49, 17)


def _workload(n: int, permutations: int, batch: int, requests: int,
              seed: int = 0) -> dict:
    """R concurrent mantel requests against one study, coalesced."""
    rng = np.random.default_rng(seed)
    svc = AnalysisService(ServeConfig(batch_size=batch, timeout_s=None,
                                      max_active=requests,
                                      auto_tune=False))
    svc.upload("x", features=rng.random((n, 32)).astype(np.float32))
    svc.upload("y", features=rng.random((n, 32)).astype(np.float32))

    ks = [min(REQUEST_KS[i % len(REQUEST_KS)], permutations)
          for i in range(requests)]
    t0 = time.perf_counter()
    handles = [svc.submit("x", "mantel", other="y", permutations=k, key=i)
               for i, k in enumerate(ks)]
    svc.run()
    wall = time.perf_counter() - t0
    assert all(h.status == "done" for h in handles), \
        [h.payload() for h in handles if h.status != "done"]

    # -- the coalescing gate: tiles == ceil(ΣK / B), one lane ------------
    tiles_coalesced = svc.scheduler.tiles_run
    tiles_expected = math.ceil(sum(ks) / batch)
    tiles_per_request = sum(math.ceil(k / batch) for k in ks)
    assert tiles_coalesced == tiles_expected, \
        (tiles_coalesced, tiles_expected)

    # -- the hoist gate: charged once per study, not per request ---------
    ws = svc.pool.get("x")
    hoist_entries = [e for e in ws.obs.ledger.entries
                     if e.op.startswith("hoist:")]
    ops = [e.op for e in hoist_entries]
    assert len(ops) == len(set(ops)), f"hoist charged twice: {ops}"
    builds = dict(ws.cache.misses)
    assert all(v == 1 for v in builds.values()), builds

    # -- the traffic gate: the ledger's own charges match the model ------
    per_perm = perm_traffic_floats(n, batch)["condensed_fused"]
    floats_coalesced = sum(
        e.floats for e in ws.obs.ledger.entries
        if e.op == "perm:serve:mantel")
    assert abs(floats_coalesced
               - tiles_coalesced * batch * per_perm) < 1e-6 * max(
                   floats_coalesced, 1.0), \
        (floats_coalesced, tiles_coalesced * batch * per_perm)
    floats_per_request = tiles_per_request * batch * per_perm

    return {
        "n": n, "batch": batch, "requests": requests, "per_request_k": ks,
        "total_permutations": sum(ks),
        "tiles_coalesced": tiles_coalesced,
        "tiles_per_request": tiles_per_request,
        "tile_ratio": tiles_per_request / tiles_coalesced,
        "perm_floats_coalesced": floats_coalesced,
        "perm_floats_per_request": floats_per_request,
        "traffic_ratio": floats_per_request / floats_coalesced,
        "hoist_builds": {str(k): v for k, v in builds.items()},
        "hoist_passes": ws.obs.ledger.hoist_passes(),
        "wall_s": wall,
        "throughput_rps": requests / wall,
    }


def run(sizes=(512, 2048), permutations: int = 999, batch: int = 32,
        requests: int = 12, out_json: str = "BENCH_serve.json") -> dict:
    print(f"\n## serve — cross-request tile coalescing "
          f"(R={requests} concurrent mantel requests per study, "
          f"mixed K, B={batch}; gates are analytic + ledger-verified)")
    print(f"{'n':>6s} {'tiles':>7s} {'vs solo':>8s} {'traffic':>8s} "
          f"{'hoists':>7s} {'wall':>8s}")
    results = {}
    for n in sizes:
        r = _workload(n, permutations, batch, requests)
        results[n] = r
        print(f"{n:6d} {r['tiles_coalesced']:7d} "
              f"{r['tile_ratio']:7.2f}x {r['traffic_ratio']:7.2f}x "
              f"{len(r['hoist_builds']):7d} {r['wall_s'] * 1e3:6.0f}ms")
    if out_json:
        payload = {"suite": "serve", "permutations": permutations,
                   "batch": batch, "requests": requests,
                   "request_ks": list(REQUEST_KS),
                   "results": {str(k): v for k, v in results.items()}}
        with open(out_json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {out_json}")
    return results
