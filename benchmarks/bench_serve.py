"""``--suite serve``: the front door's coalescing economics, gated.

The serving claim is the continuous-batching one, restated for
permutation tiles: R concurrent requests against the same study must
cost ONE set of hoists and ``ceil(ΣK_r / B)`` padded tiles — not R sets
of hoists and ``Σ ceil(K_r / B)`` tiles, which is what R independent
library calls (or a slot-per-request scheduler that can't share tiles)
would pay. Both quantities are analytic (the container-noise rule:
wall-clock is ±40% noisy, structure isn't), priced by the SAME audited
registry the live engine charges — ``obs.ledger.perm_traffic_floats``
for tile traffic, the session ledger's hoist entries for the hoists —
and the run's own per-study ``Ledger`` is the witness: the gates read
the charges the serve path actually recorded, not a model of what it
should have recorded.

Gates (asserted, not just reported):
* tiles executed == ceil(ΣK_r / B) per lane (the coalescing bound from
  the acceptance criteria);
* every hoist artifact charged exactly once per study, independent of R;
* the ledger's recorded perm traffic == tiles × B × condensed_fused(n,B).

The chaos half (``run_chaos`` / ``--chaos``) turns the ``repro.faults``
plane on the same workload — a bounded seed sweep of mixed injected
faults (transient tile errors, OOM, NaN poison, hoist/compile failures)
plus one deterministic crash/recovery scenario — and gates on the
recovery invariants, never wall-clock:
* every request terminates (done / degraded / rejected), no hangs;
* every COMPLETED request's p-value is bitwise-equal to the fault-free
  run (retries re-execute identical rows; poisoned tiles never reach
  the exceedance counts);
* retry amplification (re-executed rows / useful rows) stays under a
  fixed cap — a retry storm fails the suite before it fails a fleet;
* journal recovery executes exactly the remaining ``ceil(ΣK/B) − t``
  tiles after a crash at tile t, with zero re-hoists.

``run()`` writes ``BENCH_serve.json`` (full sizes; the ``chaos``
section carries the sweep's receipts); ``--fast`` and ``--smoke`` run
smaller without touching the tracked artifact.
"""

import json
import math
import os
import tempfile
import time

import numpy as np

from repro.faults import FaultPlan
from repro.obs.ledger import perm_traffic_floats
from repro.serve import AnalysisService, ServeConfig

#: mixed per-request K — deliberately not multiples of B, so the
#: coalescing bound is exercised with ragged tails
REQUEST_KS = (999, 499, 249, 99, 49, 17)


def _workload(n: int, permutations: int, batch: int, requests: int,
              seed: int = 0) -> dict:
    """R concurrent mantel requests against one study, coalesced."""
    rng = np.random.default_rng(seed)
    svc = AnalysisService(ServeConfig(batch_size=batch, timeout_s=None,
                                      max_active=requests,
                                      auto_tune=False))
    svc.upload("x", features=rng.random((n, 32)).astype(np.float32))
    svc.upload("y", features=rng.random((n, 32)).astype(np.float32))

    ks = [min(REQUEST_KS[i % len(REQUEST_KS)], permutations)
          for i in range(requests)]
    t0 = time.perf_counter()
    handles = [svc.submit("x", "mantel", other="y", permutations=k, key=i)
               for i, k in enumerate(ks)]
    svc.run()
    wall = time.perf_counter() - t0
    assert all(h.status == "done" for h in handles), \
        [h.payload() for h in handles if h.status != "done"]

    # -- the coalescing gate: tiles == ceil(ΣK / B), one lane ------------
    tiles_coalesced = svc.scheduler.tiles_run
    tiles_expected = math.ceil(sum(ks) / batch)
    tiles_per_request = sum(math.ceil(k / batch) for k in ks)
    assert tiles_coalesced == tiles_expected, \
        (tiles_coalesced, tiles_expected)

    # -- the hoist gate: charged once per study, not per request ---------
    ws = svc.pool.get("x")
    hoist_entries = [e for e in ws.obs.ledger.entries
                     if e.op.startswith("hoist:")]
    ops = [e.op for e in hoist_entries]
    assert len(ops) == len(set(ops)), f"hoist charged twice: {ops}"
    builds = dict(ws.cache.misses)
    assert all(v == 1 for v in builds.values()), builds

    # -- the traffic gate: the ledger's own charges match the model ------
    per_perm = perm_traffic_floats(n, batch)["condensed_fused"]
    floats_coalesced = sum(
        e.floats for e in ws.obs.ledger.entries
        if e.op == "perm:serve:mantel")
    assert abs(floats_coalesced
               - tiles_coalesced * batch * per_perm) < 1e-6 * max(
                   floats_coalesced, 1.0), \
        (floats_coalesced, tiles_coalesced * batch * per_perm)
    floats_per_request = tiles_per_request * batch * per_perm

    return {
        "n": n, "batch": batch, "requests": requests, "per_request_k": ks,
        "total_permutations": sum(ks),
        "tiles_coalesced": tiles_coalesced,
        "tiles_per_request": tiles_per_request,
        "tile_ratio": tiles_per_request / tiles_coalesced,
        "perm_floats_coalesced": floats_coalesced,
        "perm_floats_per_request": floats_per_request,
        "traffic_ratio": floats_per_request / floats_coalesced,
        "hoist_builds": {str(k): v for k, v in builds.items()},
        "hoist_passes": ws.obs.ledger.hoist_passes(),
        "wall_s": wall,
        "throughput_rps": requests / wall,
    }


# --------------------------------------------------------------------------
# The chaos suite
# --------------------------------------------------------------------------
#: injected-fault rates for the sweep — aggressive enough that every
#: recovery path fires across a few seeds, bounded enough to terminate
#: fast (stall/evict have their own targeted tests in tests/test_faults)
CHAOS_RATES = dict(tile_error=0.10, oom=0.03, nan=0.03, slow=0.0,
                   compile_rate=0.20)

#: re-executed rows per useful row; a chaos run past this is a retry
#: storm, not graceful degradation (at the sweep's rates the expected
#: value is ~0.2 — the cap leaves room for an unlucky seed, not a storm)
RETRY_AMPLIFICATION_CAP = 2.0


def _serve_pair(n: int, batch: int, requests: int, seed: int = 0,
                **cfg) -> AnalysisService:
    """One service with the x/y study pair uploaded (shared by the
    coalescing and chaos workloads — identical data per seed)."""
    rng = np.random.default_rng(seed)
    svc = AnalysisService(ServeConfig(batch_size=batch, timeout_s=None,
                                      max_active=requests,
                                      auto_tune=False, **cfg))
    svc.upload("x", features=rng.random((n, 32)).astype(np.float32))
    svc.upload("y", features=rng.random((n, 32)).astype(np.float32))
    return svc


def _submit_all(svc: AnalysisService, requests: int, permutations: int):
    ks = [min(REQUEST_KS[i % len(REQUEST_KS)], permutations)
          for i in range(requests)]
    return ks, [svc.submit("x", "mantel", other="y", permutations=k,
                           key=i) for i, k in enumerate(ks)]


def run_chaos(n: int = 256, permutations: int = 199, batch: int = 16,
              requests: int = 6, seeds=(0, 1, 2)) -> dict:
    """The seeded chaos sweep + the crash/recovery scenario, gated."""
    # -- the fault-free reference: the bitwise target --------------------
    ref_svc = _serve_pair(n, batch, requests)
    ks, ref_handles = _submit_all(ref_svc, requests, permutations)
    ref_svc.run()
    assert all(h.status == "done" for h in ref_handles)
    ref_p = {h.request_id: h.result.p_value for h in ref_handles}

    per_seed = {}
    for seed in seeds:
        svc = _serve_pair(n, batch, requests,
                          fault_plan=FaultPlan.chaos(seed=seed,
                                                     **CHAOS_RATES))
        _, handles = _submit_all(svc, requests, permutations)
        t0 = time.perf_counter()
        svc.run()
        wall = time.perf_counter() - t0
        # gate: every request terminated — no hangs under any schedule
        hung = [h.request_id for h in handles if not h.done]
        assert not hung, f"seed {seed}: requests never terminated: {hung}"
        # gate: completed results are bitwise the fault-free ones
        for h in handles:
            if h.status == "done":
                assert h.result.p_value == ref_p[h.request_id], \
                    (seed, h.request_id, h.result.p_value,
                     ref_p[h.request_id])
        # gate: bounded retry amplification
        amp = svc.metrics.retry_amplification
        assert amp <= RETRY_AMPLIFICATION_CAP, \
            f"seed {seed}: retry amplification {amp:.2f} > " \
            f"{RETRY_AMPLIFICATION_CAP}"
        per_seed[seed] = {
            "statuses": {s: sum(h.status == s for h in handles)
                         for s in ("done", "degraded", "rejected")},
            "injected": dict(svc.metrics.faults),
            "tile_failures": dict(svc.metrics.tile_failures),
            "retries": svc.metrics.retries,
            "retry_amplification": amp,
            "breaker_trips": svc.metrics.breaker_trips,
            "pool_sheds": svc.metrics.pool_sheds,
            "bitwise_completed": sum(h.status == "done" for h in handles),
            "wall_s": wall,
        }

    # -- crash/recovery: resume without re-running or re-hoisting --------
    path = os.path.join(tempfile.mkdtemp(prefix="repro_chaos_"),
                        "serve.journal")
    svc = _serve_pair(n, batch, requests, journal_path=path)
    _submit_all(svc, requests, permutations)
    total_tiles = math.ceil(sum(ks) / batch)
    crash_after = total_tiles // 3
    while svc.scheduler.tiles_run < crash_after:
        svc.step()
    pool = svc.pool                         # sessions survive the crash
    svc.journal.close()
    hoists_before = {sid: dict(pool._sessions[sid].cache.misses)
                     for sid in pool.studies()}
    svc2, handles = AnalysisService.recover(
        path, pool=pool,
        config=ServeConfig(batch_size=batch, timeout_s=None,
                           max_active=requests, auto_tune=False))
    svc2.run()
    # gate: exactly the remaining tiles ran — completed blocks stayed done
    assert svc2.scheduler.tiles_run == total_tiles - crash_after, \
        (svc2.scheduler.tiles_run, total_tiles, crash_after)
    # gate: nothing re-hoisted (counters pinned at their pre-crash state)
    for sid in pool.studies():
        assert dict(pool._sessions[sid].cache.misses) == \
            hoists_before[sid], sid
    # gate: recovered results are bitwise the uninterrupted ones,
    # matched per request id (a request already terminal at the crash
    # is NOT resubmitted — its journaled terminal stands and its tiles
    # are among the ones recovery never re-runs)
    assert all(h.status == "done" for h in handles.values()), \
        {rid: h.status for rid, h in handles.items()}
    for old_rid, h in handles.items():
        assert h.result.p_value == ref_p[old_rid], \
            (old_rid, h.result.p_value, ref_p[old_rid])
    recovery = {
        "tiles_total": total_tiles,
        "crash_after_tiles": crash_after,
        "tiles_after_recovery": svc2.scheduler.tiles_run,
        "rehoists": 0,
        "resumed_requests": svc2.metrics.resumes,
        "resumed_rows": svc2.metrics.resumed_rows,
        "already_terminal": requests - len(handles),
        "recovered_bitwise": len(handles),
    }
    return {"n": n, "batch": batch, "requests": requests,
            "per_request_k": ks, "rates": dict(CHAOS_RATES),
            "retry_amplification_cap": RETRY_AMPLIFICATION_CAP,
            "seeds": {str(s): r for s, r in per_seed.items()},
            "recovery": recovery}


def print_chaos(c: dict) -> None:
    print(f"\n## serve — chaos soak (n={c['n']}, R={c['requests']}, "
          f"B={c['batch']}; gates: all terminate, completed bitwise, "
          f"amplification <= {c['retry_amplification_cap']})")
    print(f"{'seed':>6s} {'done':>5s} {'degr':>5s} {'rej':>5s} "
          f"{'retries':>8s} {'amp':>6s} {'breaker':>8s}")
    for seed, r in c["seeds"].items():
        st = r["statuses"]
        print(f"{seed:>6s} {st['done']:5d} {st['degraded']:5d} "
              f"{st['rejected']:5d} {r['retries']:8d} "
              f"{r['retry_amplification']:6.2f} {r['breaker_trips']:8d}")
    rec = c["recovery"]
    print(f"# recovery: crash @ tile {rec['crash_after_tiles']}/"
          f"{rec['tiles_total']} -> {rec['tiles_after_recovery']} tiles "
          f"to finish, {rec['rehoists']} re-hoists, "
          f"{rec['resumed_rows']} rows resumed, "
          f"{rec['recovered_bitwise']} results bitwise")


def run(sizes=(512, 2048), permutations: int = 999, batch: int = 32,
        requests: int = 12, out_json: str = "BENCH_serve.json",
        chaos: bool = True) -> dict:
    print(f"\n## serve — cross-request tile coalescing "
          f"(R={requests} concurrent mantel requests per study, "
          f"mixed K, B={batch}; gates are analytic + ledger-verified)")
    print(f"{'n':>6s} {'tiles':>7s} {'vs solo':>8s} {'traffic':>8s} "
          f"{'hoists':>7s} {'wall':>8s}")
    results = {}
    for n in sizes:
        r = _workload(n, permutations, batch, requests)
        results[n] = r
        print(f"{n:6d} {r['tiles_coalesced']:7d} "
              f"{r['tile_ratio']:7.2f}x {r['traffic_ratio']:7.2f}x "
              f"{len(r['hoist_builds']):7d} {r['wall_s'] * 1e3:6.0f}ms")
    if chaos:
        # the chaos receipts ride the same artifact (non-int key: the
        # trajectory gate reads only the sized coalescing entries)
        results["chaos"] = run_chaos(batch=16, requests=6)
        print_chaos(results["chaos"])
    if out_json:
        payload = {"suite": "serve", "permutations": permutations,
                   "batch": batch, "requests": requests,
                   "request_ks": list(REQUEST_KS),
                   "results": {str(k): v for k, v in results.items()
                               if isinstance(k, int)}}
        if chaos:
            payload["chaos"] = results["chaos"]
        with open(out_json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {out_json}")
    return results
