"""Measured-vs-modeled telemetry: obs.probe / obs.drift / obs.metrics /
the trajectory gate.

The probes are ahead-of-time: ``jit(...).lower(avals).compile()`` with
symbolic ShapeDtypeStructs, so nothing here executes a kernel — tests
pay compile time only. The drift parity tests pin the one empirical
fact the whole subsystem stands on: the compiled scan program's
HLO-counted bytes land inside the analytic envelope the DriftSentinel
derives from the ledger/tune cost models.
"""

import json
import time

import numpy as np
import pytest

from repro.obs import drift, probe
from repro.obs.metrics import (DEFAULT_LATENCY_BUCKETS, NULL_HISTOGRAM,
                               Counter, Gauge, Histogram, prometheus_text)


# --------------------------------------------------------------------------
# HLO text counting — hand-written programs with known answers
# --------------------------------------------------------------------------
_TOY_HLO = """\
HloModule toy

%body (p: (s32[], f32[100])) -> (s32[], f32[100]) {
  %p = (s32[], f32[100]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[100]) %p), index=0
  %x = f32[100]{0} get-tuple-element((s32[], f32[100]) %p), index=1
  %y = f32[100]{0} add(f32[100]{0} %x, f32[100]{0} %x)
  ROOT %t = (s32[], f32[100]) tuple(s32[] %i, f32[100]{0} %y)
}

%cond (p: (s32[], f32[100])) -> pred[] {
  %p = (s32[], f32[100]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[100]) %p), index=0
  %k = s32[] constant(8)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %k), direction=LT
}

ENTRY %main (arg: f32[100]) -> f32[100] {
  %arg = f32[100]{0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[100]) tuple(s32[] %zero, f32[100]{0} %arg)
  %w = (s32[], f32[100]) while((s32[], f32[100]) %init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"8"}}
  ROOT %out = f32[100]{0} get-tuple-element((s32[], f32[100]) %w), index=1
}
"""


def test_trip_count_from_known_trip_count_hint():
    mult, bodies = probe.computation_multipliers(_TOY_HLO)
    assert mult["body"] == 8
    assert "body" in bodies


def test_scan_correction_adds_body_repeats():
    # the body's only counted line is the add — printed as output +
    # two operands, 3 x f32[100] = 1200 bytes (get-tuple-element /
    # tuple / parameter are free); raw XLA-style counting sees the body
    # once, the corrected count sees it trip_count times
    comps = probe._split_computations(_TOY_HLO)
    assert probe.body_once_bytes(comps["body"], comps) == 1200
    corrected, trips = probe.scan_corrected_bytes(_TOY_HLO, raw_bytes=1000)
    assert trips == {"body": 8}
    assert corrected == 1000 + 7 * 1200


def test_trip_count_from_compare_constant_when_no_hint():
    hlo = _TOY_HLO.replace(
        ', backend_config={"known_trip_count":{"n":"8"}}', "")
    mult, _ = probe.computation_multipliers(hlo)
    assert mult["body"] == 8          # recovered from `compare(i, k), LT`


def test_shape_bytes_parser():
    assert probe._shape_bytes("f32[100]{0}") == 400
    assert probe._shape_bytes("s32[4,8]") == 128
    assert probe._shape_bytes("pred[]") == 1
    assert probe._shape_bytes("f32[]") == 4


# --------------------------------------------------------------------------
# Live probes — compiled production entry points
# --------------------------------------------------------------------------
def test_probe_permute_reduce_record_fields():
    rec = probe.probe_permute_reduce(96, batch=8)
    assert rec.name == "kernels.permute_reduce"
    assert rec.backend == "cpu"
    m = 96 * 95 // 2
    # inputs: xc (m,) f32 + ys (1, m) f32 + orders (8, 96) i32 + ii/jj
    expected_args = 4 * m + 4 * m + 4 * 8 * 96 + 2 * 4 * m
    assert rec.argument_bytes == expected_args
    assert rec.output_bytes == 4 * 8          # (s, B) f32 statistics
    assert rec.bytes_corrected >= rec.argument_bytes + rec.output_bytes
    assert rec.peak_bytes >= rec.argument_bytes
    assert rec.flops > 0
    d = rec.to_dict()
    json.dumps(d)                              # serializable
    assert d["params"]["n"] == 96


def test_probe_memoizes_by_geometry():
    probe.clear_probe_cache()
    r1 = probe.probe_permute_reduce(96, batch=8)
    r2 = probe.probe_permute_reduce(96, batch=8)
    assert r1 is r2                            # process memo hit
    r3 = probe.probe_permute_reduce(96, batch=16)
    assert r3 is not r1


def test_probe_stream_pass_counts_exactly_two_passes():
    n = 1 << 20
    rec = probe.probe_stream_pass(n)
    # read + write, no scan, no temp inflation on an elementwise pass
    assert rec.bytes_corrected == 2 * 4 * n
    assert rec.scan_trips == {}


# --------------------------------------------------------------------------
# Drift parity — the ISSUE acceptance geometry (n=2048, B=32)
# --------------------------------------------------------------------------
def test_drift_parity_permute_reduce_scan_regime():
    rec = probe.probe_permute_reduce(2048, batch=32)
    sent = drift.DriftSentinel(backend="cpu")
    verdicts = sent.check_permute_reduce(rec)
    assert {v.quantity for v in verdicts} == {"bytes", "peak"}
    for v in verdicts:
        assert v.within, (v.quantity, v.measured, v.expected_lo,
                          v.expected_hi, v.note)
    by_q = {v.quantity: v for v in verdicts}
    # scan regime at n=2048 (m >> chunk): the closed form should be
    # TIGHT, not just inside the slackened envelope
    b = by_q["bytes"]
    assert b.regime == "scan"
    m = 2048 * 2047 // 2
    m_pad = -(-m // 65536) * 65536
    eff = 4.0 * (m_pad * (5 * 32 + 3 * 1 + 2) + m * (6 + 2 * 1))
    assert 0.65 * eff <= rec.bytes_corrected <= 1.35 * eff


def test_drift_rejects_square_gather_class_blowup():
    # a hypothetical implementation that re-gathers the full condensed
    # vector per permutation row moves ~B x the floor — the envelope
    # must reject it even with CPU slack
    rec = probe.probe_permute_reduce(2048, batch=32)
    blown = probe.ProbeRecord(
        name=rec.name, backend=rec.backend, flops=rec.flops,
        bytes_accessed=rec.bytes_accessed,
        bytes_corrected=11.0 * rec.bytes_corrected,
        peak_bytes=rec.peak_bytes, argument_bytes=rec.argument_bytes,
        output_bytes=rec.output_bytes, temp_bytes=rec.temp_bytes,
        scan_trips=rec.scan_trips, params=rec.params)
    verdicts = drift.DriftSentinel(backend="cpu").check_permute_reduce(blown)
    assert not all(v.within for v in verdicts)


def test_reconcile_full_record_set_within_tolerance():
    recs = [probe.probe_permute_reduce(96, batch=8),
            probe.probe_panel_stats(96, 24),
            probe.probe_stream_pass(1 << 20)]
    doc = drift.reconcile({r.name: r for r in recs})
    assert doc["within_tolerance"] is True
    assert doc["backend"] == "cpu"
    names = {v["name"] for v in doc["verdicts"]}
    assert names == {"kernels.permute_reduce", "dist.panel_stats",
                     "tune.stream_pass"}
    json.dumps(doc)


def test_workspace_report_measured_and_drift_sections():
    from repro.api.config import ExecConfig
    from repro.api.workspace import Workspace
    from repro.obs import ObsConfig

    rng = np.random.default_rng(7)
    ws = Workspace.from_features(
        rng.random((48, 12)).astype(np.float32) + .01,
        config=ExecConfig(obs=ObsConfig(enabled=True)))
    ws.permanova(rng.integers(0, 3, 48), permutations=9)
    rep = ws.report()
    assert rep.measured, "probe section missing"
    assert "kernels.permute_reduce" in rep.measured
    assert rep.drift["verdicts"]
    assert rep.drift_ok
    json.dumps(rep.to_dict())

    # probe=False switches the sections off, nothing else changes
    ws2 = Workspace.from_features(
        rng.random((48, 12)).astype(np.float32) + .01,
        config=ExecConfig(obs=ObsConfig(enabled=True, probe=False)))
    ws2.permanova(rng.integers(0, 3, 48), permutations=9)
    rep2 = ws2.report()
    assert rep2.measured == {} and rep2.drift == {}
    assert rep2.drift_ok                        # vacuously green


# --------------------------------------------------------------------------
# obs.metrics — the allocation-light primitives
# --------------------------------------------------------------------------
def test_histogram_percentiles_and_quantile_bounds():
    h = Histogram("t")
    for v in [0.001, 0.002, 0.004, 0.1, 0.2]:
        h.record(v)
    p = h.percentiles()
    assert p["count"] == 5
    assert p["max"] == pytest.approx(0.2)
    # quantiles are interpolated within buckets but always clamped to
    # the observed [min, max] — a nonzero sample set never reports 0
    assert 0.001 <= p["p50"] <= 0.2
    assert 0.001 <= p["p99"] <= 0.2
    assert p["mean"] == pytest.approx(np.mean([0.001, 0.002, 0.004,
                                               0.1, 0.2]))


def test_histogram_record_is_fast_and_allocation_light():
    h = Histogram("t")
    n = 20000
    t0 = time.perf_counter()
    for i in range(n):
        h.record(0.001 * (i % 97 + 1))
    per_call = (time.perf_counter() - t0) / n
    assert per_call < 20e-6, f"record() {per_call * 1e6:.1f}us >= 20us"
    # fixed buckets: counts array never grows with samples
    assert len(h.counts) == len(DEFAULT_LATENCY_BUCKETS) + 1


def test_null_histogram_is_inert():
    NULL_HISTOGRAM.record(123.0)
    assert NULL_HISTOGRAM.count == 0
    assert NULL_HISTOGRAM.percentiles() == {}
    assert NULL_HISTOGRAM.enabled is False


def test_counter_gauge_and_prometheus_exposition():
    c = Counter("reqs_total")
    c.inc()
    c.inc(2)
    g = Gauge("depth")
    g.set(7)
    h = Histogram("lat_seconds")
    h.record(0.005)
    text = prometheus_text([c, g, h])
    assert "# TYPE reqs_total counter" in text
    assert "reqs_total 3.0" in text
    assert "depth 7.0" in text
    assert "# TYPE lat_seconds histogram" in text
    assert 'lat_seconds_bucket{le="+Inf"} 1' in text
    assert "lat_seconds_count 1" in text
    for line in text.splitlines():             # exposition format sanity
        assert line.startswith("#") or " " in line


# --------------------------------------------------------------------------
# calibrate(mode="probe") — deterministic budget calibration
# --------------------------------------------------------------------------
def test_calibrate_probe_mode_is_deterministic():
    from repro.tune.budget import calibrate, detect_budget

    b1 = calibrate(mode="probe", large=1 << 20)
    b2 = calibrate(mode="probe", large=1 << 20)
    assert b1.source == "probed"
    assert b1.bandwidth == b2.bandwidth        # no clock involved
    # the compiled stream pass moves exactly the modeled 2 passes on
    # CPU, so probe calibration reproduces the static default
    assert b1.bandwidth == pytest.approx(detect_budget().bandwidth)
    with pytest.raises(ValueError):
        calibrate(mode="nonsense")


# --------------------------------------------------------------------------
# Trajectory ledger + gate
# --------------------------------------------------------------------------
def test_trajectory_record_gate_rebaseline_roundtrip(tmp_path):
    from benchmarks import trajectory

    jsonl = str(tmp_path / "traj.jsonl")
    base = str(tmp_path / "base.json")
    metrics = {"mantel.ratio_vs_square_gather.n64": 8.6,
               "probe.permute_reduce.bytes.n256": 8.9e6}
    trajectory.record("smoke", metrics, path=jsonl)
    trajectory.rebaseline(jsonl, base)
    # identical run: green
    assert trajectory.check("smoke", metrics, path=jsonl,
                            baseline_path=base) == []
    # ratio regression (win shrank) and byte regression (cost grew)
    bad = {"mantel.ratio_vs_square_gather.n64": 8.6 * 0.9,
           "probe.permute_reduce.bytes.n256": 8.9e6 * 1.5}
    with pytest.raises(SystemExit):
        trajectory.check("smoke", bad, path=jsonl, baseline_path=base)
    fails = trajectory.check("smoke", bad, path=jsonl, baseline_path=base,
                             raise_on_failure=False)
    assert len(fails) == 2
    # inside tolerance: green both directions
    ok = {"mantel.ratio_vs_square_gather.n64": 8.6 * 0.97,
          "probe.permute_reduce.bytes.n256": 8.9e6 * 1.2}
    assert trajectory.check("smoke", ok, path=jsonl,
                            baseline_path=base) == []
    # unknown metrics pass until the next reseed
    assert trajectory.gate({"new.metric.n8": 1.0},
                           trajectory.load_baseline(base)) == []


def test_trajectory_flatten_shapes():
    from benchmarks import trajectory

    m = trajectory.flatten("mantel", {
        64: {"ratio_vs_square_gather": 8.0, "ratio_vs_original": 12.0},
        "meta": {"ignored": True}})
    assert m == {"mantel.ratio_vs_square_gather.n64": 8.0,
                 "mantel.ratio_vs_original.n64": 12.0}
    with pytest.raises(ValueError):
        trajectory.flatten("nope", {})


def test_committed_baseline_matches_current_probe_measurements():
    """The committed trajectory_baseline.json must gate green against a
    fresh probe of this container — otherwise CI is red on arrival."""
    from benchmarks import trajectory

    base = trajectory.load_baseline()
    assert base, "benchmarks/trajectory_baseline.json missing or empty"
    probed = {k: v for k, v in trajectory.probe_metrics().items()
              if k in base}
    assert probed
    assert trajectory.gate(probed, base) == []
