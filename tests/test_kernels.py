"""Per-kernel validation: shape/dtype sweeps, Pallas (interpret=True)
vs the pure-jnp ref.py oracle — harness deliverable (c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distance_matrix import random_distance_matrix
from repro.kernels import (center_distance_matrix_pallas,
                           center_matvec_pallas,
                           is_symmetric_and_hollow_pallas,
                           mantel_corr_pallas, rmsnorm_pallas)
from repro.kernels.center_matvec_ref import center_matvec_ref
from repro.kernels.center_ref import center_distance_matrix_ref
from repro.kernels.mantel_corr_ref import mantel_corr_ref
from repro.kernels.rmsnorm_ref import rmsnorm_ref
from repro.kernels.symhollow_ref import is_symmetric_and_hollow_ref


# --------------------------------------------------------------------------
# center
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n", [16, 64, 77, 128, 200])
def test_center_matches_ref(n):
    dm = random_distance_matrix(jax.random.PRNGKey(n), n).data
    got = center_distance_matrix_pallas(dm, block_m=32, block_n=32)
    want = center_distance_matrix_ref(dm)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("bm,bn", [(8, 8), (16, 32), (64, 16)])
def test_center_block_shapes(bm, bn):
    dm = random_distance_matrix(jax.random.PRNGKey(1), 64).data
    got = center_distance_matrix_pallas(dm, block_m=bm, block_n=bn)
    want = center_distance_matrix_ref(dm)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_center_bf16():
    """bf16 path: centering subtracts near-equal magnitudes, so absolute
    error is O(bf16 eps · |E|); assert closeness + structure, not bitwise."""
    dm = random_distance_matrix(jax.random.PRNGKey(2), 64).data
    got = np.asarray(center_distance_matrix_pallas(
        dm.astype(jnp.bfloat16), block_m=32, block_n=32), np.float32)
    want = np.asarray(center_distance_matrix_ref(dm))
    scale = np.abs(want).max()
    assert np.abs(got - want).max() < 0.05 * scale
    corr = np.corrcoef(got.ravel(), want.ravel())[0, 1]
    assert corr > 0.999


# --------------------------------------------------------------------------
# center_matvec
# --------------------------------------------------------------------------
def _matvec_inputs(n, k, seed):
    d = random_distance_matrix(jax.random.PRNGKey(seed), n).data
    x = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(seed), 7),
                          (n, k))
    row_means = -0.5 * jnp.mean(d * d, axis=1)
    return d, x, row_means, jnp.mean(row_means)


@pytest.mark.parametrize("n,k", [(16, 4), (64, 10), (77, 7), (128, 20),
                                 (200, 3)])
def test_center_matvec_matches_ref(n, k):
    d, x, rm, gm = _matvec_inputs(n, k, seed=n)
    got = center_matvec_pallas(d, x, rm, gm, block_m=32, block_n=32,
                               interpret=True)
    want = center_matvec_ref(d, x)
    scale = np.abs(np.asarray(want)).max()
    np.testing.assert_allclose(got, want, rtol=1e-5,
                               atol=1e-5 * max(scale, 1.0))


@pytest.mark.parametrize("bm,bn", [(8, 8), (16, 32), (64, 16)])
def test_center_matvec_block_shapes(bm, bn):
    d, x, rm, gm = _matvec_inputs(64, 6, seed=1)
    got = center_matvec_pallas(d, x, rm, gm, block_m=bm, block_n=bn,
                               interpret=True)
    np.testing.assert_allclose(got, center_matvec_ref(d, x),
                               rtol=2e-4, atol=2e-4)


def test_center_matvec_identity_recovers_centered_matrix():
    """F @ I == F: the kernel against the materialized matrix itself."""
    n = 48
    d, _, rm, gm = _matvec_inputs(n, 1, seed=2)
    got = center_matvec_pallas(d, jnp.eye(n), rm, gm, block_m=16,
                               block_n=16, interpret=True)
    np.testing.assert_allclose(got, center_distance_matrix_ref(d),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------
# symhollow
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n", [16, 63, 128])
def test_symhollow_valid(n):
    dm = random_distance_matrix(jax.random.PRNGKey(n), n).data
    s, h = is_symmetric_and_hollow_pallas(dm, block=32)
    s_ref, h_ref = is_symmetric_and_hollow_ref(dm)
    assert bool(s) == bool(s_ref) is True
    assert bool(h) == bool(h_ref) is True


@pytest.mark.parametrize("i,j,expect_sym,expect_hollow", [
    (3, 5, False, True),      # off-diagonal asymmetry
    (60, 2, False, True),     # far block asymmetry
    (7, 7, True, False),      # diagonal violation (stays symmetric)
])
def test_symhollow_detects(i, j, expect_sym, expect_hollow):
    dm = random_distance_matrix(jax.random.PRNGKey(0), 64).data
    bad = dm.at[i, j].add(1.0)
    s, h = is_symmetric_and_hollow_pallas(bad, block=16)
    assert bool(s) == expect_sym
    assert bool(h) == expect_hollow


# --------------------------------------------------------------------------
# mantel_corr
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n,k", [(32, 8), (96, 16), (50, 8)])
def test_mantel_corr_matches_ref(n, k):
    kx, ky, kp = jax.random.split(jax.random.PRNGKey(n), 3)
    x = random_distance_matrix(kx, n).data
    y = random_distance_matrix(ky, n).data
    orders = jax.vmap(lambda kk: jax.random.permutation(kk, n))(
        jax.random.split(kp, k))
    got = mantel_corr_pallas(x, y, orders, perm_batch=4, block=16)
    iu = np.triu_indices(n, k=1)
    want = mantel_corr_ref(x, np.asarray(y)[iu], orders)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_mantel_corr_identity_perm():
    """The identity permutation must reproduce the plain Pearson r."""
    from scipy.stats import pearsonr
    n = 40
    x = random_distance_matrix(jax.random.PRNGKey(3), n).data
    y = random_distance_matrix(jax.random.PRNGKey(4), n).data
    orders = jnp.arange(n)[None, :].repeat(4, axis=0)
    got = mantel_corr_pallas(x, y, orders, perm_batch=4, block=16)
    iu = np.triu_indices(n, k=1)
    want = pearsonr(np.asarray(x)[iu], np.asarray(y)[iu]).statistic
    np.testing.assert_allclose(got, np.full(4, want), rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------------
# rmsnorm
# --------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(4, 256), (2, 7, 128), (3, 5, 4, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, shape, jnp.float32).astype(dtype)
    w = (jax.random.normal(kw, shape[-1:]) * 0.1).astype(dtype)
    got = rmsnorm_pallas(x, w, block_rows=4)
    want = rmsnorm_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
