"""Sharding-rule tests: specs are divisibility-valid for every arch on the
production mesh shapes — without touching device state (pure spec logic
on an abstract mesh via jax.eval_shape trees)."""

import jax
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCHS
from repro.runtime.serve import abstract_cache
from repro.runtime.train import abstract_train_state
from repro.sharding.rules import (ShardingRules, _fit, batch_spec,
                                  cache_specs, param_specs, make_rules)


def _abstract_rules(shape=(16, 16), axes=("data", "model")):
    mesh = AbstractMesh(shape, axes)
    return make_rules(mesh)


def test_fit_prefers_full_group_then_truncates():
    r = _abstract_rules((2, 16, 16), ("pod", "data", "model"))
    assert _fit(64, ("pod", "data"), r) == ("pod", "data")
    assert _fit(16, ("pod", "data"), r) == "data"      # 16 % 32 != 0
    assert _fit(7, ("pod", "data"), r) is None
    assert _fit(32, "model", r) == "model"
    assert _fit(24, "model", r) is None                # llama heads


def _check_spec_tree(tree, specs, rules):
    flat_t = jax.tree.leaves(tree)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_t) == len(flat_s)
    for leaf, spec in zip(flat_t, flat_s):
        assert len(spec) <= len(leaf.shape)
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            size = rules.axis_size(ax)
            assert leaf.shape[dim] % size == 0, \
                f"shape {leaf.shape} dim {dim} not divisible by {ax}({size})"


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("mesh_shape,axes", [
    ((16, 16), ("data", "model")),
    ((2, 16, 16), ("pod", "data", "model")),
])
def test_param_specs_divisible_all_archs(arch, mesh_shape, axes):
    cfg = ARCHS[arch]
    rules = _abstract_rules(mesh_shape, axes)
    params, opt = abstract_train_state(cfg)
    specs = param_specs(cfg, params, rules)
    _check_spec_tree(params, specs, rules)
    # opt-state m/v reuse the param specs — same divisibility holds
    _check_spec_tree(opt["m"], specs, rules)


@pytest.mark.parametrize("arch", ["qwen3-8b", "mamba2-1.3b",
                                  "recurrentgemma-9b", "seamless-m4t-medium"])
def test_cache_specs_divisible(arch):
    cfg = ARCHS[arch]
    rules = _abstract_rules()
    cache = abstract_cache(cfg, 128, 32768,
                           enc_len=(8192 if cfg.is_encdec else 0))
    specs = cache_specs(cfg, cache, rules)
    _check_spec_tree(cache, specs, rules)


def test_deep_cache_is_sequence_sharded():
    """decode_32k full-attention caches must shard seq over 'model'
    (flash-decoding, DESIGN §5)."""
    cfg = ARCHS["qwen3-8b"]
    rules = _abstract_rules()
    cache = abstract_cache(cfg, 128, 32768)
    specs = cache_specs(cfg, cache, rules)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    kv = [s for p, s in flat if any(getattr(e, "key", "") == "k" for e in p)]
    assert kv, "no k cache leaves found"
    for spec in kv:
        assert spec[2] == "model", spec   # (layer, batch, SEQ→model, ...)


def test_batch_spec_handles_indivisible_batch():
    rules = _abstract_rules()
    assert batch_spec(rules, 256) == P("data", None)
    assert batch_spec(rules, 1) == P(None, None)       # long_500k B=1
    assert batch_spec(rules, 24, rank=3) is not None


def test_moe_expert_axis_choice():
    """granite (32e) → experts on 'model' (EP); grok (8e) → TP inside the
    expert FFN instead."""
    rules = _abstract_rules()
    g_params, _ = abstract_train_state(ARCHS["granite-moe-1b-a400m"])
    g_specs = param_specs(ARCHS["granite-moe-1b-a400m"], g_params, rules)
    k_params, _ = abstract_train_state(ARCHS["grok-1-314b"])
    k_specs = param_specs(ARCHS["grok-1-314b"], k_params, rules)

    def moe_up_spec(specs):
        flat = jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P))[0]
        return [s for p, s in flat
                if any(getattr(e, "key", "") == "w_up" for e in p)
                and len(s) == 4][0]   # stacked (L, E, D, F)

    assert moe_up_spec(g_specs)[1] == "model"       # EP on experts
    assert moe_up_spec(k_specs)[1] is None          # 8 experts don't fit
    assert moe_up_spec(k_specs)[3] == "model"       # → TP on d_ff
