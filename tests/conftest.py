"""Shared fixtures. Deliberately does NOT set
--xla_force_host_platform_device_count: tests must see the real host
device (nothing in-tree sets the 512-device override since the
launch/dryrun retirement).
Distributed tests spawn subprocesses with their own flags."""

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _seed_numpy():
    np.random.seed(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
