"""repro.dist tests: every metric against the scipy pdist oracle
(property-style sweeps over odd/non-tile-multiple shapes, zero rows with
the pinned 0/0 conventions), the Pallas pairwise kernel against its _ref
across awkward tile shapes, the fused hoist accumulators against
square-matrix recomputation, the condensed-backed operator against the
square operator, and the Workspace.from_features acceptance battery —
including the "no n×n square on the matrix-free path" guarantee, cache
refresh()/generation semantics, the eigh lower-k coords serving, and the
shared non-finite admission checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from scipy.spatial.distance import pdist, squareform

from repro.api import ExecConfig, Workspace
from repro.api.config import _KNOWN_METRICS
from repro.core import (CenteredGramOperator, CondensedCenteredGramOperator,
                        DistanceMatrix, pcoa)
from repro.dist import (METRICS, condensed_size, get_metric,
                        pairwise_condensed, pairwise_distances)
from repro.kernels.pairwise_ops import pairwise_panel_pallas
from repro.kernels.pairwise_ref import pairwise_ref

KEY = jax.random.PRNGKey(7)


def _table(seed, n, d, nonneg=True):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d))
    if nonneg:
        x = np.abs(x)
    # sprinkle exact zeros so jaccard/canberra exercise their guards
    x[rng.random(size=x.shape) < 0.2] = 0.0
    return x.astype(np.float32)


# --------------------------------------------------------------------------
# metrics vs the scipy oracle
# --------------------------------------------------------------------------
@pytest.mark.parametrize("metric", sorted(METRICS))
@pytest.mark.parametrize("n,d", [(23, 17), (64, 5), (7, 33), (16, 16)])
def test_metric_matches_pdist(metric, n, d):
    """Acceptance: every metric ≤ 1e-5 off scipy's float64 pdist on
    random fp32 tables, including odd / non-tile-multiple n and d."""
    x = _table(0, n, d)
    got = np.asarray(pairwise_distances(x, metric, out="condensed",
                                        block=16, feature_block=8))
    want = pdist(x.astype(np.float64), metric)
    assert got.shape == (condensed_size(n),)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("metric", sorted(METRICS))
def test_zero_row_conventions(metric):
    """Pinned degenerate-pair conventions: two all-zero samples are at
    distance 0 for EVERY metric — including Bray–Curtis, where scipy
    returns NaN for the 0/0 denominator (documented in repro.dist.metrics)
    — and a zero row never produces non-finite distances."""
    x = _table(1, 12, 9)
    x[0] = 0.0
    x[5] = 0.0
    sq = np.asarray(pairwise_distances(x, metric, block=8, feature_block=4))
    assert sq[0, 5] == 0.0 and sq[5, 0] == 0.0
    assert np.all(np.isfinite(sq))
    # non-degenerate pairs still match scipy
    want = squareform(pdist(x.astype(np.float64), metric))
    mask = np.ones_like(sq, dtype=bool)
    mask[0, 5] = mask[5, 0] = False        # the 0/0 pair (scipy: NaN)
    np.testing.assert_allclose(sq[mask], want[mask], rtol=1e-5, atol=1e-5)


def test_square_output_is_symmetric_hollow_and_validates():
    x = _table(2, 21, 6)
    sq = np.asarray(pairwise_distances(x, "braycurtis", block=8))
    assert np.array_equal(sq, sq.T)
    assert np.all(np.diag(sq) == 0.0)
    DistanceMatrix(sq)                     # fused validation passes


def test_get_metric_coercion_and_config_registry_sync():
    assert get_metric("euclidean") is METRICS["euclidean"]
    assert get_metric(METRICS["jaccard"]) is METRICS["jaccard"]
    with pytest.raises(ValueError, match="unknown metric"):
        get_metric("chebyshev")
    with pytest.raises(TypeError):
        get_metric(42)
    # ExecConfig's literal metric list (it imports nothing from repro)
    # must stay in sync with the live registry
    assert tuple(sorted(METRICS)) == tuple(sorted(_KNOWN_METRICS))
    with pytest.raises(ValueError, match="unknown metric"):
        ExecConfig(metric="chebyshev")
    with pytest.raises(ValueError):
        ExecConfig(pairwise_impl="cuda")
    with pytest.raises(ValueError):
        ExecConfig(feature_block=0)


# --------------------------------------------------------------------------
# the Pallas kernel vs its oracle / the xla fallback
# --------------------------------------------------------------------------
@pytest.mark.parametrize("metric", sorted(METRICS))
@pytest.mark.parametrize("n,d,block,fb", [(30, 11, 8, 4), (17, 7, 16, 16),
                                          (32, 12, 8, 5)])
def test_pairwise_kernel_matches_ref(metric, n, d, block, fb):
    """Acceptance: the Pallas pairwise kernel agrees with the pure-jnp
    _ref across non-multiple tile shapes (padding exactness)."""
    x = jnp.asarray(_table(3, n, d))
    panel = x[:10]
    got = pairwise_panel_pallas(panel, x, metric=get_metric(metric),
                                block_n=block, feature_block=fb)
    want = pairwise_ref(panel, x, metric)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_driver_impls_agree(impl):
    x = _table(4, 27, 13)
    got = np.asarray(pairwise_distances(x, "canberra", out="condensed",
                                        block=8, feature_block=4,
                                        impl=impl))
    want = pdist(x.astype(np.float64), "canberra")
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# fused hoist accumulators
# --------------------------------------------------------------------------
def test_fused_hoists_match_square_recomputation():
    """The driver's tile-accumulated operator means / condensed moments
    equal what CenteredGramOperator / condensed_moments derive from the
    materialized square."""
    x = _table(5, 33, 9)
    prod = pairwise_condensed(x, "braycurtis", block=8, feature_block=4)
    sq = np.asarray(pairwise_distances(x, "braycurtis", block=8,
                                       feature_block=4)).astype(np.float64)
    rm = -0.5 * np.mean(sq * sq, axis=1)
    np.testing.assert_allclose(np.asarray(prod["row_means"]), rm,
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(float(prod["global_mean"]), rm.mean(),
                               rtol=1e-5, atol=1e-8)
    flat = squareform(sq, checks=False)
    centered = flat - flat.mean()
    np.testing.assert_allclose(float(prod["norm"]),
                               np.linalg.norm(centered), rtol=1e-4)
    np.testing.assert_allclose(float(prod["mean"]), flat.mean(), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(prod["condensed"]), flat,
                               rtol=1e-5, atol=1e-6)


def test_condensed_operator_matches_square_operator():
    """matvec + trace parity: the condensed-backed operator is the same
    linear map as the square-backed one."""
    x = _table(6, 37, 8)
    prod = pairwise_condensed(x, "euclidean", block=16)
    op_c = CondensedCenteredGramOperator.from_production(prod, block=16)
    sq = pairwise_distances(x, "euclidean", block=16)
    op_s = CenteredGramOperator.from_distance(jnp.asarray(sq), block=16)
    v = jnp.asarray(_table(7, 37, 3, nonneg=False))
    np.testing.assert_allclose(np.asarray(op_c.matvec(v)),
                               np.asarray(op_s.matvec(v)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(op_c.trace()), float(op_s.trace()),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(op_c.to_square()),
                               np.asarray(sq), rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------
# Workspace.from_features — the fused session
# --------------------------------------------------------------------------
def test_from_features_matrix_free_battery_never_builds_square():
    """Acceptance: from_features → pcoa → permanova (+ permdisp, anosim)
    completes without ever allocating an n×n square distance matrix on
    the matrix-free path."""
    x = _table(8, 40, 10)
    g = np.arange(40) % 4
    ws = Workspace.from_features(x, metric="braycurtis")
    ws.pcoa(dimensions=5)
    ws.permanova(g, permutations=49, key=KEY)
    ws.permdisp(g, permutations=49, key=KEY, dimensions=5)
    ws.anosim(g, permutations=49, key=KEY)
    assert "square" not in ws.cache
    assert ws._dm is None                   # the square was never touched
    # the production ran exactly once, and every later analysis reused it
    assert ws.cache.build_count("condensed") == 1
    assert ws.cache.build_count("dist_means") == 1
    assert ws.cache.build_count("operator") == 1
    # a second battery builds nothing new
    before = dict(ws.cache.misses)
    ws.pcoa(dimensions=5)
    ws.permanova(g, permutations=49, key=KEY)
    assert dict(ws.cache.misses) == before


def test_from_features_matches_square_workspace():
    """The fused session answers the same questions as a square-backed
    session over the identical distances (operator-form PERMANOVA and
    condensed-ranked ANOSIM vs their materialized twins)."""
    x = _table(9, 36, 8)
    g = np.arange(36) % 3
    ws = Workspace.from_features(x, metric="braycurtis")
    sq = pairwise_distances(x, "braycurtis")
    ws2 = Workspace(sq)

    a = ws.pcoa(dimensions=4)
    b = ws2.pcoa(dimensions=4)
    np.testing.assert_allclose(np.asarray(a.eigenvalues),
                               np.asarray(b.eigenvalues),
                               rtol=1e-3, atol=1e-5)
    pa = ws.permanova(g, permutations=99, key=KEY)
    pb = ws2.permanova(g, permutations=99, key=KEY)
    np.testing.assert_allclose(pa.statistic, pb.statistic, rtol=1e-4)
    assert abs(pa.p_value - pb.p_value) <= 2.5 / 100   # same null, fp jitter
    ra = ws.anosim(g, permutations=49, key=KEY)
    rb = ws2.anosim(g, permutations=49, key=KEY)
    assert ra.statistic == rb.statistic and ra.p_value == rb.p_value
    # the mantel family works too — fully condensed, no square demanded
    m = ws.mantel(ws2, permutations=49, key=KEY)
    assert m.statistic == pytest.approx(1.0, abs=1e-5)
    assert "square" not in ws.cache
    assert ws._dm is None


def test_mantel_all_sides_stay_square_free():
    """EVERY side of (partial) Mantel stays condensed: the permuted side's
    gathers go through closed-form triangle indexing (no square x), the
    fixed sides ride in as condensed hat vectors (no square y/z), and
    the x-side moments consume the production's fused norm scalar."""
    x = _table(20, 20, 6)
    ws_x = Workspace.from_features(x, metric="euclidean")
    ws_y = Workspace.from_features(x + 0.1, metric="euclidean")
    ws_z = Workspace.from_features(_table(21, 20, 6), metric="euclidean")
    ws_x.mantel(ws_y, permutations=19, key=KEY)
    ws_x.partial_mantel(ws_y, ws_z, permutations=19, key=KEY)
    assert "square" not in ws_x.cache and ws_x._dm is None
    assert "square" not in ws_y.cache and ws_y._dm is None
    assert "square" not in ws_z.cache and ws_z._dm is None
    # moments() consumed the fused production scalars, no re-reduction
    means = ws_y.cache.get("dist_means", lambda: None)
    assert float(ws_y.moments()["norm"]) == float(means["norm"])
    hat = np.asarray(ws_y.moments()["hat"])
    np.testing.assert_allclose(np.linalg.norm(hat), 1.0, rtol=1e-4)
    np.testing.assert_allclose(hat.sum(), 0.0, atol=1e-4)


def test_condensed_operator_rejects_overflow_n():
    """int32 triangle indexing is exact only to n = 46340 — larger n must
    refuse loudly instead of clamping wrapped gather indices."""
    with pytest.raises(ValueError, match="int32"):
        CondensedCenteredGramOperator(
            jnp.zeros((3,)), jnp.zeros((50000,)), jnp.float32(0.0), 50000)


def test_from_features_pallas_production_parity():
    x = _table(10, 20, 7)
    g = np.arange(20) % 2
    cfg = ExecConfig(pairwise_impl="pallas", block=8, feature_block=4)
    ws = Workspace.from_features(x, metric="cityblock", config=cfg)
    r = ws.permanova(g, permutations=49, key=KEY)
    r2 = Workspace.from_features(x, metric="cityblock").permanova(
        g, permutations=49, key=KEY)
    np.testing.assert_allclose(r.statistic, r2.statistic, rtol=1e-5)
    assert r.p_value == r2.p_value


def test_from_features_respects_config_metric_default():
    x = _table(11, 10, 5)
    ws = Workspace.from_features(x, config=ExecConfig(metric="euclidean"))
    assert ws._metric.name == "euclidean"
    got = np.asarray(ws.condensed())
    np.testing.assert_allclose(got, pdist(x.astype(np.float64)),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# refresh() — cache invalidation
# --------------------------------------------------------------------------
def test_refresh_yields_new_answers_and_rebuilds_once():
    """Satellite acceptance: after refresh(new_dm) the session returns the
    NEW matrix's answers and re-runs each hoist exactly once."""
    x1, x2 = _table(12, 24, 6), _table(13, 24, 6)
    sq1 = pairwise_distances(x1, "euclidean")
    sq2 = pairwise_distances(x2, "euclidean")
    g = np.arange(24) % 3

    ws = Workspace(sq1)
    old = ws.permanova(g, permutations=49, key=KEY)
    ws.pcoa(dimensions=4)
    assert ws.cache.build_count("gram") == 1

    ws.refresh(sq2)
    assert ws.generation == 1
    assert len(ws.cache) == 0               # every hoist dropped
    new = ws.permanova(g, permutations=49, key=KEY)
    ref = Workspace(sq2).permanova(g, permutations=49, key=KEY)
    assert new.statistic == ref.statistic and new.p_value == ref.p_value
    assert new.statistic != old.statistic
    assert ws.cache.build_count("gram") == 1      # re-ran exactly once
    ws.permanova(g, permutations=49, key=KEY)
    assert ws.cache.build_count("gram") == 1      # ...and then cached


def test_refresh_feature_backed_and_noarg():
    x = _table(14, 18, 5)
    ws = Workspace.from_features(x, metric="braycurtis")
    r0 = ws.pcoa(dimensions=3)
    ws.dm                                        # force the lazy square
    assert "square" in ws.cache

    ws.refresh()                                  # no-arg: caches only
    assert ws.generation == 1 and len(ws.cache) == 0
    assert ws._dm is None                         # derived square dropped
    r1 = ws.pcoa(dimensions=3)
    np.testing.assert_array_equal(np.asarray(r0.eigenvalues),
                                  np.asarray(r1.eigenvalues))
    assert ws.cache.build_count("condensed") == 1

    ws.refresh(features=x * 3.0)                  # new table, same metric
    assert ws.generation == 2 and ws._metric.name == "braycurtis"
    r2 = ws.pcoa(dimensions=3)
    assert ws.cache.build_count("condensed") == 1
    ref = Workspace.from_features(x * 3.0, metric="braycurtis").pcoa(
        dimensions=3)
    np.testing.assert_array_equal(np.asarray(r2.eigenvalues),
                                  np.asarray(ref.eigenvalues))
    with pytest.raises(ValueError, match="not both"):
        ws.refresh(np.eye(3) * 0.0, features=x)


# --------------------------------------------------------------------------
# coords cache: lower-k served from a higher-k eigh solution
# --------------------------------------------------------------------------
def test_eigh_lower_k_served_from_higher_k():
    """Satellite acceptance: a lower-k eigh request slices the cached
    higher-k solution — a HIT on the higher-k entry, no new solve."""
    dm = pairwise_distances(_table(15, 30, 6), "euclidean")
    ws = Workspace(dm)
    full = ws.pcoa(dimensions=8, method="eigh")
    assert ws.cache.build_count("gram") == 1
    hits_before = ws.cache.hits[("coords", 8, "eigh", None)]

    low = ws.pcoa(dimensions=3, method="eigh")
    assert ws.cache.hits[("coords", 8, "eigh", None)] == hits_before + 1
    assert ws.cache.build_count("gram") == 1      # no re-centering either
    np.testing.assert_array_equal(np.asarray(low.coordinates),
                                  np.asarray(full.coordinates[:, :3]))
    np.testing.assert_array_equal(np.asarray(low.eigenvalues),
                                  np.asarray(full.eigenvalues[:3]))
    np.testing.assert_array_equal(
        np.asarray(low.proportion_explained),
        np.asarray(full.proportion_explained[:3]))
    # and it matches a direct lower-k solve bitwise
    direct = Workspace(dm).pcoa(dimensions=3, method="eigh")
    np.testing.assert_array_equal(np.asarray(low.coordinates),
                                  np.asarray(direct.coordinates))

    # repeats hit the lower-k entry itself
    ws.pcoa(dimensions=3, method="eigh")
    assert ws.cache.counts(("coords", 3, "eigh", None))[0] >= 1
    # fsvd must NOT be sliced (sketch width is k-dependent)
    ws.pcoa(dimensions=6)
    before = dict(ws.cache.misses)
    ws.pcoa(dimensions=2)
    assert dict(ws.cache.misses) != before        # a genuine new solve


# --------------------------------------------------------------------------
# non-finite rejection — the shared admission check
# --------------------------------------------------------------------------
def test_workspace_rejects_non_finite():
    bad = np.asarray(pairwise_distances(_table(16, 12, 5),
                                        "euclidean")).copy()
    bad[2, 7] = np.nan
    bad[7, 2] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        Workspace(bad)
    with pytest.raises(ValueError, match="non-finite"):
        Workspace(bad, validate=False)     # the opt-out doesn't skip it
    with pytest.raises(ValueError, match="non-finite"):
        Workspace(DistanceMatrix(bad, _skip_validation=True))


def test_pcoa_rejects_non_finite():
    bad = np.zeros((8, 8), dtype=np.float32)
    bad[1, 3] = bad[3, 1] = np.inf
    with pytest.raises(ValueError, match="non-finite"):
        pcoa(DistanceMatrix(bad, _skip_validation=True), dimensions=3)


def test_from_features_rejects_non_finite_table():
    x = _table(17, 9, 4)
    x[4, 2] = np.nan
    with pytest.raises(ValueError, match="feature table"):
        Workspace.from_features(x)
    with pytest.raises(ValueError, match="feature table"):
        Workspace.from_features(_table(18, 9, 4)).refresh(features=x)


def test_operator_only_pcoa_paths():
    """dm=None is the fully matrix-free entry — and only that."""
    prod = pairwise_condensed(_table(19, 16, 5), "euclidean", block=8)
    op = CondensedCenteredGramOperator.from_production(prod, block=8)
    r = pcoa(None, dimensions=3, operator=op)
    assert r.coordinates.shape == (16, 3)
    with pytest.raises(ValueError, match="matrix-free"):
        pcoa(None, dimensions=3, method="eigh", operator=op)
    with pytest.raises(ValueError):
        pcoa(None, dimensions=3)
