"""Runtime substrate tests: data determinism, checkpoint fault-tolerance
protocol, straggler monitor, optimizer behaviour, loss learnability."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import TokenPipeline
from repro.data.distance import DistanceTileStream
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state, lr_schedule
from repro.runtime.monitor import StepMonitor


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------
def test_pipeline_deterministic_by_step():
    p1 = TokenPipeline(vocab=97, seq_len=16, global_batch=4, seed=3)
    p2 = TokenPipeline(vocab=97, seq_len=16, global_batch=4, seed=3)
    b1, b2 = p1.batch(7), p2.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(np.asarray(p1.batch(8)["tokens"]),
                              np.asarray(b1["tokens"]))


def test_pipeline_host_sharding_partitions_global_batch():
    full = TokenPipeline(vocab=97, seq_len=8, global_batch=8, seed=1)
    parts = [TokenPipeline(vocab=97, seq_len=8, global_batch=8, seed=1,
                           process_index=i, process_count=4) for i in range(4)]
    got = np.concatenate([np.asarray(p.batch(5)["tokens"]) for p in parts])
    np.testing.assert_array_equal(got, np.asarray(full.batch(5)["tokens"]))


def test_pipeline_targets_are_shifted_tokens():
    p = TokenPipeline(vocab=31, seq_len=12, global_batch=2, seed=0)
    b = p.batch(0)
    # targets[t] is the next token of the same underlying stream
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["targets"][:, :-1]))


def test_pipeline_structure_is_learnable():
    """Structured mode: > 60% of transitions follow the affine rule."""
    p = TokenPipeline(vocab=101, seq_len=256, global_batch=2, seed=0,
                      noise=0.1)
    b = p.batch(0)
    toks = np.asarray(b["tokens"][0])
    follows = np.mean((31 * toks[:-1] + 17) % 101 == toks[1:])
    assert follows > 0.6


def test_distance_tile_stream_consistency():
    ds = DistanceTileStream(n=70, tile=32, seed=5)
    dense = np.asarray(ds.dense())
    assert dense.shape == (70, 70)
    np.testing.assert_allclose(dense, dense.T, atol=1e-5)
    np.testing.assert_allclose(np.diag(dense), 0.0, atol=1e-6)
    t = np.asarray(ds.tile_at(32, 0))
    np.testing.assert_allclose(t, dense[32:64, 0:32], atol=1e-5)


# --------------------------------------------------------------------------
# checkpoint manager
# --------------------------------------------------------------------------
def _tree(seed):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16)},
            "step": jnp.asarray(seed)}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(1)
    mgr.save(5, tree, metadata={"note": "x"})
    got, meta = mgr.restore(tree)
    assert meta["step"] == 5 and meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_atomicity_ignores_partial(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(1))
    # simulate a crash mid-save: tmp dir without manifest rename
    os.makedirs(tmp_path / "step_2.tmp" / "leaves")
    assert mgr.latest_step() == 1
    # ...and a renamed dir without manifest is also ignored
    os.makedirs(tmp_path / "step_3")
    assert mgr.latest_step() == 1


def test_checkpoint_prune_keeps_newest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_async_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(9, _tree(9), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 9


# --------------------------------------------------------------------------
# straggler monitor
# --------------------------------------------------------------------------
def test_monitor_flags_stragglers():
    m = StepMonitor(k=3.0, warmup=3)
    for i in range(6):
        m.record(i, 0.10)
    rec = m.record(6, 0.55)
    assert rec.straggler
    assert m.record(7, 0.11).straggler is False
    assert len(m.stragglers()) == 1
    s = m.summary()
    assert s["steps"] == 8 and s["stragglers"] == 1


def test_monitor_deadline():
    m = StepMonitor(deadline_factor=5.0)
    for i in range(4):
        m.record(i, 0.1)
    with pytest.raises(TimeoutError):
        m.check_deadline(1.0)
    m.check_deadline(0.3)     # under deadline: fine


def test_monitor_deadline_unbounded_until_first_step():
    # no completed steps -> no median -> the watchdog must not fire
    m = StepMonitor(deadline_factor=5.0)
    assert m.deadline() == float("inf")
    m.check_deadline(1e9)


def test_monitor_stop_before_start_is_a_clear_error():
    m = StepMonitor()
    with pytest.raises(RuntimeError, match="before start"):
        m.stop(0)
    # and the failed stop leaves the monitor usable
    m.start()
    rec = m.stop(0)
    assert rec.seconds >= 0.0 and not rec.straggler


def test_monitor_live_start_stop_records_spans():
    m = StepMonitor(k=3.0, warmup=1)
    for i in range(3):
        m.start()
        m.stop(i)
    assert [r.step for r in m.records] == [0, 1, 2]
    # refolded on the span stream: each step is a phase="step" span on
    # the monitor's tracer, visible to the obs export surface
    assert m.tracer.count("step") == 3
    assert m.tracer.total("step") == pytest.approx(
        sum(r.seconds for r in m.records))


def test_monitor_deadline_emits_structured_escalation():
    # the raise carries a structured EscalationRecord (and appends it to
    # monitor.escalations) so the serve retry path consumes data, not a
    # message string
    from repro.runtime.monitor import DeadlineExceeded

    m = StepMonitor(deadline_factor=5.0)
    for i in range(4):
        m.record(i, 0.1)
    with pytest.raises(DeadlineExceeded) as ei:
        m.check_deadline(1.0, reason="test stall")
    rec = ei.value.record
    assert rec.elapsed_s == 1.0
    assert rec.deadline_s == pytest.approx(0.5)
    assert rec.median_s == pytest.approx(0.1)
    assert rec.reason == "test stall"
    assert m.escalations == [rec]
    assert m.summary()["escalations"] == 1
    # DeadlineExceeded IS a TimeoutError: existing callers keep working
    assert isinstance(ei.value, TimeoutError)


def test_monitor_escalate_unconditional_before_median():
    # a first-tile stall has no median to arm the deadline; escalate()
    # must fire anyway, abort the open span, and keep it out of the
    # straggler baseline
    m = StepMonitor(deadline_factor=5.0)
    assert m.deadline() == float("inf")
    m.start()                                # a step opens ... and stalls
    rec = m.escalate("stalled before any median")
    assert rec.aborted_open_step
    assert m._open is None                   # usable again immediately
    assert m.records == []                   # aborted: not scored
    assert m.median != m.median              # still no median (NaN)
    assert m.escalations == [rec]
    # the aborted attempt is still visible on the tracer timeline
    spans = [s for s in m.tracer.to_dicts()
             if s["attrs"].get("aborted")]
    assert len(spans) == 1


def test_monitor_abort_noop_when_idle():
    m = StepMonitor()
    m.abort()                                # no open step: no-op
    m.start()
    m.abort("giving up")
    m.start()                                # reusable after abort
    assert m.stop(0).seconds >= 0.0


def test_monitor_summary_carries_histogram_percentiles():
    m = StepMonitor(warmup=100)                # no straggler flagging
    for i in range(20):
        m.record(i, 0.01 * (1 + (i % 5)))      # 0.01 .. 0.05
    s = m.summary()
    assert s["steps"] == 20
    # the new percentile dialect (obs.metrics.Histogram) sits beside
    # the exact median/p90 kept for earlier-report compatibility
    assert 0.01 <= s["p50_s"] <= 0.05
    assert s["p50_s"] <= s["p95_s"] <= s["p99_s"] <= 0.05
    assert s["median_s"] == pytest.approx(0.03)
    assert s["stragglers"] == 0


def test_monitor_shares_a_session_tracer():
    from repro.obs.trace import Tracer
    t = Tracer()
    m = StepMonitor(tracer=t)
    m.record(0, 0.25)
    (span,) = t.spans
    assert span.phase == "step" and span.attrs["step"] == 0
    assert span.duration == pytest.approx(0.25)
    # the span export carries the straggler flag the monitor computed
    assert span.attrs["straggler"] is False


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------
def test_lr_schedule_shape():
    opt = AdamWConfig(peak_lr=1e-2, warmup_steps=10, decay_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_schedule(opt, jnp.asarray(s))) for s in
           (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert abs(lrs[2] - 1e-2) < 1e-9          # peak at warmup end
    assert lrs[3] < lrs[2]
    assert abs(lrs[4] - 1e-3) < 1e-6          # floor = ratio · peak


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = AdamWConfig(peak_lr=0.1, warmup_steps=1, decay_steps=400,
                      weight_decay=0.0, clip_norm=10.0)
    state = init_opt_state(params)

    def loss(p):
        return jnp.sum((p["w"] - jnp.asarray([1.0, 2.0])) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, opt)
    assert float(loss(params)) < 1e-2


def test_adamw_grad_clipping_bounds_update():
    params = {"w": jnp.zeros(3)}
    opt = AdamWConfig(peak_lr=1.0, warmup_steps=0, decay_steps=10,
                      clip_norm=1.0, weight_decay=0.0)
    state = init_opt_state(params)
    g = {"w": jnp.asarray([1e6, 0.0, 0.0])}
    _, _, metrics = adamw_update(g, state, params, opt)
    assert float(metrics["grad_norm"]) > 1e5   # reported raw norm
