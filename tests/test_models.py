"""Per-arch smoke tests (harness deliverable (f)) + decode/forward parity.

Every assigned architecture instantiates its reduced same-family config,
runs one forward/train step on CPU, and asserts output shapes + no NaNs.
The parity tests are the strong correctness check: prefill + token-by-
token decode must reproduce the full forward pass — this exercises KV
caches, ring buffers (sliding window), RG-LRU states, SSD states and the
enc-dec cross cache against the same math.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SMOKES, SHAPES
from repro.models import encdec as encdec_mod
from repro.models import transformer as tf_mod
from repro.models.layers import lm_logits
from repro.optim.adamw import AdamWConfig
from repro.runtime.train import build_train_step_fn, init_train_state
from repro.optim.adamw import init_opt_state

ALL_ARCHS = sorted(ARCHS.keys())


def _inputs(cfg, key, b, s):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    extras = {}
    if cfg.is_encdec:
        extras["frames"] = jax.random.normal(
            jax.random.fold_in(key, 1),
            (b, max(s // cfg.enc_len_ratio, 1), cfg.frontend_dim))
    if cfg.frontend == "vision":
        extras["patches"] = jax.random.normal(
            jax.random.fold_in(key, 2), (b, cfg.n_patches, cfg.frontend_dim))
    return toks, extras


# --------------------------------------------------------------------------
# smoke: one forward + one train step per arch
# --------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = SMOKES[arch]
    b, s = 2, 16
    key = jax.random.PRNGKey(0)
    params, opt_state = init_train_state(key, cfg)
    toks, extras = _inputs(cfg, key, b, s)

    if cfg.is_encdec:
        hidden, aux = encdec_mod.forward_train_encdec(
            params, extras["frames"], toks, cfg)
        expect_s = s
    else:
        hidden, aux = tf_mod.forward_train(
            params, toks, cfg, extra_embeds=extras.get("patches"))
        expect_s = s + (cfg.n_patches if cfg.frontend == "vision" else 0)
    assert hidden.shape == (b, expect_s, cfg.d_model)
    assert not bool(jnp.isnan(hidden).any())
    assert np.isfinite(float(aux))

    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1), **extras}
    cfg2 = dataclasses.replace(cfg, microbatches=1)
    step = build_train_step_fn(cfg2, AdamWConfig(warmup_steps=1,
                                                 decay_steps=10), None)
    params2, opt2, metrics = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x[0].astype(jnp.float32)
                                               - x[1].astype(jnp.float32)))),
        jax.tree.map(lambda a, b_: (a, b_), params, params2), 0.0)
    assert delta > 0.0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_layer_types_and_counts(arch):
    """The FULL configs (exercised via dry-run) are structurally sound."""
    cfg = ARCHS[arch]
    lt = cfg.layer_types()
    assert len(lt) == cfg.n_layers
    assert cfg.param_count() > 0
    assert cfg.active_param_count() <= cfg.param_count()
    for sname, shape in SHAPES.items():
        if sname == "long_500k":
            assert cfg.supports_shape(shape) == cfg.subquadratic
        else:
            assert cfg.supports_shape(shape)


# --------------------------------------------------------------------------
# decode == forward parity
# --------------------------------------------------------------------------
PARITY_ARCHS = ["qwen3-8b", "llama3.2-3b", "qwen1.5-4b", "grok-1-314b",
                "granite-moe-1b-a400m", "mamba2-1.3b", "nemotron-4-340b"]


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_decode_matches_forward(arch):
    cfg = SMOKES[arch]
    if cfg.n_experts:
        # capacity dropping is sequence-length dependent (train drops
        # over-capacity tokens, a single decoded token never drops) —
        # make the router dropless so the parity compares the same math
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    b, s, split = 2, 12, 6
    key = jax.random.PRNGKey(1)
    params, _ = init_train_state(key, cfg)
    toks, _ = _inputs(cfg, key, b, s)

    hidden_full, _ = tf_mod.forward_train(params, toks, cfg)

    h_pre, cache = tf_mod.prefill(params, toks[:, :split], cfg, max_len=s)
    np.testing.assert_allclose(np.asarray(h_pre),
                               np.asarray(hidden_full[:, :split]),
                               rtol=2e-3, atol=2e-3)
    for t in range(split, s):
        h_t, cache = tf_mod.decode_step(params, toks[:, t:t + 1], cache, cfg)
        np.testing.assert_allclose(
            np.asarray(h_t[:, 0]), np.asarray(hidden_full[:, t]),
            rtol=2e-3, atol=2e-3, err_msg=f"position {t}")


def test_decode_matches_forward_sliding_window():
    """recurrentgemma: ring-buffer local attention + RG-LRU state parity,
    with the sequence LONGER than the window so eviction is exercised."""
    cfg = SMOKES["recurrentgemma-9b"]
    assert cfg.window == 16
    b, s, split = 2, 24, 8
    key = jax.random.PRNGKey(2)
    params, _ = init_train_state(key, cfg)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)

    hidden_full, _ = tf_mod.forward_train(params, toks, cfg)
    h_pre, cache = tf_mod.prefill(params, toks[:, :split], cfg, max_len=s)
    np.testing.assert_allclose(np.asarray(h_pre),
                               np.asarray(hidden_full[:, :split]),
                               rtol=2e-3, atol=2e-3)
    for t in range(split, s):
        h_t, cache = tf_mod.decode_step(params, toks[:, t:t + 1], cache, cfg)
        np.testing.assert_allclose(
            np.asarray(h_t[:, 0]), np.asarray(hidden_full[:, t]),
            rtol=3e-3, atol=3e-3, err_msg=f"position {t}")


def test_decode_matches_forward_encdec():
    cfg = SMOKES["seamless-m4t-medium"]
    b, s, split = 2, 10, 5
    key = jax.random.PRNGKey(3)
    params, _ = init_train_state(key, cfg)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    frames = jax.random.normal(jax.random.fold_in(key, 1),
                               (b, 4, cfg.frontend_dim))

    hidden_full, _ = encdec_mod.forward_train_encdec(params, frames, toks, cfg)
    h_pre, cache = encdec_mod.prefill_encdec(params, frames, toks[:, :split],
                                             cfg, max_len=s)
    np.testing.assert_allclose(np.asarray(h_pre),
                               np.asarray(hidden_full[:, :split]),
                               rtol=2e-3, atol=2e-3)
    for t in range(split, s):
        h_t, cache = encdec_mod.decode_step_encdec(params, toks[:, t:t + 1],
                                                   cache, cfg)
        np.testing.assert_allclose(
            np.asarray(h_t[:, 0]), np.asarray(hidden_full[:, t]),
            rtol=2e-3, atol=2e-3, err_msg=f"position {t}")


def test_decode_matches_forward_vlm():
    """phi-3-vision: patch positions prefix the sequence."""
    cfg = SMOKES["phi-3-vision-4.2b"]
    b, s, split = 2, 10, 5
    key = jax.random.PRNGKey(4)
    params, _ = init_train_state(key, cfg)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    patches = jax.random.normal(jax.random.fold_in(key, 1),
                                (b, cfg.n_patches, cfg.frontend_dim))
    total = s + cfg.n_patches

    hidden_full, _ = tf_mod.forward_train(params, toks, cfg,
                                          extra_embeds=patches)
    h_pre, cache = tf_mod.prefill(params, toks[:, :split], cfg,
                                  extra_embeds=patches, max_len=total)
    for t in range(split, s):
        h_t, cache = tf_mod.decode_step(params, toks[:, t:t + 1], cache, cfg)
        np.testing.assert_allclose(
            np.asarray(h_t[:, 0]),
            np.asarray(hidden_full[:, cfg.n_patches + t]),
            rtol=2e-3, atol=2e-3, err_msg=f"position {t}")


# --------------------------------------------------------------------------
# attention variants exercise their configured features
# --------------------------------------------------------------------------
def test_chunked_attention_matches_full():
    """The long-S query-chunked path equals single-pass attention."""
    from repro.models import attention as attn_mod
    cfg = dataclasses.replace(SMOKES["qwen3-8b"], attn_chunk=8)
    key = jax.random.PRNGKey(5)
    p = attn_mod.init_attn(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 4096, cfg.d_model),
                          jnp.float32) * 0.1
    x_small = x[:, :64]
    pos = jnp.broadcast_to(jnp.arange(64, dtype=jnp.int32), (2, 64))
    full, _ = attn_mod.attn_forward(p, x_small, pos, cfg)          # ≤2048 path
    cfg_chunk = dataclasses.replace(cfg, attn_chunk=16)
    # force the chunked path by making the threshold small
    q = attn_mod._project_q(p, x_small, pos, cfg)
    k, v = attn_mod._project_kv(p, x_small, pos, cfg)
    mask = attn_mod._causal_mask(64, 64)
    want = attn_mod._attend(q, k, v, mask, cfg)
    want = jnp.einsum("bshk,hkd->bsd", want, p["wo"])
    np.testing.assert_allclose(np.asarray(full), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_moe_aux_loss_balanced_router():
    """A uniform router gives aux ≈ 1 (the Switch loss optimum)."""
    from repro.models import moe as moe_mod
    cfg = SMOKES["granite-moe-1b-a400m"]
    key = jax.random.PRNGKey(6)
    p = moe_mod.init_moe(key, cfg)
    p = dict(p, router=jnp.zeros_like(p["router"]))   # perfectly uniform
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, cfg.d_model))
    y, aux = moe_mod.moe_ffn(p, x, cfg)
    assert y.shape == x.shape
    assert abs(float(aux) - 1.0) < 0.2


def test_decode_matches_forward_int8_kv_cache():
    """kv_quant: int8 cache + per-(b,t,head) scales — decode parity within
    quantization tolerance (§Perf Cell B, 2× memory-floor cut)."""
    cfg = dataclasses.replace(SMOKES["qwen3-8b"], kv_quant=True)
    b, s, split = 2, 12, 6
    key = jax.random.PRNGKey(1)
    params, _ = init_train_state(key, cfg)
    toks, _ = _inputs(cfg, key, b, s)
    hidden_full, _ = tf_mod.forward_train(params, toks, cfg)
    _, cache = tf_mod.prefill(params, toks[:, :split], cfg, max_len=s)
    assert cache["blocks"][0]["k"].dtype == jnp.int8
    for t in range(split, s):
        h_t, cache = tf_mod.decode_step(params, toks[:, t:t + 1], cache, cfg)
        np.testing.assert_allclose(
            np.asarray(h_t[:, 0]), np.asarray(hidden_full[:, t]),
            rtol=0.05, atol=0.05, err_msg=f"position {t}")
