"""``kernels.permute_reduce`` validation: both implementations (the
Pallas kernel and its lax.scan twin) against the eager square-roundtrip
``_ref`` oracle, across odd n, non-tile-multiple m and B, trailing
chunks, and both interpret modes — plus the engine-facing properties
(identity order, stacked invariant rows, int32 refusal)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distance_matrix import (condensed_index,
                                        random_distance_matrix,
                                        triangle_coords)
from repro.kernels import permute_reduce
from repro.kernels.permute_reduce_ref import permute_reduce_ref

KEY = jax.random.PRNGKey(7)


def _case(n, b_perms, s, seed=0):
    m = n * (n - 1) // 2
    xc = random_distance_matrix(jax.random.PRNGKey(seed), n).condensed_form()
    ys = jax.random.normal(jax.random.fold_in(KEY, seed), (s, m))
    orders = jnp.argsort(jax.random.bits(
        jax.random.fold_in(KEY, seed + 99), (b_perms, n),
        dtype=jnp.uint32), axis=-1)
    return xc, ys, orders


# --------------------------------------------------------------------------
# triangle geometry — the closed form IS the scipy layout
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n", [2, 3, 17, 64])
def test_triangle_coords_roundtrip(n):
    ii, jj = triangle_coords(n)
    iu = np.triu_indices(n, k=1)
    np.testing.assert_array_equal(np.asarray(ii), iu[0])
    np.testing.assert_array_equal(np.asarray(jj), iu[1])
    k = condensed_index(jnp.asarray(iu[0], jnp.int32),
                        jnp.asarray(iu[1], jnp.int32), n)
    np.testing.assert_array_equal(np.asarray(k), np.arange(iu[0].size))
    # symmetric in its arguments (lo/hi normalization)
    k_swapped = condensed_index(jnp.asarray(iu[1], jnp.int32),
                                jnp.asarray(iu[0], jnp.int32), n)
    np.testing.assert_array_equal(np.asarray(k_swapped), np.asarray(k))


# --------------------------------------------------------------------------
# parity vs the _ref oracle
# --------------------------------------------------------------------------
@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("n,b_perms,s,chunk", [
    (33, 5, 1, 64),     # odd n, m=528 → trailing chunk (528 % 64 != 0)
    (17, 7, 2, 32),     # odd n AND non-multiple B, stacked rows
    (40, 3, 3, 1024),   # chunk > m: single padded chunk
    (24, 8, 2, 100),    # chunk not a multiple of 8 (geometry snaps it)
])
def test_permute_reduce_matches_ref(impl, n, b_perms, s, chunk):
    xc, ys, orders = _case(n, b_perms, s, seed=n)
    got = permute_reduce(xc, ys, orders, impl=impl, chunk=chunk,
                         interpret=True if impl == "pallas" else None)
    want = permute_reduce_ref(xc, ys, orders)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_permute_reduce_impls_agree_and_auto_interpret():
    """interpret=None auto-resolves per backend (the interpreter on this
    container's CPU) and the two impls agree on identical inputs."""
    xc, ys, orders = _case(26, 6, 2, seed=1)
    a = permute_reduce(xc, ys, orders, impl="xla")
    b = permute_reduce(xc, ys, orders, impl="pallas")   # interpret=None
    c = permute_reduce(xc, ys, orders, impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(b), np.asarray(c))


def test_permute_reduce_identity_order_is_plain_dot():
    """The identity permutation reduces to <xc, ys[s]> exactly — the
    observed-statistic path of every condensed statistic."""
    n = 30
    xc, ys, _ = _case(n, 1, 2, seed=2)
    orders = jnp.arange(n, dtype=jnp.int32)[None, :]
    got = permute_reduce(xc, ys, orders, impl="xla")
    want = ys @ xc
    np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_permute_reduce_tiny_n_edges():
    """n=2 (m=1) and n=1 (m=0, empty triangle) don't crash or mis-shape."""
    out = permute_reduce(jnp.ones((1,)), jnp.full((1, 1), 2.0),
                         jnp.asarray([[0, 1], [1, 0]]), impl="xla")
    np.testing.assert_allclose(np.asarray(out), [[2.0, 2.0]])
    empty = permute_reduce(jnp.zeros((0,)), jnp.zeros((2, 0)),
                           jnp.zeros((3, 1), jnp.int32), impl="pallas",
                           interpret=True)
    assert empty.shape == (2, 3)
    np.testing.assert_array_equal(np.asarray(empty), 0.0)


def test_permute_reduce_validates():
    xc, ys, orders = _case(10, 2, 1, seed=3)
    with pytest.raises(ValueError, match="impl"):
        permute_reduce(xc, ys, orders, impl="cuda")
    with pytest.raises(ValueError, match="condensed length"):
        permute_reduce(xc[:-1], ys, orders)
    with pytest.raises(ValueError, match="ys must be"):
        permute_reduce(xc, ys[:, :-1], orders)
    # int32 triangle indexing refuses n past the exact bound, like
    # CondensedCenteredGramOperator
    big = jnp.zeros((2, 50000), jnp.int32)
    with pytest.raises(ValueError, match="int32"):
        permute_reduce(xc, ys, big)


def test_permute_reduce_precomputed_coords_match():
    """Passing hoisted (ii, jj) — what every statistic does — is
    bitwise the recomputed path."""
    xc, ys, orders = _case(21, 4, 1, seed=4)
    ii, jj = triangle_coords(21)
    a = permute_reduce(xc, ys, orders, ii, jj, impl="xla")
    b = permute_reduce(xc, ys, orders, impl="xla")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
