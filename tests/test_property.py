"""Property-based tests (hypothesis) on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis; skip on clean envs")
from hypothesis import given, settings, strategies as st

from repro.core.centering import (center_distance_matrix,
                                  center_distance_matrix_ref)
from repro.core.distance_matrix import random_distance_matrix
from repro.core.operators import CenteredGramOperator
from repro.core.validation import is_symmetric_and_hollow
from repro.kernels import center_distance_matrix_pallas, rmsnorm_pallas
from repro.kernels.rmsnorm_ref import rmsnorm_ref
from repro.optim.compression import dequantize_int8, quantize_int8

_settings = dict(max_examples=20, deadline=None)


@given(n=st.integers(4, 80), seed=st.integers(0, 2**30))
@settings(**_settings)
def test_centering_annihilates_means(n, seed):
    dm = random_distance_matrix(jax.random.PRNGKey(seed), n).data
    f = np.asarray(center_distance_matrix(dm))
    assert np.abs(f.mean(0)).max() < 1e-3
    assert np.abs(f.mean(1)).max() < 1e-3
    assert np.abs(f - f.T).max() < 1e-4


@given(n=st.integers(4, 64), seed=st.integers(0, 2**30))
@settings(**_settings)
def test_centering_idempotent_on_centered(n, seed):
    """Gower centering of an already-centered Gram matrix: applying the
    double-centering projector twice equals once (P A P is a projection)."""
    dm = random_distance_matrix(jax.random.PRNGKey(seed), n).data
    f1 = center_distance_matrix_ref(dm)
    # re-center f1's "distance" interpretation is nonsense; instead check
    # the projector identity directly: centering the matrix of sqrt(-2 f)
    # is out of domain, so verify P f1 P == f1 (f1 already row/col centered)
    n_ = f1.shape[0]
    ones = jnp.ones((n_, n_)) / n_
    p = jnp.eye(n_) - ones
    np.testing.assert_allclose(p @ f1 @ p, f1, atol=1e-3)


@given(n=st.integers(4, 48), seed=st.integers(0, 2**30),
       scale=st.floats(0.1, 10.0))
@settings(**_settings)
def test_centering_scales_quadratically(n, seed, scale):
    """D → sD implies F → s²F (E = -D²/2 is quadratic, centering linear)."""
    dm = random_distance_matrix(jax.random.PRNGKey(seed), n).data
    f1 = np.asarray(center_distance_matrix(dm))
    f2 = np.asarray(center_distance_matrix(dm * scale))
    np.testing.assert_allclose(f2, f1 * scale**2, rtol=2e-3, atol=2e-3)


@given(n=st.integers(4, 97), seed=st.integers(0, 2**30),
       k=st.integers(1, 12), block=st.sampled_from([8, 16, 32]),
       impl=st.sampled_from(["xla", "pallas"]))
@settings(**_settings)
def test_operator_matvec_equals_materialized_any_shape(n, seed, k, block,
                                                       impl):
    """CenteredGramOperator.matvec == center_distance_matrix(D) @ X to
    ≤1e-5 relative, across odd n (non-multiples of the block) and both
    matvec backends."""
    dm = random_distance_matrix(jax.random.PRNGKey(seed), n).data
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, k))
    op = CenteredGramOperator.from_distance(dm, block=block, impl=impl)
    want = np.asarray(center_distance_matrix(dm) @ x)
    got = np.asarray(op.matvec(x))
    scale = max(np.abs(want).max(), 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5 * scale)
    # and the hoisted trace is the materialized trace
    tr = float(jnp.trace(center_distance_matrix(dm)))
    assert abs(float(op.trace()) - tr) <= 1e-5 * max(abs(tr), 1.0)


@given(n=st.integers(4, 48), seed=st.integers(0, 2**30))
@settings(**_settings)
def test_pallas_center_equals_jnp_any_shape(n, seed):
    dm = random_distance_matrix(jax.random.PRNGKey(seed), n).data
    got = center_distance_matrix_pallas(dm, block_m=16, block_n=16)
    want = center_distance_matrix_ref(dm)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@given(n=st.integers(3, 40), seed=st.integers(0, 2**30),
       i=st.integers(0, 39), j=st.integers(0, 39))
@settings(**_settings)
def test_validation_detects_any_single_asymmetry(n, seed, i, j):
    i, j = i % n, j % n
    dm = random_distance_matrix(jax.random.PRNGKey(seed), n).data
    bad = dm.at[i, j].add(1.0)
    s, h = is_symmetric_and_hollow(bad)
    if i == j:
        assert bool(h) is False
    else:
        assert bool(s) is False


@given(seed=st.integers(0, 2**30), rows=st.integers(1, 9),
       d=st.sampled_from([8, 32, 128]), c=st.floats(0.5, 4.0))
@settings(**_settings)
def test_rmsnorm_scale_invariance(seed, rows, d, c):
    """rmsnorm(c·x) == rmsnorm(x) up to fp tolerance (for c > 0)."""
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (rows, d)) + 0.1
    w = jax.random.normal(kw, (d,)) * 0.1
    a = rmsnorm_pallas(x, w, block_rows=4)
    b = rmsnorm_pallas(x * c, w, block_rows=4)
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(a, rmsnorm_ref(x, w), rtol=1e-5, atol=1e-5)


@given(seed=st.integers(0, 2**30), shape=st.sampled_from([(8,), (4, 16)]))
@settings(**_settings)
def test_int8_quantization_error_bound(seed, shape):
    g = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), shape))
    q, scale = quantize_int8(jnp.asarray(g))
    back = np.asarray(dequantize_int8(q, scale))
    # max error is half a quantization step
    assert np.abs(back - g).max() <= float(scale) * 0.5 + 1e-7


@given(seed=st.integers(0, 2**30))
@settings(max_examples=10, deadline=None)
def test_error_feedback_is_lossless_in_expectation(seed):
    """Accumulated (quantized + error) over steps equals the true sum."""
    key = jax.random.PRNGKey(seed)
    true_sum = np.zeros(32, np.float32)
    sent_sum = np.zeros(32, np.float32)
    err = jnp.zeros(32)
    for t in range(8):
        g = jax.random.normal(jax.random.fold_in(key, t), (32,))
        true_sum += np.asarray(g)
        gf = g + err
        q, s = quantize_int8(gf)
        sent = dequantize_int8(q, s)
        err = gf - sent
        sent_sum += np.asarray(sent)
    # residual error is bounded by one quantization step, not accumulated
    assert np.abs(true_sum - sent_sum).max() <= float(s) + 1e-6


@given(n=st.integers(3, 48), seed=st.integers(0, 2**30))
@settings(**_settings)
def test_hoisted_norm_is_permutation_invariant(n, seed):
    """The §4.2 hoist is sound: a row/column permutation only reorders
    the condensed entries, so the hoisted mean/norm of the permuted
    matrix equal the ones computed once outside the loop — and the
    closed-form triangle gather produces exactly that reordering."""
    from repro.core.distance_matrix import condensed_index, triangle_coords
    from repro.core.mantel import condensed_moments_vec

    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    dm = random_distance_matrix(k1, n)
    xc = dm.condensed_form()
    order = jax.random.permutation(k2, n)
    ii, jj = triangle_coords(n)
    o = order.astype(jnp.int32)
    xp_c = xc[condensed_index(o[ii], o[jj], n)]  # permuted condensed
    # ...is the same multiset as the square roundtrip's condensed form
    want = dm.permute(np.asarray(order), condensed=True)
    np.testing.assert_allclose(np.asarray(xp_c), np.asarray(want),
                               rtol=0, atol=0)
    # ⇒ the hoisted moments are permutation-invariant (fp tolerance:
    # the reduction ORDER differs between the two layouts)
    a = condensed_moments_vec(xc)
    b = condensed_moments_vec(xp_c)
    np.testing.assert_allclose(float(a["norm"]), float(b["norm"]),
                               rtol=1e-4)
    assert abs(float(jnp.sum(b["hat"]))) < 1e-3
