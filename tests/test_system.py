"""End-to-end behaviour tests for the paper's system: launcher runs,
fault-tolerant restart drill, the full microbiome-style analysis
pipeline, and serving."""

import argparse
import os

import jax.numpy as jnp
import numpy as np

from repro.core import DistanceMatrix, mantel, pcoa
from repro.data.distance import DistanceTileStream
from repro.launch import serve as serve_launch
from repro.launch import train as train_launch


def _args(**kw):
    ap = train_launch.build_argparser()
    base = ["--arch", kw.pop("arch")]
    for k, v in kw.items():
        base += ([f"--{k.replace('_', '-')}"] if v == "" else
                 [f"--{k.replace('_', '-')}", str(v)])
    base.append("--smoke")
    return ap.parse_args(base)


def test_train_launcher_loss_decreases():
    """~100k-param model, structured data: loss must fall measurably."""
    res = train_launch.run(_args(arch="llama3.2-3b", steps=30, batch=8,
                                 seq=64, lr="3e-3"))
    first = np.mean(res["losses"][:5])
    last = np.mean(res["losses"][-5:])
    assert last < first - 0.2, (first, last)


def test_train_restart_is_seamless(tmp_path):
    """Kill-and-resume drill: 4+4 resumed steps ≡ 8 straight steps."""
    ck1 = str(tmp_path / "a")
    ck2 = str(tmp_path / "b")
    # decay_steps pinned to the full horizon so the LR schedule is
    # restart-invariant (the interrupted run must see the same schedule)
    r_full = train_launch.run(_args(arch="qwen3-8b", steps=8, batch=4,
                                    seq=32, ckpt_dir=ck1, ckpt_every=4,
                                    decay_steps=8))
    train_launch.run(_args(arch="qwen3-8b", steps=4, batch=4, seq=32,
                           ckpt_dir=ck2, ckpt_every=4, decay_steps=8))
    r_resumed = train_launch.run(_args(arch="qwen3-8b", steps=8, batch=4,
                                       seq=32, ckpt_dir=ck2, ckpt_every=4,
                                       decay_steps=8, resume=""))
    # identical data (step-keyed) + identical state ⇒ identical tail losses
    np.testing.assert_allclose(r_full["losses"][4:], r_resumed["losses"],
                               rtol=1e-4, atol=1e-4)


def test_serve_launcher_continuous_batching():
    res = serve_launch.run(argparse.Namespace(
        arch="llama3.2-3b", smoke=True, batch=2, requests=4,
        prompt_len=16, gen_len=8))
    assert res["requests"] == 4
    assert res["tokens"] == 4 * 8


def test_microbiome_pipeline_end_to_end():
    """The paper's full downstream pipeline: distance matrix (streamed)
    → validation → PCoA → Mantel against a perturbed matrix."""
    ds = DistanceTileStream(n=96, tile=32, seed=0, dim=4)
    dm = DistanceMatrix(ds.dense())            # validates (fused pass)
    res = pcoa(dm, dimensions=4, method="fsvd")
    assert res.coordinates.shape == (96, 4)
    ev = np.asarray(res.eigenvalues)
    assert (ev[:4] > 0).all()

    ds2 = DistanceTileStream(n=96, tile=32, seed=0, dim=4)
    noise = 0.01 * np.abs(np.random.default_rng(0).normal(size=(96, 96)))
    noise = np.triu(noise, 1)
    d2 = np.asarray(ds2.dense()) + noise + noise.T
    dm2 = DistanceMatrix(jnp.asarray(d2))
    stat, p, _ = mantel(dm, dm2, permutations=49)
    assert stat > 0.99
    assert p <= 0.04


def test_quickstart_example_runs():
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "quickstart", os.path.join(os.path.dirname(__file__), "..",
                                   "examples", "quickstart.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    out = mod.main(fast=True)
    assert out["pcoa_dims"] >= 2
    assert 0 < out["mantel_p"] <= 1
