"""Paper-core behaviour tests: centering (§4.1), mantel (§4.2),
validation (§4.3), pcoa end-to-end — optimized paths vs the originals
and vs scipy where applicable."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from scipy.stats import pearsonr as scipy_pearsonr

from repro.core import (DistanceMatrix, DistanceMatrixError, mantel,
                        mantel_ref, pcoa, random_distance_matrix)
from repro.core.centering import (center_distance_matrix,
                                  center_distance_matrix_blocked,
                                  center_distance_matrix_ref)
from repro.core.validation import (is_symmetric_and_hollow,
                                   is_symmetric_and_hollow_blocked,
                                   is_symmetric_and_hollow_ref)


# --------------------------------------------------------------------------
# centering
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n", [8, 65, 128])
def test_center_fused_equals_original(n):
    dm = random_distance_matrix(jax.random.PRNGKey(n), n).data
    np.testing.assert_allclose(center_distance_matrix(dm),
                               center_distance_matrix_ref(dm),
                               rtol=1e-5, atol=1e-5)


def test_center_blocked_equals_fused():
    dm = random_distance_matrix(jax.random.PRNGKey(0), 128).data
    np.testing.assert_allclose(center_distance_matrix_blocked(dm, block=32),
                               center_distance_matrix(dm),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,block", [(100, 32), (65, 64), (33, 64), (7, 4)])
def test_center_blocked_pads_non_multiple_n(n, block):
    """Regression: n % block != 0 must go through the *blocked* path (padded
    trailing block), not silently fall back to the unblocked one."""
    dm = random_distance_matrix(jax.random.PRNGKey(n), n).data
    got = center_distance_matrix_blocked(dm, block=block)
    assert got.shape == (n, n)
    np.testing.assert_allclose(got, center_distance_matrix(dm),
                               rtol=1e-5, atol=1e-5)


def test_centered_matrix_is_gower():
    """Row and column means of the centered matrix must vanish."""
    dm = random_distance_matrix(jax.random.PRNGKey(1), 96).data
    f = center_distance_matrix(dm)
    np.testing.assert_allclose(np.asarray(f).mean(0), 0.0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(f).mean(1), 0.0, atol=1e-4)
    np.testing.assert_allclose(f, np.asarray(f).T, atol=1e-5)


# --------------------------------------------------------------------------
# mantel
# --------------------------------------------------------------------------
def test_mantel_stat_equals_scipy_pearson():
    n = 48
    x = random_distance_matrix(jax.random.PRNGKey(2), n)
    y = random_distance_matrix(jax.random.PRNGKey(3), n)
    stat, _, _ = mantel(x, y, permutations=8)
    iu = np.triu_indices(n, k=1)
    want = scipy_pearsonr(np.asarray(x.data)[iu],
                          np.asarray(y.data)[iu]).statistic
    assert abs(stat - want) < 1e-5


def test_mantel_optimized_equals_original():
    """Same key ⇒ identical permutations ⇒ identical null distribution."""
    n, k = 32, 16
    x = random_distance_matrix(jax.random.PRNGKey(4), n)
    y = random_distance_matrix(jax.random.PRNGKey(5), n)
    key = jax.random.PRNGKey(7)
    s_opt, p_opt, _ = mantel(x, y, permutations=k, key=key)
    s_ref, p_ref, _ = mantel_ref(x, y, permutations=k, key=key)
    assert abs(s_opt - s_ref) < 1e-5
    assert abs(p_opt - p_ref) < 1e-9


def test_mantel_self_correlation():
    x = random_distance_matrix(jax.random.PRNGKey(6), 40)
    stat, p, n = mantel(x, x, permutations=32)
    assert abs(stat - 1.0) < 1e-5
    assert p <= 2.0 / 33 + 1e-9          # identity is the best permutation
    assert n == 40


def test_mantel_correlated_matrices_significant():
    """y = distances of slightly-perturbed points ⇒ strong correlation."""
    key = jax.random.PRNGKey(8)
    pts = jax.random.normal(key, (50, 4))
    pts2 = pts + 0.01 * jax.random.normal(jax.random.fold_in(key, 1), (50, 4))

    def dmat(p):
        d2 = jnp.sum((p[:, None] - p[None, :]) ** 2, -1)
        d = jnp.sqrt(jnp.maximum(d2, 0))
        d = 0.5 * (d + d.T)
        return DistanceMatrix(d - jnp.diag(jnp.diag(d)),
                              _skip_validation=True)

    stat, p, _ = mantel(dmat(pts), dmat(pts2), permutations=99)
    assert stat > 0.95
    assert p <= 0.02


def test_mantel_alternatives():
    x = random_distance_matrix(jax.random.PRNGKey(9), 30)
    y = random_distance_matrix(jax.random.PRNGKey(10), 30)
    for alt in ("two-sided", "greater", "less"):
        stat, p, _ = mantel(x, y, permutations=16, alternative=alt)
        assert 0.0 < p <= 1.0
    with pytest.raises(ValueError):
        mantel(x, y, permutations=4, alternative="bogus")


# --------------------------------------------------------------------------
# validation + DistanceMatrix semantics
# --------------------------------------------------------------------------
def test_validation_paths_agree():
    dm = random_distance_matrix(jax.random.PRNGKey(11), 96).data
    for m in (dm, dm.at[3, 4].add(1.0), dm.at[5, 5].set(2.0)):
        ref = is_symmetric_and_hollow_ref(m)
        fused = is_symmetric_and_hollow(m)
        blocked = is_symmetric_and_hollow_blocked(m, block=32)
        assert (bool(ref[0]), bool(ref[1])) == \
            (bool(fused[0]), bool(fused[1])) == \
            (bool(blocked[0]), bool(blocked[1]))


def test_distance_matrix_rejects_bad():
    good = random_distance_matrix(jax.random.PRNGKey(12), 16).data
    with pytest.raises(DistanceMatrixError):
        DistanceMatrix(good.at[0, 1].add(1.0))
    with pytest.raises(DistanceMatrixError):
        DistanceMatrix(good.at[2, 2].set(1.0))
    with pytest.raises(DistanceMatrixError):
        DistanceMatrix(jnp.zeros((3, 4)))


def test_validation_caching_on_copy_and_permute():
    """Paper §4.3: derived objects skip re-validation."""
    dm = random_distance_matrix(jax.random.PRNGKey(13), 16)
    assert dm._validated
    assert dm.copy()._validated
    perm = dm.permute(np.arange(16)[::-1])
    assert perm._validated
    flat = dm.permute(np.arange(16)[::-1], condensed=True)
    assert flat.shape == (16 * 15 // 2,)


# --------------------------------------------------------------------------
# pcoa
# --------------------------------------------------------------------------
def test_pcoa_fsvd_matches_eigh():
    """Low-rank (dim=4) Euclidean distances: top-4 eigenpairs must agree."""
    dm = random_distance_matrix(jax.random.PRNGKey(14), 80, dim=4)
    r_eigh = pcoa(dm, dimensions=4, method="eigh")
    r_fsvd = pcoa(dm, dimensions=4, method="fsvd")
    np.testing.assert_allclose(r_fsvd.eigenvalues, r_eigh.eigenvalues,
                               rtol=1e-3)
    # coordinates match up to per-axis sign
    for j in range(4):
        a = np.asarray(r_fsvd.coordinates[:, j])
        b = np.asarray(r_eigh.coordinates[:, j])
        assert min(np.abs(a - b).max(), np.abs(a + b).max()) < 1e-2


def test_pcoa_recovers_embedding_dim():
    """dim=3 points ⇒ exactly 3 significant eigenvalues."""
    dm = random_distance_matrix(jax.random.PRNGKey(15), 60, dim=3)
    res = pcoa(dm, dimensions=8, method="eigh")
    ev = np.asarray(res.eigenvalues)
    assert (ev[:3] > 1e-3).all()
    assert np.abs(ev[3:]).max() < 1e-3 * ev[0]


def test_pcoa_centering_impls_agree():
    dm = random_distance_matrix(jax.random.PRNGKey(16), 64, dim=5)
    a = pcoa(dm, dimensions=3, method="eigh", centering_impl="ref")
    b = pcoa(dm, dimensions=3, method="eigh", centering_impl="fused")
    np.testing.assert_allclose(a.eigenvalues, b.eigenvalues, rtol=1e-4)


def test_pcoa_proportions():
    dm = random_distance_matrix(jax.random.PRNGKey(17), 50, dim=4)
    res = pcoa(dm, dimensions=4, method="eigh")
    prop = np.asarray(res.proportion_explained)
    assert (prop >= 0).all()
    assert prop.sum() <= 1.0 + 1e-5
    assert prop.sum() > 0.95          # rank-4 structure fully captured
