"""repro.faults + the serve recovery plane: chaos with receipts.

The load-bearing properties, in rough order of importance:

* under ANY seeded fault schedule every request terminates — done,
  degraded (with the partial envelope), or rejected (structured code) —
  never a hang, never a traceback;
* requests that complete under chaos finish with p-values bitwise-equal
  to the fault-free run (retries re-execute identical rows; the NaN
  admission check keeps poisoned tiles out of the counts);
* the fault schedule is a pure function of the plan seed — two runs of
  the same plan fire the same faults at the same invocations;
* journal recovery resumes a crashed service against the surviving pool
  without re-running completed permutation blocks and without a single
  re-hoist, and the recovered p-values are bitwise the uninterrupted
  ones;
* the eviction/re-upload race terminates in-flight requests with a
  structured ``stale_generation`` rejection, not a crash;
* every handle's ``payload()`` has one uniform shape regardless of how
  the request ended.
"""

import math
import time

import numpy as np
import pytest

from repro.checkpoint.journal import Journal, replay
from repro.faults import (FaultInjector, FaultPlan, FaultSpec, unit_hash)
from repro.serve import (AnalysisService, Rejected, ServeConfig,
                         serve_report)

PAYLOAD_KEYS = {"request_id", "study_id", "method", "status", "error",
                "progress", "result"}

GROUPING = np.array(["a", "b", "c"] * 8)          # n=24


def _features(n, d, seed=0):
    return np.random.default_rng(seed).random((n, d)).astype(np.float32)


def _service(**kw):
    kw.setdefault("timeout_s", None)
    kw.setdefault("auto_tune", False)
    kw.setdefault("batch_size", 16)
    return AnalysisService(ServeConfig(**kw))


def _loaded(**kw):
    s = _service(**kw)
    s.upload("x", features=_features(24, 6, seed=1))
    s.upload("y", features=_features(24, 5, seed=2))
    s.upload("z", features=_features(24, 4, seed=3))
    return s


def _reference_p(method="mantel", permutations=99, key=5, **kw):
    """The fault-free answer for one request (fresh service, no plan)."""
    s = _loaded()
    h = s.submit("x", method, permutations=permutations, key=key, **kw)
    s.run()
    assert h.status == "done"
    return h.result.p_value


# --------------------------------------------------------------------------
# The plan: determinism and validation
# --------------------------------------------------------------------------
class TestFaultPlan:
    def test_unit_hash_deterministic_uniform(self):
        vals = [unit_hash(7, "site:0", i) for i in range(200)]
        assert vals == [unit_hash(7, "site:0", i) for i in range(200)]
        assert all(0.0 <= v < 1.0 for v in vals)
        # seed, label, and index all matter
        assert unit_hash(7, "site:0", 3) != unit_hash(8, "site:0", 3)
        assert unit_hash(7, "site:0", 3) != unit_hash(7, "site:1", 3)
        assert len(set(vals)) > 190       # not degenerate

    def test_schedule_replays_exactly(self):
        plan = FaultPlan.chaos(seed=3)
        a, b = FaultInjector(plan), FaultInjector(plan)
        for _ in range(50):
            a.poll("serve.tile")
            b.poll("serve.tile")
        a.poll("serve.hoist"), b.poll("serve.hoist")
        assert a.fires == b.fires
        assert a.summary() == b.summary()

    def test_seeds_decorrelate(self):
        def fires(seed):
            inj = FaultInjector(FaultPlan.chaos(seed=seed,
                                                tile_error=0.3))
            for _ in range(60):
                inj.poll("serve.tile")
            return [ev.index for ev in inj.fires]
        assert fires(0) != fires(1)

    def test_at_and_max_fires(self):
        inj = FaultInjector(FaultPlan(specs=(
            FaultSpec("serve.tile", "error", at=(1, 3, 5), max_fires=2),)))
        fired = [i for i in range(8) if inj.poll("serve.tile")]
        assert fired == [1, 3]            # max_fires caps the at-list

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            FaultSpec("serve.nope", "error")
        with pytest.raises(ValueError):
            FaultSpec("serve.tile", "compile")     # wrong site's kind
        with pytest.raises(ValueError):
            FaultSpec("serve.tile", "error", rate=1.5)


# --------------------------------------------------------------------------
# The journal primitive
# --------------------------------------------------------------------------
class TestJournal:
    def test_roundtrip(self, tmp_path):
        path = str(tmp_path / "j.log")
        with Journal(path) as j:
            for i in range(5):
                j.append({"i": i, "x": "v" * i})
        assert [r["i"] for r in replay(path)] == list(range(5))

    def test_torn_tail_stops_replay(self, tmp_path):
        path = str(tmp_path / "j.log")
        with Journal(path) as j:
            for i in range(3):
                j.append({"i": i})
        with open(path, "a") as f:
            f.write('deadbeef {"i": 99}')       # bad crc, no newline
        assert [r["i"] for r in replay(path)] == [0, 1, 2]

    def test_corrupt_middle_truncates_suffix(self, tmp_path):
        path = str(tmp_path / "j.log")
        with Journal(path) as j:
            for i in range(4):
                j.append({"i": i})
        lines = open(path).read().splitlines(True)
        lines[1] = "00000000 {}\n"               # wrong crc mid-file
        open(path, "w").write("".join(lines))
        assert [r["i"] for r in replay(path)] == [0]

    def test_missing_file_replays_empty(self, tmp_path):
        assert list(replay(str(tmp_path / "absent.log"))) == []

    def test_reopen_appends_after_prefix(self, tmp_path):
        path = str(tmp_path / "j.log")
        with Journal(path) as j:
            j.append({"i": 0})
        with Journal(path) as j:
            j.append({"i": 1})
            assert [r["i"] for r in j.records()] == [0, 1]


# --------------------------------------------------------------------------
# Retry: transient faults are invisible in the answer
# --------------------------------------------------------------------------
class TestRetry:
    def test_transient_error_retried_bitwise(self):
        ref = _reference_p(other="y")
        plan = FaultPlan(seed=0, specs=(
            FaultSpec("serve.tile", "error", at=(0, 2)),))
        svc = _loaded(fault_plan=plan)
        h = svc.submit("x", "mantel", other="y", permutations=99, key=5)
        svc.run()
        assert h.status == "done"
        assert h.result.p_value == ref
        assert svc.metrics.retries == 2
        assert svc.metrics.tile_failures["transient"] == 2
        assert svc.metrics.retried_rows == 2 * 16
        assert svc.metrics.retry_amplification > 0

    def test_nan_poison_caught_and_retried(self):
        # a poisoned tile must NOT leak NaN rows into the exceedance
        # counts — the output admission check routes it through retry
        ref = _reference_p(other="y")
        plan = FaultPlan(seed=0, specs=(
            FaultSpec("serve.tile", "nan", at=(0,)),))
        svc = _loaded(fault_plan=plan)
        h = svc.submit("x", "mantel", other="y", permutations=99, key=5)
        svc.run()
        assert h.status == "done"
        assert h.result.p_value == ref
        assert svc.metrics.tile_failures["poison"] == 1

    def test_oom_sheds_idle_session_then_succeeds(self):
        ref = _reference_p("permanova", grouping=GROUPING)
        plan = FaultPlan(seed=0, specs=(
            FaultSpec("serve.tile", "oom", at=(0,)),))
        svc = _loaded(fault_plan=plan)
        h = svc.submit("x", "permanova", grouping=GROUPING,
                       permutations=99, key=5)
        svc.run()
        assert h.status == "done"
        assert h.result.p_value == ref
        assert svc.metrics.pool_sheds == 1
        assert svc.metrics.tile_failures["oom"] == 1
        # an IDLE session was shed; the active study survived
        assert "x" in svc.pool
        assert len(svc.pool) == 2

    def test_slow_tile_completes(self):
        ref = _reference_p(other="y")
        plan = FaultPlan(seed=0, specs=(
            FaultSpec("serve.tile", "slow", at=(1,), delay_s=0.02),))
        svc = _loaded(fault_plan=plan)
        h = svc.submit("x", "mantel", other="y", permutations=99, key=5)
        svc.run()
        assert h.status == "done"
        assert h.result.p_value == ref
        assert svc.metrics.retries == 0   # slow is not a failure

    def test_backoff_is_bounded_and_deterministic(self):
        from repro.serve import RetryPolicy
        pol = RetryPolicy(base_s=0.01, multiplier=2.0, max_backoff_s=0.1,
                          jitter=0.5, seed=4)
        delays = [pol.backoff(f, "backoff:mantel", f) for f in
                  range(1, 12)]
        assert delays == [pol.backoff(f, "backoff:mantel", f)
                          for f in range(1, 12)]
        assert all(d <= 0.1 * 1.5 for d in delays)    # capped (+jitter)
        assert delays[0] < delays[3]                  # grows early


# --------------------------------------------------------------------------
# Watchdog escalation: stalled tiles re-enter the retry path
# --------------------------------------------------------------------------
class TestStallEscalation:
    def test_stalled_tile_escalates_and_recovers_bitwise(self):
        ref = _reference_p(other="y")
        plan = FaultPlan(seed=0, specs=(
            FaultSpec("serve.tile", "stall", at=(0,)),))
        svc = _loaded(fault_plan=plan)
        h = svc.submit("x", "mantel", other="y", permutations=99, key=5)
        svc.run()
        assert h.status == "done"
        assert h.result.p_value == ref
        assert svc.metrics.escalations == 1
        assert len(svc.scheduler.monitor.escalations) == 1
        rec = svc.scheduler.monitor.escalations[0]
        assert rec.aborted_open_step or rec.deadline_s < rec.elapsed_s
        # the aborted attempt never entered the scored step records —
        # only the tiles that actually completed are in the baseline
        assert len(svc.scheduler.monitor.records) == \
            svc.scheduler.tiles_run

    def test_stall_never_hangs_before_median(self):
        # a FIRST-tile stall has no straggler median to arm the
        # deadline — escalate() must fire anyway (regression: this hung)
        plan = FaultPlan(seed=0, specs=(
            FaultSpec("serve.tile", "stall", at=(0,)),))
        svc = _loaded(fault_plan=plan)
        h = svc.submit("x", "mantel", other="y", permutations=33, key=1)
        t0 = time.monotonic()
        svc.run()
        assert time.monotonic() - t0 < 60
        assert h.done


# --------------------------------------------------------------------------
# Circuit breaker: poison requests degrade instead of wedging the lane
# --------------------------------------------------------------------------
class TestCircuitBreaker:
    def test_persistent_failure_rejects_with_circuit_open(self):
        svc = _loaded(fault_plan=FaultPlan(seed=0, specs=(
            FaultSpec("serve.tile", "error", rate=1.0),)))
        h = svc.submit("x", "mantel", other="y", permutations=99, key=5)
        svc.run()
        assert h.status == "rejected"      # zero draws done: no envelope
        assert h.error.code == "circuit_open"
        assert svc.metrics.breaker_trips == 1
        assert svc.metrics.tile_failures["transient"] == 3  # k then trip

    def test_midflight_failure_degrades_with_envelope(self):
        # first tile succeeds, everything after fails: the request has
        # real draws, so it degrades to the partial envelope
        svc = _loaded(fault_plan=FaultPlan(seed=0, specs=(
            FaultSpec("serve.tile", "error", at=tuple(range(1, 200))),)))
        h = svc.submit("x", "mantel", other="y", permutations=99, key=5)
        svc.run()
        assert h.status == "degraded"
        assert h.error.code == "circuit_open"
        frame = h.partial()
        assert frame.draws_done == 16
        assert 0.0 < frame.p_lo <= frame.p_hi <= 1.0
        # the envelope brackets the fault-free answer
        ref = _reference_p(other="y")
        assert frame.p_lo <= ref <= frame.p_hi
        p = h.payload()
        assert p["status"] == "degraded"
        assert p["error"]["code"] == "circuit_open"
        assert p["progress"]["p_lo"] == frame.p_lo

    def test_breaker_isolates_lane_not_service(self):
        # the poisoned lane opens; a different method's lane is fine
        svc = _loaded(fault_plan=FaultPlan(seed=0, specs=(
            FaultSpec("serve.tile", "error", rate=1.0, max_fires=3),)))
        bad = svc.submit("x", "mantel", other="y", permutations=99, key=5)
        svc.run()
        assert bad.error.code == "circuit_open"
        good = svc.submit("x", "permanova", grouping=GROUPING,
                          permutations=49, key=6)
        svc.run()
        assert good.status == "done"


# --------------------------------------------------------------------------
# Compile faults at activation
# --------------------------------------------------------------------------
class TestCompileFaults:
    def test_transient_compile_retries_at_activation(self):
        ref = _reference_p(other="y")
        svc = _loaded(fault_plan=FaultPlan(seed=0, specs=(
            FaultSpec("serve.hoist", "compile", rate=1.0, max_fires=1),)))
        h = svc.submit("x", "mantel", other="y", permutations=99, key=5)
        svc.run()
        assert h.status == "done"
        assert h.result.p_value == ref
        assert svc.metrics.faults["serve.hoist:compile"] == 1

    def test_persistent_compile_becomes_unavailable(self):
        svc = _loaded(fault_plan=FaultPlan(seed=0, specs=(
            FaultSpec("serve.hoist", "compile", rate=1.0),)))
        h = svc.submit("x", "mantel", other="y", permutations=99, key=5)
        svc.run()
        assert h.status == "rejected"
        assert h.error.code == "unavailable"


# --------------------------------------------------------------------------
# The eviction / re-upload race (stale generations)
# --------------------------------------------------------------------------
class TestStaleGeneration:
    def _midflight(self, svc, k=99):
        h1 = svc.submit("x", "mantel", other="y", permutations=k, key=5)
        h2 = svc.submit("x", "mantel", other="y", permutations=k, key=6)
        while svc.scheduler.tiles_run < 1:
            svc.step()
        assert not h1.done and not h2.done          # genuinely mid-tile
        return h1, h2

    def test_reupload_mid_tile_rejects_inflight_structurally(self):
        svc = _loaded()
        h1, h2 = self._midflight(svc)
        gen0 = svc.pool.get("x").generation
        svc.upload("x", features=_features(24, 6, seed=99))
        for h in (h1, h2):
            assert h.status == "rejected"
            assert h.error.code == "stale_generation"
            assert h.error.detail["study_id"] == "x"
        assert svc.pool.get("x").generation == gen0 + 1
        assert svc.metrics.stale_terminations == 2
        svc.run()                                   # no residue, no crash
        # the lane died with its generation
        assert not svc.scheduler.lanes
        # new submissions run against the new data
        h3 = svc.submit("x", "mantel", other="y", permutations=33, key=7)
        svc.run()
        assert h3.status == "done"

    def test_reupload_of_operand_study_is_also_stale(self):
        # the OTHER side of a mantel lane going stale must invalidate too
        svc = _loaded()
        h1, _ = self._midflight(svc)
        svc.upload("y", features=_features(24, 5, seed=77))
        assert h1.status == "rejected"
        assert h1.error.code == "stale_generation"

    def test_injected_pool_eviction_race(self):
        plan = FaultPlan(seed=0, specs=(
            FaultSpec("serve.pool", "evict", at=(2,), max_fires=1),))
        svc = _loaded(fault_plan=plan)
        h = svc.submit("x", "mantel", other="y", permutations=99, key=5)
        svc.run()
        assert h.done                               # terminated, not hung
        assert h.status == "rejected"
        assert h.error.code == "stale_generation"
        assert "x" not in svc.pool                  # really evicted
        rep = serve_report(svc)
        assert rep["faults"]["injected"]["serve.pool:evict"] == 1


# --------------------------------------------------------------------------
# Deadlines and cancellation
# --------------------------------------------------------------------------
class TestDeadlinesAndCancel:
    def test_active_deadline_cancels_cooperatively(self):
        svc = _loaded()
        h = svc.submit("x", "mantel", other="y", permutations=999, key=5,
                       timeout_s=3600.0)
        while svc.scheduler.tiles_run < 2:
            svc.step()
        h.deadline = time.monotonic() - 1.0         # lapse it, precisely
        svc.run()
        assert h.status == "degraded"               # draws done: envelope
        assert h.error.code == "deadline"
        assert h.partial().draws_done >= 32
        ref = _reference_p(other="y", permutations=999)
        assert h.partial().p_lo <= ref <= h.partial().p_hi

    def test_cancel_queued_request(self):
        svc = _loaded(max_active=1)
        svc.submit("x", "mantel", other="y", permutations=99, key=5)
        h2 = svc.submit("x", "permanova", grouping=GROUPING,
                        permutations=99, key=6)
        assert svc.cancel(h2) is True
        assert h2.status == "rejected"
        assert h2.error.code == "cancelled"
        assert svc.cancel(h2) is False              # already terminal
        svc.run()

    def test_cancel_active_request_degrades(self):
        svc = _loaded()
        h = svc.submit("x", "mantel", other="y", permutations=999, key=5)
        while svc.scheduler.tiles_run < 1:
            svc.step()
        assert svc.cancel(h) is True
        assert h.status == "degraded"
        assert h.error.code == "cancelled"
        svc.run()


# --------------------------------------------------------------------------
# Journal recovery: crash, rebuild, resume — bitwise
# --------------------------------------------------------------------------
class TestJournalRecovery:
    KS = (99, 49, 33)                                # ΣK=181, B=16 → 12

    def _reference(self):
        s = _loaded()
        hs = [s.submit("x", "mantel", other="y", permutations=k,
                       key=10 + i) for i, k in enumerate(self.KS)]
        s.run()
        return [h.result.p_value for h in hs]

    def test_recover_resumes_bitwise_without_rehoisting(self, tmp_path):
        ref = self._reference()
        path = str(tmp_path / "serve.journal")
        svc = _loaded(journal_path=path)
        for i, k in enumerate(self.KS):
            svc.submit("x", "mantel", other="y", permutations=k,
                       key=10 + i)
        t = 4                                        # crash after 4 tiles
        while svc.scheduler.tiles_run < t:
            svc.step()
        pool = svc.pool                              # sessions survive
        svc.journal.close()                          # the "crash"
        hoists_before = {
            sid: dict(pool._sessions[sid].cache.misses)
            for sid in pool.studies()}

        svc2, handles = AnalysisService.recover(
            path, pool=pool,
            config=ServeConfig(timeout_s=None, auto_tune=False,
                               batch_size=16))
        assert len(handles) == 3                     # none were terminal
        svc2.run()
        got = [handles[rid].result.p_value
               for rid in sorted(handles, key=lambda r: int(r[1:]))]
        assert got == ref                            # bitwise, post-crash
        # completed blocks were NOT re-run: exactly the remaining tiles
        total = math.ceil(sum(self.KS) / 16)
        assert svc2.scheduler.tiles_run == total - t
        # ... and NOTHING re-hoisted (the counters stay pinned)
        for sid in pool.studies():
            assert dict(pool._sessions[sid].cache.misses) == \
                hoists_before[sid]
        assert svc2.metrics.resumes == 1             # only r1 had progress
        assert svc2.metrics.resumed_rows == t * 16

    def test_second_recovery_is_empty(self, tmp_path):
        path = str(tmp_path / "serve.journal")
        svc = _loaded(journal_path=path)
        svc.submit("x", "mantel", other="y", permutations=33, key=5)
        while svc.scheduler.tiles_run < 1:
            svc.step()
        pool = svc.pool
        svc.journal.close()
        svc2, handles = AnalysisService.recover(path, pool=pool)
        assert len(handles) == 1
        svc2.run()
        svc2.journal.close()
        # every request now has a terminal record — nothing to resume
        svc3, handles3 = AnalysisService.recover(path, pool=pool)
        assert handles3 == {}

    def test_terminal_requests_not_resubmitted(self, tmp_path):
        path = str(tmp_path / "serve.journal")
        svc = _loaded(journal_path=path)
        h = svc.submit("x", "mantel", other="y", permutations=33, key=5)
        svc.run()                                    # finishes cleanly
        assert h.status == "done"
        svc.journal.close()
        svc2, handles = AnalysisService.recover(path, pool=svc.pool)
        assert handles == {}


# --------------------------------------------------------------------------
# The chaos soak: the CI gate, in-miniature
# --------------------------------------------------------------------------
class TestChaosSoak:
    def _requests(self, svc):
        return [
            svc.submit("x", "mantel", other="y", permutations=49, key=0),
            svc.submit("x", "mantel", other="y", permutations=33, key=1),
            svc.submit("x", "permanova", grouping=GROUPING,
                       permutations=49, key=2),
            svc.submit("x", "anosim", grouping=GROUPING,
                       permutations=33, key=3),
        ]

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_all_terminate_and_completed_are_bitwise(self, seed):
        clean = _loaded()
        ref = {h.request_id: h for h in self._requests(clean)}
        clean.run()
        svc = _loaded(fault_plan=FaultPlan.chaos(
            seed=seed, tile_error=0.15, oom=0.05, nan=0.05, slow=0.0,
            compile_rate=0.3))
        handles = self._requests(svc)
        t0 = time.monotonic()
        svc.run()
        assert time.monotonic() - t0 < 120
        for h in handles:
            assert h.done, f"request {h.request_id} never terminated"
            assert h.status in ("done", "degraded", "rejected",
                                "timed_out")
            if h.status == "done":
                assert h.result.p_value == \
                    ref[h.request_id].result.p_value
        # amplification stays bounded even at aggressive rates
        assert svc.metrics.retry_amplification <= 2.0
        rep = serve_report(svc)
        assert rep["faults"]["plan"]["seed"] == seed
        assert rep["faults"]["retries"] == svc.metrics.retries


# --------------------------------------------------------------------------
# Payload uniformity + zero-cost-when-disabled
# --------------------------------------------------------------------------
class TestSurface:
    def test_payload_shape_uniform_across_outcomes(self):
        clean = _loaded()
        done = clean.submit("x", "permanova", grouping=GROUPING,
                            permutations=49, key=1)
        with pytest.raises(Rejected):
            clean.submit("x", "nonsense")
        bad = clean.submit("x", "mantel", other="missing", permutations=9)
        clean.run()
        queued = clean.submit("x", "anosim", grouping=GROUPING)
        faulty = _loaded(fault_plan=FaultPlan(seed=0, specs=(
            FaultSpec("serve.tile", "error", at=tuple(range(1, 200))),)))
        degraded = faulty.submit("x", "mantel", other="y",
                                 permutations=99, key=5)
        faulty.run()
        statuses = {}
        for h in (done, degraded, bad, queued):
            p = h.payload()
            assert set(p.keys()) == PAYLOAD_KEYS, h.status
            statuses[h.status] = p
        assert statuses["done"]["error"] is None
        assert statuses["done"]["result"]["p_value"] is not None
        assert statuses["degraded"]["error"]["code"] == "circuit_open"
        assert statuses["degraded"]["progress"]["p_hi"] <= 1.0
        assert statuses["degraded"]["result"] is None
        assert statuses["rejected"]["error"]["code"] == "unknown_study"
        assert statuses["queued"]["result"] is None

    def test_disabled_plane_is_absent(self):
        svc = _loaded()
        assert svc.injector is None
        assert svc.scheduler.injector is None
        assert svc.journal is None
        h = svc.submit("x", "mantel", other="y", permutations=33, key=5)
        svc.run()
        assert h.status == "done"
        rep = serve_report(svc)
        assert "plan" not in rep["faults"]
        assert rep["faults"]["retries"] == 0
        assert rep["faults"]["retry_amplification"] == 0.0

    def test_degraded_counts_separately_from_completed(self):
        svc = _loaded(fault_plan=FaultPlan(seed=0, specs=(
            FaultSpec("serve.tile", "error", at=tuple(range(1, 200))),)))
        svc.submit("x", "mantel", other="y", permutations=99, key=5)
        svc.run()
        g = serve_report(svc)["gauges"]
        assert g["degraded"] == 1
        assert g["completed"] == 0
