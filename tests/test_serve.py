"""repro.serve: pool, admission, coalescing determinism, streaming,
watchdog.

The load-bearing properties, in rough order of importance:

* coalescing is bitwise-neutral — a request's p-value is identical
  whether it runs alone or packed into shared tiles with strangers,
  across K ∈ {17, 49, 999}, and the whole mixed-K run compiles ONE
  ``kernels.permute_reduce`` program;
* the serve path agrees bitwise with the library (``Workspace``) path
  for every permutation test, and pcoa serves off the pooled cache;
* hoists run once per study regardless of request count; tiles respect
  the ceil(ΣK/B) bound; streamed bounds are monotone envelopes of the
  final p;
* admission failures are structured payloads (codes, not tracebacks);
  the pool evicts by LRU under both budgets and invalidates by
  generation on re-upload;
* the StepMonitor watchdog covers the tile loop (heartbeat between
  tiles trips on a stalled tile).
"""

import math

import numpy as np
import pytest

from repro.api.config import ExecConfig
from repro.api.workspace import Workspace
from repro.obs.compile import RecompileError, sentinel
from repro.runtime.monitor import StepMonitor
from repro.serve import (AnalysisService, Rejected, RequestQueue,
                         ServeConfig, SessionPool, partial_bounds,
                         serve_report, validate_upload)


def _features(n, d, seed=0):
    return np.random.default_rng(seed).random((n, d)).astype(np.float32)


def _service(**kw):
    kw.setdefault("timeout_s", None)
    kw.setdefault("auto_tune", False)
    kw.setdefault("batch_size", 16)
    return AnalysisService(ServeConfig(**kw))


GROUPING = np.array(["a", "b", "c"] * 8)          # n=24


@pytest.fixture
def svc():
    s = _service()
    s.upload("x", features=_features(24, 6, seed=1))
    s.upload("y", features=_features(24, 5, seed=2))
    s.upload("z", features=_features(24, 4, seed=3))
    return s


# --------------------------------------------------------------------------
# Coalescing determinism — THE acceptance property
# --------------------------------------------------------------------------
class TestCoalescingDeterminism:
    @pytest.mark.parametrize("k", [17, 49, 999])
    def test_alone_vs_coalesced_bitwise(self, svc, k):
        # coalesced: the K-under-test shares tiles with two strangers
        h = svc.submit("x", "mantel", other="y", permutations=k, key=5)
        svc.submit("x", "mantel", other="y", permutations=33, key=11)
        svc.submit("x", "mantel", other="y", permutations=77, key=12)
        svc.run()
        # alone: a fresh service, nothing to share with
        solo = _service()
        solo.upload("x", features=_features(24, 6, seed=1))
        solo.upload("y", features=_features(24, 5, seed=2))
        hs = solo.submit("x", "mantel", other="y", permutations=k, key=5)
        solo.run()
        assert h.result.p_value == hs.result.p_value
        assert h.result.statistic == hs.result.statistic

    def test_mixed_k_single_program(self, svc):
        with sentinel.expect("kernels.permute_reduce", max_programs=1):
            for k, key in ((17, 0), (49, 1), (999, 2)):
                svc.submit("x", "mantel", other="y", permutations=k,
                           key=key)
            svc.submit("x", "anosim", grouping=GROUPING, permutations=49,
                       key=3)
            svc.run()
        assert sentinel.expect is not None  # the context not raising IS
        # the assertion (RecompileError on >1 program)

    def test_recompile_error_class_importable(self):
        assert issubclass(RecompileError, Exception)


# --------------------------------------------------------------------------
# Serve vs library parity — all six analyses through the front door
# --------------------------------------------------------------------------
class TestServeLibraryParity:
    def _ws(self, seed, d):
        return Workspace.from_features(
            _features(24, d, seed=seed), config=ExecConfig(batch_size=16))

    def test_permanova(self, svc):
        h = svc.submit("x", "permanova", grouping=GROUPING,
                       permutations=99, key=7)
        svc.run()
        ref = self._ws(1, 6).permanova(GROUPING, permutations=99, key=7)
        assert h.result.p_value == ref.p_value
        assert h.result.statistic == ref.statistic

    def test_anosim(self, svc):
        h = svc.submit("x", "anosim", grouping=GROUPING, permutations=99,
                       key=7)
        svc.run()
        ref = self._ws(1, 6).anosim(GROUPING, permutations=99, key=7)
        assert h.result.p_value == ref.p_value

    def test_permdisp(self, svc):
        h = svc.submit("x", "permdisp", grouping=GROUPING,
                       permutations=99, key=7, dimensions=4)
        svc.run()
        ref = self._ws(1, 6).permdisp(GROUPING, permutations=99, key=7,
                                      dimensions=4)
        assert h.result.p_value == ref.p_value

    def test_mantel(self, svc):
        h = svc.submit("x", "mantel", other="y", permutations=99, key=7)
        svc.run()
        ref = self._ws(1, 6).mantel(self._ws(2, 5), permutations=99, key=7)
        assert h.result.p_value == ref.p_value

    def test_partial_mantel(self, svc):
        h = svc.submit("x", "partial_mantel", other="y", control="z",
                       permutations=99, key=7)
        svc.run()
        ref = self._ws(1, 6).partial_mantel(self._ws(2, 5), self._ws(3, 4),
                                            permutations=99, key=7)
        assert h.result.p_value == ref.p_value

    def test_pcoa_serves_from_pool_cache(self, svc):
        h = svc.submit("x", "pcoa", dimensions=3)
        svc.run()
        assert h.status == "done"
        assert h.result.coordinates.shape == (24, 3)
        ws = svc.pool.get("x")
        # a second identical request is a cache hit, not a re-solve
        h2 = svc.submit("x", "pcoa", dimensions=3)
        svc.run()
        assert ws.cache.build_count("coords") == 1
        assert h2.status == "done"


# --------------------------------------------------------------------------
# Scheduling economics: tiles, hoists, slot reuse
# --------------------------------------------------------------------------
class TestSchedulingEconomics:
    def test_tile_bound_and_hoist_once(self, svc):
        ks = [17, 49, 99, 33]
        for i, k in enumerate(ks):
            svc.submit("x", "mantel", other="y", permutations=k, key=i)
        svc.run()
        assert svc.scheduler.tiles_run == math.ceil(sum(ks) / 16)
        ws = svc.pool.get("x")
        assert all(v == 1 for v in ws.cache.misses.values()), \
            dict(ws.cache.misses)
        # ledger: hoist ops charged exactly once each
        hoist_ops = [e.op for e in ws.obs.ledger.entries
                     if e.op.startswith("hoist:")]
        assert len(hoist_ops) == len(set(hoist_ops))

    def test_slot_reuse_fills_mid_tile(self, svc):
        # 17 + 15 = 32 = exactly two B=16 tiles IF the second request's
        # rows backfill the first's final partial tile
        svc.submit("x", "mantel", other="y", permutations=17, key=0)
        svc.submit("x", "mantel", other="y", permutations=15, key=1)
        svc.run()
        assert svc.scheduler.tiles_run == 2

    def test_different_lanes_do_not_coalesce(self, svc):
        # different grouping content -> different lane, own tiles
        g2 = np.array(["a", "b"] * 12)
        svc.submit("x", "permanova", grouping=GROUPING, permutations=17,
                   key=0)
        svc.submit("x", "permanova", grouping=g2, permutations=17, key=1)
        svc.run()
        assert svc.scheduler.tiles_run == 4      # 2 lanes x 2 tiles

    def test_streaming_monotone_envelope(self, svc):
        h = svc.submit("x", "mantel", other="y", permutations=999, key=3)
        svc.run()
        assert len(h.updates) == math.ceil(999 / 16)
        los = [u.p_lo for u in h.updates]
        his = [u.p_hi for u in h.updates]
        assert los == sorted(los)                 # nondecreasing
        assert his == sorted(his, reverse=True)   # nonincreasing
        p = h.result.p_value
        assert all(lo <= p <= hi for lo, hi in zip(los, his))
        assert los[-1] == p == his[-1]            # collapse onto final
        draws = [u.draws_done for u in h.updates]
        assert draws == sorted(draws) and draws[-1] == 999

    def test_partial_bounds_math(self):
        b = partial_bounds(c=3, draws_done=10, permutations=99)
        assert b["p_lo"] == pytest.approx(4 / 100)
        assert b["p_hi"] == pytest.approx((3 + 89 + 1) / 100)
        assert b["p_partial"] == pytest.approx(4 / 11)
        done = partial_bounds(c=3, draws_done=99, permutations=99)
        assert done["p_lo"] == done["p_hi"] == done["p_partial"]


# --------------------------------------------------------------------------
# Pool: LRU, byte budgets, generation invalidation
# --------------------------------------------------------------------------
class TestSessionPool:
    def test_lru_eviction_by_count(self):
        pool = SessionPool(max_sessions=2)
        cfg = ExecConfig()
        for sid in ("a", "b", "c"):
            pool.admit(sid, cfg, features=_features(8, 3))
        assert len(pool) == 2 and "a" not in pool
        assert pool.evictions == 1

    def test_lru_touch_on_get(self):
        pool = SessionPool(max_sessions=2)
        cfg = ExecConfig()
        pool.admit("a", cfg, features=_features(8, 3))
        pool.admit("b", cfg, features=_features(8, 3))
        pool.get("a")                      # touch: b becomes LRU
        pool.admit("c", cfg, features=_features(8, 3))
        assert "a" in pool and "b" not in pool

    def test_byte_budget_eviction(self):
        pool = SessionPool(max_sessions=10, max_bytes=1)
        cfg = ExecConfig()
        ws_a = pool.admit("a", cfg, features=_features(16, 4))
        ws_a.condensed()                   # make 'a' cost real bytes
        assert pool.nbytes() > 1
        pool.admit("b", cfg, features=_features(16, 4))
        assert "a" not in pool             # evicted to chase the budget

    def test_exclude_pins_survive(self):
        pool = SessionPool(max_sessions=1)
        cfg = ExecConfig()
        pool.admit("a", cfg, features=_features(8, 3))
        pool.admit("b", cfg, features=_features(8, 3))
        # 'a' was evicted by b's admit; now protect b against everything
        assert pool.evict(exclude={"b"}) == []

    def test_reupload_bumps_generation_and_drops_cache(self, svc):
        ws = svc.pool.get("x")
        ws.condensed()
        g0, old_keys = ws.generation, set(ws.cache.keys())
        assert old_keys
        ack = svc.upload("x", features=_features(24, 6, seed=99))
        assert ack["generation"] == g0 + 1
        assert svc.pool.get("x") is ws      # same session object
        assert "condensed" not in ws.cache  # hoists dropped

    def test_nbytes_surfaced_in_workspace_report(self):
        ws = Workspace.from_features(_features(16, 4))
        ws.condensed()
        rep = ws.report()
        meta = rep.meta["cache_nbytes"]
        assert meta["total"] == ws.cache.nbytes() > 0
        assert meta["by_key"]["condensed"] > 0

    def test_nbytes_dedups_shared_buffers(self):
        ws = Workspace.from_features(_features(16, 4))
        ws.condensed()
        solo = ws.cache.nbytes()
        ws.operator()          # holds a reference to the same condensed
        assert ws.cache.nbytes() <= solo + 200   # means only, no double
        assert ws.cache.nbytes("operator") > 0   # per-key: full closure


# --------------------------------------------------------------------------
# Admission: structured rejection, queue bounds, timeouts
# --------------------------------------------------------------------------
class TestAdmission:
    def test_non_finite_upload_payload(self):
        svc = _service()
        bad = _features(8, 3).copy()
        bad[2, 1] = np.nan
        with pytest.raises(Rejected) as ei:
            svc.upload("s", features=bad)
        payload = ei.value.rejection.payload()
        assert payload["error"]["code"] == "non_finite"
        assert "traceback" not in str(payload).lower()

    def test_too_large_upload(self):
        svc = _service(max_n=16)
        with pytest.raises(Rejected) as ei:
            svc.upload("s", features=_features(17, 3))
        assert ei.value.rejection.code == "too_large"
        assert ei.value.rejection.detail["max_n"] == 16

    def test_triangle_guard_is_the_library_bound(self):
        from repro.core.distance_matrix import MAX_TRIANGLE_N
        import inspect
        from repro.serve.admission import validate_upload as vu
        # the admission cap defaults to the library's i32 triangle bound
        assert inspect.signature(vu).parameters["max_n"].default \
            == MAX_TRIANGLE_N == ServeConfig().max_n
        kind, n = validate_upload(features=_features(4, 2))
        assert (kind, n) == ("features", 4)
        kind, n = validate_upload(np.zeros((4, 4), np.float32))
        assert (kind, n) == ("dm", 4)

    def test_asymmetric_square_rejected_structured(self):
        svc = _service()
        m = np.arange(16, dtype=np.float32).reshape(4, 4)
        with pytest.raises(Rejected) as ei:
            svc.upload("s", m)
        assert ei.value.rejection.code == "bad_request"

    def test_unknown_study(self, svc):
        with pytest.raises(Rejected) as ei:
            svc.submit("nope", "permanova", grouping=GROUPING)
        assert ei.value.rejection.code == "unknown_study"

    def test_unknown_method(self, svc):
        with pytest.raises(Rejected) as ei:
            svc.submit("x", "tsne")
        assert ei.value.rejection.code == "bad_request"

    def test_queue_full_rejects_handle(self):
        svc = _service(max_queue=2)
        svc.upload("x", features=_features(24, 6, seed=1))
        svc.upload("y", features=_features(24, 5, seed=2))
        handles = [svc.submit("x", "mantel", other="y", permutations=9,
                              key=i) for i in range(3)]
        assert handles[2].status == "rejected"
        assert handles[2].error.code == "queue_full"
        svc.run()
        assert [h.status for h in handles[:2]] == ["done", "done"]

    def test_queued_timeout_fires(self, svc):
        h = svc.submit("x", "mantel", other="y", permutations=9,
                       timeout_s=-1.0)        # already expired
        svc.run()
        assert h.status == "timed_out"
        assert h.error.code == "timeout"

    def test_bad_grouping_is_structured_not_traceback(self, svc):
        h = svc.submit("x", "permanova", grouping=["a", "b"])  # wrong len
        svc.run()
        assert h.status == "rejected"
        assert h.error.code == "bad_request"

    def test_collinear_partial_mantel_structured(self, svc):
        svc.upload("ycopy", features=_features(24, 5, seed=2))  # z == y
        h = svc.submit("x", "partial_mantel", other="y", control="ycopy",
                       permutations=9)
        svc.run()
        assert h.status == "rejected"
        assert "collinear" in h.error.message

    def test_request_queue_bounds(self):
        q = RequestQueue(max_depth=1)

        class H:
            deadline = None
        q.push(H(), None)
        with pytest.raises(Rejected):
            q.push(H(), None)


# --------------------------------------------------------------------------
# Watchdog: the StepMonitor covers the tile loop
# --------------------------------------------------------------------------
class TestServeWatchdog:
    def test_tiles_flow_through_monitor(self, svc):
        svc.submit("x", "mantel", other="y", permutations=99, key=0)
        svc.run()
        mon = svc.scheduler.monitor
        assert len(mon.records) == svc.scheduler.tiles_run > 0
        assert all(r.seconds > 0 for r in mon.records)
        assert mon.deadline_factor == svc.config.deadline_factor

    def test_watchdog_fires_between_tiles(self):
        # regression: a tile that began but never completed must trip
        # the deadline on the NEXT loop turn's heartbeat, not hang
        mon = StepMonitor(deadline_factor=1.0)
        for i in range(4):
            mon.record(i, 1e-4)             # establish a tiny median
        mon.start()                          # a tile opens ... and stalls
        import time
        time.sleep(0.01)                     # >> deadline = 1e-4 s
        with pytest.raises(TimeoutError):
            mon.heartbeat()

    def test_heartbeat_noop_when_idle(self):
        mon = StepMonitor()
        mon.heartbeat()                      # no open step: no-op
        assert mon.elapsed() is None
        mon.start()
        assert mon.elapsed() >= 0.0
        mon.stop(0)
        assert mon.elapsed() is None


# --------------------------------------------------------------------------
# Reporting
# --------------------------------------------------------------------------
class TestServeReport:
    def test_report_sections(self, svc):
        svc.submit("x", "mantel", other="y", permutations=33, key=0)
        svc.submit("x", "permanova", grouping=GROUPING, permutations=17,
                   key=1)
        svc.run()
        rep = serve_report(svc)
        assert rep["gauges"]["completed"] == 2
        assert rep["gauges"]["latency_s"]["median"] > 0
        assert rep["pool"]["sessions"] == 3
        assert rep["pool"]["nbytes"] == svc.pool.nbytes() > 0
        assert rep["scheduler"]["tiles_run"] == svc.scheduler.tiles_run
        x = rep["studies"]["x"]
        assert x["ledger"]["hoist_passes"] > 0
        assert all(v == 1 for v in x["hoist_builds"].values())
        assert rep["monitor"]["steps"] == svc.scheduler.tiles_run
        # request latencies entered the span stream as serve-phase spans
        names = [s["name"] for s in rep["spans"]]
        assert any(n.startswith("request:mantel") for n in names)

    def test_latency_histograms_in_report(self, svc):
        svc.submit("x", "mantel", other="y", permutations=33, key=0)
        svc.submit("x", "permanova", grouping=GROUPING, permutations=17,
                   key=1)
        svc.run()
        lat = serve_report(svc)["latency"]
        assert set(lat) == {"queue_wait_s", "tile_s", "request_s"}
        req = lat["request_s"]
        assert req["count"] == 2
        assert req["p50"] > 0 and req["p95"] >= req["p50"]
        assert req["p99"] <= req["max"]
        # every executed tile was timed through the StepMonitor span
        assert lat["tile_s"]["count"] == svc.scheduler.tiles_run
        # both requests waited in the queue before activation
        assert lat["queue_wait_s"]["count"] == 2

    def test_slo_breach_counters(self):
        # thresholds of 0 seconds: every sample is a breach — the
        # counters must tick without affecting results
        s = _service(slo_queue_wait_s=0.0, slo_tile_s=0.0,
                     slo_request_s=0.0)
        s.upload("x", features=_features(24, 6, seed=1))
        s.upload("y", features=_features(24, 5, seed=2))
        h = s.submit("x", "mantel", other="y", permutations=33, key=0)
        s.run()
        assert h.status == "done"
        slo = serve_report(s)["slo"]
        assert slo["thresholds_s"] == {"queue_wait": 0.0, "tile": 0.0,
                                       "request": 0.0}
        assert slo["breaches"]["request"] == 1
        assert slo["breaches"]["tile"] == s.scheduler.tiles_run
        assert slo["breaches"]["queue_wait"] == 1
        # unset thresholds -> empty map, zero breaches (default svc)
        s2 = _service()
        assert serve_report(s2)["slo"] == {
            "thresholds_s": {},
            "breaches": {"queue_wait": 0, "tile": 0, "request": 0}}

    def test_prometheus_exposition(self, svc):
        svc.submit("x", "mantel", other="y", permutations=33, key=0)
        svc.run()
        text = svc.metrics.prometheus()
        assert "# TYPE serve_request_seconds histogram" in text
        assert 'serve_request_seconds_bucket{le="+Inf"} 1' in text
        assert "serve_slo_breach_request_total 0.0" in text

    def test_rejections_counted_in_gauges(self, svc):
        with pytest.raises(Rejected):
            svc.submit("ghost", "permanova", grouping=GROUPING)
        assert svc.report()["gauges"]["rejected"]["unknown_study"] == 1

    def test_async_driver(self, svc):
        import asyncio

        async def client():
            h = svc.submit("x", "mantel", other="y", permutations=33,
                           key=0)
            await svc.wait(h)
            return h

        h = asyncio.run(client())
        assert h.status == "done"
