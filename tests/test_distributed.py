"""Distributed-path tests on an 8-device host-platform mesh.

The device-count override must happen before jax initializes, so these
tests run a worker script in a subprocess (the main pytest process keeps
the real single device). One subprocess runs ALL scenarios to amortize
startup; each scenario prints a JSON verdict line.
"""

import json
import os
import subprocess
import sys

import pytest

_WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.core.centering import (center_distance_matrix,
                                  center_distance_matrix_distributed)
from repro.core.distance_matrix import random_distance_matrix
from repro.core.mantel import mantel, mantel_distributed
from repro.core.pcoa import pcoa
from repro.configs import SMOKES
from repro.optim.adamw import AdamWConfig
from repro.optim.compression import (compressed_psum, init_error_state)
from repro.runtime.train import init_train_state, make_train_step, build_train_step_fn
from repro.sharding.rules import make_rules, param_specs, cache_specs, named
from repro.checkpoint.manager import CheckpointManager
import tempfile, dataclasses

def verdict(name, ok, detail=""):
    print(json.dumps({"name": name, "ok": bool(ok), "detail": str(detail)}))

mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,) * 3)

# 1. distributed centering == fused centering
dm = random_distance_matrix(jax.random.PRNGKey(0), 64).data
want = center_distance_matrix(dm)
got = center_distance_matrix_distributed(dm, mesh)
verdict("centering_distributed", np.allclose(got, want, atol=1e-4),
        np.abs(np.asarray(got) - np.asarray(want)).max())

# 2. distributed mantel: same null-distribution statistics family
x = random_distance_matrix(jax.random.PRNGKey(1), 32)
y = random_distance_matrix(jax.random.PRNGKey(2), 32)
s_host, p_host, _ = mantel(x, y, permutations=64, key=jax.random.PRNGKey(5))
s_dist, p_dist, _ = mantel_distributed(x, y, mesh, permutations=64,
                                       key=jax.random.PRNGKey(5))
verdict("mantel_distributed_stat", abs(s_host - s_dist) < 1e-5,
        f"{s_host} vs {s_dist}")
verdict("mantel_distributed_pvalue", abs(p_host - p_dist) < 0.15,
        f"{p_host} vs {p_dist}")

# 3. pcoa with distributed centering matches
r1 = pcoa(random_distance_matrix(jax.random.PRNGKey(3), 64, dim=4),
          dimensions=4, method="eigh")
r2 = pcoa(random_distance_matrix(jax.random.PRNGKey(3), 64, dim=4),
          dimensions=4, method="eigh", centering_impl="distributed",
          mesh=mesh)
verdict("pcoa_distributed", np.allclose(r1.eigenvalues, r2.eigenvalues,
                                        rtol=1e-3))

# 4. sharded train step == single-device train step (loss parity)
cfg = dataclasses.replace(SMOKES["qwen3-8b"], microbatches=2,
                          param_dtype="float32", compute_dtype="float32")
params, opt_state = init_train_state(jax.random.PRNGKey(7), cfg)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(8), (8, 32), 0,
                                      cfg.vocab),
         "targets": jax.random.randint(jax.random.PRNGKey(9), (8, 32), 0,
                                       cfg.vocab)}
opt = AdamWConfig(warmup_steps=1, decay_steps=10)
step_local = jax.jit(build_train_step_fn(cfg, opt, None))
_, _, m_local = step_local(params, opt_state, batch)
rules = make_rules(mesh)
with mesh:
    step_sharded = make_train_step(cfg, opt, mesh, rules, params, opt_state,
                                   batch)
    p2, o2, m_shard = step_sharded(params, opt_state, batch)
verdict("train_step_parity",
        abs(float(m_local["loss"]) - float(m_shard["loss"])) < 1e-3,
        f"{float(m_local['loss'])} vs {float(m_shard['loss'])}")

# 5. multi-pod (3-axis) mesh lowers and runs the same step
# (scenario 4 DONATED params/opt_state — re-init fresh buffers)
params, opt_state = init_train_state(jax.random.PRNGKey(7), cfg)
rules3 = make_rules(mesh3)
with mesh3:
    step3 = make_train_step(cfg, opt, mesh3, rules3, params, opt_state, batch)
    params5, opt5 = jax.tree.map(jnp.copy, (params, opt_state))
    _, _, m3 = step3(params5, opt5, batch)
verdict("train_step_multipod",
        abs(float(m_local["loss"]) - float(m3["loss"])) < 1e-3,
        float(m3["loss"]))

# 6. elastic checkpoint: save sharded on 4x2, restore onto 2x2x2 and 1-dev
with tempfile.TemporaryDirectory() as td:
    mgr = CheckpointManager(td)
    mgr.save(3, {"params": p2, "opt": o2})
    specs3 = param_specs(cfg, params, rules3)
    o_specs3 = {"m": specs3, "v": specs3, "step": P()}
    state3, meta = mgr.restore({"params": params, "opt": opt_state},
                               mesh=mesh3,
                               specs={"params": specs3, "opt": o_specs3})
    ok = True
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(state3["params"])):
        ok &= np.allclose(np.asarray(a, np.float32),
                          np.asarray(b, np.float32), atol=1e-6)
    # and a plain un-meshed restore
    state1, _ = mgr.restore({"params": params, "opt": opt_state})
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(state1["params"])):
        ok &= np.allclose(np.asarray(a, np.float32),
                          np.asarray(b, np.float32), atol=1e-6)
    verdict("elastic_checkpoint", ok, meta["step"])

# 7. compressed cross-pod psum with error feedback ~ exact mean
from jax import shard_map
def sync(g, err):
    return compressed_psum(g, err, "pod", bits=8)
g_global = jax.random.normal(jax.random.PRNGKey(11), (2, 64))
err0 = jnp.zeros((2, 64))
f = jax.jit(shard_map(sync, mesh=mesh3.abstract_mesh if False else mesh3,
                      in_specs=(P("pod", None), P("pod", None)),
                      out_specs=(P("pod", None), P("pod", None))))
with mesh3:
    synced, err = f(g_global, err0)
true_mean = np.asarray(g_global).mean(axis=0)
got0 = np.asarray(synced)[0]
verdict("compressed_psum", np.abs(got0 - true_mean).max() < 0.05,
        np.abs(got0 - true_mean).max())

# 8. decode step lowers + runs sharded with cache specs
from repro.runtime.serve import make_decode_step
from repro.models import transformer as tf_mod
params, _ = init_train_state(jax.random.PRNGKey(7), cfg)  # fresh buffers
with mesh:
    cache = tf_mod.init_cache(cfg, 8, 64)
    dec = make_decode_step(cfg, mesh, rules, params, cache)
    logits, cache2 = dec(params, jnp.zeros((8, 1), jnp.int32), cache)
verdict("decode_sharded", bool(jnp.isfinite(logits).all())
        and int(cache2["pos"]) == 1, logits.shape)
"""


@pytest.fixture(scope="module")
def worker_output():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _WORKER], env=env,
                          capture_output=True, text=True, timeout=1500)
    verdicts = {}
    for line in proc.stdout.splitlines():
        try:
            v = json.loads(line)
            verdicts[v["name"]] = v
        except (json.JSONDecodeError, KeyError):
            continue
    if not verdicts:
        raise RuntimeError(f"worker produced no verdicts.\nstdout:"
                           f"{proc.stdout[-2000:]}\nstderr:{proc.stderr[-4000:]}")
    return verdicts


_SCENARIOS = ["centering_distributed", "mantel_distributed_stat",
              "mantel_distributed_pvalue", "pcoa_distributed",
              "train_step_parity", "train_step_multipod",
              "elastic_checkpoint", "compressed_psum", "decode_sharded"]


@pytest.mark.parametrize("name", _SCENARIOS)
def test_distributed(worker_output, name):
    assert name in worker_output, f"scenario {name} did not report"
    v = worker_output[name]
    assert v["ok"], f"{name} failed: {v['detail']}"
