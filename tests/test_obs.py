"""repro.obs tests: span tracer semantics and exports, the analytic
traffic registry's parity with the published BENCH ratios (10.97x mantel,
11-vs-16 api passes), the recompile sentinel's one-program-per-shape
guarantee across K values, RunReport assembly from an instrumented
Workspace battery, and the disabled path's zero-overhead contract."""

import json
import time

import jax
import numpy as np
import pytest

from repro.api import ExecConfig, Workspace
from repro.core import random_distance_matrix
from repro.obs import (FEATURE_HOIST_PASSES, HOIST_PASSES, NULL_OBS,
                       NULL_SPAN, CompileSentinel, Ledger, ObsConfig,
                       RecompileError, RunReport, Tracer, build_report,
                       current_obs, perm_traffic_floats, production_floats,
                       sentinel)

KEY = jax.random.PRNGKey(7)


def _features(seed, n=40, d=8):
    rng = np.random.default_rng(seed)
    return (rng.random((n, d)) + 0.01).astype(np.float32)


def _obs_ws(seed, n=40, d=8, **cfg):
    config = ExecConfig(obs=ObsConfig(enabled=True), **cfg)
    return Workspace.from_features(_features(seed, n, d), config=config)


# --------------------------------------------------------------------------
# registry parity: the ledger reproduces the published BENCH accounting
# --------------------------------------------------------------------------
def test_registry_parity_mantel_headline():
    """The 10.97x BENCH_mantel headline is square_gather/condensed_fused
    at n=2048, B=32 — pinned against the ONE consolidated registry."""
    floats = perm_traffic_floats(2048, 32)
    ratio = floats["square_gather"] / floats["condensed_fused"]
    assert ratio == pytest.approx(10.97, abs=0.005)
    # and the eager-original model stays the most expensive formulation
    assert floats["original"] > floats["square_gather"]


def test_registry_parity_api_session_passes():
    """The BENCH_api 4-analysis battery: 11 n²-passes for one shared
    Workspace vs 16 for per-call standalone sessions, straight from the
    registry's pass table."""
    shared = sum(HOIST_PASSES[a] for a in
                 ("operator", "gram", "condensed", "ranks", "coords"))
    assert shared == 11.0
    standalone = (
        (HOIST_PASSES["operator"] + HOIST_PASSES["coords"])    # pcoa
        + HOIST_PASSES["gram"]                                 # permanova
        + (HOIST_PASSES["operator"] + HOIST_PASSES["coords"])  # permdisp
        + (HOIST_PASSES["condensed"] + HOIST_PASSES["ranks"])  # anosim
    )
    assert standalone == 16.0


def test_registry_parity_benchmarks_import_the_registry():
    """Satellite: the benchmark scripts no longer own private copies of
    the audited tables — they ARE the registry objects."""
    from benchmarks import bench_api, bench_dist, bench_mantel
    assert bench_api._PASSES is HOIST_PASSES
    assert bench_dist._PASSES_BASE is HOIST_PASSES
    assert bench_dist._PASSES_FUSED is FEATURE_HOIST_PASSES
    assert bench_mantel.perm_traffic_floats is perm_traffic_floats


def test_feature_table_discounts():
    """The feature-backed column only differs where the square-free
    production makes builds cheaper — never more expensive."""
    assert set(FEATURE_HOIST_PASSES) == set(HOIST_PASSES)
    for k in HOIST_PASSES:
        assert FEATURE_HOIST_PASSES[k] <= HOIST_PASSES[k], k
    assert FEATURE_HOIST_PASSES["operator"] == 0.0   # fused accumulators
    assert FEATURE_HOIST_PASSES["coords"] == 2.0     # condensed matvecs


def test_production_floats_formula():
    # ceil(n/b) panels stream the full (n, d) table + one read of x
    assert production_floats(256, 32, 64) == 4 * 256 * 32 + 256 * 32
    assert production_floats(100, 10, 256) == 100 * 10 + 100 * 10  # b -> n


# --------------------------------------------------------------------------
# ledger
# --------------------------------------------------------------------------
def test_ledger_charges_and_totals():
    led = Ledger()
    led.charge_hoist("gram", 100)
    led.charge_hoist("coords", 100, table=FEATURE_HOIST_PASSES)
    led.charge_perm_batch("mantel", 100, permutations=64, batch=32)
    led.charge_production(100, 8, 50)
    assert led.hoist_passes() == 4.0 + 2.0
    per = perm_traffic_floats(100, 32)["condensed_fused"]
    expect = (4.0 * 100 * 100 + 2.0 * 100 * 100 + per * 64
              + production_floats(100, 8, 50))
    assert led.total_floats() == pytest.approx(expect)
    assert led.total_bytes() == pytest.approx(4.0 * expect)
    by_op = led.by_op()
    assert set(by_op) == {"hoist:gram", "hoist:coords", "perm:mantel",
                          "production"}
    assert by_op["perm:mantel"]["count"] == 1
    # every entry keeps the parameter point for offline re-audit
    entry = led.entries[2]
    assert entry.params["batch"] == 32
    assert entry.params["model"] == "condensed_fused"
    assert entry.bytes == 4.0 * entry.floats


# --------------------------------------------------------------------------
# span tracer
# --------------------------------------------------------------------------
def test_tracer_nesting_and_phase_accounting():
    t = Tracer()
    with t.span("outer", phase="hoist", n=10):
        with t.span("inner", phase="solve"):
            pass
        t.record("pre_timed", 0.5, phase="step")
    (root,) = t.spans
    assert root.name == "outer" and root.phase == "hoist"
    assert [c.name for c in root.children] == ["inner", "pre_timed"]
    assert root.duration >= root.children[0].duration
    assert t.count() == 3 and t.count("solve") == 1
    assert t.total("step") == pytest.approx(0.5)


def test_tracer_rejects_unknown_phase():
    with pytest.raises(ValueError, match="phase"):
        Tracer().span("x", phase="warp")


def test_span_end_before_begin_is_an_error():
    t = Tracer()
    with pytest.raises(RuntimeError, match="before begin"):
        t.span("x").end()


def test_tracer_exports_json_and_chrome_trace():
    t = Tracer()
    with t.span("a", phase="hoist", impl="xla"):
        with t.span("b", phase="per_perm"):
            pass
    tree = json.loads(t.to_json())
    assert tree[0]["name"] == "a"
    assert tree[0]["children"][0]["name"] == "b"
    events = t.to_chrome_trace()
    assert {e["name"] for e in events} == {"a", "b"}
    for e in events:
        assert e["ph"] == "X" and e["dur"] >= 0.0
    a = next(e for e in events if e["name"] == "a")
    assert a["cat"] == "hoist" and a["args"]["impl"] == "xla"
    # tree_lines renders one line per span, child indented under parent
    lines = t.tree_lines()
    assert len(lines) == 2 and "a [hoist]" in lines[0]


def test_ambient_session_stack():
    class FakeSession:
        enabled = True

    s = FakeSession()
    t = Tracer()
    assert current_obs() is NULL_OBS
    with t.span("outer", session=s):
        assert current_obs() is s
    assert current_obs() is NULL_OBS


# --------------------------------------------------------------------------
# the disabled path: zero-overhead contract
# --------------------------------------------------------------------------
def test_null_singletons_are_process_wide():
    """The no-op fast path allocates nothing per call: every disabled
    span/session IS the shared singleton."""
    assert NULL_OBS.span("anything", phase="hoist", n=10) is NULL_SPAN
    assert NULL_SPAN.__enter__() is NULL_SPAN
    assert NULL_SPAN.add(x=1) is NULL_SPAN
    assert NULL_SPAN.begin().end() is NULL_SPAN
    assert NULL_OBS.charge_hoist("gram", 100) is None
    assert not NULL_OBS.enabled
    # a default Workspace rides the singleton — no session object exists
    ws = Workspace(random_distance_matrix(KEY, 12))
    assert ws.obs is NULL_OBS
    assert ws.cache.obs is NULL_OBS


def test_disabled_span_fast_path_overhead():
    """The satellite's <2% overhead claim, asserted where it is testable
    deterministically: the per-call cost of the disabled span path is
    sub-microsecond-scale (generous 20µs/call bound vs the engine's
    multi-ms analysis calls it brackets)."""
    calls = 20_000
    t0 = time.perf_counter()
    for _ in range(calls):
        with current_obs().span("engine.x", phase="per_perm", n=40,
                                permutations=999, batch_size=32):
            pass
    per_call = (time.perf_counter() - t0) / calls
    assert per_call < 20e-6


# --------------------------------------------------------------------------
# recompile sentinel
# --------------------------------------------------------------------------
def test_sentinel_counts_traces_and_programs():
    s = CompileSentinel()
    s.note("f", (10, 32))
    s.note("f", (10, 32))
    s.note("f", (20, 32))
    s.note("g")                       # signature-less: trace count only
    assert s.traces("f") == 3 and s.programs("f") == 2
    assert s.traces("g") == 1 and s.programs("g") == 0
    snap = s.snapshot()
    s.note("f", (30, 32))
    assert s.since(snap) == {"f": {"traces": 1, "programs": 1}}
    assert s.since(s.snapshot()) == {}


def test_sentinel_expect_raises_on_budget_breach():
    s = CompileSentinel()
    with s.expect("f", max_programs=1):
        s.note("f", (1,))
    with pytest.raises(RecompileError, match="distinct programs"):
        with s.expect("f", max_programs=1):
            s.note("f", (2,))
            s.note("f", (3,))
    with pytest.raises(RecompileError, match="traces"):
        with s.expect("g", max_programs=9, max_traces=1):
            s.note("g")
            s.note("g")


def test_one_permute_reduce_program_serves_any_k():
    """THE acceptance invariant, now runtime-assertable: across two
    different permutation counts (padded per_batch path), the batched
    condensed kernel compiles exactly ONE program — jax caches the inner
    jit's trace by abstract values even across outer engine retraces.

    n=41 is unique to this test: the process-wide jit cache must be cold
    for this shape or no trace lands inside the sentinel window."""
    ws, wsy = _obs_ws(0, n=41), _obs_ws(1, n=41)
    base = sentinel.snapshot()
    with sentinel.expect("kernels.permute_reduce", max_programs=1):
        ws.mantel(wsy, permutations=49, key=KEY)   # 2 padded tiles of 32
        ws.mantel(wsy, permutations=17, key=KEY)   # 1 padded tile
    delta = sentinel.since(base)["kernels.permute_reduce"]
    assert delta == {"traces": 1, "programs": 1}
    # the engine-level counter sees both outer retraces (K is static on
    # the outer jit) but still exactly one per_batch program
    eng = sentinel.since(base)["stats.engine.per_batch"]
    assert eng["traces"] == 2 and eng["programs"] == 1


# --------------------------------------------------------------------------
# RunReport: the instrumented battery end-to-end
# --------------------------------------------------------------------------
def test_feature_backed_battery_report():
    """Acceptance: the full 6-analysis battery on an obs-enabled feature-
    backed Workspace yields a RunReport whose ledger carries every hoist,
    permutation batch and the production sweep, whose hoist passes match
    the feature-backed registry column, and whose compile window holds
    the one-program guarantee."""
    ws, wsy, wsz = _obs_ws(2), _obs_ws(3), _obs_ws(4)
    g = np.arange(40) % 4
    ws.pcoa(dimensions=5)
    ws.permanova(g, permutations=49, key=KEY)
    ws.permdisp(g, permutations=49, key=KEY, dimensions=5)
    ws.anosim(g, permutations=49, key=KEY)
    ws.mantel(wsy, permutations=49, key=KEY)
    ws.partial_mantel(wsy, wsz, permutations=49, key=KEY)

    rep = ws.report(meta={"suite": "test"})
    assert isinstance(rep, RunReport)
    assert rep.meta["backing"] == "features" and rep.meta["suite"] == "test"

    # ledger: every instrumented op charged, none double-charged
    by_op = rep.ledger["by_op"]
    for op in ("production", "hoist:condensed", "hoist:operator",
               "hoist:coords", "hoist:ranks", "hoist:moments",
               "perm:mantel", "perm:partial_mantel", "perm:anosim"):
        assert op in by_op, op
        assert by_op[op]["count"] == 1, op
    # feature-backed column: condensed .5 + operator 0 + dist_means 0 +
    # coords 2 + ranks 1 + moments .5 = 4 n²-passes for the full battery
    assert rep.hoist_passes == pytest.approx(4.0)
    assert rep.total_bytes == pytest.approx(4.0 * rep.ledger["total_floats"])
    per = perm_traffic_floats(40, 32)["condensed_fused"]
    # 49 permutations pad to 2 tiles of 32 -> 64 charged draws
    assert by_op["perm:mantel"]["floats"] == pytest.approx(per * 64)

    # spans: the ws.* roots with their hoists nested beneath
    roots = [s["name"] for s in rep.spans]
    for name in ("ws.pcoa", "ws.permanova", "ws.permdisp", "ws.anosim",
                 "ws.mantel", "ws.partial_mantel"):
        assert name in roots, name
    pcoa_span = rep.spans[roots.index("ws.pcoa")]
    nested = [c["name"] for c in pcoa_span.get("children", ())]
    assert "hoist:coords" in nested

    # cache + compile sections are live
    assert rep.cache["misses"]
    assert rep.programs("kernels.permute_reduce") >= 1

    # the document round-trips
    doc = json.loads(rep.to_json())
    assert doc["meta"]["n"] == 40
    assert doc["ledger"]["hoist_passes"] == pytest.approx(4.0)


def test_square_backed_battery_reproduces_bench_api_11_passes():
    """Acceptance: the square-backed BENCH_api battery (pcoa + permanova
    + permdisp + anosim) charges exactly the 11 n²-passes the published
    accounting reports — live, from the instrumented HoistCache."""
    dm = random_distance_matrix(KEY, 36)
    ws = Workspace(dm, config=ExecConfig(obs=ObsConfig(enabled=True)))
    g = np.arange(36) % 3
    ws.pcoa(dimensions=5)
    ws.permanova(g, permutations=49, key=KEY)
    ws.permdisp(g, permutations=49, key=KEY, dimensions=5)
    ws.anosim(g, permutations=49, key=KEY)
    rep = ws.report()
    assert rep.meta["backing"] == "distance_matrix"
    assert rep.hoist_passes == pytest.approx(11.0)
    assert rep.ledger["by_op"]["hoist:gram"]["floats"] == 4.0 * 36 * 36


def test_disabled_report_still_carries_cache_and_sentinel():
    ws = Workspace(random_distance_matrix(KEY, 12))   # obs off (default)
    ws.pcoa(dimensions=3)
    rep = ws.report()
    assert rep.spans == [] and rep.ledger == {}
    assert rep.meta["obs_enabled"] is False
    assert any("coords" in k for k in rep.cache["misses"])
    assert rep.compile == sentinel.snapshot()         # full process view


def test_report_save_roundtrip(tmp_path):
    ws = _obs_ws(5, n=16, d=4)
    ws.pcoa(dimensions=3)
    path = str(tmp_path / "report.json")
    ws.report().save(path)
    with open(path) as f:
        doc = json.load(f)
    assert doc["meta"]["n"] == 16 and doc["spans"]


def test_spans_accumulate_across_refresh_generations():
    ws = _obs_ws(6, n=16, d=4)
    ws.pcoa(dimensions=3)
    ws.refresh()
    ws.pcoa(dimensions=3)
    rep = ws.report()
    assert rep.meta["generation"] == 1
    # both generations' builds were charged to the session ledger
    assert rep.ledger["by_op"]["hoist:coords"]["count"] == 2
    # ...but the cache section reflects only the live generation
    assert sum(rep.cache["misses"].values()) < len(rep.ledger["entries"])


# --------------------------------------------------------------------------
# config plumbing
# --------------------------------------------------------------------------
def test_obs_config_validation_and_execconfig_integration():
    with pytest.raises(ValueError):
        ObsConfig(enabled="yes")
    with pytest.raises(ValueError, match="obs"):
        ExecConfig(obs="on")
    # None coerces to the disabled default; configs stay hashable pytree
    # metadata (the jit-cache key contract)
    assert ExecConfig(obs=None) == ExecConfig()
    assert hash(ExecConfig(obs=ObsConfig())) == hash(ExecConfig())
    assert ExecConfig(obs=ObsConfig(enabled=True)) != ExecConfig()
    assert not ExecConfig().obs.enabled


def test_build_report_without_session():
    rep = build_report(None, cache=None, meta={"x": 1})
    assert rep.meta["x"] == 1 and rep.cache == {}
    assert rep.spans == [] and rep.ledger == {}
