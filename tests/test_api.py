"""repro.api tests: Workspace-routed analyses vs the standalone free
functions (bitwise golden parity per key), HoistCache hit/miss accounting
("the O(n²) hoist ran once" as an assertion), ExecConfig validation and
threading, unified RNG coercion, and the pcoa dimensions regression."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExecConfig, HoistCache, Workspace
from repro.core import (DistanceMatrix, mantel, pcoa,
                        random_distance_matrix, resolve_dimensions)
from repro.stats import (anosim, as_key, partial_mantel, permanova,
                         permdisp)

KEY = jax.random.PRNGKey(7)
N = 36


def _dm(seed, n=N):
    return random_distance_matrix(jax.random.PRNGKey(seed), n)


def _grouping(n=N, k=3):
    return np.array([i % k for i in range(n)])


# --------------------------------------------------------------------------
# golden parity: Workspace-routed == standalone, bitwise, same key
# --------------------------------------------------------------------------
def test_workspace_matches_standalone_bitwise():
    """Acceptance: the session API changes how often D is read, never the
    answer — p-values, statistics and coordinates are bitwise identical
    to the free functions for the same key."""
    dm, dm2, dm3, g = _dm(0), _dm(1), _dm(2), _grouping()
    ws = Workspace(dm)

    w_pcoa = ws.pcoa(dimensions=5)
    w_perm = ws.permanova(g, permutations=49, key=KEY)
    w_disp = ws.permdisp(g, permutations=49, key=KEY, dimensions=5)
    w_anos = ws.anosim(g, permutations=49, key=KEY)
    w_mant = ws.mantel(dm2, permutations=49, key=KEY)
    w_pmant = ws.partial_mantel(dm2, dm3, permutations=49, key=KEY)

    s_pcoa = pcoa(dm, dimensions=5)
    s_perm = permanova(dm, g, permutations=49, key=KEY)
    s_disp = permdisp(dm, g, permutations=49, key=KEY, dimensions=5)
    s_anos = anosim(dm, g, permutations=49, key=KEY)
    s_mant = mantel(dm, dm2, permutations=49, key=KEY)
    s_pmant = partial_mantel(dm, dm2, dm3, permutations=49, key=KEY)

    np.testing.assert_array_equal(np.asarray(w_pcoa.coordinates),
                                  np.asarray(s_pcoa.coordinates))
    np.testing.assert_array_equal(np.asarray(w_pcoa.eigenvalues),
                                  np.asarray(s_pcoa.eigenvalues))
    for w, s in [(w_perm, s_perm), (w_disp, s_disp), (w_anos, s_anos),
                 (w_pmant, s_pmant)]:
        assert w.statistic == s.statistic
        assert w.p_value == s.p_value
    assert (w_mant.statistic, w_mant.p_value, w_mant.sample_size) == s_mant


def test_workspace_hoists_run_once():
    """Acceptance: pcoa + permanova + permdisp + anosim on one Workspace
    performs each O(n²) centering/rank hoist at most once (miss counters),
    and repeats are pure cache hits."""
    dm, g = _dm(3), _grouping()
    ws = Workspace(dm)
    ws.pcoa(dimensions=5)
    ws.permanova(g, permutations=19, key=KEY)
    ws.permdisp(g, permutations=19, key=KEY, dimensions=5)
    ws.anosim(g, permutations=19, key=KEY)

    for artifact in ("operator", "gram", "ranks"):
        assert ws.cache.build_count(artifact) <= 1, artifact
    assert ws.cache.build_count("coords") == 1      # permdisp reused pcoa's
    assert ws.cache.counts(("coords", 5, "fsvd",
                            tuple(np.asarray(jax.random.PRNGKey(42)))))[0] >= 1

    # a second round of the same analyses builds nothing new
    before = dict(ws.cache.misses)
    ws.permanova(g, permutations=19, key=KEY)
    ws.anosim(g, permutations=19, key=KEY)
    ws.pcoa(dimensions=5)
    assert dict(ws.cache.misses) == before
    assert ws.cache.hits["gram"] >= 1
    assert ws.cache.hits["ranks"] >= 1


def test_hoist_cache_counters():
    c = HoistCache()
    assert c.get("a", lambda: 41) == 41
    assert c.get("a", lambda: 99) == 41              # cached, not rebuilt
    assert c.counts("a") == (1, 1)
    assert c.build_count("a") == 1
    assert ("a" in c) and len(c) == 1
    c.get(("coords", 3), lambda: "x")
    c.get(("coords", 5), lambda: "y")
    assert c.build_count("coords") == 2


def test_workspace_mantel_shares_both_sides():
    """Both operands' moments come from their own session caches: testing
    x against two different y-sides reuses the x-side condensed moments
    (zero extra normalization passes), a shared y-Workspace is normalized
    once across sessions — and NO session builds any square artifact:
    the condensed batch loop needs neither a square hat form nor square
    distances."""
    x, y, z = Workspace(_dm(4)), Workspace(_dm(5)), Workspace(_dm(6))
    x.mantel(y, permutations=19, key=KEY)
    x.mantel(z, permutations=19, key=KEY)            # new y-side...
    x.partial_mantel(y, z, permutations=19, key=KEY)
    for ws in (x, y, z):
        assert ws.cache.build_count("moments") == 1  # ...same x-side hoist
        assert ws.cache.build_count("condensed") == 1
        assert ws.cache.build_count("square") == 0
        assert ws.cache.build_count("hat_full") == 0  # artifact retired
    # the x-side moments were HIT (reused) on the second and third tests
    assert x.cache.counts("moments")[0] >= 2


def test_workspace_mantel_family_square_free_on_features():
    """Satellite acceptance: a Mantel-family call on a feature-backed
    Workspace performs ZERO ``"square"`` cache builds — the whole family
    (and ANOSIM's ranks) runs off condensed storage."""
    k1, k2, k3 = (jax.random.PRNGKey(s) for s in (50, 51, 52))
    t = np.abs(np.asarray(jax.random.normal(k1, (30, 7))))
    ws = Workspace.from_features(t, metric="braycurtis")
    ws_y = Workspace.from_features(
        np.abs(np.asarray(jax.random.normal(k2, (30, 7)))))
    ws_z = Workspace.from_features(
        np.abs(np.asarray(jax.random.normal(k3, (30, 7)))))
    g = _grouping(30)
    ws.pcoa(dimensions=3)
    ws.permanova(g, permutations=19, key=KEY)
    ws.permdisp(g, permutations=19, key=KEY, dimensions=3)
    ws.anosim(g, permutations=19, key=KEY)
    ws.mantel(ws_y, permutations=19, key=KEY)
    ws.partial_mantel(ws_y, ws_z, permutations=19, key=KEY)
    for w in (ws, ws_y, ws_z):
        assert w.cache.build_count("square") == 0
        assert w._dm is None                    # never even wrapped one


# --------------------------------------------------------------------------
# ExecConfig
# --------------------------------------------------------------------------
def test_execconfig_validates():
    with pytest.raises(ValueError):
        ExecConfig(matvec_impl="cuda")
    with pytest.raises(ValueError):
        ExecConfig(centering_impl="bogus")
    with pytest.raises(ValueError):
        ExecConfig(kernel="cuda")
    with pytest.raises(ValueError):
        ExecConfig(centering_impl="distributed")     # needs a mesh
    with pytest.raises(ValueError):
        ExecConfig(batch_size=0)
    cfg = ExecConfig(block=128).replace(batch_size=16)
    assert cfg.block == 128 and cfg.batch_size == 16
    assert cfg.resolve_batch_size(None, 32) == 16    # config beats default
    assert cfg.resolve_batch_size(4, 32) == 4        # explicit beats config
    # leaf-free pytree: hashable, jit-static-safe
    assert not jax.tree_util.tree_leaves(cfg)
    assert hash(cfg) == hash(ExecConfig(block=128, batch_size=16))


def test_execconfig_threads_through_pallas_paths():
    """One config switches every kernel choice; results match the xla
    route (the dispatchers only change the execution schedule)."""
    dm, g = _dm(8, 24), _grouping(24)
    cfg = ExecConfig(matvec_impl="pallas", kernel="pallas", block=32)
    ws, ws_x = Workspace(dm, config=cfg), Workspace(dm)
    a = ws.pcoa(dimensions=3)
    b = ws_x.pcoa(dimensions=3)
    np.testing.assert_allclose(np.asarray(a.coordinates),
                               np.asarray(b.coordinates), atol=1e-4)
    pm = ws.partial_mantel(_dm(9, 24), _dm(10, 24), permutations=19, key=KEY)
    pm_x = ws_x.partial_mantel(_dm(9, 24), _dm(10, 24), permutations=19,
                               key=KEY)
    assert abs(pm.statistic - pm_x.statistic) < 1e-5
    assert pm.p_value == pm_x.p_value


def test_workspace_canonicalizes_and_validates():
    raw = np.asarray(_dm(11).data, dtype=np.float64)
    ws = Workspace(raw)                              # raw array accepted
    assert ws.data.dtype == jnp.float32              # canonical fp32
    assert ws.dm._validated
    with pytest.raises(Exception):
        Workspace(raw + np.eye(N))                   # non-hollow rejected
    with pytest.raises(ValueError):
        Workspace(_dm(11)).mantel(_dm(12, 20))       # shape mismatch
    with pytest.raises(ValueError):
        Workspace(_dm(11)).permanova(_grouping(12))  # grouping mismatch


def test_workspace_validate_false_is_consistent():
    """validate=False admits the matrix once for the whole session — no
    later analysis revalidates (pcoa's internal copy used to re-run the
    check the caller explicitly opted out of)."""
    bad = np.array(_dm(20, 16).data).copy()
    bad[0, 1] += 0.5                                 # asymmetric on purpose
    ws = Workspace(bad, validate=False)
    assert ws.dm._validated                          # trusted once admitted
    ws.pcoa(dimensions=3)                            # copy() must not raise
    with pytest.raises(Exception):
        Workspace(bad)                               # default still rejects
    # a directly-constructed session validates an unvalidated
    # DistanceMatrix wrapper just like a raw array...
    bad_dm = DistanceMatrix(jnp.asarray(bad), validate=False)
    with pytest.raises(Exception):
        Workspace(bad_dm)
    assert Workspace(bad_dm, validate=False).dm._validated
    # ...but the legacy free functions trust it as constructed, exactly
    # like the pre-session implementations that read dm.data directly
    r = permanova(bad_dm, _grouping(16), permutations=9, key=KEY)
    assert 0.0 < r.p_value <= 1.0


def test_workspace_collinear_control_raises():
    x, y = _dm(13), _dm(14)
    with pytest.raises(ValueError, match="collinear"):
        Workspace(x).partial_mantel(y, y, permutations=9)


# --------------------------------------------------------------------------
# unified RNG handling
# --------------------------------------------------------------------------
def test_as_key_coercion_rule():
    np.testing.assert_array_equal(np.asarray(as_key(None, default=5)),
                                  np.asarray(jax.random.PRNGKey(5)))
    np.testing.assert_array_equal(np.asarray(as_key(7)),
                                  np.asarray(jax.random.PRNGKey(7)))
    np.testing.assert_array_equal(np.asarray(as_key(np.int64(7))),
                                  np.asarray(jax.random.PRNGKey(7)))
    np.testing.assert_array_equal(np.asarray(as_key(KEY)), np.asarray(KEY))


def test_int_seed_equals_key_everywhere():
    """`key=7` and `key=PRNGKey(7)` draw identical permutations in every
    entry point (the one documented coercion rule)."""
    dm, dm2, g = _dm(15), _dm(16), _grouping()
    k7 = jax.random.PRNGKey(7)
    assert permanova(dm, g, 19, 7) == permanova(dm, g, 19, k7)
    assert anosim(dm, g, 19, 7) == anosim(dm, g, 19, k7)
    assert mantel(dm, dm2, 19, 7) == mantel(dm, dm2, 19, k7)
    a = pcoa(dm, dimensions=3, key=7)
    b = pcoa(dm, dimensions=3, key=k7)
    np.testing.assert_array_equal(np.asarray(a.coordinates),
                                  np.asarray(b.coordinates))


def test_results_record_method_and_key():
    dm, g = _dm(17), _grouping()
    ws = Workspace(dm)
    r = ws.permanova(g, permutations=19, key=7)
    assert r.method == "permanova"
    np.testing.assert_array_equal(np.asarray(r.key),
                                  np.asarray(jax.random.PRNGKey(7)))
    o = ws.pcoa(dimensions=3)
    assert o.method == "fsvd" and o.key is not None
    assert ws.pcoa(dimensions=3, method="eigh").key is None  # deterministic
    # results stay plain frozen dataclasses
    assert dataclasses.is_dataclass(r) and dataclasses.is_dataclass(o)


# --------------------------------------------------------------------------
# pcoa dimensions validation (satellite regression)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("method", ["fsvd", "eigh"])
def test_pcoa_dimensions_validation_consistent(method):
    """Regression: `dimensions <= 0` raises and `dimensions > n` clamps to
    n on BOTH solver paths (fsvd used to silently slice from the bottom of
    the spectrum for negative k)."""
    dm = _dm(18, 20)
    for bad in (0, -3):
        with pytest.raises(ValueError, match="dimensions"):
            pcoa(dm, dimensions=bad, method=method)
        with pytest.raises(ValueError, match="dimensions"):
            Workspace(dm).pcoa(dimensions=bad, method=method)
    r = pcoa(dm, dimensions=55, method=method)       # > n clamps to n
    assert r.coordinates.shape == (20, 20)
    with pytest.raises(ValueError, match="dimensions"):
        permdisp(dm, _grouping(20), permutations=9, dimensions=-1)


def test_pcoa_rejects_mismatched_prebuilt_artifacts():
    """A prebuilt hoist the taken path would silently ignore is an error —
    dropping the O(n²) artifact the caller paid for defeats its point."""
    from repro.core import CenteredGramOperator, materialized_gram
    dm = _dm(19, 16)
    op = CenteredGramOperator.from_distance(dm.data)
    g = materialized_gram(dm.data)
    with pytest.raises(ValueError, match="gram"):
        pcoa(dm, dimensions=3, gram=g)                   # runs matrix-free
    with pytest.raises(ValueError, match="operator"):
        pcoa(dm, dimensions=3, method="eigh", operator=op)
    # matched artifacts are consumed
    a = pcoa(dm, dimensions=3, operator=op)
    b = pcoa(dm, dimensions=3)
    np.testing.assert_array_equal(np.asarray(a.coordinates),
                                  np.asarray(b.coordinates))
    pcoa(dm, dimensions=3, method="eigh", gram=g)


def test_resolve_dimensions_rule():
    assert resolve_dimensions(None, 10) == 9         # scikit-bio: all axes
    assert resolve_dimensions(3, 10) == 3
    assert resolve_dimensions(99, 10) == 10          # clamp
    assert resolve_dimensions(None, 1) == 1          # degenerate floor
    for bad in (0, -1):
        with pytest.raises(ValueError):
            resolve_dimensions(bad, 10)


def test_hoist_counters_across_refresh_generations():
    """refresh() drops every artifact WITH fresh counters: the next
    analysis re-runs each hoist exactly once, and the generation-0
    tallies don't leak into the generation-1 cache."""
    dm, g = _dm(11), _grouping()
    ws = Workspace(dm)
    ws.permanova(g, permutations=19, key=KEY)
    ws.permanova(g, permutations=19, key=KEY)
    gen0 = ws.cache
    assert ws.generation == 0
    assert gen0.counts("gram") == (1, 1)             # one build, one reuse

    ws.refresh()
    assert ws.generation == 1
    assert ws.cache is not gen0                      # a NEW cache object
    assert len(ws.cache) == 0
    assert ws.cache.counts("gram") == (0, 0)         # counters start clean
    assert gen0.counts("gram") == (1, 1)             # old tallies untouched

    r0 = ws.permanova(g, permutations=19, key=KEY)
    r1 = ws.permanova(g, permutations=19, key=KEY)
    assert ws.cache.counts("gram") == (1, 1)         # hoisted exactly once
    assert r0.statistic == r1.statistic

    # re-admitting NEW data through refresh() also restarts the tallies
    ws.refresh(dm=_dm(12))
    assert ws.generation == 2 and ws.cache.counts("gram") == (0, 0)
    ws.permanova(g, permutations=19, key=KEY)
    assert ws.cache.counts("gram") == (0, 1)


def test_eigh_coords_slice_hit_path_exact_counts():
    """A lower-k eigh request is served by SLICING a cached higher-k
    solution: exactly one hit on the higher-k entry, a slice-only build
    of the lower-k entry (the gram/solve pipeline does NOT re-run), and
    the sliced coordinates are bitwise the higher-k solution's prefix."""
    ws = Workspace(_dm(13))
    full = ws.pcoa(dimensions=8, method="eigh")
    k8 = ("coords", 8, "eigh", None)
    assert ws.cache.counts(k8) == (0, 1)
    assert ws.cache.counts("gram") == (0, 1)         # eigh's one solve

    low = ws.pcoa(dimensions=3, method="eigh")
    k3 = ("coords", 3, "eigh", None)
    assert ws.cache.counts(k8) == (1, 1)             # slice source: a HIT
    assert ws.cache.counts(k3) == (0, 1)             # the slice build
    assert ws.cache.counts("gram") == (0, 1)         # and NO re-solve
    np.testing.assert_array_equal(np.asarray(low.coordinates),
                                  np.asarray(full.coordinates)[:, :3])
    np.testing.assert_array_equal(np.asarray(low.eigenvalues),
                                  np.asarray(full.eigenvalues)[:3])

    # the sliced entry is itself cached: ask again, nothing builds
    ws.pcoa(dimensions=3, method="eigh")
    assert ws.cache.counts(k3) == (1, 1)
    assert ws.cache.counts(k8) == (1, 1)             # not consulted again

    # slicing picks the SMALLEST covering solution once several exist
    ws.pcoa(dimensions=12, method="eigh")            # k=12 solve (miss)
    ws.pcoa(dimensions=6, method="eigh")             # 6 ≤ 8 < 12 -> from k8
    assert ws.cache.counts(k8) == (2, 1)
    assert ws.cache.counts(("coords", 12, "eigh", None)) == (0, 1)
