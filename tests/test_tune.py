"""repro.tune tests: ledger/model parity (the published BENCH numbers
reproduced from the cost model), solver properties (fits-the-budget,
monotone traffic, K-independence, the int32 triangle guard, shrink-only
feature_block), the ExecConfig auto plumbing, and the acceptance
battery — an ``ExecConfig(auto=True)`` session must be bitwise-identical
per key to the default-config run while never modeling more traffic.
"""

import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.api.config import ExecConfig
from repro.api.workspace import Workspace
from repro.core.distance_matrix import MAX_TRIANGLE_N, random_distance_matrix
from repro.core.mantel import MantelStatistic
from repro.obs import sentinel
from repro.obs.ledger import (HOIST_PASSES, perm_traffic_floats,
                              production_floats)
from repro.stats import permutation_test
from repro.tune import (BackendBudget, calibrate, detect_budget,
                        load_profile, perm_batch_cost, production_cost,
                        save_profile, solve_tiles)
from repro.tune.model import (SQUARE_SESSION_ARTIFACTS,
                              STANDALONE_SESSION_ARTIFACTS,
                              session_hoist_passes)
from repro.tune.solve import (BATCH_MAX, DEFAULT_BATCH, DEFAULT_BLOCK,
                              DEFAULT_CHUNK, DEFAULT_FEATURE_BLOCK)

KEY = jax.random.PRNGKey(7)


def _budget(working_bytes, backend="cpu"):
    return BackendBudget(backend=backend, working_bytes=working_bytes,
                         capacity_bytes=32 * 2**20, bandwidth=3e10,
                         latency=30e-6)


# --------------------------------------------------------------------------
# ledger/model parity — the two can never drift
# --------------------------------------------------------------------------
def test_model_reproduces_published_mantel_ratio():
    """The cost model's perm term IS the ledger's: the 10.97x headline
    (square_gather / condensed_fused at n=2048, B=32) falls out of
    ``perm_batch_cost`` untouched."""
    cost = perm_batch_cost(2048, 32, 65536, s=1)
    ledger = perm_traffic_floats(2048, 32)
    assert cost.traffic_floats == ledger["condensed_fused"]
    assert ledger["square_gather"] / cost.traffic_floats == \
        pytest.approx(10.97, abs=0.005)


def test_model_reproduces_published_api_session_passes():
    """The 11-vs-16 BENCH_api accounting from the model's session
    artifact lists + the ledger's pass table."""
    assert session_hoist_passes(SQUARE_SESSION_ARTIFACTS) == 11.0
    assert session_hoist_passes(STANDALONE_SESSION_ARTIFACTS) == 16.0
    # and the feature-backed column discounts, never inflates
    assert session_hoist_passes(SQUARE_SESSION_ARTIFACTS,
                                feature_backed=True) < 11.0


def test_model_production_parity_with_ledger():
    """``production_cost`` prices traffic with the ledger function
    itself, at every (n, d, block) point."""
    for n, d, b in [(100, 10, 32), (2048, 128, 256), (64, 8, 512)]:
        assert production_cost(n, d, b).traffic_floats == \
            production_floats(n, d, b)


def test_model_traffic_monotone_in_n_and_k():
    """Modeled traffic is monotone non-decreasing in n (per
    permutation) and in K (trivially linear: per-perm × K) — the
    sanity property that keeps the solver's argmin meaningful."""
    per_perm = [perm_batch_cost(n, 32, 65536).traffic_floats
                for n in (64, 128, 512, 2048, 4096)]
    assert all(a <= b for a, b in zip(per_perm, per_perm[1:]))
    prod = [production_cost(n, 64, 256).traffic_floats
            for n in (64, 128, 512, 2048)]
    assert all(a <= b for a, b in zip(prod, prod[1:]))
    for k1, k2 in [(99, 999), (999, 9999)]:
        assert per_perm[0] * k1 <= per_perm[0] * k2


# --------------------------------------------------------------------------
# solver properties
# --------------------------------------------------------------------------
def test_solver_choices_fit_stated_budget():
    """Property: across a spread of (n, d, budget), every solved tile's
    modeled tunable resident set fits the budget it was solved for."""
    rng = np.random.default_rng(0)
    for _ in range(25):
        n = int(rng.integers(8, 3000))
        d = int(rng.integers(2, 800))
        budget = _budget(int(rng.integers(256, 16 * 1024)) * 1024)
        t = solve_tiles(n, d, budget=budget)
        bf = budget.working_floats
        assert perm_batch_cost(n, t.batch_size, t.chunk,
                               s=2).resident_floats <= bf
        assert production_cost(n, d, t.block,
                               t.feature_block).resident_floats <= bf


def test_solver_never_models_worse_than_defaults():
    """The BENCH_tune gate at test scale: for every op the solved tiles
    model <= the effective traffic of the hand-picked constants, on
    loose and tight budgets alike."""
    for wb in (256 * 1024, 1 * 2**20, 16 * 2**20):
        for n, d in [(48, 8), (512, 64), (2048, 128), (300, None)]:
            t = solve_tiles(n, d, budget=_budget(wb))
            td = t.to_dict()
            for op in td["modeled"]:
                assert (td["modeled"][op]["traffic_floats"]
                        <= td["modeled_default"][op]["traffic_floats"]), \
                    (op, n, d, wb)


def test_solver_is_k_independent_and_capped():
    """batch/chunk are functions of (n, budget) only — no K parameter
    exists to leak into the engine's trace signature — and the batch
    caps at BATCH_MAX regardless of headroom."""
    import inspect
    assert "K" not in inspect.signature(solve_tiles).parameters
    assert "permutations" not in inspect.signature(solve_tiles).parameters
    t = solve_tiles(64, budget=_budget(64 * 2**20))
    assert t.batch_size <= BATCH_MAX


def test_solver_respects_int32_triangle_guard():
    """Satellite bugfix: the solver refuses n past the int32 triangle
    bound up front — auto-tuning can never hand the permutation kernels
    an n whose closed-form index would overflow."""
    with pytest.raises(ValueError, match="int32 triangle"):
        solve_tiles(MAX_TRIANGLE_N + 1)
    # the bound itself is fine
    t = solve_tiles(MAX_TRIANGLE_N, budget=_budget(2**20))
    assert t.batch_size >= 1


def test_solver_feature_block_and_block_shrink_only():
    """feature_block (per-chunk accumulator merges) and block (matvec
    row-panel partial sums) are value-affecting, so the solver may only
    ever SHRINK them from the defaults — with a roomy budget it returns
    the defaults exactly, which is what makes auto bitwise-identical to
    the default run whenever the default fits."""
    for wb in (64 * 1024, 2**20, 16 * 2**20):
        for n, d in [(128, 16), (2048, 512), (1000, 4)]:
            t = solve_tiles(n, d, budget=_budget(wb))
            assert t.feature_block <= min(DEFAULT_FEATURE_BLOCK, d)
            assert t.block <= DEFAULT_BLOCK
    roomy = solve_tiles(2048, 64, budget=_budget(64 * 2**20))
    assert roomy.block == DEFAULT_BLOCK
    assert roomy.feature_block == min(DEFAULT_FEATURE_BLOCK, 64)


def test_solved_defaults_match_constants():
    """The solver's one authoritative copy of each hand-picked constant
    is pinned against the modules that execute them."""
    from repro.kernels import permute_reduce_ops
    from repro.dist import driver
    assert DEFAULT_CHUNK == permute_reduce_ops.DEFAULT_CHUNK
    assert DEFAULT_BLOCK == driver._DEFAULT_BLOCK
    assert DEFAULT_FEATURE_BLOCK == driver._DEFAULT_FEATURE_BLOCK
    assert DEFAULT_BATCH == 32          # the Workspace battery default


# --------------------------------------------------------------------------
# budget: defaults, calibration, profile round-trip
# --------------------------------------------------------------------------
def test_detect_budget_backends():
    for be in ("cpu", "tpu", "gpu"):
        b = detect_budget(be)
        assert b.backend == be and b.working_bytes > 0
        assert b.working_bytes <= b.capacity_bytes
    assert detect_budget().backend == jax.default_backend()


def test_calibration_profile_roundtrip(tmp_path):
    """calibrate() measures rate constants only (capacities stay
    static), and profiles survive the JSON round-trip."""
    base = detect_budget()
    cal = calibrate(base, small=1 << 10, large=1 << 16, reps=2)
    assert cal.source == "calibrated"
    assert cal.bandwidth > 0 and cal.latency >= 0
    assert cal.working_bytes == base.working_bytes
    path = str(tmp_path / "profile.json")
    save_profile(cal, path)
    loaded = load_profile(path)
    assert loaded.source == "profile"
    assert loaded.bandwidth == cal.bandwidth
    assert loaded.working_bytes == cal.working_bytes
    # and the solver accepts it
    t = solve_tiles(64, profile=path)
    assert t.budget.source == "profile"


# --------------------------------------------------------------------------
# ExecConfig auto plumbing
# --------------------------------------------------------------------------
def test_execconfig_accepts_and_validates_auto():
    ExecConfig(block="auto", feature_block="auto", batch_size="auto",
               chunk="auto")               # all fine
    assert ExecConfig(auto=True).needs_resolution
    assert ExecConfig(chunk="auto").needs_resolution
    assert not ExecConfig().needs_resolution
    for bad in ({"block": 0}, {"block": "big"}, {"chunk": -3},
                {"batch_size": "autotune"}, {"feature_block": 0}):
        with pytest.raises(ValueError):
            ExecConfig(**bad)
    # configs with auto knobs stay hashable (leaf-free pytree contract)
    hash(ExecConfig(auto=True))
    hash(ExecConfig(block="auto"))


def test_execconfig_resolve_materializes_all_knobs():
    cfg, tuned = ExecConfig(auto=True).resolve(256, 32)
    assert not cfg.needs_resolution and not cfg.auto
    for knob in ("block", "feature_block", "batch_size", "chunk"):
        assert isinstance(getattr(cfg, knob), int), knob
    assert tuned is not None and tuned.n == 256
    # no-op without auto semantics
    plain = ExecConfig()
    assert plain.resolve(256, 32) == (plain, None)


def test_execconfig_resolve_honors_explicit_knobs():
    """auto=True only solves knobs left at their defaults — explicitly
    pinned values pass through untouched."""
    cfg, tuned = ExecConfig(auto=True, block=64, chunk=2048).resolve(512, 16)
    assert cfg.block == 64 and cfg.chunk == 2048
    assert isinstance(cfg.batch_size, int)          # this one was solved
    assert tuned is not None


# --------------------------------------------------------------------------
# the acceptance battery: auto end-to-end, bitwise vs default
# --------------------------------------------------------------------------
def _feature_sessions(config):
    rng = np.random.default_rng(3)
    mk = lambda: rng.random((48, 12), dtype=np.float32) + 0.01  # noqa: E731
    return (Workspace.from_features(mk(), config=config),
            Workspace.from_features(mk(), config=config),
            Workspace.from_features(mk(), config=config))


def test_auto_battery_bitwise_identical_to_default():
    """ExecConfig(auto=True) end-to-end on a feature-backed session:
    every tile solver-chosen, every analysis result bitwise-identical
    per key to the default-config run."""
    ws_d, wy_d, wz_d = _feature_sessions(ExecConfig())
    ws_a, wy_a, wz_a = _feature_sessions(ExecConfig(auto=True))
    assert ws_a.tuned is not None
    g = np.arange(48) % 4

    ca = ws_a.pcoa(dimensions=6).coordinates
    cd = ws_d.pcoa(dimensions=6).coordinates
    assert (np.asarray(ca) == np.asarray(cd)).all()

    pairs = [
        (ws_a.permanova(g, permutations=99, key=KEY),
         ws_d.permanova(g, permutations=99, key=KEY)),
        (ws_a.anosim(g, permutations=99, key=KEY),
         ws_d.anosim(g, permutations=99, key=KEY)),
        (ws_a.permdisp(g, permutations=99, key=KEY, dimensions=6),
         ws_d.permdisp(g, permutations=99, key=KEY, dimensions=6)),
        (ws_a.mantel(wy_a, permutations=99, key=KEY),
         ws_d.mantel(wy_d, permutations=99, key=KEY)),
        (ws_a.partial_mantel(wy_a, wz_a, permutations=99, key=KEY),
         ws_d.partial_mantel(wy_d, wz_d, permutations=99, key=KEY)),
    ]
    for ra, rd in pairs:
        assert float(ra.statistic) == float(rd.statistic)
        assert float(ra.p_value) == float(rd.p_value)


def test_auto_one_program_serves_every_k():
    """Satellite bugfix gate: auto-tuning must not reintroduce the
    trailing-block recompile — the solved batch_size is K-independent,
    so different K values share ONE padded per_batch program and ONE
    kernels.permute_reduce program."""
    dm = random_distance_matrix(jax.random.PRNGKey(5), 40)
    dm2 = random_distance_matrix(jax.random.PRNGKey(6), 40)
    ws = Workspace(dm, config=ExecConfig(auto=True))
    with sentinel.expect("kernels.permute_reduce", max_programs=1):
        with sentinel.expect("stats.engine.per_batch", max_programs=1):
            ws.mantel(dm2, permutations=49, key=KEY)
            ws.mantel(dm2, permutations=17, key=KEY)
            ws.mantel(dm2, permutations=128, key=KEY)


def test_engine_batch_size_auto_resolves():
    """A config that never went through Workspace admission still
    resolves ``batch_size='auto'`` inside the engine, against the
    statistic's n."""
    x = random_distance_matrix(jax.random.PRNGKey(0), 36)
    y = random_distance_matrix(jax.random.PRNGKey(1), 36)
    stat = MantelStatistic(x.data, y.data, 36)
    r_auto = permutation_test(stat, permutations=45, key=KEY,
                              config=ExecConfig(batch_size="auto"))
    r_def = permutation_test(stat, permutations=45, key=KEY)
    assert float(r_auto.statistic) == float(r_def.statistic)
    assert float(r_auto.p_value) == float(r_def.p_value)


# --------------------------------------------------------------------------
# knob invariance (extends the engine batch-size invariance to the
# remaining tuned knobs)
# --------------------------------------------------------------------------
def test_results_invariant_to_block():
    """block tiles ROWS: each produced distance is computed from the
    full feature vector regardless of panel membership, so the
    condensed matrix is bitwise-invariant across tile sizes. The
    matvec-backed ordination re-associates panel partial sums, so
    coordinates are only fp-equal — which is why the solver treats
    block as freely tunable for production but the battery pins
    p-values, not coords, across blocks."""
    rng = np.random.default_rng(3)
    feats = rng.random((48, 12), dtype=np.float32) + 0.01
    base_dm = None
    for blk in (16, 48, 256, 1024):
        cond = np.asarray(Workspace.from_features(
            feats, config=ExecConfig(block=blk)).condensed())
        if base_dm is None:
            base_dm = cond
        else:
            assert (cond == base_dm).all(), blk

    dm = random_distance_matrix(jax.random.PRNGKey(2), 48)
    base_c = None
    for blk in (16, 48, 256):
        c = np.asarray(Workspace(
            dm, config=ExecConfig(block=blk)).pcoa(dimensions=5).coordinates)
        if base_c is None:
            base_c = c
        else:
            assert np.allclose(c, base_c, atol=1e-4), blk


def test_pvalues_invariant_to_chunk():
    """The observed statistic is chunk-free (the per_perm path never
    chunks) and, at a fixed key, the null count — hence the p-value —
    is stable across chunk choices."""
    x = random_distance_matrix(jax.random.PRNGKey(0), 36)
    y = random_distance_matrix(jax.random.PRNGKey(1), 36)
    rs = [permutation_test(
            MantelStatistic(x.data, y.data, 36, chunk=c),
            permutations=45, key=KEY, batch_size=8)
          for c in (None, 64, 256, 630)]
    for r in rs[1:]:
        assert float(r.statistic) == float(rs[0].statistic)
        assert float(r.p_value) == float(rs[0].p_value)


def test_feature_block_shrunk_results_close():
    """feature_block IS value-affecting (per-chunk merges) — a shrunk
    chunk must stay allclose and deliver the same p-values at test
    scale, which is why the solver only ever shrinks it."""
    rng = np.random.default_rng(9)
    feats = rng.random((40, 24), dtype=np.float32) + 0.01
    g = np.arange(40) % 4
    r1 = Workspace.from_features(
        feats, config=ExecConfig(feature_block=24)).permanova(
            g, permutations=49, key=KEY)
    r2 = Workspace.from_features(
        feats, config=ExecConfig(feature_block=8)).permanova(
            g, permutations=49, key=KEY)
    assert float(r1.statistic) == pytest.approx(float(r2.statistic),
                                                rel=1e-5)
    assert float(r1.p_value) == float(r2.p_value)


# --------------------------------------------------------------------------
# reporting
# --------------------------------------------------------------------------
def test_report_surfaces_resolved_tiles():
    """Satellite: report() shows the EXECUTED geometry — post-tune,
    post-snap — not just the requested knob values."""
    dm = random_distance_matrix(jax.random.PRNGKey(4), 30)
    ws = Workspace(dm, config=ExecConfig(auto=True))
    doc = ws.report().to_dict()
    tiles = doc["meta"]["tiles"]
    assert tiles["auto"] is True
    assert tiles["block_executed"] <= 30
    assert tiles["chunk_executed"] <= 30 * 29 // 2 + 7
    assert doc["meta"]["tune"]["n"] == 30
    assert doc["meta"]["tune"]["budget"]["backend"] == \
        jax.default_backend()
    # round-trips through JSON (CI uploads reports)
    json.dumps(doc)

    # a default session reports requested == executed-ish geometry and
    # no tune section
    ws2 = Workspace(dm)
    doc2 = ws2.report().to_dict()
    assert doc2["meta"]["tiles"]["auto"] is False
    assert "tune" not in doc2["meta"]
    assert ws2.config_requested is ws2.config


def test_workspace_refresh_resolves_for_new_n():
    """refresh(dm=...) with a different n re-solves from the REQUESTED
    config — the tuned tiles track the admitted data."""
    dm1 = random_distance_matrix(jax.random.PRNGKey(1), 24)
    dm2 = random_distance_matrix(jax.random.PRNGKey(2), 120)
    ws = Workspace(dm1, config=ExecConfig(auto=True))
    t1 = dataclasses.replace(ws.tuned)
    ws.refresh(dm=dm2)
    assert ws.tuned.n == 120 and t1.n == 24
    assert ws.config_requested.auto      # the intent survives
    assert not ws.config.auto            # the resolution is concrete
