"""repro.stats subsystem tests: engine protocol, each fused statistic vs
its eager scikit-bio-style oracle (statistic AND p-value, same PRNG key),
the refactored core.mantel engine path, and the distributed engine."""

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mantel, mantel_ref, random_distance_matrix
from repro.core.mantel import MantelStatistic
from repro.stats import (anosim, anosim_ref, partial_mantel,
                         partial_mantel_ref, permanova, permanova_ref,
                         permdisp, permdisp_ref, permutation_test,
                         permutation_test_distributed)
from repro.stats.engine import encode_grouping, permutation_orders
from repro.stats.permanova import PermanovaStatistic

KEY = jax.random.PRNGKey(7)


def _dm(seed, n=36):
    return random_distance_matrix(jax.random.PRNGKey(seed), n)


def _grouping(n=36, k=3):
    return np.array([i % k for i in range(n)])


# --------------------------------------------------------------------------
# engine: the refactored mantel path is pinned against the oracle
# --------------------------------------------------------------------------
@pytest.mark.parametrize("alternative", ["two-sided", "greater", "less"])
def test_mantel_engine_matches_ref_all_alternatives(alternative):
    """Same key ⇒ identical permutations ⇒ identical p-value, any tail."""
    x, y = _dm(0), _dm(1)
    s_opt, p_opt, n_opt = mantel(x, y, permutations=48, key=KEY,
                                 alternative=alternative)
    s_ref, p_ref, n_ref = mantel_ref(x, y, permutations=48, key=KEY,
                                     alternative=alternative)
    assert abs(s_opt - s_ref) < 1e-5
    assert abs(p_opt - p_ref) < 1e-9
    assert n_opt == n_ref == 36


def test_engine_runs_custom_statistic():
    """The protocol is pluggable: a toy statistic goes through unchanged."""

    @partial(jax.tree_util.register_dataclass,
             data_fields=["v"], meta_fields=["n"])
    @dataclasses.dataclass
    class FirstElement:
        v: jax.Array
        n: int

        def hoist(self):
            return {"v": self.v}

        def per_perm(self, inv, order):
            return inv["v"][order[0]]

    v = jnp.arange(10.0)
    r = permutation_test(FirstElement(v, 10), permutations=33, key=KEY)
    assert r.statistic == 0.0                      # identity order → v[0]
    assert 0.0 < r.p_value <= 1.0
    assert r.sample_size == 10 and r.permutations == 33


def test_engine_per_batch_single_trace_any_k():
    """Satellite acceptance: the per_batch path pads orders to FULL
    batch_size tiles (wrapping real permutations) and masks the tail, so
    one jit trace serves every K — the pre-change engine traced a second
    program whenever batch_size didn't divide K (e.g. the canonical
    999 % 32)."""
    traced_shapes = []

    @partial(jax.tree_util.register_dataclass,
             data_fields=["v"], meta_fields=["n"])
    @dataclasses.dataclass
    class Probe:
        v: jax.Array
        n: int

        def hoist(self):
            return {"v": self.v}

        def per_perm(self, inv, order):
            return inv["v"][order[0]]

        def per_batch(self, inv, orders):
            traced_shapes.append(tuple(orders.shape))   # records per TRACE
            return inv["v"][orders[:, 0]]

    r = permutation_test(Probe(jnp.arange(10.0), 10), permutations=999,
                         key=KEY, batch_size=32)
    assert traced_shapes == [(32, 10)]     # one trace, full tiles only
    assert r.permutations == 999 and 0.0 < r.p_value <= 1.0
    # batch_size > K still runs (one padded tile) without a second trace
    traced_shapes.clear()
    r2 = permutation_test(Probe(jnp.arange(10.0) + 1.0, 10),
                          permutations=5, key=KEY, batch_size=8)
    assert traced_shapes == [(8, 10)]
    assert r2.permutations == 5


def test_engine_results_invariant_to_batch_size():
    """The tile size is an execution knob, never a semantic one: any
    batch_size (dividing K or not) gives bitwise-identical statistics
    and p-values for the same key, on the batch-fused mantel path."""
    x, y = _dm(0), _dm(1)
    rs = [permutation_test(MantelStatistic(x.data, y.data, len(x)),
                           permutations=45, key=KEY, batch_size=bs)
          for bs in (1, 7, 32, 64)]
    for r in rs[1:]:
        assert r.statistic == rs[0].statistic
        assert r.p_value == rs[0].p_value


def test_engine_rejects_bad_alternative():
    x, y = _dm(0), _dm(1)
    with pytest.raises(ValueError):
        mantel(x, y, permutations=4, alternative="bogus")
    with pytest.raises(ValueError):
        permutation_test(MantelStatistic(x.data, y.data, len(x)),
                         permutations=4, alternative="bogus")


def test_encode_grouping():
    codes, k = encode_grouping(["a", "b", "a", "c", "b", "a"])
    assert k == 3
    assert codes.tolist() == [0, 1, 0, 2, 1, 0]
    with pytest.raises(ValueError):
        encode_grouping(["a", "a", "a"])           # one group
    with pytest.raises(ValueError):
        encode_grouping(["a", "b", "c"])           # all singletons


# --------------------------------------------------------------------------
# permanova
# --------------------------------------------------------------------------
def test_permanova_fused_matches_ref():
    dm, g = _dm(2), _grouping()
    got = permanova(dm, g, permutations=99, key=KEY)
    want = permanova_ref(dm, g, permutations=99, key=KEY)
    assert abs(got.statistic - want.statistic) < 1e-5
    assert abs(got.p_value - want.p_value) < 1e-9


def test_permanova_detects_group_structure():
    """Points drawn around well-separated group centroids ⇒ huge F, p→min."""
    key = jax.random.PRNGKey(3)
    n, k = 45, 3
    g = _grouping(n, k)
    centers = 25.0 * jax.random.normal(key, (k, 4))
    pts = centers[g] + jax.random.normal(jax.random.fold_in(key, 1), (n, 4))
    d = jnp.sqrt(jnp.maximum(
        jnp.sum((pts[:, None] - pts[None, :]) ** 2, -1), 0))
    d = 0.5 * (d + d.T)
    from repro.core import DistanceMatrix
    dm = DistanceMatrix(d - jnp.diag(jnp.diag(d)), _skip_validation=True)
    r = permanova(dm, g, permutations=99, key=KEY)
    assert r.statistic > 50.0
    assert r.p_value == pytest.approx(1 / 100)
    # and no structure ⇒ F near 1, p not extreme
    r0 = permanova(_dm(4, n), g, permutations=99, key=KEY)
    assert r0.p_value > 0.05


def test_permanova_string_labels_and_validation():
    dm = _dm(5)
    labels = ["ctl" if i % 3 else "trt" for i in range(36)]
    r = permanova(dm, labels, permutations=49, key=KEY)
    assert 0.0 < r.p_value <= 1.0
    with pytest.raises(ValueError):
        permanova(dm, _grouping(12), permutations=9)   # length mismatch


# --------------------------------------------------------------------------
# anosim
# --------------------------------------------------------------------------
def test_anosim_fused_matches_ref():
    dm, g = _dm(6), _grouping()
    got = anosim(dm, g, permutations=99, key=KEY)
    want = anosim_ref(dm, g, permutations=99, key=KEY)
    assert abs(got.statistic - want.statistic) < 1e-5
    assert abs(got.p_value - want.p_value) < 1e-9


def test_anosim_r_range_and_structure():
    """R ∈ [−1, 1]; separated groups ⇒ R → 1 with minimal p."""
    key = jax.random.PRNGKey(8)
    n, k = 40, 4
    g = _grouping(n, k)
    centers = 50.0 * jax.random.normal(key, (k, 3))
    pts = centers[g] + jax.random.normal(jax.random.fold_in(key, 1), (n, 3))
    d = jnp.sqrt(jnp.maximum(
        jnp.sum((pts[:, None] - pts[None, :]) ** 2, -1), 0))
    d = 0.5 * (d + d.T)
    from repro.core import DistanceMatrix
    dm = DistanceMatrix(d - jnp.diag(jnp.diag(d)), _skip_validation=True)
    r = anosim(dm, g, permutations=99, key=KEY)
    assert 0.9 < r.statistic <= 1.0
    assert r.p_value == pytest.approx(1 / 100)
    r0 = anosim(_dm(9, n), g, permutations=99, key=KEY)
    assert -1.0 <= r0.statistic <= 1.0


# --------------------------------------------------------------------------
# permdisp
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n,k,perms", [(32, 3, 99), (27, 3, 49)])
def test_permdisp_fused_matches_ref(n, k, perms):
    """Acceptance: identical keys ⇒ identical permutation orders ⇒
    identical p-values, fused (matrix-free PCoA coords) vs eager oracle."""
    dm, g = _dm(23, n), _grouping(n, k)
    got = permdisp(dm, g, permutations=perms, key=KEY)
    want = permdisp_ref(dm, g, permutations=perms, key=KEY)
    assert abs(got.statistic - want.statistic) < 1e-4 * max(
        abs(want.statistic), 1.0)
    assert abs(got.p_value - want.p_value) < 1e-9


def test_permdisp_detects_dispersion_difference():
    """Two groups around one centroid, radically different spreads ⇒ huge
    F and the minimal p; equal spreads ⇒ F near 1, p not extreme."""
    key = jax.random.PRNGKey(30)
    n = 40
    g = _grouping(n, 2)
    scales = jnp.where(jnp.asarray(g) == 0, 0.05, 5.0)[:, None]
    pts = scales * jax.random.normal(key, (n, 3))
    d = jnp.sqrt(jnp.maximum(
        jnp.sum((pts[:, None] - pts[None, :]) ** 2, -1), 0))
    d = 0.5 * (d + d.T)
    from repro.core import DistanceMatrix
    dm = DistanceMatrix(d - jnp.diag(jnp.diag(d)), _skip_validation=True)
    r = permdisp(dm, g, permutations=99, key=KEY)
    assert r.statistic > 10.0
    assert r.p_value == pytest.approx(1 / 100)
    r0 = permdisp(_dm(34, n), g, permutations=99, key=KEY)
    assert r0.p_value > 0.05


def test_permdisp_low_dimensional_and_eigh():
    """dimensions=k truncation and the eigh coordinate path both run and
    stay consistent with each other on low-rank (dim=8 < k) input."""
    dm, g = _dm(32), _grouping()
    a = permdisp(dm, g, permutations=49, key=KEY, dimensions=12)
    b = permdisp(dm, g, permutations=49, key=KEY, dimensions=12,
                 method="eigh")
    assert abs(a.statistic - b.statistic) < 1e-3 * max(abs(b.statistic), 1.0)
    assert abs(a.p_value - b.p_value) < 1e-9


def test_permdisp_validation():
    dm = _dm(33)
    with pytest.raises(ValueError):
        permdisp(dm, _grouping(12), permutations=9)    # length mismatch
    with pytest.raises(ValueError):
        permdisp(dm, ["a"] * 36, permutations=9)       # one group


# --------------------------------------------------------------------------
# partial mantel
# --------------------------------------------------------------------------
def test_partial_mantel_fused_matches_ref():
    x, y, z = _dm(10), _dm(11), _dm(12)
    got = partial_mantel(x, y, z, permutations=48, key=KEY)
    want = partial_mantel_ref(x, y, z, permutations=48, key=KEY)
    assert abs(got.statistic - want.statistic) < 1e-5
    assert abs(got.p_value - want.p_value) < 1e-9


def test_partial_mantel_pallas_kernel_path():
    """The per-batch route through kernels.mantel_corr gives the same test.

    K=35 with the default batch of 8 leaves a remainder block of 3: the
    engine must still route every permutation through per_batch."""
    x, y, z = _dm(13, 24), _dm(14, 24), _dm(15, 24)
    xla = partial_mantel(x, y, z, permutations=35, key=KEY, kernel="xla")
    pal = partial_mantel(x, y, z, permutations=35, key=KEY, kernel="pallas")
    assert abs(xla.statistic - pal.statistic) < 1e-5
    assert abs(xla.p_value - pal.p_value) < 1e-9
    with pytest.raises(ValueError):
        partial_mantel(x, y, z, permutations=8, kernel="cuda")


def test_partial_mantel_rejects_collinear_control():
    """z == y makes the residualization 0/0 — must raise, not report the
    most significant p-value via NaN comparisons."""
    x, y = _dm(20), _dm(21)
    with pytest.raises(ValueError, match="collinear"):
        partial_mantel(x, y, y, permutations=9)


def test_partial_mantel_controls_for_confounder():
    """y == x ⇒ partial r stays ~1 whatever z; controlling x's own driver
    z == x must *not* report spurious correlation against independent y."""
    x, z = _dm(16), _dm(17)
    r_same = partial_mantel(x, x, z, permutations=32, key=KEY)
    assert r_same.statistic > 0.99
    y_indep = _dm(18)
    r_ctl = partial_mantel(x, y_indep, x, permutations=99, key=KEY)
    assert abs(r_ctl.statistic) < 0.2
    assert r_ctl.p_value > 0.01


# --------------------------------------------------------------------------
# distributed engine (1-device mesh on CPU: exercises the shard_map path)
# --------------------------------------------------------------------------
def test_engine_distributed_single_device_mesh():
    from jax.sharding import Mesh

    n = 32
    dm = _dm(19, n)
    codes, k = encode_grouping(_grouping(n, 4))
    stat = PermanovaStatistic(dm.data, jnp.asarray(codes), n, k)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
    r = permutation_test_distributed(stat, mesh, permutations=64, key=KEY,
                                     alternative="greater")
    r_host = permutation_test(stat, permutations=64, key=KEY,
                              alternative="greater")
    # same observed statistic; the null draws differ (per-device fold_in)
    assert abs(r.statistic - r_host.statistic) < 1e-5
    assert 0.0 < r.p_value <= 1.0
    assert r.permutations == 64
    with pytest.raises(ValueError):
        permutation_test_distributed(stat, mesh, permutations=64,
                                     alternative="bogus")


def test_permutation_orders_deterministic():
    a = permutation_orders(KEY, 5, 12)
    b = permutation_orders(KEY, 5, 12)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for row in np.asarray(a):
        assert sorted(row.tolist()) == list(range(12))
