"""CenteredGramOperator + matrix-free PCoA: the operator must be an exact
drop-in for ``center_distance_matrix(D) @ X``, and the matrix-free fsvd
must match the materialized eigh oracle — the PR 2 acceptance gates."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CenteredGramOperator,
                        centered_gram_matvec_distributed, pcoa,
                        random_distance_matrix)
from repro.core.centering import center_distance_matrix


def _matvec_case(n, seed, k=7):
    d = random_distance_matrix(jax.random.PRNGKey(seed), n).data
    x = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(seed), 1),
                          (n, k))
    return d, x, center_distance_matrix(d) @ x


# --------------------------------------------------------------------------
# operator vs materialized centering
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n", [7, 33, 65, 101, 128])
def test_matvec_matches_materialized_odd_n(n):
    """F@X without F, across n that are not block multiples (block=32)."""
    d, x, want = _matvec_case(n, seed=n)
    got = CenteredGramOperator.from_distance(d, block=32).matvec(x)
    scale = np.abs(np.asarray(want)).max()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5 * max(scale, 1.0))


@pytest.mark.parametrize("n", [16, 77, 96])
def test_matvec_pallas_impl_matches(n):
    d, x, want = _matvec_case(n, seed=n + 1)
    got = CenteredGramOperator.from_distance(d, block=32,
                                             impl="pallas").matvec(x)
    scale = np.abs(np.asarray(want)).max()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5 * max(scale, 1.0))


def test_matvec_1d_vector_roundtrip():
    d, _, _ = _matvec_case(24, seed=3)
    v = jax.random.normal(jax.random.PRNGKey(9), (24,))
    op = CenteredGramOperator.from_distance(d)
    got = op.matvec(v)
    assert got.shape == (24,)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(center_distance_matrix(d) @ v),
                               rtol=1e-4, atol=1e-4)


def test_trace_exact():
    """tr(F) from the hoisted sums == trace of the materialized matrix."""
    d = random_distance_matrix(jax.random.PRNGKey(2), 67).data
    op = CenteredGramOperator.from_distance(d)
    want = float(jnp.trace(center_distance_matrix(d)))
    assert abs(float(op.trace()) - want) < 1e-3 * max(abs(want), 1.0)
    assert float(op.trace()) > 0.0


def test_operator_crosses_jit_boundary():
    """The pytree registration: a jitted consumer caches per (shape, meta)."""
    d, x, want = _matvec_case(32, seed=5)

    @jax.jit
    def consume(op, x):
        return op.matvec(x) + op.trace()

    got = consume(CenteredGramOperator.from_distance(d, block=16), x)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want) +
                               float(jnp.trace(center_distance_matrix(d))),
                               rtol=1e-4, atol=1e-3)


def test_operator_rejects_unknown_impl():
    d = random_distance_matrix(jax.random.PRNGKey(0), 8).data
    with pytest.raises(ValueError):
        CenteredGramOperator.from_distance(d, impl="cuda")


def test_materialize_is_the_fused_centering():
    d = random_distance_matrix(jax.random.PRNGKey(4), 40).data
    op = CenteredGramOperator.from_distance(d)
    np.testing.assert_allclose(np.asarray(op.materialize()),
                               np.asarray(center_distance_matrix(d)),
                               rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------
# distributed matvec (1-device mesh on CPU: exercises the shard_map path)
# --------------------------------------------------------------------------
def test_matvec_distributed_single_device_mesh():
    from jax.sharding import Mesh

    d, x, want = _matvec_case(32, seed=6)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    got = centered_gram_matvec_distributed(d, x, mesh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# matrix-free pcoa — the acceptance gate
# --------------------------------------------------------------------------
def test_matrix_free_fsvd_matches_eigh_oracle_n512():
    """Acceptance: matrix-free fsvd coordinates match the materialized
    eigh oracle (up to per-axis sign) to ≤1e-4 relative at n=512."""
    dm = random_distance_matrix(jax.random.PRNGKey(512), 512, dim=6)
    r_eigh = pcoa(dm, dimensions=6, method="eigh")
    r_mf = pcoa(dm, dimensions=6, method="fsvd")      # default: matrix-free
    np.testing.assert_allclose(np.asarray(r_mf.eigenvalues),
                               np.asarray(r_eigh.eigenvalues), rtol=1e-4)
    scale = np.abs(np.asarray(r_eigh.coordinates)).max()
    for j in range(6):
        a = np.asarray(r_mf.coordinates[:, j])
        b = np.asarray(r_eigh.coordinates[:, j])
        assert min(np.abs(a - b).max(), np.abs(a + b).max()) <= 1e-4 * scale


def test_matrix_free_matches_materialized_fsvd():
    """Same solver, same key: operator path == materialize-then-solve."""
    dm = random_distance_matrix(jax.random.PRNGKey(20), 96, dim=5)
    key = jax.random.PRNGKey(1)
    r_mat = pcoa(dm, dimensions=5, key=key, materialize=True)
    r_mf = pcoa(dm, dimensions=5, key=key)
    np.testing.assert_allclose(np.asarray(r_mf.eigenvalues),
                               np.asarray(r_mat.eigenvalues),
                               rtol=1e-3, atol=1e-3)


def test_pcoa_pallas_matvec_impl():
    dm = random_distance_matrix(jax.random.PRNGKey(21), 64, dim=4)
    r_xla = pcoa(dm, dimensions=4, block=32)
    r_pal = pcoa(dm, dimensions=4, block=32, matvec_impl="pallas")
    np.testing.assert_allclose(np.asarray(r_pal.eigenvalues),
                               np.asarray(r_xla.eigenvalues),
                               rtol=1e-3, atol=1e-4)


def test_proportion_explained_uses_exact_total():
    """fsvd with k ≪ rank: proportions must be shares of the FULL inertia
    (operator trace), not renormalized over the top-k — the old
    ``total <= 0`` fallback's silent failure mode."""
    dm = random_distance_matrix(jax.random.PRNGKey(22), 128, dim=16)
    r = pcoa(dm, dimensions=4, method="fsvd")
    prop = np.asarray(r.proportion_explained)
    assert (prop >= 0).all()
    # 4 of 16 significant axes: the captured share must be well below 1
    assert prop.sum() < 0.9
    # and it must equal eigenvalues / exact trace
    from repro.core import CenteredGramOperator
    total = float(CenteredGramOperator.from_distance(dm.data).trace())
    np.testing.assert_allclose(
        prop, np.maximum(np.asarray(r.eigenvalues), 0.0) / total, rtol=1e-4)


def test_proportion_explained_degenerate_zero_matrix():
    """The all-zero distance matrix has zero inertia: proportions are 0,
    not NaN and not a silently renormalized top-k share."""
    from repro.core import DistanceMatrix
    dm = DistanceMatrix(jnp.zeros((12, 12)), _skip_validation=True)
    r = pcoa(dm, dimensions=3, method="fsvd")
    assert np.all(np.asarray(r.proportion_explained) == 0.0)
