"""repro.dist — tiled pairwise beta-diversity distances.

Every analysis this repo serves (PCoA, PERMANOVA, ANOSIM, Mantel,
PERMDISP) starts from an n×n distance matrix; this package owns the one
O(n²·d) step upstream of them all — turning an (n, d) feature table into
distances — and fuses it straight into the hoists the analyses consume:

* ``metrics``  — the ``Metric`` protocol (pytree dataclasses, the same
  design language as ``stats.Statistic``) with Euclidean, Bray–Curtis,
  Jaccard, Canberra and Cityblock instances; each declares a
  feature-chunk-additive ``accumulate`` and a ``finish``, which is what
  lets the reduce fuse into a tile sweep.
* ``driver``   — the cache-blocked producer: row panels stream through
  the Pallas ``kernels.pairwise`` kernel (``impl="pallas"``) or the
  ``lax.map`` fallback (``impl="xla"``), emitting the condensed form
  while the operator means (row/global means of E = −½ D∘D) and the
  Mantel moments accumulate tile-by-tile — so
  ``Workspace.from_features(...)`` runs a feature-table→PCoA→PERMANOVA
  session without an n×n square distance matrix ever existing.

Quick use (the ``scipy.spatial.distance.pdist`` migration path):

    from repro.dist import pairwise_distances
    cond = pairwise_distances(table, "braycurtis", out="condensed")

Session use (the fused path — see ``repro.api.Workspace``):

    ws = Workspace.from_features(table, metric="braycurtis")
    ws.pcoa(dimensions=10); ws.permanova(grouping, 999, key=0)
"""

from repro.dist.metrics import (METRICS, BrayCurtis, Canberra, Cityblock,
                                Euclidean, Jaccard, Metric, get_metric)
from repro.dist.driver import (condensed_size, pairwise_condensed,
                               pairwise_distances)

__all__ = [
    "METRICS", "Metric", "get_metric",
    "Euclidean", "BrayCurtis", "Jaccard", "Canberra", "Cityblock",
    "condensed_size", "pairwise_condensed", "pairwise_distances",
]
