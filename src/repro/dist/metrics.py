"""Beta-diversity distance metrics as pytree dataclasses.

Every metric this subsystem ships reduces a pair of feature vectors to a
distance through the same algebraic shape: a sum over features of an
elementwise term (one or two running accumulators), followed by a cheap
finishing transform. That shape is exactly what the tiled pairwise driver
and the Pallas kernel need — the per-feature terms can be accumulated
chunk-by-chunk while the (bm, d) × (bn, d) tiles are resident in
VMEM/cache, and only the tiny (bm, bn) accumulators survive between
chunks.

A ``Metric`` therefore declares two hooks (the same design language as
``stats.engine.Statistic``'s hoist/per_perm split):

* ``accumulate(xi, xj)`` — partial accumulators for ONE feature chunk:
  ``xi`` (bm, dc) against ``xj`` (bn, dc) → dict of (bm, bn) arrays.
  Accumulators are additive over feature chunks (the driver simply sums
  dicts), which is what lets the reduce fuse into the tile sweep.
* ``finish(acc)`` — the (bm, bn) distance tile from the summed
  accumulators.

Instances are frozen ``register_dataclass`` pytrees with no data fields,
so they are hashable (usable as ``jax.jit`` static arguments — the kernel
specializes per metric) and can also ride inside jitted pytrees.

Zero-feature padding is free for every metric: a feature where both
vectors are 0 contributes 0 to every accumulator (for Jaccard the
"either nonzero" count is 0 too), so the driver pads the feature axis to
chunk multiples without masking.

Degenerate-pair conventions (pinned by ``tests/test_dist.py``):

* **Bray–Curtis 0/0** — two all-zero samples have denominator 0; we
  define d = 0 (identical samples), where SciPy ≥ 1.9 returns NaN. This
  is the scikit-bio/QIIME convention: an empty sample is identical to
  another empty sample, not incomparably far from it.
* **Jaccard 0/0** — d = 0, matching SciPy's own convention since 1.2.
* **Canberra 0/0 terms** — per-feature 0/0 terms count as 0 (SciPy's
  convention).

All five metrics match ``scipy.spatial.distance.pdist`` to ≤ 1e-5 on
random fp32 tables (property-tested), modulo the Bray–Curtis NaN
convention above.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

Acc = Dict[str, jax.Array]


@runtime_checkable
class Metric(Protocol):
    """A pairwise distance metric, split at the chunk-accumulation boundary.

    ``name`` is the registry key (and what ``ExecConfig.metric`` /
    ``Workspace.from_features(metric=...)`` accept); ``accumulate`` maps
    one feature chunk of both tiles to additive (bm, bn) accumulators;
    ``finish`` turns the summed accumulators into the distance tile.
    """

    name: str

    def accumulate(self, xi: jax.Array, xj: jax.Array) -> Acc: ...

    def finish(self, acc: Acc) -> jax.Array: ...


def _pairwise(xi: jax.Array, xj: jax.Array):
    """Broadcast one feature chunk to per-pair terms: (bm, bn, dc)."""
    return xi[:, None, :], xj[None, :, :]


def _safe_div(num: jax.Array, den: jax.Array) -> jax.Array:
    """num/den with the 0/0 → 0 convention (identical/empty samples)."""
    return jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), 0.0)


@partial(jax.tree_util.register_dataclass, data_fields=[], meta_fields=[])
@dataclasses.dataclass(frozen=True)
class Euclidean:
    """√Σ(a−b)² — computed diff-based (not the ‖a‖²+‖b‖²−2a·b Gram trick,
    which loses ~3 decimal digits to cancellation in fp32) so the pdist
    oracle parity holds at 1e-5."""

    name = "euclidean"

    def accumulate(self, xi, xj):
        a, b = _pairwise(xi, xj)
        d = a - b
        return {"ss": jnp.sum(d * d, axis=-1)}

    def finish(self, acc):
        return jnp.sqrt(jnp.maximum(acc["ss"], 0.0))


@partial(jax.tree_util.register_dataclass, data_fields=[], meta_fields=[])
@dataclasses.dataclass(frozen=True)
class Cityblock:
    """Σ|a−b| (Manhattan)."""

    name = "cityblock"

    def accumulate(self, xi, xj):
        a, b = _pairwise(xi, xj)
        return {"s": jnp.sum(jnp.abs(a - b), axis=-1)}

    def finish(self, acc):
        return acc["s"]


@partial(jax.tree_util.register_dataclass, data_fields=[], meta_fields=[])
@dataclasses.dataclass(frozen=True)
class Canberra:
    """Σ |a−b| / (|a|+|b|), 0/0 feature terms counting 0 (SciPy)."""

    name = "canberra"

    def accumulate(self, xi, xj):
        a, b = _pairwise(xi, xj)
        den = jnp.abs(a) + jnp.abs(b)
        return {"s": jnp.sum(_safe_div(jnp.abs(a - b), den), axis=-1)}

    def finish(self, acc):
        return acc["s"]


@partial(jax.tree_util.register_dataclass, data_fields=[], meta_fields=[])
@dataclasses.dataclass(frozen=True)
class BrayCurtis:
    """Σ|a−b| / Σ|a+b| — THE workhorse of microbiome beta diversity
    (Sfiligoi et al. 2021). 0/0 (two empty samples) → 0, documented
    above; intended for non-negative abundance tables."""

    name = "braycurtis"

    def accumulate(self, xi, xj):
        a, b = _pairwise(xi, xj)
        return {"num": jnp.sum(jnp.abs(a - b), axis=-1),
                "den": jnp.sum(jnp.abs(a + b), axis=-1)}

    def finish(self, acc):
        return _safe_div(acc["num"], acc["den"])


@partial(jax.tree_util.register_dataclass, data_fields=[], meta_fields=[])
@dataclasses.dataclass(frozen=True)
class Jaccard:
    """Presence/absence disagreement: #(a≠b) / #(a≠0 ∨ b≠0), SciPy's
    real-vector semantics (a≠b implies at least one is nonzero, so the
    numerator needs no nonzero guard). 0/0 → 0 like SciPy ≥ 1.2."""

    name = "jaccard"

    def accumulate(self, xi, xj):
        a, b = _pairwise(xi, xj)
        dt = xi.dtype
        return {"neq": jnp.sum((a != b).astype(dt), axis=-1),
                "nz": jnp.sum(((a != 0) | (b != 0)).astype(dt), axis=-1)}

    def finish(self, acc):
        return _safe_div(acc["neq"], acc["nz"])


def merge_acc(acc: Acc, part: Acc) -> Acc:
    """Sum two chunks' accumulators (all metrics are feature-additive)."""
    return {k: acc[k] + part[k] for k in acc}


METRICS: Dict[str, Metric] = {
    m.name: m for m in (Euclidean(), Cityblock(), Canberra(), BrayCurtis(),
                        Jaccard())
}


def get_metric(metric) -> Metric:
    """Coerce a metric name or instance to the registered ``Metric``."""
    if isinstance(metric, str):
        try:
            return METRICS[metric]
        except KeyError:
            raise ValueError(
                f"unknown metric {metric!r}; available: "
                f"{sorted(METRICS)}") from None
    if isinstance(metric, Metric):
        return metric
    raise TypeError(f"metric must be a name or Metric instance, "
                    f"got {type(metric).__name__}")
