"""Cache-blocked pairwise-distance driver with fused hoist accumulation.

This is the subsystem's tentpole move: the (n, d) feature table becomes
condensed distances **panel by panel**, and every downstream O(n²) hoist
that can be expressed as a running sum is accumulated *while each panel
is resident* — the paper's "compute while the data is already in cache"
argument applied one level upstream of the analyses:

* the **condensed** form (scipy ``pdist`` layout) is emitted per panel:
  the upper-triangle entries of row panel [i0, i1) occupy one contiguous
  condensed range, gathered straight out of the (b, n) strip;
* the **operator means** — row/global means of E = −½ D∘D, exactly what
  ``CenteredGramOperator.from_distance`` hoists from a square D — come
  from each strip's row sums of D², so ``Workspace.from_features`` can
  run matrix-free PCoA/PERMANOVA without a square n×n ever existing;
* the **condensed moments** — the mean and centered norm of the condensed
  vector, the permuted-side hoist of the Mantel family — come from the
  same row sums (Σ over the full hollow matrix is twice the condensed Σ).

Peak memory is one (block, n) strip plus the (m,) condensed output,
m = n(n−1)/2 — the square matrix is never allocated. Panel compute
dispatches per ``impl``: ``"pallas"`` routes through the VMEM-tiled
``kernels.pairwise`` (backend-dispatched interpret, like ``mantel_corr``),
``"xla"`` is the ``lax.map`` row-panel fallback — sub-panels of rows
stream against the full table with the metric's reduce feature-chunked,
so the broadcast term stays (rows, n, chunk)-bounded.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.metrics import Metric, get_metric, merge_acc
from repro.kernels.dispatch import clamp_block
from repro.obs.compile import note_trace
from repro.obs.trace import current_obs

_DEFAULT_BLOCK = 256
_DEFAULT_FEATURE_BLOCK = 128
_ROW_CHUNK = 8


def condensed_size(n: int) -> int:
    """m = n(n−1)/2, the scipy ``pdist`` condensed length."""
    return n * (n - 1) // 2


def _panel_condensed_indices(n: int, i0: int, i1: int) -> np.ndarray:
    """Local flat indices into a (b, n) row strip for the condensed
    entries owned by rows [i0, i1) — one contiguous condensed range
    (row r owns positions [r(2n−r−1)/2, …), each a run of n−1−r)."""
    return np.concatenate(
        [(r - i0) * n + np.arange(r + 1, n) for r in range(i0, i1)]
        or [np.zeros(0, dtype=np.int64)]).astype(np.int32)


def _panel_xla(xi: jax.Array, x: jax.Array, metric: Metric,
               feature_block: int) -> jax.Array:
    """lax.map row-panel fallback: (bm, d) × (n, d) → (bm, n).

    Rows stream in sub-panels so each step's broadcast term is bounded at
    (row_chunk, n, feature_block); the feature axis is chunked by static
    slicing (no padding needed — the trailing short chunk is just a
    smaller slice in the same trace).
    """
    bm, d = xi.shape
    rb = next(r for r in range(min(_ROW_CHUNK, bm), 0, -1) if bm % r == 0)
    sub = xi.reshape(bm // rb, rb, d)

    def one(p):
        acc = None
        for c0 in range(0, d, feature_block):
            part = metric.accumulate(p[:, c0:c0 + feature_block],
                                     x[:, c0:c0 + feature_block])
            acc = part if acc is None else merge_acc(acc, part)
        return metric.finish(acc)

    return jax.lax.map(one, sub).reshape(bm, x.shape[0])


@partial(jax.jit, static_argnames=("metric", "feature_block", "impl",
                                   "interpret", "block"))
def _panel_stats(xi: jax.Array, x: jax.Array, *, metric: Metric,
                 feature_block: int, impl: str, interpret: Optional[bool],
                 block: int):
    """One row strip + its fused running sums: (strip, Σ_j d, Σ_j d²).

    The row sums ride the same jit region as the strip compute, so XLA
    fuses them into the panel sweep — the hoists cost no extra pass."""
    note_trace("dist.panel_stats",
               (xi.shape, x.shape, metric.name, feature_block, impl, block))
    if impl == "pallas":
        from repro.kernels.pairwise_ops import pairwise_panel_pallas
        strip = pairwise_panel_pallas(xi, x, metric=metric, block_n=block,
                                      feature_block=feature_block,
                                      interpret=interpret)
    else:
        strip = _panel_xla(xi, x, metric, feature_block)
    return strip, jnp.sum(strip, axis=1), jnp.sum(strip * strip, axis=1)


def pairwise_condensed(x, metric="braycurtis", *,
                       block: int = _DEFAULT_BLOCK,
                       feature_block: int = _DEFAULT_FEATURE_BLOCK,
                       impl: str = "xla",
                       interpret: Optional[bool] = None) -> dict:
    """Condensed distances + fused hoists from an (n, d) feature table.

    Returns a dict:

    * ``condensed``   — (m,) scipy-pdist-layout distances, fp32;
    * ``row_means``   — (n,) row means of E = −½ D∘D (the
      ``CenteredGramOperator`` hoist, accumulated tile-by-tile);
    * ``global_mean`` — () global mean of E;
    * ``mean`` / ``norm`` — condensed mean and centered condensed norm
      (the Mantel family's permuted-side moments);
    * ``n`` / ``metric`` — provenance.

    The square n×n matrix is never allocated; peak memory is one
    (block, n) strip plus the condensed output.
    """
    metric = get_metric(metric)
    if impl not in ("xla", "pallas"):
        raise ValueError(f"unknown pairwise impl {impl!r}")
    x = jnp.asarray(x)
    if x.ndim != 2:
        raise ValueError(f"expected an (n, d) feature table, got {x.shape}")
    if x.dtype != jnp.float32:
        x = x.astype(jnp.float32)
    n = x.shape[0]
    d = int(x.shape[1])
    b = clamp_block(n, block)
    obs = current_obs()          # the ambient session (NULL_OBS when none)

    cond_parts, rs1_parts, rs2_parts = [], [], []
    with obs.span("dist.pairwise_condensed", phase="production", n=n, d=d,
                  block=b, impl=impl, metric=metric.name,
                  panels=-(-n // b)):
        for i0 in range(0, n, b):
            i1 = min(i0 + b, n)
            xi = x[i0:i1]
            if i1 - i0 < b:                 # pad the short tail panel so
                xi = jnp.pad(xi, ((0, b - (i1 - i0)), (0, 0)))  # one trace fits all
            strip, rs1, rs2 = _panel_stats(xi, x, metric=metric,
                                           feature_block=feature_block,
                                           impl=impl, interpret=interpret,
                                           block=b)
            rs1_parts.append(rs1[:i1 - i0])
            rs2_parts.append(rs2[:i1 - i0])
            idx = _panel_condensed_indices(n, i0, i1)
            if idx.size:
                cond_parts.append(strip.reshape(-1)[jnp.asarray(idx)])
    obs.charge_production(n, d, b, metric=metric.name, impl=impl)

    rowsum_d = jnp.concatenate(rs1_parts)
    rowsum_d2 = jnp.concatenate(rs2_parts)
    condensed = (jnp.concatenate(cond_parts) if cond_parts
                 else jnp.zeros((0,), dtype=x.dtype))

    m = condensed_size(n)
    row_means = -0.5 * rowsum_d2 / n
    global_mean = jnp.mean(row_means)
    # Σ over the full hollow matrix is exactly twice the condensed Σ
    sum_c = 0.5 * jnp.sum(rowsum_d)
    sumsq_c = 0.5 * jnp.sum(rowsum_d2)
    mean_c = sum_c / max(m, 1)
    norm = jnp.sqrt(jnp.maximum(sumsq_c - m * mean_c * mean_c, 0.0))
    return {"condensed": condensed, "row_means": row_means,
            "global_mean": global_mean, "mean": mean_c, "norm": norm,
            "n": n, "metric": metric.name}


def pairwise_distances(x, metric="braycurtis", *, out: str = "square",
                       block: int = _DEFAULT_BLOCK,
                       feature_block: int = _DEFAULT_FEATURE_BLOCK,
                       impl: str = "xla",
                       interpret: Optional[bool] = None) -> jax.Array:
    """The ``scipy.spatial.distance.pdist``/``squareform`` replacement.

    ``out="square"`` assembles the full (n, n) matrix panel-by-panel
    (exactly symmetric and hollow by construction — each (i, j) is the
    same fp expression as (j, i)); ``out="condensed"`` is the pdist
    layout via the streaming driver (no n×n allocated).
    """
    if out == "condensed":
        return pairwise_condensed(x, metric, block=block,
                                  feature_block=feature_block, impl=impl,
                                  interpret=interpret)["condensed"]
    if out != "square":
        raise ValueError(f"out must be 'square' or 'condensed', got {out!r}")
    metric = get_metric(metric)
    if impl not in ("xla", "pallas"):
        raise ValueError(f"unknown pairwise impl {impl!r}")
    x = jnp.asarray(x)
    if x.ndim != 2:
        raise ValueError(f"expected an (n, d) feature table, got {x.shape}")
    if x.dtype != jnp.float32:
        x = x.astype(jnp.float32)
    n = x.shape[0]
    b = clamp_block(n, block)
    parts = []
    for i0 in range(0, n, b):
        i1 = min(i0 + b, n)
        xi = x[i0:i1]
        if i1 - i0 < b:
            xi = jnp.pad(xi, ((0, b - (i1 - i0)), (0, 0)))
        strip, _, _ = _panel_stats(xi, x, metric=metric,
                                   feature_block=feature_block, impl=impl,
                                   interpret=interpret, block=b)
        parts.append(strip[:i1 - i0])
    return jnp.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]
