"""The tile solver: budget in, every knob out.

Replaces the four independently hand-picked constants (center-matvec
512, mantel/pairwise/driver 256, feature_block 128, permute-reduce
chunk 64k, engine batch 8/32) with ONE policy: enumerate lane-snapped
candidates through the SAME ``kernels.dispatch`` snapping the kernels
execute, keep those whose ``repro.tune.model`` resident set fits the
``BackendBudget``, and take the one minimizing modeled *effective*
traffic (traffic evaluated at the budget-clamped reuse — a tile too big
to stay resident gets no credit for the reuse it cannot realize).

Guarantees the tests pin:

* the hand-picked default is always in the candidate set, so the
  solved choice never models worse effective traffic than the
  constants it replaces (the BENCH_tune gate);
* ``batch_size``/``chunk`` are solved from (n, S, budget) only — K is
  deliberately NOT an input, so the engine's one padded per-batch
  program keeps serving every K (the PR-5 sentinel invariant);
* ``feature_block`` AND ``block`` only ever *shrink* under budget
  pressure, never grow — growing feature_block would reorder the
  metric accumulator merges, and any block change re-associates the
  operator matvec's row-panel partial sums, moving results in the last
  ulp (the bitwise-stability rule: auto keeps the default geometry
  whenever it fits, so it stays bitwise-identical to the default run
  on any problem the default's resident set can host);
* n beyond the int32 triangle bound is refused here, before any kernel
  sees it (same guard, same message family as ``permute_reduce``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.distance_matrix import MAX_TRIANGLE_N
from repro.kernels.dispatch import lane_geometry, pick_block, snap_chunk
from repro.tune.budget import BackendBudget, detect_budget, load_profile
from repro.tune.model import (condensed_size, matvec_cost, perm_batch_cost,
                              perm_batch_fit, production_cost)

__all__ = ["TunedTiles", "solve_tiles", "resolve_exec_config",
           "DEFAULT_BLOCK", "DEFAULT_FEATURE_BLOCK", "DEFAULT_BATCH",
           "DEFAULT_CHUNK", "BATCH_MAX"]

# the hand-picked constants the solver must never price worse than —
# one authoritative copy each, asserted against the owning modules in
# tests so they cannot drift silently
DEFAULT_BLOCK = 256          # mantel_corr/pairwise/driver block
DEFAULT_FEATURE_BLOCK = 128  # pairwise/driver feature chunk
DEFAULT_BATCH = 32           # the Workspace battery's batch
DEFAULT_CHUNK = 65536        # permute_reduce condensed chunk

#: solved batches cap here regardless of budget headroom: past ~128 the
#: modeled 3m/B amortization is already <3% from its asymptote while
#: the (B, n) order block and (B, chunk) gather tile keep growing
BATCH_MAX = 128

_BLOCK_CANDIDATES = (2048, 1024, 512, 256, 128, 64, 32, 16, 8)
_CHUNK_CANDIDATES = (131072, 65536, 32768, 16384, 8192, 4096)
_BATCH_CANDIDATES = (128, 64, 32, 16, 8)
_MIN_CHUNK = 4096
_MIN_FEATURE_BLOCK = 8


@dataclasses.dataclass(frozen=True)
class TunedTiles:
    """One solved configuration: the knobs, the budget they were fit
    against, and the modeled costs of both the solved and the default
    tiles (so reports and the BENCH gate can show the delta without
    re-running the solver)."""

    n: int
    d: Optional[int]
    block: int
    feature_block: int
    batch_size: int
    chunk: int
    backend: str
    budget: BackendBudget
    modeled: dict
    modeled_default: dict

    def to_dict(self) -> dict:
        return {"n": self.n, "d": self.d, "block": self.block,
                "feature_block": self.feature_block,
                "batch_size": self.batch_size, "chunk": self.chunk,
                "backend": self.backend, "budget": self.budget.to_dict(),
                "modeled": dict(self.modeled),
                "modeled_default": dict(self.modeled_default)}


def _fit_block(n: int, d: Optional[int], fb: int, lane: int, floor: int,
               budget_floats: float, cap: Optional[int] = None) -> int:
    """Largest lane-snapped candidate block (<= ``cap`` when given)
    whose production AND matvec resident sets fit; modeled production
    traffic is non-increasing in block, so largest-that-fits is also
    cheapest-that-fits. With ``cap`` this doubles as the EFFECTIVE
    block of a requested size under the budget — what a hand-picked
    constant really achieves, which is how ``modeled_default`` is
    priced (same clamp for both sides of the comparison)."""
    d_eff = d if d is not None else 0     # dm-backed: no production sweep
    cands = set(_BLOCK_CANDIDATES + (DEFAULT_BLOCK,))
    if cap is not None:
        cands.add(cap)                    # the requested size is always
    seen = []                             # its own first candidate
    for cand in sorted(cands, reverse=True):
        if cap is not None and cand > cap:
            continue
        b = pick_block(n, cand, lane, floor=floor)
        if b in seen:
            continue
        seen.append(b)
        fits_mv = matvec_cost(n, 16, b, lane=lane).resident_floats \
            <= budget_floats
        fits_prod = (d_eff == 0
                     or production_cost(n, d_eff, b, fb).resident_floats
                     <= budget_floats)
        if fits_mv and fits_prod:
            return b
    return seen[-1] if seen else pick_block(n, floor, lane, floor=floor)


def _solve_batch_chunk(n: int, s: int, budget_floats: float
                       ) -> tuple[int, int]:
    """Joint (batch, chunk): the largest candidate batch for which some
    chunk >= _MIN_CHUNK keeps the scan step resident, paired with the
    largest such chunk. Per-permutation traffic m(1+3/B)+n is strictly
    decreasing in B, so largest-feasible-B is the argmin."""
    m = condensed_size(n)
    for batch in _BATCH_CANDIDATES:
        for cand in _CHUNK_CANDIDATES:
            chunk, _ = snap_chunk(m, cand)
            cost = perm_batch_cost(n, batch, chunk, s)
            if (cost.resident_floats <= budget_floats
                    and chunk >= min(_MIN_CHUNK, m)):
                return batch, chunk
    # nothing fits at candidate granularity: close the form directly
    chunk, _ = snap_chunk(m, _MIN_CHUNK)
    return perm_batch_fit(n, chunk, budget_floats, s), chunk


def solve_tiles(n: int, d: Optional[int] = None, *,
                budget: Optional[BackendBudget] = None,
                profile: Optional[str] = None,
                interpret: Optional[bool] = None, s: int = 2) -> TunedTiles:
    """Solve every tile knob for a problem of ``n`` observations (and
    ``d`` features when feature-backed).

    ``s`` is the widest streamed-invariant stack the session may run
    (partial Mantel stacks 2 rows; sizing residency for the widest
    keeps one solve valid for the whole battery). K is deliberately not
    a parameter — see the module docstring. ``profile`` loads a
    ``save_profile`` JSON; explicit ``budget`` wins over it.
    """
    if n > MAX_TRIANGLE_N:
        raise ValueError(
            f"solve_tiles supports n <= {MAX_TRIANGLE_N} (int32 triangle "
            f"indexing would overflow in the permutation kernels); got "
            f"n={n}")
    if n < 1:
        raise ValueError(f"need n >= 1, got n={n}")
    if budget is None:
        budget = load_profile(profile) if profile else detect_budget()
    bf = budget.working_floats
    lane, floor = lane_geometry(interpret)

    # feature_block: start at the default (clamped to d) and SHRINK only
    # while even the smallest block cannot fit the production step
    fb = DEFAULT_FEATURE_BLOCK if d is None else max(
        min(DEFAULT_FEATURE_BLOCK, d), 1)
    if d:
        while (fb > _MIN_FEATURE_BLOCK
               and production_cost(n, d, pick_block(n, 8, lane, floor=floor),
                                   fb).resident_floats > bf):
            fb //= 2

    # block is shrink-only from the default (cap=DEFAULT_BLOCK): the
    # operator matvec re-associates row-panel partials, so a block the
    # default run never executed would move matvec-backed results off
    # bitwise. Modeled effective traffic loses nothing: an over-budget
    # default is priced at this same clamped geometry anyway.
    block = _fit_block(n, d, fb, lane, floor, bf, cap=DEFAULT_BLOCK)
    batch, chunk = _solve_batch_chunk(n, s, bf)
    batch = max(min(batch, BATCH_MAX), 1)

    def _modeled(blk, f_blk, bt, ck):
        # traffic is priced at the EFFECTIVE tiles under the budget —
        # a requested block/batch too big to stay resident realizes
        # only the reuse of the largest geometry that does fit, for the
        # solved and the hand-picked constants alike
        f_blk = max(min(f_blk, d), 1) if d else f_blk
        b_eff = _fit_block(n, d, f_blk, lane, floor, bf, cap=blk)
        out = {"perm_batch": perm_batch_cost(n, bt, ck, s,
                                             budget_floats=bf).to_dict(),
               "matvec": matvec_cost(n, 16, b_eff, lane=lane).to_dict()}
        if d:
            out["production"] = production_cost(n, d, b_eff,
                                                f_blk).to_dict()
        return out

    return TunedTiles(
        n=n, d=d, block=block, feature_block=fb, batch_size=batch,
        chunk=chunk, backend=budget.backend, budget=budget,
        modeled=_modeled(block, fb, batch, chunk),
        modeled_default=_modeled(DEFAULT_BLOCK, DEFAULT_FEATURE_BLOCK,
                                 DEFAULT_BATCH, DEFAULT_CHUNK))


def resolve_exec_config(config, n: int, d: Optional[int] = None):
    """Materialize an ``ExecConfig``'s auto knobs into concrete tiles.

    Returns ``(resolved_config, tuned)`` where ``resolved_config`` has
    every ``"auto"`` (or, under ``auto=True``, every left-at-default)
    knob replaced by the solved value — or ``(config, None)`` untouched
    when nothing asked for tuning. Explicitly-set concrete knobs are
    always honored, even under ``auto=True``.
    """
    import dataclasses as _dc

    auto_all = bool(getattr(config, "auto", False))

    def wants(name, default):
        v = getattr(config, name)
        return v == "auto" or (auto_all and v == default)

    want_block = wants("block", 256)
    want_fb = wants("feature_block", 128)
    want_batch = getattr(config, "batch_size") == "auto" or (
        auto_all and getattr(config, "batch_size") is None)
    want_chunk = getattr(config, "chunk") == "auto" or (
        auto_all and getattr(config, "chunk") is None)
    if not (want_block or want_fb or want_batch or want_chunk):
        return config, None

    tuned = solve_tiles(n, d, profile=getattr(config, "tune_profile", None),
                        interpret=config.interpret)
    updates = {"auto": False}
    if want_block:
        updates["block"] = tuned.block
    if want_fb:
        updates["feature_block"] = tuned.feature_block
    if want_batch:
        updates["batch_size"] = tuned.batch_size
    if want_chunk:
        updates["chunk"] = tuned.chunk
    return _dc.replace(config, **updates), tuned
