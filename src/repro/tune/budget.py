"""Backend byte budgets: what "fits in fast memory" means, per backend.

``detect_budget()`` answers the question every hand-picked tile constant
in this repo used to answer implicitly: how many bytes may a kernel's
working set occupy and still stream at full bandwidth? On TPU that is
the VMEM budget the Pallas kernels double-buffer inside (16 MiB/core on
v5e — the same figure ``kernels.center_ops`` documents for its 512 tile
default); on CPU it is an L2-class working budget (the interpreter and
the XLA scan paths live or die by L2 residency of the per-step tile);
on GPU an L2-class slice.

``calibrate()`` upgrades the static bandwidth/latency defaults to
measured ones with a two-point timed probe (one small buffer dominated
by dispatch latency, one large buffer dominated by stream bandwidth —
a two-unknown linear fit, exactly the roofline decomposition
``launch.mesh`` models statically). Profiles round-trip through JSON so
CI can persist a container's calibration as an artifact and later runs
can ``load_profile()`` instead of re-probing.

The solver consumes budgets in fp32 floats: ``budget.working_floats``.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp

# the TPU roofline constants live in launch.mesh (PEAK_FLOPS / HBM_BW);
# reusing HBM_BW here keeps the tuner's TPU bandwidth and the roofline
# model's the same number
from repro.launch.mesh import HBM_BW

__all__ = ["BackendBudget", "detect_budget", "calibrate",
           "save_profile", "load_profile"]

#: bytes per fp32 element — every budget below is quoted in bytes and
#: converted via this
_FP32 = 4


@dataclasses.dataclass(frozen=True)
class BackendBudget:
    """One backend's memory-system description, as the solver sees it.

    * ``working_bytes`` — the budget a single kernel step's tunable
      resident set must fit (VMEM on TPU, an L2-class slice on CPU/GPU);
    * ``capacity_bytes`` — the larger next-level pool (HBM/L3): only
      used for sanity bounds, never for tile fitting;
    * ``bandwidth`` / ``latency`` — stream bandwidth (bytes/s) and
      per-dispatch latency (s), static defaults unless calibrated;
    * ``source`` — ``"default"``, ``"calibrated"`` or ``"profile"``.
    """

    backend: str
    working_bytes: int
    capacity_bytes: int
    bandwidth: float
    latency: float
    source: str = "default"

    @property
    def working_floats(self) -> float:
        return self.working_bytes / _FP32

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "BackendBudget":
        return BackendBudget(**d)


#: static per-backend defaults. TPU: v5e VMEM (16 MiB) and the
#: launch.mesh HBM roofline bandwidth. CPU: a conservative 1 MiB L2
#: working slice (per-core L2 is 0.5–2 MiB across the x86 fleet; the
#: solver prefers tiles that fit the SMALL end so they fit everywhere)
#: over a 32 MiB L3. GPU: an 8 MiB L2 slice over HBM.
_DEFAULTS = {
    "tpu": dict(working_bytes=16 * 2**20, capacity_bytes=16 * 2**30,
                bandwidth=HBM_BW, latency=3e-6),
    "cpu": dict(working_bytes=1 * 2**20, capacity_bytes=32 * 2**20,
                bandwidth=3e10, latency=30e-6),
    "gpu": dict(working_bytes=8 * 2**20, capacity_bytes=2 * 2**30,
                bandwidth=9e11, latency=5e-6),
}


def detect_budget(backend: Optional[str] = None) -> BackendBudget:
    """The static budget for ``backend`` (default: the live
    ``jax.default_backend()``); unknown backends get the CPU column."""
    be = backend or jax.default_backend()
    d = _DEFAULTS.get(be, _DEFAULTS["cpu"])
    return BackendBudget(backend=be, source="default", **d)


def _time_pass(x: jax.Array, reps: int = 5) -> float:
    """Median seconds for one jitted elementwise pass over ``x``."""
    f = jax.jit(lambda a: a * 2.0 + 1.0)
    f(x).block_until_ready()                         # compile outside timing
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        f(x).block_until_ready()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def calibrate(base: Optional[BackendBudget] = None, *,
              small: int = 1 << 12, large: int = 1 << 22,
              reps: int = 5, mode: str = "wall") -> BackendBudget:
    """Fit the budget's rate constants from streaming probes.

    ``mode="wall"`` (the historical path) times two jitted passes — one
    small (latency-dominated), one large (bandwidth-dominated) — and
    solves the two-point linear fit. A pass over N floats costs
    ``latency + bytes/bandwidth``. Wall timings inherit this container's
    ±40% noise, which is why nothing downstream gates on them.

    ``mode="probe"`` is deterministic: it compiles the SAME pass
    ahead-of-time (``obs.probe.probe_stream_pass``) and reads the
    compiled program's scan-corrected byte count instead of the clock.
    The effective bandwidth is the backend default scaled by
    naive/measured bytes — if XLA's compiled pass moves more bytes than
    the 2-per-element model assumes (extra copies, padding), the solver
    should price streams proportionally slower. Latency keeps the
    backend default (dispatch latency has no compile-time observable).
    Same answer on every run of a container image, immune to noisy
    neighbors; ``source="probed"``.

    Either mode returns a new budget with measured constants; capacities
    stay the static per-backend values (probing cache SIZES from
    wall-clock is exactly the noise this repo's analytic-gate policy
    avoids — so only rate constants are ever measured).
    """
    b = base or detect_budget()
    if mode == "probe":
        from repro.obs.probe import probe_stream_pass
        rec = probe_stream_pass(large)
        naive = 2.0 * _FP32 * large
        factor = max(rec.bytes_corrected / naive, 1e-6)
        return dataclasses.replace(b, bandwidth=b.bandwidth / factor,
                                   source="probed")
    if mode != "wall":
        raise ValueError(f"calibrate mode must be 'wall' or 'probe', "
                         f"got {mode!r}")
    t_small = _time_pass(jnp.ones((small,), jnp.float32), reps)
    t_large = _time_pass(jnp.ones((large,), jnp.float32), reps)
    # each element moves ~2 fp32 (read + write) per pass
    bytes_small, bytes_large = 2 * _FP32 * small, 2 * _FP32 * large
    dt = max(t_large - t_small, 1e-12)
    bandwidth = (bytes_large - bytes_small) / dt
    latency = max(t_small - bytes_small / bandwidth, 0.0)
    return dataclasses.replace(b, bandwidth=bandwidth, latency=latency,
                               source="calibrated")


def save_profile(budget: BackendBudget, path: str) -> None:
    """Persist a budget (typically a calibrated one) as JSON."""
    with open(path, "w") as f:
        json.dump(budget.to_dict(), f, indent=2)


def load_profile(path: str) -> BackendBudget:
    """Reload a ``save_profile`` JSON; source becomes ``"profile"``."""
    with open(path) as f:
        d = json.load(f)
    d["source"] = "profile"
    return BackendBudget.from_dict(d)
