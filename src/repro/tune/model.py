"""Per-kernel analytic cost model: traffic AND residency as closed forms.

The traffic side is NOT a re-derivation — every floats-moved figure here
is computed by calling the audited ``repro.obs.ledger`` registry
(``HOIST_PASSES``/``FEATURE_HOIST_PASSES``, ``perm_traffic_floats``,
``production_floats``) so the tuner's model and the runtime's ledger
charges are the same functions and can never drift. What this module
*adds* is the **resident-set** side: for each kernel, the fp32 working
set that must stay cache/VMEM-resident as a closed form of the tile
knobs — the quantity the ``repro.tune.solve`` solver fits against the
measured ``BackendBudget``. The snapping rules are the shared
``kernels.dispatch`` helpers, so modeled tiles equal executed tiles.

Parameter names match the ledger's: n observations, d features, K
permutations, B permutation batch, S streamed invariant rows
(Mantel/ANOSIM 1, partial Mantel 2), plus the tile knobs block /
feature_block / chunk.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.kernels.dispatch import clamp_block, pick_block, snap_chunk
from repro.obs.ledger import (FEATURE_HOIST_PASSES, HOIST_PASSES,
                              hoist_floats, perm_traffic_floats,
                              production_floats)

__all__ = [
    "CostTerms", "condensed_size", "perm_batch_cost", "perm_batch_fit",
    "production_cost", "matvec_cost", "session_hoist_passes",
    "SQUARE_SESSION_ARTIFACTS", "STANDALONE_SESSION_ARTIFACTS",
]

#: artifact builds of the canonical 4-analysis battery (pcoa + permanova
#: + permdisp + anosim) on ONE shared Workspace — the BENCH_api
#: "11 passes" side of the published 11-vs-16 accounting
SQUARE_SESSION_ARTIFACTS = ("operator", "gram", "condensed", "ranks",
                            "coords")
#: the same battery as four one-shot Workspaces (the legacy free
#: functions) — the "16 passes" side: pcoa and permdisp each rebuild
#: operator+coords, permanova rebuilds gram, anosim condensed+ranks
STANDALONE_SESSION_ARTIFACTS = ("operator", "coords",      # pcoa
                                "gram",                    # permanova
                                "operator", "coords",      # permdisp
                                "condensed", "ranks")      # anosim


def condensed_size(n: int) -> int:
    """m = n(n−1)/2 (duplicated from ``dist.driver`` to keep this module
    import-light; the parity test pins them equal)."""
    return n * (n - 1) // 2


@dataclasses.dataclass(frozen=True)
class CostTerms:
    """One kernel configuration, costed.

    * ``traffic_floats``  — fp32 floats streamed end to end (the ledger
      figure; what the solver minimizes);
    * ``resident_floats`` — fp32 floats that must be simultaneously
      live for the tile loop to achieve the modeled traffic (what the
      solver fits under the budget);
    * ``base_floats``     — untunable always-resident state (e.g. the
      condensed source xc of the permutation loop): reported so budget
      audits see the full footprint, but EXCLUDED from the tunable fit —
      no tile choice can shrink it, and at production n it exceeds any
      L2-class budget on its own;
    * ``params``          — the parameter point, for RunReport audits.
    """

    op: str
    traffic_floats: float
    resident_floats: float
    base_floats: float
    params: dict

    @property
    def traffic_bytes(self) -> float:
        return 4.0 * self.traffic_floats

    @property
    def resident_bytes(self) -> float:
        return 4.0 * self.resident_floats

    def to_dict(self) -> dict:
        return {"op": self.op, "traffic_floats": self.traffic_floats,
                "traffic_bytes": self.traffic_bytes,
                "resident_floats": self.resident_floats,
                "resident_bytes": self.resident_bytes,
                "base_floats": self.base_floats,
                "params": dict(self.params)}


# --------------------------------------------------------------------------
# the permutation inner loop (kernels.permute_reduce)
# --------------------------------------------------------------------------
def perm_resident_floats(n: int, batch: int, chunk: int, s: int = 1
                         ) -> float:
    """Tunable working set of one ``permute_reduce`` scan step: the
    (B, chunk) gather tile, the (S, chunk) invariant tile, the two
    (chunk,) triangle-coordinate rows, and the (B, n) order block that
    every step re-reads. (The (m,) condensed source is ``base``, not
    counted here — see ``CostTerms.base_floats``.)"""
    return float(chunk) * (batch + s + 2) + float(batch) * n


def perm_batch_fit(n: int, chunk: int, budget_floats: float, s: int = 1
                   ) -> int:
    """Largest batch B whose tunable working set fits ``budget_floats``
    at the given chunk — the reuse clamp of the effective-traffic model:
    past this B the ŷ/ii/jj tiles no longer stay resident across the
    batch, so the modeled 3m/B amortization stops improving."""
    # chunk·(B+s+2) + B·n <= budget  ⇒  B <= (budget − chunk(s+2)) / (chunk+n)
    b = int((budget_floats - float(chunk) * (s + 2)) // (chunk + n))
    return max(b, 1)


def perm_batch_cost(n: int, batch: int, chunk: int, s: int = 1,
                    budget_floats: Optional[float] = None) -> CostTerms:
    """Per-permutation cost of the condensed fused loop at (B, chunk).

    Traffic is the ledger's ``condensed_fused`` term — m(1 + 3/B) + n
    per permutation — evaluated at the EFFECTIVE batch
    ``min(B, perm_batch_fit(...))`` when a budget is supplied: a batch
    too large for its invariant tiles to stay resident gets no reuse
    credit beyond the batch that does fit.
    """
    m = condensed_size(n)
    chunk, _ = snap_chunk(m, chunk)
    b_eff = batch
    if budget_floats is not None:
        b_eff = min(batch, perm_batch_fit(n, chunk, budget_floats, s))
    per_perm = perm_traffic_floats(n, max(b_eff, 1))["condensed_fused"]
    return CostTerms(
        op="perm_batch", traffic_floats=per_perm,
        resident_floats=perm_resident_floats(n, batch, chunk, s),
        base_floats=float(m),
        params={"n": n, "batch": batch, "batch_effective": b_eff,
                "chunk": chunk, "s": s, "model": "condensed_fused"})


# --------------------------------------------------------------------------
# the tiled distance production sweep (dist.driver / kernels.pairwise)
# --------------------------------------------------------------------------
def production_cost(n: int, d: int, block: int,
                    feature_block: int = 128) -> CostTerms:
    """Feature traffic and residency of the tiled pairwise production.

    Traffic is the ledger's ``production_floats`` (⌈n/b⌉·n·d + n·d —
    the clamp inside it is ``dispatch.clamp_block``'s rule). Residency
    per panel step: the (b, d) row panel, one (b, feature_block)
    column-block operand pair, and the (b, n) output strip.
    """
    b = clamp_block(n, block)
    fb = max(min(feature_block, d), 1)
    resident = float(b) * d + 2.0 * b * fb + float(b) * n
    return CostTerms(
        op="production", traffic_floats=production_floats(n, d, block),
        resident_floats=resident, base_floats=0.0,
        params={"n": n, "d": d, "block": b, "feature_block": fb})


# --------------------------------------------------------------------------
# the centered-operator matvec (kernels.center_matvec) / fsvd coords
# --------------------------------------------------------------------------
def matvec_cost(n: int, k: int, block: int, passes: float = 1.0,
                lane: int = 8) -> CostTerms:
    """Traffic and residency of ``passes`` fused center-matvec sweeps
    (the coords artifact is ``passes=HOIST_PASSES['coords']`` = 4 such
    reads of D). Traffic per pass is one read of D — n² floats, the
    ledger's ``hoist_floats`` unit. Residency per tile step: one
    (b, b) D tile, the (b, k) x panel, and the (b, k) partial output.
    """
    b = pick_block(n, block, lane)
    resident = float(b) * b + 2.0 * float(b) * max(k, 1)
    return CostTerms(
        op="matvec", traffic_floats=passes * hoist_floats("square", n),
        resident_floats=resident, base_floats=0.0,
        params={"n": n, "k": k, "block": b, "passes": passes})


# --------------------------------------------------------------------------
# session-level pass accounting (the BENCH_api 11-vs-16 battery)
# --------------------------------------------------------------------------
def session_hoist_passes(artifacts, feature_backed: bool = False) -> float:
    """Total n²-passes of a session that builds ``artifacts`` (in
    order, duplicates = rebuilds), straight from the ledger's pass
    tables. ``session_hoist_passes(SQUARE_SESSION_ARTIFACTS)`` is the
    published 11; ``...(STANDALONE_SESSION_ARTIFACTS)`` the 16."""
    t = FEATURE_HOIST_PASSES if feature_backed else HOIST_PASSES
    return float(sum(t.get(a, 0.0) for a in artifacts))
