"""repro.tune — cost-model-driven autotuning.

Every tile size in this repo used to be a hand-picked constant (the
center-matvec 512 block, the mantel/pairwise 256, the 64k permute
chunk, the 8-vs-32 batch). This package picks them from a measured
budget instead:

* ``model``  — per-kernel closed-form traffic AND residency, the
  traffic side imported verbatim from the audited ``obs.ledger``
  registry (parity by construction);
* ``budget`` — per-backend byte budgets (VMEM / L2-class) with an
  optional two-point timed calibration, JSON-persistable;
* ``solve``  — the solver: lane-snapped candidates, fit the modeled
  resident set under the budget, minimize modeled effective traffic.

Entry point for users: ``ExecConfig(auto=True)`` (or any single knob
set to ``"auto"``) — ``Workspace`` resolves it against the admitted
data's (n, d) and records the solved tiles in ``report()``.
"""

from repro.tune.budget import (BackendBudget, calibrate, detect_budget,
                               load_profile, save_profile)
from repro.tune.model import (CostTerms, matvec_cost, perm_batch_cost,
                              perm_batch_fit, production_cost,
                              session_hoist_passes)
from repro.tune.solve import TunedTiles, resolve_exec_config, solve_tiles

__all__ = [
    "BackendBudget", "calibrate", "detect_budget", "load_profile",
    "save_profile", "CostTerms", "matvec_cost", "perm_batch_cost",
    "perm_batch_fit", "production_cost", "session_hoist_passes",
    "TunedTiles", "resolve_exec_config", "solve_tiles",
]
