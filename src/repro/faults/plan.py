"""Deterministic, seed-scheduled fault injection for the serving plane.

The service's fault model has to be *testable*, which rules out the two
easy designs: real chaos (kill -9, cgroup throttling) is not
reproducible inside a unit test, and ``random.random() < rate`` checks
drift with call interleavings. This module's schedule is a pure
function of ``(plan.seed, site, invocation_index)``: every injection
site keeps its own invocation counter, and whether fault spec *i* fires
at invocation *k* of site *s* is decided by a counter-keyed hash —
``unit_hash(seed, f"{s}:{i}", k) < rate`` — so the same plan against
the same request sequence injects the same faults in the same places,
run after run, regardless of wall clock or scheduling jitter. That
determinism is what lets the chaos suite assert the strong property:
*completed* requests' p-values are bitwise-equal to the fault-free run.

Fault classes (``FaultSpec.kind``), matching the failure taxonomy the
recovery plane in ``repro.serve`` handles:

* ``error``   — transient tile-compute failure (device hiccup);
* ``oom``     — simulated allocator out-of-memory on a tile;
* ``nan``     — NaN-poisoned tile statistics (silent numeric corruption,
  the nastiest class: without an output admission check it would skew
  exceedance counts instead of crashing);
* ``slow``    — a tile that completes late (sleeps ``delay_s`` inside
  the timed window — exercises the straggler flagger / SLO breaches);
* ``stall``   — a tile that *begins but never completes* (the step span
  is left open) — exercises the ``StepMonitor`` watchdog escalation;
* ``compile`` — lane hoist/compile failure at activation;
* ``evict``   — a session-pool eviction race: a study with live tiles
  is force-dropped, and its in-flight requests must terminate with a
  structured ``stale_generation`` rejection, not a crash.

Injection points are threaded through ``serve/scheduler.py`` (site
``serve.tile``), ``serve/service.py`` (``serve.hoist``, ``serve.pool``)
— and they are zero-cost no-ops when disabled: a service built without
a plan holds no injector at all (``injector is None`` guards), so the
hot tile loop pays nothing for the capability.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import Counter
from typing import Optional, Tuple

#: the sites the serving plane polls, and the kinds each site understands
SITES = {
    "serve.tile": ("error", "oom", "nan", "slow", "stall"),
    "serve.hoist": ("compile",),
    "serve.pool": ("evict",),
}


# --------------------------------------------------------------------------
# The fault taxonomy as an exception hierarchy
# --------------------------------------------------------------------------
class FaultError(RuntimeError):
    """Base of every injected fault. Subclasses ``RuntimeError`` on
    purpose: the recovery plane catches ``(FaultError, RuntimeError)``
    around tile execution, so a *real* transient device error (jax's
    ``XlaRuntimeError`` is a ``RuntimeError``) takes the same retry
    path as an injected one — the injector exists to prove that path."""


class TransientTileError(FaultError):
    """A tile-compute failure expected to succeed on retry."""


class AllocFault(FaultError):
    """Simulated allocator OOM — besides the retry, the service sheds
    pool bytes (evicts an idle session) before the next attempt."""


class CompileFault(FaultError):
    """Lane hoist/compile failure at request activation."""


class StallFault(FaultError):
    """A tile that began but never completed: the scheduler leaves the
    step span OPEN, so the next loop turn's watchdog heartbeat must
    escalate it into the retry path."""


class PoisonError(FaultError):
    """Raised by the scheduler's own tile-output admission check when a
    tile returns non-finite statistics (whether injected or real)."""


def unit_hash(seed: int, label: str, index: int) -> float:
    """Deterministic uniform in [0, 1) from ``(seed, label, index)``.

    One stable hash serves both the injector's fire decisions and the
    retry plane's backoff jitter — nothing in the fault/recovery path
    consumes ambient randomness, which is precisely why a chaos run is
    replayable."""
    h = hashlib.blake2b(f"{seed}:{label}:{index}".encode(),
                       digest_size=8).digest()
    return int.from_bytes(h, "big") / 2.0 ** 64


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault class at one injection site.

    ``rate`` fires probabilistically (by counter hash — deterministic
    for a fixed plan seed); ``at`` names explicit invocation indices
    that always fire (for pinpoint regression tests). ``max_fires``
    bounds the total (None = unbounded), ``delay_s`` is the sleep for
    ``slow``/``stall`` kinds.
    """

    site: str
    kind: str
    rate: float = 0.0
    at: Tuple[int, ...] = ()
    max_fires: Optional[int] = None
    delay_s: float = 0.0

    def __post_init__(self):
        kinds = SITES.get(self.site)
        if kinds is None:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"expected one of {sorted(SITES)}")
        if self.kind not in kinds:
            raise ValueError(f"site {self.site!r} does not understand "
                             f"kind {self.kind!r}; expected one of {kinds}")
        if not (0.0 <= self.rate <= 1.0):
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seed plus the fault specs it schedules (frozen, hashable-ish).

    ``FaultPlan.chaos(seed)`` builds the representative mixed plan the
    chaos suite sweeps; tests compose exact plans from specs directly.
    """

    seed: int = 0
    specs: Tuple[FaultSpec, ...] = ()

    @staticmethod
    def chaos(seed: int = 0, *, tile_error: float = 0.08,
              oom: float = 0.02, nan: float = 0.02, slow: float = 0.02,
              compile_rate: float = 0.05, evict: float = 0.0,
              delay_s: float = 0.0) -> "FaultPlan":
        """The mixed chaos-soak plan: every transient class at once.

        ``stall`` and ``evict`` default off here (each has its own
        targeted scenario in the suite) but can be dialed in."""
        specs = []
        if tile_error:
            specs.append(FaultSpec("serve.tile", "error", rate=tile_error))
        if oom:
            specs.append(FaultSpec("serve.tile", "oom", rate=oom))
        if nan:
            specs.append(FaultSpec("serve.tile", "nan", rate=nan,
                                   max_fires=4))
        if slow:
            specs.append(FaultSpec("serve.tile", "slow", rate=slow,
                                   delay_s=delay_s))
        if compile_rate:
            specs.append(FaultSpec("serve.hoist", "compile",
                                   rate=compile_rate, max_fires=2))
        if evict:
            specs.append(FaultSpec("serve.pool", "evict", rate=evict,
                                   max_fires=1))
        return FaultPlan(seed=seed, specs=tuple(specs))


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One fault that actually fired (the injector's audit trail)."""

    site: str
    kind: str
    index: int          # the site invocation it fired at


class FaultInjector:
    """Polls a :class:`FaultPlan` at named injection sites.

    ``poll(site)`` advances that site's invocation counter and returns
    the specs firing at this invocation (usually empty). The decision
    is a pure function of (plan seed, spec position, invocation index),
    so two services running identical request sequences under the same
    plan observe identical fault schedules. ``fires`` is the audit
    trail the serve metrics fold into ``serve_report()``.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._counts: Counter = Counter()
        self._fired: Counter = Counter()
        self.fires: list = []

    def poll(self, site: str) -> list:
        """The specs firing at this invocation of ``site``."""
        index = self._counts[site]
        self._counts[site] = index + 1
        out = []
        for i, spec in enumerate(self.plan.specs):
            if spec.site != site:
                continue
            if spec.max_fires is not None and self._fired[i] >= spec.max_fires:
                continue
            fire = index in spec.at or (
                spec.rate > 0.0
                and unit_hash(self.plan.seed, f"{site}:{i}", index)
                < spec.rate)
            if fire:
                self._fired[i] += 1
                self.fires.append(FaultEvent(site, spec.kind, index))
                out.append(spec)
        return out

    def invocations(self, site: str) -> int:
        return self._counts[site]

    def summary(self) -> dict:
        """Fired counts by ``site:kind`` — the report's injected view."""
        tally: Counter = Counter()
        for ev in self.fires:
            tally[f"{ev.site}:{ev.kind}"] += 1
        return dict(tally)
