"""repro.faults: the deterministic fault-injection plane.

Commodity/edge deployments make failures the common case, not the
exception — so before a real transport or out-of-core IO can be layered
on ``repro.serve``, the service needs a *defined* fault model. This
package supplies the adversary half: seed-scheduled fault plans
(:class:`FaultPlan`) polled at injection points inside the serve
scheduler/service (:class:`FaultInjector`), deterministic enough that a
chaos run's surviving results can be gated bitwise against the
fault-free run. The recovery half — retry with backoff, per-lane
circuit breakers, deadlines, journal recovery — lives in
``repro.serve``; this package only ever *causes* trouble.
"""

from repro.faults.plan import (SITES, AllocFault, CompileFault, FaultError,
                               FaultEvent, FaultInjector, FaultPlan,
                               FaultSpec, PoisonError, StallFault,
                               TransientTileError, unit_hash)

__all__ = [
    "FaultPlan", "FaultSpec", "FaultInjector", "FaultEvent", "SITES",
    "FaultError", "TransientTileError", "AllocFault", "CompileFault",
    "StallFault", "PoisonError", "unit_hash",
]
