"""Tiled distance-matrix streaming for out-of-core paper workloads.

A 100k×100k fp32 distance matrix is 40 GB — beyond one chip's HBM. This
loader yields (row_block, col_block) tiles of a *deterministic* synthetic
Euclidean distance matrix (random points, seeded) so the pod-scale
centering/Mantel paths can be driven without materializing the matrix on
any single host — the I/O-side mirror of the paper's tiling.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DistanceTileStream:
    n: int
    dim: int = 16
    seed: int = 0
    tile: int = 4096
    dtype: str = "float32"

    def _points(self, start: int, size: int) -> jax.Array:
        key = jax.random.PRNGKey(self.seed)
        rows = jnp.arange(start, start + size, dtype=jnp.uint32)
        return jax.vmap(
            lambda r: jax.random.normal(jax.random.fold_in(key, r),
                                        (self.dim,)))(rows)

    def tile_at(self, i: int, j: int) -> jax.Array:
        """Distance tile D[i:i+T, j:j+T] (clipped at the matrix edge)."""
        ti = min(self.tile, self.n - i)
        tj = min(self.tile, self.n - j)
        a = self._points(i, ti)
        b = self._points(j, tj)
        d2 = (jnp.sum(a * a, 1)[:, None] + jnp.sum(b * b, 1)[None, :]
              - 2.0 * a @ b.T)
        d = jnp.sqrt(jnp.maximum(d2, 0.0)).astype(self.dtype)
        if i == j:
            d = d - jnp.diag(jnp.diag(d))      # exact hollowness
        return d

    def row_strip(self, i: int) -> jax.Array:
        """Full row strip D[i:i+T, :] assembled from tiles."""
        return jnp.concatenate([self.tile_at(i, j)
                                for j in range(0, self.n, self.tile)], axis=1)

    def tiles(self) -> Iterator[Tuple[int, int, jax.Array]]:
        for i in range(0, self.n, self.tile):
            for j in range(0, self.n, self.tile):
                yield i, j, self.tile_at(i, j)

    def dense(self) -> jax.Array:
        """Materialize (small n only — tests/benchmarks)."""
        return jnp.concatenate([self.row_strip(i)
                                for i in range(0, self.n, self.tile)], axis=0)
