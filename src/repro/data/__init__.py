from repro.data.pipeline import TokenPipeline, make_batch_specs
from repro.data.distance import DistanceTileStream

__all__ = ["TokenPipeline", "make_batch_specs", "DistanceTileStream"]
