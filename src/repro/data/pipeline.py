"""Deterministic, shard-aware synthetic token pipeline.

Properties a 1000-node training job needs from its data layer, reproduced
here without an external corpus:

* **determinism by (step, position)** — batches are a pure function of the
  global step, so restart/elastic-resume produces byte-identical data
  regardless of host count or mesh shape;
* **host-sharded** — each process materializes only its slice of the
  global batch (``process_index``/``process_count``);
* **learnable structure** — tokens follow a noisy affine recurrence
  ``t_{i+1} = (a·t_i + c) mod V`` with flip probability ``noise``, so a
  real model demonstrably reduces loss on it (quickstart example), while
  ``mode="uniform"`` gives i.i.d. tokens for pure throughput work.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mode: str = "structured"          # structured | uniform
    noise: float = 0.05
    process_index: int = 0
    process_count: int = 1

    def __post_init__(self):
        if self.global_batch % self.process_count:
            raise ValueError("global_batch must divide over processes")
        self.local_batch = self.global_batch // self.process_count
        self._a = 31 % self.vocab or 1
        self._c = 17 % self.vocab

    def batch(self, step: int) -> dict:
        """→ {"tokens": (local_B, S) int32, "targets": (local_B, S) int32}."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        rows = np.arange(self.local_batch) + self.process_index * self.local_batch
        keys = jax.vmap(lambda r: jax.random.fold_in(key, r))(
            jnp.asarray(rows, jnp.uint32))

        if self.mode == "uniform":
            toks = jax.vmap(lambda k: jax.random.randint(
                k, (self.seq_len + 1,), 0, self.vocab))(keys)
        else:
            def one_row(k):
                k0, k1 = jax.random.split(k)
                start = jax.random.randint(k0, (), 0, self.vocab)
                flips = jax.random.bernoulli(k1, self.noise,
                                             (self.seq_len + 1,))
                rand = jax.random.randint(jax.random.fold_in(k1, 7),
                                          (self.seq_len + 1,), 0, self.vocab)

                def stepf(t, i):
                    nxt = (self._a * t + self._c) % self.vocab
                    nxt = jnp.where(flips[i], rand[i], nxt)
                    return nxt, nxt

                _, seq = jax.lax.scan(stepf, start,
                                      jnp.arange(self.seq_len + 1))
                return seq

            toks = jax.vmap(one_row)(keys)
        toks = jnp.asarray(toks, jnp.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def make_batch_specs(cfg, shape, dtype=jnp.int32) -> dict:
    """ShapeDtypeStruct stand-ins for the training batch (dry-run inputs)."""
    b, s = shape.global_batch, shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((b, s), dtype),
             "targets": jax.ShapeDtypeStruct((b, s), dtype)}
    return specs
