"""Gradient compression with error feedback for cross-pod sync.

Cross-pod ICI/DCN links are the scarcest bandwidth tier at 1000+ nodes;
the classic mitigation is quantized gradient exchange with an error-
feedback accumulator (the quantization residual is replayed into the
next step, so the *expected* update is unbiased and convergence matches
fp32 all-reduce in practice).

Two levels, both usable inside ``shard_map`` over the 'pod' axis:

* ``compressed_psum(..., bits=16)`` — bf16 exchange (2× traffic cut);
* ``compressed_psum(..., bits=8)``  — int8 + per-tensor fp32 scale
  (≈4× traffic cut; sum accumulated in int32).

``train.py --grad-compression`` wires this under the pure-DP pod axis
(grads are FSDP-reduce-scattered *within* a pod by GSPMD as usual; only
the pod-level sync is hand-compressed).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(grads, error_state, axis_name: str, bits: int = 8):
    """Mean-reduce ``grads`` over ``axis_name`` with error feedback.

    Returns (synced_grads fp32, new_error_state). Must run inside
    shard_map with ``axis_name`` bound.
    """
    n = jax.lax.axis_size(axis_name)

    def one(g, err):
        gf = g.astype(jnp.float32) + err
        if bits == 8:
            q, scale = quantize_int8(gf)
            sent = dequantize_int8(q, scale)
            total = jax.lax.psum(q.astype(jnp.int32), axis_name)
            scales = jax.lax.all_gather(scale, axis_name)
            # exact sum of what peers sent: Σ_p q_p·scale_p; per-peer scales
            # differ, so reconstruct via the gathered scales
            qs = jax.lax.all_gather(q.astype(jnp.int32), axis_name)
            del total
            synced = jnp.tensordot(scales, qs.astype(jnp.float32), axes=(0, 0)) / n
        else:
            sent = gf.astype(jnp.bfloat16).astype(jnp.float32)
            synced = jax.lax.psum(gf.astype(jnp.bfloat16), axis_name)
            synced = synced.astype(jnp.float32) / n
        new_err = gf - sent
        return synced, new_err

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
