"""AdamW with warmup+cosine schedule and global-norm clipping.

Implemented from scratch (no optax dependency). Moment dtype is
configurable per arch (``cfg.opt_dtype``): fp32 default, bf16 for the
≥100B archs so optimizer state fits the 16 GB/chip HBM budget at 256
chips (DESIGN §5). m/v shard exactly like their parameters (FSDP/ZeRO):
the optimizer update is fully elementwise, so GSPMD keeps it local.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(opt: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = opt.peak_lr * step / max(opt.warmup_steps, 1)
    prog = jnp.clip((step - opt.warmup_steps)
                    / max(opt.decay_steps - opt.warmup_steps, 1), 0.0, 1.0)
    cos = opt.min_lr_ratio + (1 - opt.min_lr_ratio) * 0.5 \
        * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < opt.warmup_steps, warm, opt.peak_lr * cos)


def init_opt_state(params: Any, dtype=jnp.float32) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def _global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, opt_state, params, opt: AdamWConfig):
    """→ (new_params, new_opt_state, metrics). Decoupled weight decay is
    skipped for 1-D leaves (norm scales, biases), standard practice."""
    step = opt_state["step"] + 1
    lr = lr_schedule(opt, step)
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, opt.clip_norm / (gnorm + 1e-9))

    b1, b2 = opt.b1, opt.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        u = (m_new / c1) / (jnp.sqrt(v_new / c2) + opt.eps)
        if p.ndim > 1:
            u = u + opt.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * u
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
