from repro.optim.adamw import AdamWConfig, init_opt_state, adamw_update, lr_schedule
from repro.optim.compression import (quantize_int8, dequantize_int8,
                                     compressed_psum, init_error_state)

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update", "lr_schedule",
           "quantize_int8", "dequantize_int8", "compressed_psum",
           "init_error_state"]
