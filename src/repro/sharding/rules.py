"""Logical-axis → mesh-axis sharding rules (DESIGN §5).

Parallelism layout on the production mesh (pod?, data, model):

* **DP**   — batch over ('pod', 'data');
* **FSDP** — weight/optimizer-state sharding over the same DP axes
             (ZeRO-3 under GSPMD: all-gather at use, reduce-scatter grads);
* **TP**   — heads / d_ff / experts / vocab / recurrent channels over
             'model';
* **EP**   — MoE experts over 'model' when E divides it (granite 32e);
             otherwise TP inside each expert's FFN (grok 8e);
* **SP**   — decode-time KV caches shard their *sequence* axis over
             'model' (flash-decoding: the softmax reductions over the
             sharded axis lower to two small all-reduces per layer).

Rules are name-based over the param pytree paths; every rule fits the
axis only when the dimension divides it (``_fit``) so no GSPMD padding
is silently introduced — fallbacks are explicit (e.g. llama3.2's 24
heads → attention weights replicated over TP, smaller prefill query
chunks bound the head-replicated score buffer).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    dp: Tuple[str, ...]      # batch axes, e.g. ("pod", "data") / ("data",)
    fsdp: Tuple[str, ...]    # weight-sharding axes
    tp: str = "model"

    def axis_size(self, axes) -> int:
        if isinstance(axes, str):
            axes = (axes,)
        return int(np.prod([self.mesh.shape[a] for a in axes])) if axes else 1


def make_rules(mesh: Mesh, fsdp: bool = True) -> ShardingRules:
    """fsdp=True → ZeRO-3 weight sharding over the DP axes (memory-min);
    fsdp=False → weights/opt-state replicated over DP, TP only
    (collective-min: no per-use weight all-gathers, one grad all-reduce).
    The FSDP↔DP choice is the main §Perf lever for models whose optimizer
    state fits replicated."""
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    return ShardingRules(mesh=mesh, dp=dp, fsdp=dp if fsdp else ())


def _fit(dim: int, axes, rules: ShardingRules):
    """Largest suffix-truncated axis group whose product divides ``dim``.
    ('pod','data') → try both, then ('data',), then None."""
    if isinstance(axes, str):
        axes = (axes,)
    axes = tuple(axes)
    while axes:
        if dim % rules.axis_size(axes) == 0:
            return axes if len(axes) > 1 else axes[0]
        axes = axes[1:]
    return None


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return "/".join(parts)


# --------------------------------------------------------------------------
# parameter rules
# --------------------------------------------------------------------------
def _param_spec(name: str, shape, cfg, rules: ShardingRules) -> P:
    tp, fsdp = rules.tp, rules.fsdp
    nd = len(shape)
    leaf = name.rsplit("/", 1)[-1]

    if leaf in ("table", "head"):                       # (V, D)
        return P(_fit(shape[0], tp, rules), _fit(shape[1], fsdp, rules))
    if leaf in ("wq", "wk", "wv"):                      # (D, H, hd)
        # kv heads that do not fit the TP axis are REPLICATED (attention
        # expands K/V to the full head count — see models/attention._attend);
        # their weights are small (D·K·hd).
        h_ax = _fit(shape[1], tp, rules)
        return P(_fit(shape[0], fsdp, rules), h_ax, None)
    if leaf == "wo":                                    # (H, hd, D)
        h_ax = _fit(shape[0], tp, rules)
        return P(h_ax, None, _fit(shape[2], fsdp, rules))
    if leaf in ("bq", "bk", "bv"):                      # (H, hd)
        return P(_fit(shape[0], tp, rules), None)
    if leaf in ("w_gate", "w_up"):
        if nd == 3:                                     # (E, D, F) MoE
            e_ax = _fit(shape[0], tp, rules)
            f_ax = None if e_ax else _fit(shape[2], tp, rules)
            return P(e_ax, _fit(shape[1], fsdp, rules), f_ax)
        return P(_fit(shape[0], fsdp, rules), _fit(shape[1], tp, rules))
    if leaf == "w_down":
        if nd == 3:                                     # (E, F, D) MoE
            e_ax = _fit(shape[0], tp, rules)
            f_ax = None if e_ax else _fit(shape[1], tp, rules)
            return P(e_ax, f_ax, _fit(shape[2], fsdp, rules))
        return P(_fit(shape[0], tp, rules), _fit(shape[1], fsdp, rules))
    if leaf == "router":                                # (D, E) fp32
        return P(_fit(shape[0], fsdp, rules), None)
    # recurrent block
    if leaf in ("w_gate_branch", "w_rec_branch"):       # (D, R)
        return P(_fit(shape[0], fsdp, rules), _fit(shape[1], tp, rules))
    if leaf in ("w_a", "w_x") and nd == 2 and shape[0] == shape[1]:
        return P(_fit(shape[0], fsdp, rules), _fit(shape[1], tp, rules))
    if leaf in ("b_a", "b_x", "lambda"):                # (R,)
        return P(_fit(shape[0], tp, rules))
    if leaf == "w_out":                                 # (R|di, D)
        return P(_fit(shape[0], tp, rules), _fit(shape[1], fsdp, rules))
    # ssd block
    if leaf in ("w_x", "w_z"):                          # (D, di)
        return P(_fit(shape[0], fsdp, rules), _fit(shape[1], tp, rules))
    if leaf in ("w_b", "w_c"):                          # (D, g*N)
        # g·N is tiny (128 for mamba2); TP-sharding it turns every SSD
        # state contraction into a psum of x-sized f32 tensors — replicate
        # (§Perf C, iteration hc-C3)
        return P(_fit(shape[0], fsdp, rules), None)
    if leaf == "w_dt":                                  # (D, nh)
        return P(_fit(shape[0], fsdp, rules), _fit(shape[1], tp, rules))
    if leaf in ("dt_bias", "a_log", "d_skip"):          # (nh,)
        return P(_fit(shape[0], tp, rules))
    if leaf == "conv_w":                                # (W, channels)
        return P(None, _fit(shape[1], tp, rules))
    if leaf == "norm_w":                                # (di,)
        return P(_fit(shape[0], tp, rules))
    if leaf == "proj":                                  # frontend (fd, D)
        return P(None, _fit(shape[1], fsdp, rules))
    # norms / scalars / anything small: replicate
    return P(*([None] * nd))


_STACKED_PREFIXES = ("blocks", "enc_blocks", "dec_blocks")


def param_specs(cfg, params, rules: ShardingRules):
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs).
    Scanned stacks get a leading None (layer axis unsharded)."""

    def one(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        stacked = name.split("/", 1)[0] in _STACKED_PREFIXES
        if stacked:
            spec = _param_spec(name, shape[1:], cfg, rules)
            return P(None, *spec)
        return _param_spec(name, shape, cfg, rules)

    return jax.tree_util.tree_map_with_path(one, params)


# --------------------------------------------------------------------------
# cache rules (decode/prefill state)
# --------------------------------------------------------------------------
def _cache_spec(name: str, shape, cfg, rules: ShardingRules) -> P:
    dp, tp = rules.dp, rules.tp
    leaf = name.rsplit("/", 1)[-1]
    nd = len(shape)
    if leaf in ("k", "v", "cross_k", "cross_v"):        # (B, S, K, hd)
        b_ax = _fit(shape[0], dp, rules)
        # SP: sequence over 'model' (flash-decoding); ring buffers (local
        # windows) stay unsharded in seq — they are small.
        s_ax = _fit(shape[1], tp, rules) if shape[1] > 4096 else None
        k_ax = None if s_ax else _fit(shape[2], tp, rules)
        return P(b_ax, s_ax, k_ax, None)
    if leaf in ("k_scale", "v_scale"):                  # (B, S, K)
        b_ax = _fit(shape[0], dp, rules)
        s_ax = _fit(shape[1], tp, rules) if shape[1] > 4096 else None
        return P(b_ax, s_ax, None)
    if leaf == "pos" and nd == 1:
        return P(None)
    if leaf == "conv":                                  # (B, W-1, channels)
        return P(_fit(shape[0], dp, rules), None, _fit(shape[2], tp, rules))
    if leaf == "h":
        if nd == 2:                                     # rec state (B, R)
            return P(_fit(shape[0], dp, rules), _fit(shape[1], tp, rules))
        if nd == 4:                                     # ssd state (B,nh,N,hd)
            return P(_fit(shape[0], dp, rules), _fit(shape[1], tp, rules),
                     None, None)
    return P(*([None] * nd))


def cache_specs(cfg, cache, rules: ShardingRules):
    def one(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        if not shape:
            return P()
        stacked = any(s in name.split("/") for s in ("blocks", "dec"))
        if stacked and len(shape) >= 1:
            spec = _cache_spec(name, shape[1:], cfg, rules)
            return P(None, *spec)
        return _cache_spec(name, shape, cfg, rules)

    return jax.tree_util.tree_map_with_path(one, cache)


# --------------------------------------------------------------------------
# batch / activation rules
# --------------------------------------------------------------------------
def batch_spec(rules: ShardingRules, batch: int, rank: int = 2) -> P:
    """Tokens/targets (B, S): batch over the DP axes that divide it."""
    b_ax = _fit(batch, rules.dp, rules)
    return P(b_ax, *([None] * (rank - 1)))


def logits_spec(rules: ShardingRules, batch: int, vocab: int) -> P:
    return P(_fit(batch, rules.dp, rules), None, _fit(vocab, rules.tp, rules))


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
