from repro.sharding.rules import (ShardingRules, make_rules, param_specs,
                                  cache_specs, batch_spec, named)

__all__ = ["ShardingRules", "make_rules", "param_specs", "cache_specs",
           "batch_spec", "named"]
