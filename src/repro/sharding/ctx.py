"""Activation-sharding context.

GSPMD propagates parameter shardings well, but long scan chains can drop
the *batch* sharding of the residual stream (observed on the dry-run:
f32 logits (256, 4096, V/16) — 40 GB/device — because `hidden` reached
the loss batch-replicated). Production JAX LM stacks pin activation
shardings explicitly at layer boundaries; this context carries the
current ShardingRules into model code without threading it through every
call signature.

The runtime step functions enter ``use_rules(rules)`` *inside* the traced
function, so the constraints are baked in at trace time; when no context
is set (unit tests, kernels), ``constrain`` is a no-op.
"""

from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_CURRENT: Optional[object] = None      # ShardingRules


@contextlib.contextmanager
def use_rules(rules):
    global _CURRENT
    prev = _CURRENT
    _CURRENT = rules
    try:
        yield
    finally:
        _CURRENT = prev


def current_rules():
    return _CURRENT


def constrain_batch(x: jax.Array, batch_dim: int = 0,
                    seq_dim: Optional[int] = None) -> jax.Array:
    """Pin dim ``batch_dim`` to the DP axes (divisibility-checked) and —
    when ``seq_dim`` is given — that dim to the TP axis (sequence-parallel
    residual stream). Leaves the rest to GSPMD."""
    r = _CURRENT
    if r is None:
        return x
    from repro.sharding.rules import _fit
    spec = [None] * x.ndim
    spec[batch_dim] = _fit(x.shape[batch_dim], r.dp, r)
    if seq_dim is not None:
        spec[seq_dim] = _fit(x.shape[seq_dim], r.tp, r)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(r.mesh, P(*spec)))


def constrain(x: jax.Array, *axes) -> jax.Array:
    """Explicit spec: constrain(x, 'dp', None, 'tp') maps 'dp'→rules.dp,
    'tp'→rules.tp with divisibility checks."""
    r = _CURRENT
    if r is None:
        return x
    from repro.sharding.rules import _fit
    spec = []
    for dim, a in enumerate(axes):
        if a == "dp":
            spec.append(_fit(x.shape[dim], r.dp, r))
        elif a == "tp":
            spec.append(_fit(x.shape[dim], r.tp, r))
        else:
            spec.append(a)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(r.mesh, P(*spec)))
