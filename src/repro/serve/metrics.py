"""ServeMetrics + serve_report: the front door's observability binding.

Requests overlap in time, and the ``repro.obs`` tracer's nesting is
strict begin/end bracketing — so request-lifecycle timings enter the
span stream via ``Tracer.record`` (pre-timed appends, phase="serve"),
never as live overlapping spans. Per-study analytic costs (hoist
charges, per-tile permutation traffic) ride each pooled Workspace's own
``ObsSession`` ledger — the same audited terms as the library engine —
and ``serve_report()`` folds both together with the pool, queue, and
watchdog state into one service-level document.

Latency *distributions* ride ``obs.metrics.Histogram`` — fixed
log-spaced buckets, O(1) memory however long the service runs (the old
unbounded ``latencies`` list was a slow leak with a reporting API) —
one histogram each for queue wait (submit → activation), tile execution
(the scheduler's StepMonitor stopwatch), and end-to-end request latency
(submit → completion). Each may carry an SLO threshold from
``ServeConfig``; samples past it tick a breach ``Counter``. The report
carries p50/p95/p99 per distribution, and ``ServeMetrics.prometheus()``
renders the whole set as Prometheus text exposition for scraping.

* gauges — queue depth, active/admitted/completed/rejected counts,
  throughput (completed per second of service uptime), latency
  quantiles;
* latency — the three histograms' percentiles; slo — thresholds +
  breach counts;
* pool — sessions, per-study resident hoist bytes, evictions;
* scheduler — tiles executed, rows per tile, live lanes;
* studies — each pooled session's ledger totals + HoistCache counters
  (so "hoists charged once per study, not per request" is a readable
  fact, and the per-study ``RunReport`` remains available via
  ``Workspace.report()``);
* monitor — the ``StepMonitor`` summary (tile medians, p50/p95/p99,
  stragglers).
"""

from __future__ import annotations

import time
from collections import Counter as TallyCounter
from typing import Optional

from repro.obs.metrics import Counter, Histogram, prometheus_text
from repro.obs.trace import Tracer

#: histogram name -> ServeConfig threshold attribute
_SLO_FIELDS = {"queue_wait": "slo_queue_wait_s",
               "tile": "slo_tile_s",
               "request": "slo_request_s"}


class ServeMetrics:
    """Counters + histograms + a pre-timed span stream for one service.

    ``slo`` maps histogram names (``queue_wait`` / ``tile`` /
    ``request``) to threshold seconds; a recorded sample past its
    threshold increments the matching breach counter.
    """

    def __init__(self, slo: Optional[dict] = None):
        self.tracer = Tracer()
        self.t0 = time.perf_counter()
        self.admitted = 0          # requests accepted into the queue
        self.uploads = 0
        self.completed = 0
        self.rejections = TallyCounter()  # code -> count (timeouts too)
        self.tiles = 0
        self.tile_rows = 0
        self.tile_parts = 0
        self.queue_depth = 0
        self.slo = {k: v for k, v in (slo or {}).items() if v is not None}
        self.hist = {
            "queue_wait": Histogram("serve_queue_wait_seconds"),
            "tile": Histogram("serve_tile_seconds"),
            "request": Histogram("serve_request_seconds"),
        }
        self.breaches = {name: Counter(f"serve_slo_breach_{name}_total")
                         for name in self.hist}
        # -- fault/recovery accounting (the repro.faults control plane) --
        self.faults = TallyCounter()         # injected, by "site:kind"
        self.tile_failures = TallyCounter()  # failed tile attempts, by kind
        self.retries = 0                     # tile attempts re-scheduled
        self.retried_rows = 0                # rows re-executed by retries
        self.backoff_s = 0.0                 # cumulative scheduled backoff
        self.breaker_trips = 0
        self.escalations = 0                 # watchdog stall escalations
        self.cancels = TallyCounter()        # cancellations, by code
        self.stale_terminations = 0          # stale_generation rejections
        self.resumes = 0                     # journal-recovered requests
        self.resumed_rows = 0                # rows NOT re-run thanks to it
        self.degraded = 0                    # partial-envelope terminations
        self.pool_sheds = 0                  # OOM-pressure evictions

    # -- recording ---------------------------------------------------------
    def _observe(self, name: str, seconds: float) -> None:
        self.hist[name].record(seconds)
        limit = self.slo.get(name)
        if limit is not None and seconds > limit:
            self.breaches[name].inc()

    def record_upload(self, study_id: str, n: int, seconds: float) -> None:
        self.uploads += 1
        self.tracer.record(f"upload:{study_id}", seconds, phase="serve",
                           study=study_id, n=n)

    def record_admission(self) -> None:
        self.admitted += 1

    def record_rejection(self, code: str) -> None:
        self.rejections[code] += 1

    def record_queue_wait(self, seconds: float) -> None:
        """Submit → activation delay for one request."""
        self._observe("queue_wait", seconds)

    def record_tile(self, rows: int, parts: int,
                    seconds: Optional[float] = None) -> None:
        self.tiles += 1
        self.tile_rows += rows
        self.tile_parts += parts
        if seconds is not None:
            self._observe("tile", seconds)

    def record_completion(self, handle, seconds: float) -> None:
        """A finished request: latency histogram + one pre-timed serve
        span (requests overlap, so live spans would corrupt the tracer's
        nesting stack — ``record`` appends without opening one). A
        degraded termination counts separately — its envelope is a
        partial answer, not a completion."""
        if handle.status == "degraded":
            self.degraded += 1
        else:
            self.completed += 1
        self._observe("request", seconds)
        self.tracer.record(f"request:{handle.method}", seconds,
                           phase="serve", request_id=handle.request_id,
                           study=handle.study_id,
                           permutations=handle.permutations)

    def sample_queue_depth(self, depth: int) -> None:
        self.queue_depth = depth

    # -- fault/recovery recording ------------------------------------------
    def record_fault(self, site: str, kind: str) -> None:
        """One injected fault actually firing at a site."""
        self.faults[f"{site}:{kind}"] += 1

    def record_tile_failure(self, kind: str, rows: int) -> None:
        """One failed tile attempt (injected or real); ``rows`` is the
        tile's row count — work that produced nothing."""
        self.tile_failures[kind] += 1

    def record_retry(self, rows: int, backoff_s: float) -> None:
        """A lane re-scheduled after a failed attempt: the retried rows
        feed the amplification metric, the backoff the pacing one."""
        self.retries += 1
        self.retried_rows += rows
        self.backoff_s += backoff_s

    def record_breaker(self) -> None:
        self.breaker_trips += 1

    def record_escalation(self) -> None:
        self.escalations += 1

    def record_cancel(self, code: str) -> None:
        self.cancels[code] += 1

    def record_stale(self) -> None:
        self.stale_terminations += 1

    def record_resume(self, rows: int) -> None:
        """One journal-recovered request resuming at ``rows`` draws —
        rows the rebuilt service did NOT re-execute."""
        self.resumes += 1
        self.resumed_rows += rows

    def record_shed(self) -> None:
        self.pool_sheds += 1

    @property
    def retry_amplification(self) -> float:
        """Rows re-executed by retries per successfully-executed row —
        the chaos suite's boundedness gate (a retry storm shows up here
        long before it shows up in latency)."""
        return self.retried_rows / max(1, self.tile_rows)

    def faults_report(self) -> dict:
        """The fault/recovery section of ``serve_report()``."""
        return {
            "injected": dict(self.faults),
            "tile_failures": dict(self.tile_failures),
            "retries": self.retries,
            "retried_rows": self.retried_rows,
            "retry_amplification": self.retry_amplification,
            "backoff_s": self.backoff_s,
            "breaker_trips": self.breaker_trips,
            "escalations": self.escalations,
            "cancelled": dict(self.cancels),
            "stale_terminations": self.stale_terminations,
            "resumes": self.resumes,
            "resumed_rows": self.resumed_rows,
            "degraded": self.degraded,
            "pool_sheds": self.pool_sheds,
        }

    # -- gauges ------------------------------------------------------------
    def gauges(self) -> dict:
        uptime = time.perf_counter() - self.t0
        req = self.hist["request"]
        return {
            "uptime_s": uptime,
            "queue_depth": self.queue_depth,
            "uploads": self.uploads,
            "admitted": self.admitted,
            "completed": self.completed,
            "degraded": self.degraded,
            "rejected": dict(self.rejections),
            "throughput_rps": (self.completed / uptime) if uptime else 0.0,
            "latency_s": {
                "median": req.quantile(0.5),
                "p90": req.quantile(0.9),
                "max": req.max if req.count else None,
            },
            "rows_per_tile": (self.tile_rows / self.tiles
                              if self.tiles else None),
            "requests_per_tile": (self.tile_parts / self.tiles
                                  if self.tiles else None),
        }

    def latency(self) -> dict:
        """p50/p95/p99 (+count/mean/max) per latency distribution."""
        return {f"{name}_s": h.percentiles()
                for name, h in self.hist.items()}

    def slo_report(self) -> dict:
        return {"thresholds_s": dict(self.slo),
                "breaches": {name: c.value
                             for name, c in self.breaches.items()}}

    def prometheus(self) -> str:
        """The full metric set as Prometheus text exposition."""
        return prometheus_text(list(self.hist.values()) +
                               list(self.breaches.values()))


def serve_report(service) -> dict:
    """One service-level document (see module docstring)."""
    pool, sched = service.pool, service.scheduler
    studies = {}
    for sid in pool.studies():
        ws = pool._sessions[sid]
        studies[sid] = {
            "n": ws.n,
            "generation": ws.generation,
            "cache_nbytes": ws.cache.nbytes(),
            "hoist_builds": {str(k): v for k, v in ws.cache.misses.items()},
            "hoist_hits": {str(k): v for k, v in ws.cache.hits.items()},
            "ledger": (ws.obs.ledger.totals() if ws.obs.enabled else {}),
        }
    faults = service.metrics.faults_report()
    injector = getattr(service, "injector", None)
    if injector is not None:
        faults["plan"] = {"seed": injector.plan.seed,
                          "fired": injector.summary()}
    return {
        "gauges": service.metrics.gauges(),
        "latency": service.metrics.latency(),
        "slo": service.metrics.slo_report(),
        "faults": faults,
        "pool": {
            "sessions": len(pool),
            "max_sessions": pool.max_sessions,
            "max_bytes": pool.max_bytes,
            "nbytes": pool.nbytes(),
            "nbytes_by_study": pool.nbytes_by_study(),
            "evictions": pool.evictions,
        },
        "scheduler": {
            "tiles_run": sched.tiles_run,
            "batch_size": sched.batch_size,
            "live_lanes": len(sched.lanes),
        },
        "studies": studies,
        "monitor": (sched.monitor.summary() if sched.monitor._spans
                    else {"steps": 0}),
        "spans": service.metrics.tracer.to_dicts(),
    }
