"""ServeMetrics + serve_report: the front door's observability binding.

Requests overlap in time, and the ``repro.obs`` tracer's nesting is
strict begin/end bracketing — so request-lifecycle timings enter the
span stream via ``Tracer.record`` (pre-timed appends, phase="serve"),
never as live overlapping spans. Per-study analytic costs (hoist
charges, per-tile permutation traffic) ride each pooled Workspace's own
``ObsSession`` ledger — the same audited terms as the library engine —
and ``serve_report()`` folds both together with the pool, queue, and
watchdog state into one service-level document:

* gauges — queue depth, active/admitted/completed/rejected counts,
  throughput (completed per second of service uptime), latency
  quantiles;
* pool — sessions, per-study resident hoist bytes, evictions;
* scheduler — tiles executed, rows per tile, live lanes;
* studies — each pooled session's ledger totals + HoistCache counters
  (so "hoists charged once per study, not per request" is a readable
  fact, and the per-study ``RunReport`` remains available via
  ``Workspace.report()``);
* monitor — the ``StepMonitor`` summary (tile medians, stragglers).
"""

from __future__ import annotations

import statistics
import time
from collections import Counter

from repro.obs.trace import Tracer


class ServeMetrics:
    """Counters + gauges + a pre-timed span stream for one service."""

    def __init__(self):
        self.tracer = Tracer()
        self.t0 = time.perf_counter()
        self.admitted = 0          # requests accepted into the queue
        self.uploads = 0
        self.completed = 0
        self.rejections = Counter()   # code -> count (timeouts included)
        self.tiles = 0
        self.tile_rows = 0
        self.tile_parts = 0
        self.latencies: list = []
        self.queue_depth = 0

    # -- recording ---------------------------------------------------------
    def record_upload(self, study_id: str, n: int, seconds: float) -> None:
        self.uploads += 1
        self.tracer.record(f"upload:{study_id}", seconds, phase="serve",
                           study=study_id, n=n)

    def record_admission(self) -> None:
        self.admitted += 1

    def record_rejection(self, code: str) -> None:
        self.rejections[code] += 1

    def record_tile(self, rows: int, parts: int) -> None:
        self.tiles += 1
        self.tile_rows += rows
        self.tile_parts += parts

    def record_completion(self, handle, seconds: float) -> None:
        """A finished request: latency gauge + one pre-timed serve span
        (requests overlap, so live spans would corrupt the tracer's
        nesting stack — ``record`` appends without opening one)."""
        self.completed += 1
        self.latencies.append(seconds)
        self.tracer.record(f"request:{handle.method}", seconds,
                           phase="serve", request_id=handle.request_id,
                           study=handle.study_id,
                           permutations=handle.permutations)

    def sample_queue_depth(self, depth: int) -> None:
        self.queue_depth = depth

    # -- gauges ------------------------------------------------------------
    def gauges(self) -> dict:
        uptime = time.perf_counter() - self.t0
        lat = sorted(self.latencies)
        q = (lambda f: lat[min(len(lat) - 1, int(f * len(lat)))]
             ) if lat else (lambda f: None)
        return {
            "uptime_s": uptime,
            "queue_depth": self.queue_depth,
            "uploads": self.uploads,
            "admitted": self.admitted,
            "completed": self.completed,
            "rejected": dict(self.rejections),
            "throughput_rps": (self.completed / uptime) if uptime else 0.0,
            "latency_s": {
                "median": statistics.median(lat) if lat else None,
                "p90": q(0.9), "max": lat[-1] if lat else None,
            },
            "rows_per_tile": (self.tile_rows / self.tiles
                              if self.tiles else None),
            "requests_per_tile": (self.tile_parts / self.tiles
                                  if self.tiles else None),
        }


def serve_report(service) -> dict:
    """One service-level document (see module docstring)."""
    pool, sched = service.pool, service.scheduler
    studies = {}
    for sid in pool.studies():
        ws = pool._sessions[sid]
        studies[sid] = {
            "n": ws.n,
            "generation": ws.generation,
            "cache_nbytes": ws.cache.nbytes(),
            "hoist_builds": {str(k): v for k, v in ws.cache.misses.items()},
            "hoist_hits": {str(k): v for k, v in ws.cache.hits.items()},
            "ledger": (ws.obs.ledger.totals() if ws.obs.enabled else {}),
        }
    return {
        "gauges": service.metrics.gauges(),
        "pool": {
            "sessions": len(pool),
            "max_sessions": pool.max_sessions,
            "max_bytes": pool.max_bytes,
            "nbytes": pool.nbytes(),
            "nbytes_by_study": pool.nbytes_by_study(),
            "evictions": pool.evictions,
        },
        "scheduler": {
            "tiles_run": sched.tiles_run,
            "batch_size": sched.batch_size,
            "live_lanes": len(sched.lanes),
        },
        "studies": studies,
        "monitor": (sched.monitor.summary() if sched.monitor._spans
                    else {"steps": 0}),
        "spans": service.metrics.tracer.to_dicts(),
    }
