"""repro.serve: the multi-tenant analysis front door.

The library made each analysis cheap (hoist-once sessions, fused
condensed permutation tiles); this package makes *many concurrent
studies* cheap: a byte-budgeted LRU pool of live ``Workspace`` sessions
(``pool``), a scheduler that coalesces permutation requests from
different clients into shared padded tiles and streams anytime p-value
bounds as tiles complete (``scheduler``), bounded admission with
structured rejection (``admission``), and full ``repro.obs`` binding
(``metrics``). ``AnalysisService`` in ``service`` is the assembled
front door; ``python -m repro.launch.serve --smoke`` drives it end to
end.
"""

from repro.serve.admission import (Rejected, Rejection, RequestQueue,
                                   validate_upload)
from repro.serve.metrics import ServeMetrics, serve_report
from repro.serve.pool import SessionPool
from repro.serve.scheduler import (Lane, RetryPolicy, StreamUpdate,
                                   TileScheduler, exceedances,
                                   operand_fingerprint, partial_bounds)
from repro.serve.service import (METHODS, AnalysisService, RequestHandle,
                                 ServeConfig)

__all__ = [
    "AnalysisService", "ServeConfig", "RequestHandle", "METHODS",
    "SessionPool", "TileScheduler", "Lane", "StreamUpdate", "RetryPolicy",
    "RequestQueue", "Rejected", "Rejection", "validate_upload",
    "ServeMetrics", "serve_report", "partial_bounds", "exceedances",
    "operand_fingerprint",
]
