"""AnalysisService: the multi-tenant front door over the analysis stack.

One object ties the subsystem together: uploads admit studies into the
``SessionPool`` (validation + ``ExecConfig(auto=True)`` tune-solve at
admission), submissions enter the bounded ``RequestQueue``, and the
event loop (``step`` / ``run`` / ``arun``) activates queued requests up
to a concurrency bound and pumps the ``TileScheduler`` one coalesced
tile at a time. Clients hold a ``RequestHandle``: streamed
``StreamUpdate`` frames while tiles complete, then the final
``PermutationTestResult`` (or an ``OrdinationResult`` for ``pcoa``,
served synchronously off the pooled session's coordinate cache), or a
structured ``Rejection`` — never a traceback.

The service is cooperative and single-threaded by design (jax dispatch
is itself async; tiles are the natural quantum): ``arun`` is an asyncio
driver that yields between tiles so many client coroutines can await
their handles concurrently — see ``examples/serve_session.py``.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Optional

from repro.api.config import ExecConfig
from repro.core.distance_matrix import MAX_TRIANGLE_N
from repro.obs.config import ObsConfig
from repro.serve.admission import (Rejected, Rejection, RequestQueue,
                                   validate_upload)
from repro.serve.metrics import ServeMetrics, serve_report
from repro.serve.pool import SessionPool
from repro.serve.scheduler import TileScheduler, operand_fingerprint
from repro.stats.engine import as_key

#: the analyses the front door serves — the Workspace battery, complete
METHODS = ("pcoa", "permanova", "anosim", "permdisp", "mantel",
           "partial_mantel")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Service knobs (all bounded-by-default: a front door that cannot
    say no is a memory leak with an API).

    ``batch_size`` is the coalesced tile's B — the same quantity as
    ``ExecConfig.batch_size``, fixed service-wide so every study's tiles
    share program shapes. ``max_active`` bounds concurrently-scheduled
    requests (the rest wait in the admission queue, where ``timeout_s``
    deadlines and ``max_queue`` backpressure apply). ``auto_tune`` runs
    the ``repro.tune`` solver at upload against each study's own (n, d).
    ``deadline_factor`` parameterizes the tile watchdog
    (``runtime.monitor.StepMonitor``).

    The ``slo_*_s`` thresholds (all optional) arm the latency SLOs:
    queue wait (submit → activation), tile execution, and end-to-end
    request latency samples past a threshold tick the matching breach
    counter in ``serve_report()["slo"]`` — the alerting hook a fleet
    dashboard scrapes (``ServeMetrics.prometheus()``) without the
    service ever failing a request over a slow tile."""

    batch_size: int = 32
    max_sessions: int = 8
    max_bytes: Optional[int] = None
    max_queue: int = 64
    max_active: int = 8
    max_n: int = MAX_TRIANGLE_N
    timeout_s: Optional[float] = 30.0
    auto_tune: bool = True
    observe: bool = True
    deadline_factor: float = 20.0
    slo_queue_wait_s: Optional[float] = None
    slo_tile_s: Optional[float] = None
    slo_request_s: Optional[float] = None


class RequestHandle:
    """A client's view of one request: status, streamed updates, result.

    ``status`` walks queued → active → done (or rejected/timed_out).
    ``updates`` accumulates ``StreamUpdate`` frames; ``result`` is the
    final ``PermutationTestResult`` / ``OrdinationResult``; ``error``
    the ``Rejection``. ``payload()`` is the wire-shaped response for
    whatever state the request is in.
    """

    def __init__(self, request_id: str, study_id: str, method: str,
                 permutations: int, key, alternative: Optional[str],
                 params: dict):
        self.request_id = request_id
        self.study_id = study_id
        self.method = method
        self.permutations = permutations
        self.key = key
        self.alternative = alternative
        self.params = params
        self.status = "queued"
        self.updates: list = []
        self.result = None
        self.error: Optional[Rejection] = None
        self.statistic: Optional[float] = None
        self.deadline: Optional[float] = None
        self.t_submit = time.perf_counter()
        self.t_done: Optional[float] = None

    # -- scheduler callbacks ----------------------------------------------
    def push_update(self, update) -> None:
        self.updates.append(update)

    def complete(self, result) -> None:
        self.result = result
        self.status = "done"
        self.t_done = time.perf_counter()

    def reject(self, rejection: Rejection) -> None:
        self.error = rejection
        self.status = ("timed_out" if rejection.code == "timeout"
                       else "rejected")
        self.t_done = time.perf_counter()

    # -- client surface ----------------------------------------------------
    @property
    def done(self) -> bool:
        return self.status in ("done", "rejected", "timed_out")

    def partial(self):
        """The latest streamed frame (None before the first tile)."""
        return self.updates[-1] if self.updates else None

    def payload(self) -> dict:
        """The wire-shaped response for the request's current state."""
        base = {"request_id": self.request_id, "study_id": self.study_id,
                "method": self.method, "status": self.status}
        if self.error is not None:
            base.update(self.error.payload())
        elif self.method == "pcoa":
            if self.result is not None:
                base["result"] = {
                    "dimensions": int(self.result.coordinates.shape[1]),
                    "proportion_explained":
                        [float(v) for v in self.result.proportion_explained],
                }
        else:
            if self.partial() is not None:
                base["progress"] = self.partial().to_dict()
            if self.result is not None:
                base["result"] = {
                    "statistic": self.result.statistic,
                    "p_value": self.result.p_value,
                    "permutations": self.result.permutations,
                    "sample_size": self.result.sample_size,
                }
        return base


class AnalysisService:
    """The front door (see module docstring)."""

    def __init__(self, config: Optional[ServeConfig] = None):
        self.config = config if config is not None else ServeConfig()
        self.pool = SessionPool(self.config.max_sessions,
                                self.config.max_bytes)
        self.queue = RequestQueue(self.config.max_queue)
        self.metrics = ServeMetrics(slo={
            "queue_wait": self.config.slo_queue_wait_s,
            "tile": self.config.slo_tile_s,
            "request": self.config.slo_request_s})
        self.scheduler = TileScheduler(
            batch_size=self.config.batch_size, metrics=self.metrics)
        self.scheduler.monitor.deadline_factor = self.config.deadline_factor
        self._active: list = []
        self._ids = itertools.count(1)
        self._exec_config = ExecConfig(
            batch_size=self.config.batch_size,
            auto=self.config.auto_tune,
            obs=ObsConfig(enabled=self.config.observe))

    # -- uploads -----------------------------------------------------------
    def upload(self, study_id: str, data=None, *, features=None,
               metric=None) -> dict:
        """Admit (or re-admit) one study; returns the admission ack.

        Validation happens before any O(n²) work (structured rejection
        payloads for non-finite/oversized/misshapen uploads); admission
        builds the pooled ``Workspace`` — which resolves
        ``ExecConfig(auto=True)`` against this study's own (n, d) — and
        re-upload of a known id routes through ``Workspace.refresh``:
        the generation bumps, every cached hoist drops, and in-flight
        requests pinned to the old generation finish against the data
        they were admitted with.
        """
        t0 = time.perf_counter()
        try:
            kind, n = validate_upload(data, features,
                                      max_n=self.config.max_n)
        except Rejected as e:
            self.metrics.record_rejection(e.rejection.code)
            raise
        try:
            ws = self.pool.admit(
                study_id, self._exec_config,
                dm=data if kind == "dm" else None,
                features=features if kind == "features" else None,
                metric=metric)
        except ValueError as e:
            # the Workspace's own admission checks (asymmetry, non-hollow
            # diagonal, ...) — still a structured refusal, not a traceback
            self.metrics.record_rejection("bad_request")
            raise Rejected(Rejection("bad_request", str(e),
                                     {"study_id": study_id})) from None
        self.metrics.record_upload(study_id, n,
                                   time.perf_counter() - t0)
        return {"study_id": study_id, "n": ws.n,
                "generation": ws.generation,
                "backing": kind,
                "cache_nbytes": ws.cache.nbytes(),
                "tuned": ws.tuned is not None}

    # -- submissions -------------------------------------------------------
    def submit(self, study_id: str, method: str, *, grouping=None,
               other=None, control=None, permutations: int = 999,
               key=None, alternative: Optional[str] = None,
               dimensions: Optional[int] = None, pcoa_method: str = "fsvd",
               timeout_s: Optional[float] = None) -> RequestHandle:
        """Enqueue one analysis request; returns its handle immediately.

        ``other``/``control`` name *uploaded studies* (the Mantel-family
        operands live server-side, like the permuted side). The request
        waits in the bounded queue until the loop activates it;
        ``queue_full`` raises ``Rejected`` immediately, a lapsed
        ``timeout_s`` fails the handle with a ``timeout`` rejection.
        """
        if method not in METHODS:
            self.metrics.record_rejection("bad_request")
            raise Rejected.make(
                "bad_request",
                f"unknown method {method!r}; available: {list(METHODS)}",
                method=method)
        if study_id not in self.pool:
            self.metrics.record_rejection("unknown_study")
            raise Rejected.make(
                "unknown_study",
                f"study {study_id!r} is not resident (never uploaded, or "
                f"evicted) — upload it first", study_id=study_id)
        handle = RequestHandle(
            request_id=f"r{next(self._ids)}", study_id=study_id,
            method=method, permutations=int(permutations),
            key=as_key(key), alternative=alternative,
            params={"grouping": grouping, "other": other,
                    "control": control, "dimensions": dimensions,
                    "pcoa_method": pcoa_method})
        try:
            self.queue.push(handle, timeout_s if timeout_s is not None
                            else self.config.timeout_s)
        except Rejected as e:
            self.metrics.record_rejection(e.rejection.code)
            handle.reject(e.rejection)
            return handle
        self.metrics.record_admission()
        self.metrics.sample_queue_depth(len(self.queue))
        return handle

    # -- activation --------------------------------------------------------
    def _lane_key(self, ws, handle) -> tuple:
        """Requests may share a tile iff this matches: same study at the
        same generation, same method, same operand identities (grouping
        content; Mantel operand studies at their own generations; the
        ordination geometry behind permdisp)."""
        p = handle.params
        operands = [operand_fingerprint(p["grouping"])]
        for name in ("other", "control"):
            sid = p[name]
            if sid is not None:
                ref = self.pool.get(sid)
                operands.append((sid, ref.generation if ref else None))
            else:
                operands.append(None)
        operands.append((p["dimensions"], p["pcoa_method"])
                        if handle.method == "permdisp" else None)
        return (handle.study_id, ws.generation, handle.method,
                tuple(operands))

    def _activate(self, handle) -> None:
        """Bind one queued request to the scheduler (or finish it on the
        spot for ``pcoa``). Statistic-construction failures — bad
        grouping length, mismatched operand sizes, collinear partial-
        Mantel controls — become ``bad_request`` rejections."""
        self.metrics.record_queue_wait(
            time.perf_counter() - handle.t_submit)
        ws = self.pool.get(handle.study_id)
        if ws is None:                        # evicted while queued
            handle.reject(Rejection(
                "unknown_study",
                f"study {handle.study_id!r} was evicted while the "
                f"request waited; re-upload and retry",
                {"study_id": handle.study_id}))
            self.metrics.record_rejection("unknown_study")
            return
        p = handle.params
        try:
            if handle.method == "pcoa":
                dims = p["dimensions"] if p["dimensions"] is not None else 10
                result = ws.pcoa(dimensions=dims, method=p["pcoa_method"],
                                 key=handle.key)
                handle.complete(result)
                self._finish(handle)
                return
            kwargs = {}
            if handle.method in ("permanova", "anosim", "permdisp"):
                kwargs["grouping"] = p["grouping"]
            if handle.method == "permdisp":
                kwargs["dimensions"] = p["dimensions"]
                kwargs["pcoa_method"] = p["pcoa_method"]
            if handle.method in ("mantel", "partial_mantel"):
                kwargs["other"] = self._resolve_operand(p["other"], "other")
            if handle.method == "partial_mantel":
                kwargs["control"] = self._resolve_operand(p["control"],
                                                          "control")
            stat, default_alt = ws.statistic(handle.method, **kwargs)
            self.scheduler.submit(handle, ws, self._lane_key(ws, handle),
                                  stat, default_alt)
            self._active.append(handle)
        except Rejected as e:
            handle.reject(e.rejection)
            self.metrics.record_rejection(e.rejection.code)
        except (ValueError, TypeError) as e:
            rej = Rejection("bad_request", str(e),
                            {"method": handle.method})
            handle.reject(rej)
            self.metrics.record_rejection("bad_request")

    def _resolve_operand(self, sid, role: str):
        if sid is None:
            raise Rejected.make("bad_request",
                                f"this method requires {role}= naming an "
                                f"uploaded study")
        ws = self.pool.get(sid)
        if ws is None:
            raise Rejected.make("unknown_study",
                                f"{role} study {sid!r} is not resident",
                                study_id=sid)
        return ws

    # -- the loop ----------------------------------------------------------
    def step(self) -> bool:
        """One loop turn: expire lapsed deadlines, activate queued
        requests up to ``max_active``, run one coalesced tile, retire
        finished requests. Returns True while work remains."""
        now = time.monotonic()
        for handle in self.queue.expired(now):
            handle.reject(Rejection(
                "timeout",
                f"request waited past its {self.config.timeout_s}s "
                f"deadline in the admission queue",
                {"request_id": handle.request_id}))
            self.metrics.record_rejection("timeout")
        self._active = [h for h in self._active if not h.done]
        while len(self._active) < self.config.max_active and len(self.queue):
            handle = self.queue.pop()
            if handle is None:
                break
            self._activate(handle)
        self.metrics.sample_queue_depth(len(self.queue))
        ran = self.scheduler.step()
        for handle in list(self._active):
            if handle.done:
                self._finish(handle)
                self._active.remove(handle)
        # keep in-flight studies out of eviction's reach
        self.pool.evict(exclude=self.scheduler.active_studies())
        return ran or bool(len(self.queue)) or bool(self._active)

    def _finish(self, handle) -> None:
        self.metrics.record_completion(
            handle, (handle.t_done or time.perf_counter())
            - handle.t_submit)

    def run(self) -> None:
        """Drain synchronously: loop until queue and scheduler are empty."""
        while self.step():
            pass

    async def arun(self) -> None:
        """Asyncio driver: one tile per loop turn, yielding between
        tiles so client coroutines awaiting handles interleave."""
        import asyncio
        while self.step():
            await asyncio.sleep(0)

    async def wait(self, handle: RequestHandle):
        """Await one handle (pump the loop while it is pending)."""
        import asyncio
        while not handle.done:
            self.step()
            await asyncio.sleep(0)
        return handle

    # -- reporting ---------------------------------------------------------
    def report(self) -> dict:
        return serve_report(self)
