"""AnalysisService: the multi-tenant front door over the analysis stack.

One object ties the subsystem together: uploads admit studies into the
``SessionPool`` (validation + ``ExecConfig(auto=True)`` tune-solve at
admission), submissions enter the bounded ``RequestQueue``, and the
event loop (``step`` / ``run`` / ``arun``) activates queued requests up
to a concurrency bound and pumps the ``TileScheduler`` one coalesced
tile at a time. Clients hold a ``RequestHandle``: streamed
``StreamUpdate`` frames while tiles complete, then the final
``PermutationTestResult`` (or an ``OrdinationResult`` for ``pcoa``,
served synchronously off the pooled session's coordinate cache), or a
structured ``Rejection`` — never a traceback.

The service is cooperative and single-threaded by design (jax dispatch
is itself async; tiles are the natural quantum): ``arun`` is an asyncio
driver that yields between tiles so many client coroutines can await
their handles concurrently — see ``examples/serve_session.py``.

Fault tolerance: the service owns the assembled recovery plane. A
``ServeConfig.fault_plan`` (``repro.faults.FaultPlan``) arms the
deterministic injector at the three serve sites — tiles
(``serve.tile``, handled by the scheduler's retry/breaker path), lane
hoists (``serve.hoist``, retried at activation, ``unavailable`` when
exhausted), and the pool (``serve.pool``, a forced mid-flight eviction
whose in-flight requests terminate with ``stale_generation``). Per-
request deadlines follow a request from the queue *through execution*
(cooperative cancellation at tile boundaries, degrading to the partial
envelope); an injected/real allocator OOM sheds an idle pooled session
before the retry. With ``journal_path`` set, every submission, per-tile
progress record, and terminal state lands in a crash-safe append-only
journal (``checkpoint.journal``), and ``AnalysisService.recover``
rebuilds a service from the journal's valid prefix against a surviving
pool: completed permutation blocks are NOT re-run and nothing re-hoists,
so recovered requests finish with bitwise-identical p-values.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.api.config import ExecConfig
from repro.checkpoint.journal import Journal
from repro.checkpoint.journal import replay as journal_replay
from repro.core.distance_matrix import MAX_TRIANGLE_N
from repro.faults import CompileFault, FaultInjector, FaultPlan
from repro.obs.config import ObsConfig
from repro.serve.admission import (Rejected, Rejection, RequestQueue,
                                   validate_upload)
from repro.serve.metrics import ServeMetrics, serve_report
from repro.serve.pool import SessionPool
from repro.serve.scheduler import (RetryPolicy, StreamUpdate, TileScheduler,
                                   operand_fingerprint, partial_bounds)
from repro.stats.engine import as_key

#: the analyses the front door serves — the Workspace battery, complete
METHODS = ("pcoa", "permanova", "anosim", "permdisp", "mantel",
           "partial_mantel")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Service knobs (all bounded-by-default: a front door that cannot
    say no is a memory leak with an API).

    ``batch_size`` is the coalesced tile's B — the same quantity as
    ``ExecConfig.batch_size``, fixed service-wide so every study's tiles
    share program shapes. ``max_active`` bounds concurrently-scheduled
    requests (the rest wait in the admission queue, where ``timeout_s``
    deadlines and ``max_queue`` backpressure apply — and the deadline
    keeps following the request through execution: an active request
    past it is cooperatively cancelled at the next tile boundary,
    degrading to its partial envelope). ``auto_tune`` runs the
    ``repro.tune`` solver at upload against each study's own (n, d).
    ``deadline_factor`` parameterizes the tile watchdog
    (``runtime.monitor.StepMonitor``).

    The ``slo_*_s`` thresholds (all optional) arm the latency SLOs:
    queue wait (submit → activation), tile execution, and end-to-end
    request latency samples past a threshold tick the matching breach
    counter in ``serve_report()["slo"]`` — the alerting hook a fleet
    dashboard scrapes (``ServeMetrics.prometheus()``) without the
    service ever failing a request over a slow tile.

    Fault/recovery knobs: ``retry_*`` shape the bounded exponential
    backoff for failed tiles (deterministic jitter — replayable);
    ``breaker_failures`` consecutive failures (or ``retry_budget``
    lifetime failures) open a lane's circuit breaker, degrading its
    requests instead of retrying forever; ``fault_plan`` arms the
    deterministic injector (None = every injection point compiles to an
    ``is None`` check — zero-cost when disabled); ``journal_path``
    enables the crash-safe progress journal (``journal_fsync`` trades
    throughput for durability-per-record)."""

    batch_size: int = 32
    max_sessions: int = 8
    max_bytes: Optional[int] = None
    max_queue: int = 64
    max_active: int = 8
    max_n: int = MAX_TRIANGLE_N
    timeout_s: Optional[float] = 30.0
    auto_tune: bool = True
    observe: bool = True
    deadline_factor: float = 20.0
    slo_queue_wait_s: Optional[float] = None
    slo_tile_s: Optional[float] = None
    slo_request_s: Optional[float] = None
    retry_base_s: float = 0.01
    retry_multiplier: float = 2.0
    retry_max_backoff_s: float = 0.5
    retry_jitter: float = 0.5
    breaker_failures: int = 3
    retry_budget: int = 64
    fault_plan: Optional[FaultPlan] = None
    journal_path: Optional[str] = None
    journal_fsync: bool = False


class RequestHandle:
    """A client's view of one request: status, streamed updates, result.

    ``status`` walks queued → active → done (or degraded / rejected /
    timed_out — ``degraded`` means the service terminated the request
    early but *some* draws completed, so the final streamed frame's
    ``[p_lo, p_hi]`` envelope is a valid partial answer). ``updates``
    accumulates ``StreamUpdate`` frames; ``result`` is the final
    ``PermutationTestResult`` / ``OrdinationResult``; ``error`` the
    ``Rejection``. ``payload()`` is the wire-shaped response — one
    uniform shape for every terminal state.
    """

    def __init__(self, request_id: str, study_id: str, method: str,
                 permutations: int, key, alternative: Optional[str],
                 params: dict):
        self.request_id = request_id
        self.study_id = study_id
        self.method = method
        self.permutations = permutations
        self.key = key
        self.alternative = alternative
        self.params = params
        self.status = "queued"
        self.updates: list = []
        self.result = None
        self.error: Optional[Rejection] = None
        self.statistic: Optional[float] = None
        self.deadline: Optional[float] = None
        self.resume_cursor = 0        # journal recovery: draws already done
        self.resume_count = 0         # ... and exceedances among them
        self.t_submit = time.perf_counter()
        self.t_done: Optional[float] = None

    # -- scheduler callbacks ----------------------------------------------
    def push_update(self, update) -> None:
        self.updates.append(update)

    def complete(self, result) -> None:
        self.result = result
        self.status = "done"
        self.t_done = time.perf_counter()

    def reject(self, rejection: Rejection) -> None:
        self.error = rejection
        self.status = ("timed_out" if rejection.code in ("timeout",
                                                         "deadline")
                       else "rejected")
        self.t_done = time.perf_counter()

    def degrade(self, rejection: Rejection, *, draws_done: int,
                count: int, permutations: int) -> None:
        """Terminate early WITH a partial answer: a final frame whose
        envelope ``[p_lo, p_hi]`` brackets the p-value the request would
        have finished with (circuit breaker, cancellation, deadline)."""
        bounds = partial_bounds(count, draws_done, permutations)
        self.updates.append(StreamUpdate(
            request_id=self.request_id, method=self.method,
            draws_done=draws_done, permutations=permutations,
            exceedances=count, done=False, **bounds))
        self.error = rejection
        self.status = "degraded"
        self.t_done = time.perf_counter()

    # -- client surface ----------------------------------------------------
    @property
    def done(self) -> bool:
        return self.status in ("done", "degraded", "rejected", "timed_out")

    def partial(self):
        """The latest streamed frame (None before the first tile)."""
        return self.updates[-1] if self.updates else None

    def payload(self) -> dict:
        """The wire-shaped response for the request's current state.

        One uniform shape regardless of outcome: ``status`` is always
        present; ``error`` is the structured rejection or None;
        ``progress`` is the latest streamed frame (which for permutation
        methods carries the partial-bounds fields ``p_partial`` /
        ``p_lo`` / ``p_hi`` — for a degraded request this IS the
        deliverable) or None; ``result`` the final result or None.
        Callers branch on ``status``/``error`` — never on which keys
        exist."""
        p = self.partial()
        out = {"request_id": self.request_id, "study_id": self.study_id,
               "method": self.method, "status": self.status,
               "error": (self.error.payload()["error"]
                         if self.error is not None else None),
               "progress": p.to_dict() if p is not None else None,
               "result": None}
        if self.result is not None:
            if self.method == "pcoa":
                out["result"] = {
                    "dimensions": int(self.result.coordinates.shape[1]),
                    "proportion_explained":
                        [float(v) for v in self.result.proportion_explained],
                }
            else:
                out["result"] = {
                    "statistic": self.result.statistic,
                    "p_value": self.result.p_value,
                    "permutations": self.result.permutations,
                    "sample_size": self.result.sample_size,
                }
        return out


def _key_data(key) -> list:
    """A PRNG key as a JSON-serializable list (journal wire form)."""
    try:
        return np.asarray(key).tolist()
    except TypeError:
        import jax
        return np.asarray(jax.random.key_data(key)).tolist()


class AnalysisService:
    """The front door (see module docstring).

    ``pool`` lets a rebuilt service adopt a surviving ``SessionPool``
    (the journal-recovery path: sessions — and their hoists — outlive
    the front-door state that crashed)."""

    def __init__(self, config: Optional[ServeConfig] = None,
                 pool: Optional[SessionPool] = None):
        self.config = config if config is not None else ServeConfig()
        self.pool = pool if pool is not None else SessionPool(
            self.config.max_sessions, self.config.max_bytes)
        self.queue = RequestQueue(self.config.max_queue)
        self.metrics = ServeMetrics(slo={
            "queue_wait": self.config.slo_queue_wait_s,
            "tile": self.config.slo_tile_s,
            "request": self.config.slo_request_s})
        plan = self.config.fault_plan
        self.injector = FaultInjector(plan) if plan is not None else None
        self.journal = (Journal(self.config.journal_path,
                                fsync=self.config.journal_fsync)
                        if self.config.journal_path else None)
        retry = RetryPolicy(
            base_s=self.config.retry_base_s,
            multiplier=self.config.retry_multiplier,
            max_backoff_s=self.config.retry_max_backoff_s,
            jitter=self.config.retry_jitter,
            breaker_failures=self.config.breaker_failures,
            budget=self.config.retry_budget,
            seed=plan.seed if plan is not None else 0)
        self.scheduler = TileScheduler(
            batch_size=self.config.batch_size, metrics=self.metrics,
            injector=self.injector, retry=retry, journal=self.journal,
            on_oom=self._shed)
        self.scheduler.monitor.deadline_factor = self.config.deadline_factor
        self._active: list = []
        self._ids = itertools.count(1)
        self._exec_config = ExecConfig(
            batch_size=self.config.batch_size,
            auto=self.config.auto_tune,
            obs=ObsConfig(enabled=self.config.observe))

    # -- uploads -----------------------------------------------------------
    def upload(self, study_id: str, data=None, *, features=None,
               metric=None) -> dict:
        """Admit (or re-admit) one study; returns the admission ack.

        Validation happens before any O(n²) work (structured rejection
        payloads for non-finite/oversized/misshapen uploads); admission
        builds the pooled ``Workspace`` — which resolves
        ``ExecConfig(auto=True)`` against this study's own (n, d) — and
        re-upload of a known id routes through ``Workspace.refresh``:
        the generation bumps, every cached hoist drops, and any request
        *mid-flight against the old generation* is terminated with a
        structured ``stale_generation`` rejection — its hoisted data no
        longer matches what the client believes is uploaded, so
        finishing it would silently answer about replaced data.
        """
        t0 = time.perf_counter()
        try:
            kind, n = validate_upload(data, features,
                                      max_n=self.config.max_n)
        except Rejected as e:
            self.metrics.record_rejection(e.rejection.code)
            raise
        resident = study_id in self.pool
        try:
            ws = self.pool.admit(
                study_id, self._exec_config,
                dm=data if kind == "dm" else None,
                features=features if kind == "features" else None,
                metric=metric)
        except ValueError as e:
            # the Workspace's own admission checks (asymmetry, non-hollow
            # diagonal, ...) — still a structured refusal, not a traceback
            self.metrics.record_rejection("bad_request")
            raise Rejected(Rejection("bad_request", str(e),
                                     {"study_id": study_id})) from None
        if resident:
            # the re-upload race: lanes hoisted against the old
            # generation are stale the moment refresh() returns
            self.scheduler.invalidate_study(
                study_id, keep_generation=ws.generation)
        self.metrics.record_upload(study_id, n,
                                   time.perf_counter() - t0)
        return {"study_id": study_id, "n": ws.n,
                "generation": ws.generation,
                "backing": kind,
                "cache_nbytes": ws.cache.nbytes(),
                "tuned": ws.tuned is not None}

    # -- submissions -------------------------------------------------------
    def submit(self, study_id: str, method: str, *, grouping=None,
               other=None, control=None, permutations: int = 999,
               key=None, alternative: Optional[str] = None,
               dimensions: Optional[int] = None, pcoa_method: str = "fsvd",
               timeout_s: Optional[float] = None) -> RequestHandle:
        """Enqueue one analysis request; returns its handle immediately.

        ``other``/``control`` name *uploaded studies* (the Mantel-family
        operands live server-side, like the permuted side). The request
        waits in the bounded queue until the loop activates it;
        ``queue_full`` raises ``Rejected`` immediately, a lapsed
        ``timeout_s`` fails the handle with a ``timeout`` rejection
        while queued or cancels it cooperatively once active.
        """
        if method not in METHODS:
            self.metrics.record_rejection("bad_request")
            raise Rejected.make(
                "bad_request",
                f"unknown method {method!r}; available: {list(METHODS)}",
                method=method)
        if study_id not in self.pool:
            self.metrics.record_rejection("unknown_study")
            raise Rejected.make(
                "unknown_study",
                f"study {study_id!r} is not resident (never uploaded, or "
                f"evicted) — upload it first", study_id=study_id)
        handle = RequestHandle(
            request_id=f"r{next(self._ids)}", study_id=study_id,
            method=method, permutations=int(permutations),
            key=as_key(key), alternative=alternative,
            params={"grouping": grouping, "other": other,
                    "control": control, "dimensions": dimensions,
                    "pcoa_method": pcoa_method})
        try:
            self.queue.push(handle, timeout_s if timeout_s is not None
                            else self.config.timeout_s)
        except Rejected as e:
            self.metrics.record_rejection(e.rejection.code)
            handle.reject(e.rejection)
            return handle
        self._journal_submit(handle)
        self.metrics.record_admission()
        self.metrics.sample_queue_depth(len(self.queue))
        return handle

    def cancel(self, handle: RequestHandle) -> bool:
        """Client abort: terminate one request wherever it is. A queued
        request rejects (``cancelled``); an active one cancels
        cooperatively at the tile boundary, degrading to its partial
        envelope when any draws completed. Returns False when the
        request already terminated."""
        if handle.done:
            return False
        rej = Rejection("cancelled", "request cancelled by client",
                        {"request_id": handle.request_id})
        if handle.status == "queued":
            try:
                self.queue._q.remove(handle)
            except ValueError:
                pass
            handle.reject(rej)
            self.metrics.record_cancel("cancelled")
            return True
        return self.scheduler.cancel(handle, rej)

    # -- activation --------------------------------------------------------
    def _lane_key(self, ws, handle) -> tuple:
        """Requests may share a tile iff this matches: same study at the
        same generation, same method, same operand identities (grouping
        content; Mantel operand studies at their own generations; the
        ordination geometry behind permdisp)."""
        p = handle.params
        operands = [operand_fingerprint(p["grouping"])]
        for name in ("other", "control"):
            sid = p[name]
            if sid is not None:
                ref = self.pool.get(sid)
                operands.append((sid, ref.generation if ref else None))
            else:
                operands.append(None)
        operands.append((p["dimensions"], p["pcoa_method"])
                        if handle.method == "permdisp" else None)
        return (handle.study_id, ws.generation, handle.method,
                tuple(operands))

    def _activate(self, handle) -> None:
        """Bind one queued request to the scheduler (or finish it on the
        spot for ``pcoa``). Statistic-construction failures — bad
        grouping length, mismatched operand sizes, collinear partial-
        Mantel controls — become ``bad_request`` rejections; a lane
        hoist/compile failure retries, then ``unavailable``."""
        self.metrics.record_queue_wait(
            time.perf_counter() - handle.t_submit)
        ws = self.pool.get(handle.study_id)
        if ws is None:                        # evicted while queued
            handle.reject(Rejection(
                "unknown_study",
                f"study {handle.study_id!r} was evicted while the "
                f"request waited; re-upload and retry",
                {"study_id": handle.study_id}))
            self.metrics.record_rejection("unknown_study")
            return
        p = handle.params
        try:
            if handle.method == "pcoa":
                dims = p["dimensions"] if p["dimensions"] is not None else 10
                result = ws.pcoa(dimensions=dims, method=p["pcoa_method"],
                                 key=handle.key)
                handle.complete(result)
                self._finish(handle)
                return
            kwargs = {}
            if handle.method in ("permanova", "anosim", "permdisp"):
                kwargs["grouping"] = p["grouping"]
            if handle.method == "permdisp":
                kwargs["dimensions"] = p["dimensions"]
                kwargs["pcoa_method"] = p["pcoa_method"]
            if handle.method in ("mantel", "partial_mantel"):
                kwargs["other"] = self._resolve_operand(p["other"], "other")
            if handle.method == "partial_mantel":
                kwargs["control"] = self._resolve_operand(p["control"],
                                                          "control")
            stat, default_alt = ws.statistic(handle.method, **kwargs)
            lane_key = self._lane_key(ws, handle)
            attempts = 0
            while True:
                try:
                    self.scheduler.submit(handle, ws, lane_key, stat,
                                          default_alt)
                    break
                except CompileFault as e:
                    # transient hoist/compile failure: retry the
                    # activation in place (the lane was never created,
                    # so nothing to unwind), give up as `unavailable`
                    attempts += 1
                    if attempts >= max(2, self.config.breaker_failures):
                        handle.reject(Rejection(
                            "unavailable",
                            f"lane compilation failed "
                            f"{attempts} times: {e}",
                            {"method": handle.method,
                             "attempts": attempts}))
                        self.metrics.record_rejection("unavailable")
                        return
            self._active.append(handle)
        except Rejected as e:
            handle.reject(e.rejection)
            self.metrics.record_rejection(e.rejection.code)
        except (ValueError, TypeError) as e:
            rej = Rejection("bad_request", str(e),
                            {"method": handle.method})
            handle.reject(rej)
            self.metrics.record_rejection("bad_request")

    def _resolve_operand(self, sid, role: str):
        if sid is None:
            raise Rejected.make("bad_request",
                                f"this method requires {role}= naming an "
                                f"uploaded study")
        ws = self.pool.get(sid)
        if ws is None:
            raise Rejected.make("unknown_study",
                                f"{role} study {sid!r} is not resident",
                                study_id=sid)
        return ws

    # -- fault hooks -------------------------------------------------------
    def _shed(self, lane) -> None:
        """Allocator-pressure response (real or injected OOM): drop one
        idle pooled session — never one with in-flight rows — so the
        retry runs against a smaller resident set."""
        victim = self.pool.shed(exclude=self.scheduler.active_studies()
                                | {lane.key[0]})
        if victim is not None:
            self.metrics.record_shed()

    def _poll_pool_faults(self) -> None:
        """The ``serve.pool`` injection site: a forced eviction of a
        study with live tiles — the eviction/re-upload race the
        ``stale_generation`` path exists for."""
        if self.injector is None:
            return
        for spec in self.injector.poll("serve.pool"):
            if spec.kind != "evict":
                continue
            victims = sorted(self.scheduler.active_studies())
            if not victims:
                continue
            self.metrics.record_fault("serve.pool", "evict")
            self.pool.drop(victims[0])
            self.scheduler.invalidate_study(victims[0])

    # -- the loop ----------------------------------------------------------
    def step(self) -> bool:
        """One loop turn: fire pool faults (when armed), expire lapsed
        deadlines (queued AND active), activate queued requests up to
        ``max_active``, run one coalesced tile, retire finished
        requests. Returns True while work remains."""
        self._poll_pool_faults()
        now = time.monotonic()
        for handle in self.queue.expired(now):
            handle.reject(Rejection(
                "timeout",
                f"request waited past its {self.config.timeout_s}s "
                f"deadline in the admission queue",
                {"request_id": handle.request_id}))
            self.metrics.record_rejection("timeout")
        for handle in self._active:
            if (not handle.done and handle.deadline is not None
                    and now > handle.deadline):
                # cooperative cancellation: the deadline followed the
                # request out of the queue; draws done so far degrade it
                self.scheduler.cancel(handle, Rejection(
                    "deadline",
                    "request exceeded its deadline while executing",
                    {"request_id": handle.request_id}))
        self._active = [h for h in self._active if not h.done]
        while len(self._active) < self.config.max_active and len(self.queue):
            handle = self.queue.pop()
            if handle is None:
                break
            self._activate(handle)
        self.metrics.sample_queue_depth(len(self.queue))
        ran = self.scheduler.step()
        for handle in list(self._active):
            if handle.done:
                self._finish(handle)
                self._active.remove(handle)
        # keep in-flight studies out of eviction's reach
        self.pool.evict(exclude=self.scheduler.active_studies())
        return ran or bool(len(self.queue)) or bool(self._active)

    def _finish(self, handle) -> None:
        self.metrics.record_completion(
            handle, (handle.t_done or time.perf_counter())
            - handle.t_submit)
        if self.journal is not None:
            self.journal.append({"t": "terminal",
                                 "rid": handle.request_id,
                                 "status": handle.status})

    def run(self) -> None:
        """Drain synchronously: loop until queue and scheduler are empty."""
        while self.step():
            pass

    async def arun(self) -> None:
        """Asyncio driver: one tile per loop turn, yielding between
        tiles so client coroutines awaiting handles interleave."""
        import asyncio
        while self.step():
            await asyncio.sleep(0)

    async def wait(self, handle: RequestHandle):
        """Await one handle (pump the loop while it is pending)."""
        import asyncio
        while not handle.done:
            self.step()
            await asyncio.sleep(0)
        return handle

    # -- journal / recovery ------------------------------------------------
    def _journal_submit(self, handle: RequestHandle) -> None:
        if self.journal is None:
            return
        p = handle.params
        g = p["grouping"]
        self.journal.append({
            "t": "submit", "rid": handle.request_id,
            "study": handle.study_id, "method": handle.method,
            "permutations": handle.permutations,
            "key": _key_data(handle.key),
            "alternative": handle.alternative,
            "grouping": (np.asarray(g).tolist() if g is not None else None),
            "other": p["other"], "control": p["control"],
            "dimensions": p["dimensions"],
            "pcoa_method": p["pcoa_method"]})

    @classmethod
    def recover(cls, journal_path: str, *, pool: SessionPool,
                config: Optional[ServeConfig] = None):
        """Rebuild a service from a crashed one's journal.

        ``pool`` is the surviving ``SessionPool`` — sessions (and their
        hoists) live independently of the front-door state that
        crashed, so recovery re-hoists NOTHING. The journal's valid
        prefix is replayed: requests with a terminal record are done;
        the rest are resubmitted with their original PRNG key and their
        last journaled ``(cursor, count)``, so completed permutation
        blocks are not re-run and the finished p-values are bitwise
        what the uninterrupted run would have produced (orders are a
        pure function of the key; exceedance counts are order-
        independent sums). Returns ``(service, handles)`` where
        ``handles`` maps each recovered *original* request id to its
        new ``RequestHandle``.
        """
        records = list(journal_replay(journal_path))
        cfg = dataclasses.replace(config if config is not None
                                  else ServeConfig(),
                                  journal_path=journal_path)
        svc = cls(config=cfg, pool=pool)
        submits: dict = {}
        progress: dict = {}
        terminal: set = set()
        for r in records:
            t = r.get("t")
            if t == "submit":
                submits[r["rid"]] = r
            elif t == "progress":
                progress[r["rid"]] = r      # last one wins: the frontier
            elif t == "terminal":
                terminal.add(r["rid"])
        handles: dict = {}
        for rid, r in submits.items():
            if rid in terminal:
                continue
            try:
                h = svc.submit(
                    r["study"], r["method"],
                    grouping=(np.asarray(r["grouping"])
                              if r.get("grouping") is not None else None),
                    other=r.get("other"), control=r.get("control"),
                    permutations=r["permutations"],
                    key=jnp.asarray(r["key"], jnp.uint32),
                    alternative=r.get("alternative"),
                    dimensions=r.get("dimensions"),
                    pcoa_method=r.get("pcoa_method") or "fsvd")
            except Rejected:
                # the study did not survive the crash (pool rebuilt
                # smaller, say) — the request stays failed, structured
                continue
            pr = progress.get(rid)
            if pr is not None:
                h.resume_cursor = int(pr["cursor"])
                h.resume_count = int(pr["count"])
            # the old id will never get a terminal record of its own;
            # mark it re-mapped so a second recovery won't duplicate it
            if svc.journal is not None:
                svc.journal.append({"t": "terminal", "rid": rid,
                                    "status": "resubmitted",
                                    "as": h.request_id})
            handles[rid] = h
        return svc, handles

    # -- reporting ---------------------------------------------------------
    def report(self) -> dict:
        return serve_report(self)
