"""TileScheduler: cross-request coalescing of permutation tiles.

The continuous-batching idiom, refactored from token slots to
permutation tiles. A request for K permutations is K independent rows of
``(n,)`` orders; the engine executes rows in padded ``(B, n)`` tiles
(``stats.engine.tile_statistics``). Nothing about a row depends on its
tile-mates — ``kernels.permute_reduce`` reduces each order column
independently, and the vmapped ``per_perm`` fallback is row-independent
too — so the scheduler is free to pack rows from *different* requests
into one tile whenever they share the exact invariant stack (study,
generation, method, operands). That buys:

* **slot reuse** — when a request's last rows retire mid-tile, the next
  tile immediately fills those rows from the queue's next request; chip
  utilization doesn't dip between requests;
* **one program per statistic shape** — every tile has the same (B, n)
  avals regardless of per-request K (a drained lane pads by cycling the
  rows it did collect), so the engine's one-padded-program sentinel
  invariant extends across the whole mixed-K serve run;
* **bitwise determinism** — each request's orders come from its own PRNG
  key via ``engine.permutation_orders`` (identical to what a standalone
  ``Workspace`` run draws), and row independence means its p-value is
  bit-for-bit the same whether it ran alone or coalesced.

Streaming: after each tile the scheduler pushes a ``StreamUpdate`` per
contributing request — running exceedance count, the anytime estimate
``p_partial``, and the *exact envelope* ``[p_lo, p_hi]``: ``p_lo``
assumes every remaining draw misses, ``p_hi`` assumes every remaining
draw exceeds, so ``p_lo`` is monotone nondecreasing, ``p_hi`` monotone
nonincreasing, and the final p-value always lands inside every streamed
interval (they converge to it at the last tile).

Fault tolerance (the recovery half of ``repro.faults``):

* **retry with backoff** — a tile that fails (injected fault, real
  ``RuntimeError`` from the device, or non-finite statistics caught by
  the output admission check) consumes NO cursor state: the lane backs
  off (cooperatively — ``not_before`` skips it while other lanes run;
  bounded exponential delay with deterministic jitter) and the SAME
  rows re-execute on the next attempt. jax execution is deterministic,
  so a retried tile reproduces the fault-free values bit-for-bit —
  which is why completed requests under chaos gate bitwise against the
  fault-free run. Retry amplification (re-executed rows) is metered and
  capped.
* **per-lane circuit breaker** — ``breaker_failures`` *consecutive*
  failures (or a blown per-lane retry budget) quarantine the lane:
  every in-flight request degrades to a partial result carrying the
  existing confidence envelope (or a rejection when no draws finished)
  instead of wedging the lane forever on a poison request.
* **cooperative cancellation** — ``cancel()`` terminates one request at
  a tile boundary (per-request deadlines and client aborts), degrading
  it to its current envelope.
* **watchdog escalation** — a tile that began but never completed (the
  step span survives to the next loop turn) is escalated by the
  ``StepMonitor`` heartbeat into the SAME retry path, via the
  structured ``EscalationRecord`` rather than a loop-killing raise.
* **journal** — after every successful tile, each contributing
  request's ``(cursor, count)`` is appended to the crash-safe journal
  (``checkpoint.journal``); counters are append-only, so replaying the
  journal's valid prefix after a crash resumes with completed
  permutation blocks bit-for-bit intact.

Every tile is timed through a ``runtime.monitor.StepMonitor`` span
(phase="step"), so the straggler/deadline watchdog covers serve loops,
and charged to the study's ``repro.obs`` ledger with the same
``charge_perm_batch`` terms the library engine uses.
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.faults import (AllocFault, CompileFault, FaultError, PoisonError,
                          StallFault, TransientTileError, unit_hash)
from repro.runtime.monitor import DeadlineExceeded, StepMonitor
from repro.serve.admission import Rejection
from repro.stats import engine


# --------------------------------------------------------------------------
# Streaming math
# --------------------------------------------------------------------------
def partial_bounds(c: int, draws_done: int, permutations: int) -> dict:
    """Anytime p-value state after ``draws_done`` of K draws with ``c``
    exceedances so far.

    * ``p_partial = (c+1)/(draws_done+1)`` — the estimate *as if* the
      test stopped here (a valid Monte-Carlo p at this draw count);
    * ``p_lo = (c+1)/(K+1)`` — the final p if no remaining draw exceeds
      (monotone nondecreasing in draws_done);
    * ``p_hi = (c + (K - draws_done) + 1)/(K+1)`` — the final p if every
      remaining draw exceeds (monotone nonincreasing).

    The true final p-value lies in ``[p_lo, p_hi]`` for every prefix,
    and both bounds equal it at ``draws_done == K`` — bitwise: all three
    divide in fp32, the same arithmetic ``engine.finish`` performs, so
    the last frame's collapsed envelope IS the final p-value.
    """
    f = np.float32
    k1 = f(permutations + 1)
    return {"p_partial": float(f(c + 1) / f(draws_done + 1)),
            "p_lo": float(f(c + 1) / k1),
            "p_hi": float(f(c + (permutations - draws_done) + 1) / k1)}


def exceedances(observed: float, values: np.ndarray,
                alternative: str) -> int:
    """Null draws at least as extreme as ``observed`` — the numpy twin of
    ``engine.count_better`` (identical comparisons on the same fp32
    values, so incremental serve counts match the engine's one-shot
    count exactly; a NaN observed compares False everywhere, and the
    finisher turns that into a NaN p like ``engine.finish``)."""
    v = np.asarray(values)
    if alternative == "two-sided":
        return int(np.sum(np.abs(v) >= abs(observed)))
    if alternative == "greater":
        return int(np.sum(v >= observed))
    if alternative == "less":
        return int(np.sum(v <= observed))
    raise ValueError(f"unknown alternative {alternative!r}")


@dataclasses.dataclass(frozen=True)
class StreamUpdate:
    """One streamed progress frame for one request (see module docstring
    for the bound semantics)."""

    request_id: str
    method: str
    draws_done: int
    permutations: int
    exceedances: int
    p_partial: float
    p_lo: float
    p_hi: float
    done: bool

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


# --------------------------------------------------------------------------
# Retry policy
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff with deterministic jitter, plus the
    circuit-breaker thresholds (see ``ServeConfig`` for the knobs'
    service-level defaults and docs)."""

    base_s: float = 0.01
    multiplier: float = 2.0
    max_backoff_s: float = 0.5
    jitter: float = 0.5
    breaker_failures: int = 3
    budget: int = 64
    seed: int = 0

    def backoff(self, failures: int, label: str, index: int) -> float:
        """Delay before attempt ``failures + 1``. Jitter is a
        deterministic hash of (seed, label, index) — chaos runs replay
        with identical pacing."""
        raw = self.base_s * self.multiplier ** max(failures - 1, 0)
        delay = min(raw, self.max_backoff_s)
        return delay * (1.0 + self.jitter * unit_hash(self.seed, label,
                                                      index))


# --------------------------------------------------------------------------
# Lane keys — "may these requests share a tile?"
# --------------------------------------------------------------------------
def operand_fingerprint(value) -> Optional[tuple]:
    """Hashable identity of a request operand (grouping array etc.):
    dtype + shape + content digest. Two requests coalesce only when
    every operand fingerprint matches — identical invariant stacks."""
    if value is None:
        return None
    arr = np.asarray(value)
    return (arr.dtype.str, arr.shape,
            hashlib.sha1(arr.tobytes()).hexdigest()[:16])


# --------------------------------------------------------------------------
# The scheduler
# --------------------------------------------------------------------------
class _Active:
    """One in-flight request's scheduling state (internal)."""

    __slots__ = ("handle", "orders", "cursor", "count", "observed",
                 "alternative")

    def __init__(self, handle, orders, observed: float, alternative: str):
        self.handle = handle
        self.orders = orders          # (K, n) — this request's own draws
        self.cursor = 0               # rows already executed
        self.count = 0                # running exceedances
        self.observed = observed
        self.alternative = alternative


class Lane:
    """All in-flight requests that share one invariant stack.

    Holds the hoisted ``(stat, invariants, observed)`` built once at
    lane creation and a FIFO of ``_Active`` requests. ``next_tile``
    assembles the next (B, n) tile: rows come from the front request
    until it drains, then the next (slot reuse); a short final tile pads
    by cycling the rows it did collect — real permutations, so the tile
    avals (and hence the compiled program) never change, and the padded
    rows are simply not attributed to any request.

    Fault state: ``failures`` counts *consecutive* failed tile attempts
    (reset on success — the breaker trips at ``breaker_failures``),
    ``retries`` the lane-lifetime total (capped by the retry budget),
    ``not_before`` the monotonic instant before which the lane is
    backing off (the step loop skips it, cooperatively).
    """

    def __init__(self, key, ws, stat, invariants, observed: float,
                 batch_size: int):
        self.key = key
        self.ws = ws
        self.stat = stat
        self.invariants = invariants
        self.observed = observed
        self.batch_size = int(batch_size)
        self.requests: list = []
        self.tiles_run = 0
        self.failures = 0             # consecutive failed attempts
        self.retries = 0              # lane-lifetime failed attempts
        self.not_before = 0.0         # monotonic backoff gate

    def pending_rows(self) -> int:
        return sum(a.orders.shape[0] - a.cursor for a in self.requests)

    def next_tile(self):
        """``(tile, parts)``: the (B, n) orders tile plus
        ``[(active, take), ...]`` attributing its leading rows."""
        b = self.batch_size
        parts, chunks, have = [], [], 0
        for a in self.requests:
            if have == b:
                break
            take = min(b - have, a.orders.shape[0] - a.cursor)
            if take:
                chunks.append(a.orders[a.cursor:a.cursor + take])
                parts.append((a, take))
                have += take
        if have < b:                      # drained: pad by cycling rows
            real = jnp.concatenate(chunks) if len(chunks) > 1 else chunks[0]
            chunks.append(real[jnp.arange(b - have) % have])
        tile = jnp.concatenate(chunks) if len(chunks) > 1 else chunks[0]
        return tile, parts


class TileScheduler:
    """Round-robin tile executor over coalescing lanes.

    ``submit`` binds a request to its lane (creating the lane — one
    hoist via ``engine.hoist_and_observe`` — when it is the first);
    ``step`` executes ONE tile from the next lane with pending rows,
    streams updates, finishes retired requests. The service drives
    ``step`` in its event loop; a stalled tile (open step span at the
    loop head) is escalated by the watchdog into the retry path.

    ``injector`` (``repro.faults.FaultInjector`` or None) arms the
    ``serve.tile`` injection site; ``retry`` is the backoff/breaker
    policy; ``journal`` (``checkpoint.Journal`` or None) receives
    per-request progress records after each tile; ``on_oom`` is the
    service's allocator-pressure hook (shed pool bytes before retry).
    """

    def __init__(self, batch_size: int = 32,
                 monitor: Optional[StepMonitor] = None, metrics=None,
                 injector=None, retry: Optional[RetryPolicy] = None,
                 journal=None, on_oom=None):
        self.batch_size = int(batch_size)
        self.monitor = monitor if monitor is not None else StepMonitor()
        self.metrics = metrics
        self.injector = injector
        self.retry = retry if retry is not None else RetryPolicy()
        self.journal = journal
        self.on_oom = on_oom
        self.lanes: "OrderedDict[tuple, Lane]" = OrderedDict()
        self.tiles_run = 0
        self._step_counter = 0
        self._stalled_lane: Optional[Lane] = None

    # -- submission --------------------------------------------------------
    def submit(self, handle, ws, lane_key, stat, default_alternative: str
               ) -> None:
        """Activate one admitted request on its lane. Raises
        ``CompileFault`` when the ``serve.hoist`` injection site fires
        at lane creation (the service retries activation)."""
        lane = self.lanes.get(lane_key)
        if lane is None:
            if self.injector is not None:
                for spec in self.injector.poll("serve.hoist"):
                    if spec.kind == "compile":
                        if self.metrics is not None:
                            self.metrics.record_fault("serve.hoist",
                                                      "compile")
                        raise CompileFault(
                            f"injected hoist/compile failure for lane "
                            f"{lane_key[2]}")
            b = ws.config.resolve_batch_size(None, self.batch_size)
            with ws.obs.span("serve.hoist_lane", phase="serve",
                             method=handle.method, n=stat.n,
                             batch_size=b):
                invariants, observed = engine.hoist_and_observe(stat)
            lane = Lane(lane_key, ws, stat, invariants, float(observed),
                        b)
            self.lanes[lane_key] = lane
        orders = engine.permutation_orders(
            handle.key, handle.permutations, stat.n)
        alt = handle.alternative or default_alternative
        active = _Active(handle, orders, lane.observed, alt)
        k = int(orders.shape[0])
        resume = int(getattr(handle, "resume_cursor", 0) or 0)
        if resume:
            # journal recovery: completed permutation blocks are NOT
            # re-run — the append-only (cursor, count) state restores
            # bit-for-bit and execution continues at the cursor
            active.cursor = min(resume, k)
            active.count = int(getattr(handle, "resume_count", 0) or 0)
            if self.metrics is not None:
                self.metrics.record_resume(active.cursor)
        handle.status = "active"
        handle.statistic = lane.observed
        if active.cursor >= k:
            # the crash landed between the last progress record and the
            # terminal record: every draw is already done — finish now
            self._emit(active)
            if not lane.requests and not lane.pending_rows():
                del self.lanes[lane_key]
            return
        lane.requests.append(active)

    # -- execution ---------------------------------------------------------
    def has_work(self) -> bool:
        return any(lane.pending_rows() for lane in self.lanes.values())

    def active_studies(self) -> set:
        """Study ids with in-flight rows — the pool's eviction pin set."""
        return {lane.key[0] for lane in self.lanes.values()
                if lane.pending_rows()}

    def step(self) -> bool:
        """Execute one tile; returns False when no lane had work.

        A failed tile (fault, device error, non-finite output) consumes
        nothing: the lane backs off and the SAME rows retry. A stalled
        tile from the previous turn is escalated here, first."""
        if self._consume_stall():
            return True
        now = time.monotonic()
        lane = next((ln for ln in self.lanes.values()
                     if ln.pending_rows() and ln.not_before <= now), None)
        if lane is None:
            waits = [ln.not_before - now for ln in self.lanes.values()
                     if ln.pending_rows()]
            if waits:                     # all backing off: wait it out
                time.sleep(min(min(waits), 0.05))
                return True
            return False
        # round-robin: the lane we serve moves to the back
        self.lanes.move_to_end(lane.key)
        tile, parts = lane.next_tile()
        b = tile.shape[0]
        self._step_counter += 1
        self.monitor.start()
        try:
            values = self._execute(lane, tile)
        except StallFault:
            # the tile "never returns": leave the step span OPEN so the
            # next loop turn's watchdog heartbeat escalates it — the
            # regression the monitor's escalate() path exists for
            self._stalled_lane = lane
            if self.metrics is not None:
                self.metrics.record_tile_failure("stall", b)
            return True
        except (FaultError, RuntimeError) as e:
            self.monitor.abort(reason=str(e))
            self._tile_failure(lane, b, e)
            return True
        step_rec = self.monitor.stop(self._step_counter)
        lane.failures = 0                 # consecutive window resets
        lane.tiles_run += 1
        self.tiles_run += 1
        # the padded tail rows are real gathers — charged like the
        # engine charges its own padded tiles
        lane.ws.obs.charge_perm_batch(
            f"serve:{parts[0][0].handle.method}", lane.stat.n, b, b)
        if self.metrics is not None:
            self.metrics.record_tile(b, len(parts),
                                     seconds=step_rec.seconds)
        offset = 0
        for active, take in parts:
            rows = values[offset:offset + take]
            offset += take
            active.count += exceedances(active.observed, rows,
                                        active.alternative)
            active.cursor += take
            self._journal_progress(active)
            self._emit(active)
        for active, _ in parts:
            if active.cursor >= active.orders.shape[0]:
                lane.requests.remove(active)
        if not lane.pending_rows() and not lane.requests:
            del self.lanes[lane.key]
        return True

    # -- tile execution + fault injection ----------------------------------
    def _execute(self, lane: Lane, tile) -> np.ndarray:
        """One tile through the engine, with the ``serve.tile``
        injection site armed and the non-finite output admission check
        (injected or real NaN statistics take the retry path instead of
        silently skewing exceedance counts)."""
        specs = (self.injector.poll("serve.tile")
                 if self.injector is not None else ())
        poison_rows = None
        for spec in specs:
            if self.metrics is not None:
                self.metrics.record_fault("serve.tile", spec.kind)
            if spec.kind == "slow":
                time.sleep(spec.delay_s)          # completes, but late
            elif spec.kind == "stall":
                if spec.delay_s:
                    time.sleep(spec.delay_s)
                raise StallFault("injected stalled tile")
            elif spec.kind == "error":
                raise TransientTileError("injected transient tile error")
            elif spec.kind == "oom":
                raise AllocFault("injected allocator OOM on tile")
            elif spec.kind == "nan":
                poison_rows = spec
        values = np.asarray(
            engine.tile_statistics(lane.stat, lane.invariants, tile))
        if poison_rows is not None:
            values = values.copy()
            values[:] = np.nan
        if not np.all(np.isfinite(values)):
            raise PoisonError(
                f"tile returned non-finite statistics "
                f"({int(np.sum(~np.isfinite(values)))}/{values.size} rows)")
        return values

    def _fault_kind(self, exc: Exception) -> str:
        if isinstance(exc, AllocFault):
            return "oom"
        if isinstance(exc, PoisonError):
            return "poison"
        if isinstance(exc, TransientTileError):
            return "transient"
        if isinstance(exc, StallFault):
            return "stall"
        return "runtime"

    def _tile_failure(self, lane: Lane, rows: int, exc: Exception) -> None:
        """The shared retry path: back off and re-attempt, or trip the
        breaker. Cursor state was NOT advanced, so the retried tile
        re-executes the identical rows (bitwise-neutral)."""
        kind = self._fault_kind(exc)
        lane.failures += 1
        lane.retries += 1
        if self.metrics is not None:
            self.metrics.record_tile_failure(kind, rows)
        if kind == "oom" and self.on_oom is not None:
            self.on_oom(lane)
        over_breaker = lane.failures >= self.retry.breaker_failures
        over_budget = lane.retries > self.retry.budget
        if over_breaker or over_budget:
            why = ("circuit breaker opened after "
                   f"{lane.failures} consecutive tile failures"
                   if over_breaker else
                   f"lane retry budget exhausted ({lane.retries} > "
                   f"{self.retry.budget})")
            self.quarantine(lane, Rejection(
                "circuit_open",
                f"{why}; last failure: {exc}",
                {"method": lane.key[2], "failures": lane.failures,
                 "retries": lane.retries, "kind": kind}))
            return
        delay = self.retry.backoff(lane.failures, f"backoff:{lane.key[2]}",
                                   lane.retries)
        lane.not_before = time.monotonic() + delay
        if self.metrics is not None:
            self.metrics.record_retry(rows, delay)

    def _consume_stall(self) -> bool:
        """Escalate a tile that began last turn but never completed.

        The heartbeat path is tried first: past the straggler deadline
        it raises ``DeadlineExceeded`` carrying the structured
        ``EscalationRecord``. Before any median exists (deadline = inf)
        the stall is escalated unconditionally — a first-tile stall
        must not hang the loop. Either way the record feeds the same
        retry path as any other tile failure."""
        if self.monitor._open is None:
            return False
        try:
            self.monitor.heartbeat()
            # under-deadline (or pre-median) but the span is open at the
            # loop head — in this single-threaded loop that can only
            # mean the previous tile never completed: escalate anyway,
            # a watchdog that cannot fire before warmup would let a
            # first-tile stall hang the service
            record = self.monitor.escalate("stalled tile detected at "
                                           "step head")
        except DeadlineExceeded as e:
            record = e.record
            self.monitor.abort(reason=record.reason)
        lane = self._stalled_lane
        self._stalled_lane = None
        if self.metrics is not None:
            self.metrics.record_escalation()
        if lane is not None:
            self._tile_failure(
                lane, lane.batch_size,
                TransientTileError(
                    f"watchdog escalation: {record.reason} "
                    f"(elapsed {record.elapsed_s:.3f}s, deadline "
                    f"{record.deadline_s:.3f}s)"))
        return True

    # -- quarantine / cancellation / invalidation --------------------------
    def _terminate(self, active: _Active, rejection: Rejection,
                   degrade_ok: bool = True) -> None:
        """Terminal state for one in-flight request: a degraded partial
        result when any draws completed (the streamed envelope IS the
        deliverable), a rejection otherwise."""
        handle = active.handle
        k = int(active.orders.shape[0])
        if degrade_ok and active.cursor > 0:
            handle.degrade(rejection,
                           draws_done=active.cursor, count=active.count,
                           permutations=k)
        else:
            handle.reject(rejection)

    def quarantine(self, lane: Lane, rejection: Rejection) -> None:
        """Open the lane's breaker: degrade/reject every request, drop
        the lane. The lane's hoists (owned by the Workspace cache) stay
        resident — quarantine isolates the poison *request stack*, not
        the study."""
        if self.metrics is not None:
            self.metrics.record_breaker()
        for active in list(lane.requests):
            self._terminate(active, rejection)
        lane.requests.clear()
        self.lanes.pop(lane.key, None)

    def cancel(self, handle, rejection: Rejection) -> bool:
        """Cooperatively cancel one in-flight request at a tile
        boundary (deadline lapse or client abort): it terminates as a
        degraded partial (draws so far) or a rejection."""
        for lane in list(self.lanes.values()):
            for active in lane.requests:
                if active.handle is handle:
                    self._terminate(active, rejection)
                    lane.requests.remove(active)
                    if not lane.requests:
                        self.lanes.pop(lane.key, None)
                    if self.metrics is not None:
                        self.metrics.record_cancel(rejection.code)
                    return True
        return False

    def invalidate_study(self, study_id: str,
                         keep_generation: Optional[int] = None) -> int:
        """Terminate every in-flight request bound to ``study_id`` at a
        generation other than ``keep_generation`` (None = all): the
        eviction/re-upload race. The data a stale lane hoisted no
        longer exists as far as the client is concerned, so in-flight
        requests terminate with a structured ``stale_generation``
        rejection — never a crash, and never a result computed against
        data the client just replaced. Returns the request count."""

        def stale(key) -> bool:
            if key[0] == study_id and (keep_generation is None
                                       or key[1] != keep_generation):
                return True
            for op in key[3]:
                # Mantel-family operands carry (study_id, generation)
                if (isinstance(op, tuple) and len(op) == 2
                        and op[0] == study_id
                        and (keep_generation is None
                             or op[1] != keep_generation)):
                    return True
            return False

        terminated = 0
        for key, lane in list(self.lanes.items()):
            if not stale(key):
                continue
            for active in list(lane.requests):
                self._terminate(active, Rejection(
                    "stale_generation",
                    f"study {study_id!r} was re-uploaded or evicted while "
                    f"this request was in flight; its hoisted data is "
                    f"stale — resubmit against the current generation",
                    {"study_id": study_id,
                     "lane_generation": key[1],
                     "request_id": active.handle.request_id}),
                    degrade_ok=False)
                terminated += 1
                if self.metrics is not None:
                    self.metrics.record_stale()
            lane.requests.clear()
            self.lanes.pop(key, None)
        return terminated

    # -- streaming / journaling --------------------------------------------
    def _journal_progress(self, active: _Active) -> None:
        if self.journal is not None:
            self.journal.append({"t": "progress",
                                 "rid": active.handle.request_id,
                                 "cursor": int(active.cursor),
                                 "count": int(active.count)})

    def _emit(self, active: _Active) -> None:
        k = int(active.orders.shape[0])
        done = active.cursor >= k
        bounds = partial_bounds(active.count, active.cursor, k)
        update = StreamUpdate(
            request_id=active.handle.request_id,
            method=active.handle.method,
            draws_done=active.cursor, permutations=k,
            exceedances=active.count, done=done, **bounds)
        active.handle.push_update(update)
        if done:
            # identical finishing rule to engine.finish, down to the
            # fp32 division: +1 correction, NaN observed -> NaN p
            p = np.float32(active.count + 1) / np.float32(k + 1)
            active.handle.complete(engine.PermutationTestResult(
                active.observed,
                float("nan") if np.isnan(active.observed) else float(p),
                active.orders.shape[1], k, active.handle.method,
                active.handle.key))
