"""TileScheduler: cross-request coalescing of permutation tiles.

The continuous-batching idiom, refactored from token slots to
permutation tiles. A request for K permutations is K independent rows of
``(n,)`` orders; the engine executes rows in padded ``(B, n)`` tiles
(``stats.engine.tile_statistics``). Nothing about a row depends on its
tile-mates — ``kernels.permute_reduce`` reduces each order column
independently, and the vmapped ``per_perm`` fallback is row-independent
too — so the scheduler is free to pack rows from *different* requests
into one tile whenever they share the exact invariant stack (study,
generation, method, operands). That buys:

* **slot reuse** — when a request's last rows retire mid-tile, the next
  tile immediately fills those rows from the queue's next request; chip
  utilization doesn't dip between requests;
* **one program per statistic shape** — every tile has the same (B, n)
  avals regardless of per-request K (a drained lane pads by cycling the
  rows it did collect), so the engine's one-padded-program sentinel
  invariant extends across the whole mixed-K serve run;
* **bitwise determinism** — each request's orders come from its own PRNG
  key via ``engine.permutation_orders`` (identical to what a standalone
  ``Workspace`` run draws), and row independence means its p-value is
  bit-for-bit the same whether it ran alone or coalesced.

Streaming: after each tile the scheduler pushes a ``StreamUpdate`` per
contributing request — running exceedance count, the anytime estimate
``p_partial``, and the *exact envelope* ``[p_lo, p_hi]``: ``p_lo``
assumes every remaining draw misses, ``p_hi`` assumes every remaining
draw exceeds, so ``p_lo`` is monotone nondecreasing, ``p_hi`` monotone
nonincreasing, and the final p-value always lands inside every streamed
interval (they converge to it at the last tile).

Every tile is timed through a ``runtime.monitor.StepMonitor`` span
(phase="step"), so the straggler/deadline watchdog covers serve loops,
and charged to the study's ``repro.obs`` ledger with the same
``charge_perm_batch`` terms the library engine uses.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.runtime.monitor import StepMonitor
from repro.stats import engine


# --------------------------------------------------------------------------
# Streaming math
# --------------------------------------------------------------------------
def partial_bounds(c: int, draws_done: int, permutations: int) -> dict:
    """Anytime p-value state after ``draws_done`` of K draws with ``c``
    exceedances so far.

    * ``p_partial = (c+1)/(draws_done+1)`` — the estimate *as if* the
      test stopped here (a valid Monte-Carlo p at this draw count);
    * ``p_lo = (c+1)/(K+1)`` — the final p if no remaining draw exceeds
      (monotone nondecreasing in draws_done);
    * ``p_hi = (c + (K - draws_done) + 1)/(K+1)`` — the final p if every
      remaining draw exceeds (monotone nonincreasing).

    The true final p-value lies in ``[p_lo, p_hi]`` for every prefix,
    and both bounds equal it at ``draws_done == K`` — bitwise: all three
    divide in fp32, the same arithmetic ``engine.finish`` performs, so
    the last frame's collapsed envelope IS the final p-value.
    """
    f = np.float32
    k1 = f(permutations + 1)
    return {"p_partial": float(f(c + 1) / f(draws_done + 1)),
            "p_lo": float(f(c + 1) / k1),
            "p_hi": float(f(c + (permutations - draws_done) + 1) / k1)}


def exceedances(observed: float, values: np.ndarray,
                alternative: str) -> int:
    """Null draws at least as extreme as ``observed`` — the numpy twin of
    ``engine.count_better`` (identical comparisons on the same fp32
    values, so incremental serve counts match the engine's one-shot
    count exactly; a NaN observed compares False everywhere, and the
    finisher turns that into a NaN p like ``engine.finish``)."""
    v = np.asarray(values)
    if alternative == "two-sided":
        return int(np.sum(np.abs(v) >= abs(observed)))
    if alternative == "greater":
        return int(np.sum(v >= observed))
    if alternative == "less":
        return int(np.sum(v <= observed))
    raise ValueError(f"unknown alternative {alternative!r}")


@dataclasses.dataclass(frozen=True)
class StreamUpdate:
    """One streamed progress frame for one request (see module docstring
    for the bound semantics)."""

    request_id: str
    method: str
    draws_done: int
    permutations: int
    exceedances: int
    p_partial: float
    p_lo: float
    p_hi: float
    done: bool

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


# --------------------------------------------------------------------------
# Lane keys — "may these requests share a tile?"
# --------------------------------------------------------------------------
def operand_fingerprint(value) -> Optional[tuple]:
    """Hashable identity of a request operand (grouping array etc.):
    dtype + shape + content digest. Two requests coalesce only when
    every operand fingerprint matches — identical invariant stacks."""
    if value is None:
        return None
    arr = np.asarray(value)
    return (arr.dtype.str, arr.shape,
            hashlib.sha1(arr.tobytes()).hexdigest()[:16])


# --------------------------------------------------------------------------
# The scheduler
# --------------------------------------------------------------------------
class _Active:
    """One in-flight request's scheduling state (internal)."""

    __slots__ = ("handle", "orders", "cursor", "count", "observed",
                 "alternative")

    def __init__(self, handle, orders, observed: float, alternative: str):
        self.handle = handle
        self.orders = orders          # (K, n) — this request's own draws
        self.cursor = 0               # rows already executed
        self.count = 0                # running exceedances
        self.observed = observed
        self.alternative = alternative


class Lane:
    """All in-flight requests that share one invariant stack.

    Holds the hoisted ``(stat, invariants, observed)`` built once at
    lane creation and a FIFO of ``_Active`` requests. ``next_tile``
    assembles the next (B, n) tile: rows come from the front request
    until it drains, then the next (slot reuse); a short final tile pads
    by cycling the rows it did collect — real permutations, so the tile
    avals (and hence the compiled program) never change, and the padded
    rows are simply not attributed to any request.
    """

    def __init__(self, key, ws, stat, invariants, observed: float,
                 batch_size: int):
        self.key = key
        self.ws = ws
        self.stat = stat
        self.invariants = invariants
        self.observed = observed
        self.batch_size = int(batch_size)
        self.requests: list = []
        self.tiles_run = 0

    def pending_rows(self) -> int:
        return sum(a.orders.shape[0] - a.cursor for a in self.requests)

    def next_tile(self):
        """``(tile, parts)``: the (B, n) orders tile plus
        ``[(active, take), ...]`` attributing its leading rows."""
        b = self.batch_size
        parts, chunks, have = [], [], 0
        for a in self.requests:
            if have == b:
                break
            take = min(b - have, a.orders.shape[0] - a.cursor)
            if take:
                chunks.append(a.orders[a.cursor:a.cursor + take])
                parts.append((a, take))
                have += take
        if have < b:                      # drained: pad by cycling rows
            real = jnp.concatenate(chunks) if len(chunks) > 1 else chunks[0]
            chunks.append(real[jnp.arange(b - have) % have])
        tile = jnp.concatenate(chunks) if len(chunks) > 1 else chunks[0]
        return tile, parts


class TileScheduler:
    """Round-robin tile executor over coalescing lanes.

    ``submit`` binds a request to its lane (creating the lane — one
    hoist via ``engine.hoist_and_observe`` — when it is the first);
    ``step`` executes ONE tile from the next lane with pending rows,
    streams updates, finishes retired requests. The service drives
    ``step`` in its event loop; ``monitor.heartbeat()`` runs at each
    step head so a stalled tile trips the deadline watchdog.
    """

    def __init__(self, batch_size: int = 32,
                 monitor: Optional[StepMonitor] = None, metrics=None):
        self.batch_size = int(batch_size)
        self.monitor = monitor if monitor is not None else StepMonitor()
        self.metrics = metrics
        self.lanes: "OrderedDict[tuple, Lane]" = OrderedDict()
        self.tiles_run = 0
        self._step_counter = 0

    # -- submission --------------------------------------------------------
    def submit(self, handle, ws, lane_key, stat, default_alternative: str
               ) -> None:
        """Activate one admitted request on its lane."""
        lane = self.lanes.get(lane_key)
        if lane is None:
            b = ws.config.resolve_batch_size(None, self.batch_size)
            with ws.obs.span("serve.hoist_lane", phase="serve",
                             method=handle.method, n=stat.n,
                             batch_size=b):
                invariants, observed = engine.hoist_and_observe(stat)
            lane = Lane(lane_key, ws, stat, invariants, float(observed),
                        b)
            self.lanes[lane_key] = lane
        orders = engine.permutation_orders(
            handle.key, handle.permutations, stat.n)
        alt = handle.alternative or default_alternative
        active = _Active(handle, orders, lane.observed, alt)
        lane.requests.append(active)
        handle.status = "active"
        handle.statistic = lane.observed

    # -- execution ---------------------------------------------------------
    def has_work(self) -> bool:
        return any(lane.pending_rows() for lane in self.lanes.values())

    def active_studies(self) -> set:
        """Study ids with in-flight rows — the pool's eviction pin set."""
        return {lane.key[0] for lane in self.lanes.values()
                if lane.pending_rows()}

    def step(self) -> bool:
        """Execute one tile; returns False when no lane had work."""
        self.monitor.heartbeat()
        lane = next((ln for ln in self.lanes.values()
                     if ln.pending_rows()), None)
        if lane is None:
            return False
        # round-robin: the lane we serve moves to the back
        self.lanes.move_to_end(lane.key)
        tile, parts = lane.next_tile()
        b = tile.shape[0]
        self._step_counter += 1
        self.monitor.start()
        values = np.asarray(
            engine.tile_statistics(lane.stat, lane.invariants, tile))
        step_rec = self.monitor.stop(self._step_counter)
        lane.tiles_run += 1
        self.tiles_run += 1
        # the padded tail rows are real gathers — charged like the
        # engine charges its own padded tiles
        lane.ws.obs.charge_perm_batch(
            f"serve:{parts[0][0].handle.method}", lane.stat.n, b, b)
        if self.metrics is not None:
            self.metrics.record_tile(b, len(parts),
                                     seconds=step_rec.seconds)
        offset = 0
        for active, take in parts:
            rows = values[offset:offset + take]
            offset += take
            active.count += exceedances(active.observed, rows,
                                        active.alternative)
            active.cursor += take
            self._emit(active)
        for active, _ in parts:
            if active.cursor >= active.orders.shape[0]:
                lane.requests.remove(active)
        if not lane.pending_rows() and not lane.requests:
            del self.lanes[lane.key]
        return True

    def _emit(self, active: _Active) -> None:
        k = int(active.orders.shape[0])
        done = active.cursor >= k
        bounds = partial_bounds(active.count, active.cursor, k)
        update = StreamUpdate(
            request_id=active.handle.request_id,
            method=active.handle.method,
            draws_done=active.cursor, permutations=k,
            exceedances=active.count, done=done, **bounds)
        active.handle.push_update(update)
        if done:
            # identical finishing rule to engine.finish, down to the
            # fp32 division: +1 correction, NaN observed -> NaN p
            p = np.float32(active.count + 1) / np.float32(k + 1)
            active.handle.complete(engine.PermutationTestResult(
                active.observed,
                float("nan") if np.isnan(active.observed) else float(p),
                active.orders.shape[1], k, active.handle.method,
                active.handle.key))
