"""Admission control: structured rejection + the bounded request queue.

A service front door fails differently from a library: a bad upload or
an overloaded queue must come back as a structured error payload the
client can branch on, never a traceback, and never by silently holding
the connection. This module owns both halves:

* ``Rejection`` / ``Rejected`` — the error currency. Every refusal has a
  stable machine-readable ``code`` (``non_finite``, ``too_large``,
  ``bad_shape``, ``queue_full``, ``timeout``, ``unknown_study``,
  ``bad_request`` — and, from the fault/recovery plane:
  ``circuit_open`` when a lane's breaker quarantined the request,
  ``stale_generation`` when its study was re-uploaded or evicted
  mid-flight, ``deadline`` when an *active* request was cooperatively
  cancelled past its deadline, ``cancelled`` for client aborts, and
  ``unavailable`` when lane compilation failed repeatedly), a human
  message, and a detail dict; ``payload()`` is the wire form.
* ``validate_upload`` — the data gate, reusing the library's own checks
  (``core.validation.ensure_finite``; the ``n > MAX_TRIANGLE_N`` int32
  triangle guard every condensed-indexed kernel enforces) so the service
  refuses exactly what the analysis stack would refuse, just politely
  and *before* any O(n²) work.
* ``RequestQueue`` — a bounded FIFO with per-request deadlines. Pushing
  past ``max_depth`` rejects immediately (backpressure, not unbounded
  buffering); requests whose deadline lapses while queued are expired
  with a ``timeout`` rejection instead of running stale.

Tune-solve at admission happens one layer up: ``AnalysisService.upload``
admits each study through a ``Workspace`` built on
``ExecConfig(auto=True)``, so the pool only ever holds sessions whose
tile geometry was solved against their own (n, d).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import numpy as np

from repro.core.distance_matrix import MAX_TRIANGLE_N
from repro.core.validation import ensure_finite


@dataclasses.dataclass(frozen=True)
class Rejection:
    """One structured refusal: a stable code, a human message, detail."""

    code: str
    message: str
    detail: dict = dataclasses.field(default_factory=dict)

    def payload(self) -> dict:
        """The wire form — what a transport would serialize back."""
        return {"error": {"code": self.code, "message": self.message,
                          "detail": dict(self.detail)}}


class Rejected(Exception):
    """Raised internally wherever admission refuses; carries the
    ``Rejection`` so the front door can return ``payload()`` instead of
    letting a traceback escape."""

    def __init__(self, rejection: Rejection):
        super().__init__(rejection.message)
        self.rejection = rejection

    @classmethod
    def make(cls, code: str, message: str, **detail) -> "Rejected":
        return cls(Rejection(code, message, detail))


def validate_upload(data=None, features=None, *,
                    max_n: int = MAX_TRIANGLE_N) -> tuple:
    """Gate one study upload; returns ``(kind, n)`` or raises ``Rejected``.

    ``kind`` is ``"dm"`` (square distance matrix) or ``"features"``
    ((n, d) table). Checks, in order: exactly one operand; array-shaped;
    plausible dimensionality; ``n`` within both the service cap and the
    int32 triangle bound; finite everywhere (the library's own fused
    single-pass ``ensure_finite``). All failures surface as structured
    ``Rejection`` payloads — the service never shows a client a
    traceback for bad data.
    """
    if (data is None) == (features is None):
        raise Rejected.make("bad_request",
                            "upload exactly one of data= (square distance "
                            "matrix) or features= ((n, d) table)")
    kind = "dm" if data is not None else "features"
    arr = np.asarray(data if data is not None else features)
    if arr.ndim != 2:
        raise Rejected.make("bad_shape",
                            f"expected a 2-d array, got shape {arr.shape}",
                            shape=list(arr.shape))
    if kind == "dm" and arr.shape[0] != arr.shape[1]:
        raise Rejected.make("bad_shape",
                            f"distance matrix must be square, got "
                            f"{arr.shape[0]}x{arr.shape[1]}",
                            shape=list(arr.shape))
    n = int(arr.shape[0])
    cap = min(int(max_n), MAX_TRIANGLE_N)
    if n > cap:
        raise Rejected.make(
            "too_large",
            f"n={n} exceeds this service's limit of {cap} samples "
            f"(int32 condensed triangle indexing is exact only to "
            f"n={MAX_TRIANGLE_N})",
            n=n, max_n=cap)
    try:
        ensure_finite(arr, what=("distance matrix" if kind == "dm"
                                 else "feature table"))
    except ValueError as e:
        raise Rejected.make("non_finite", str(e), n=n) from None
    return kind, n


class RequestQueue:
    """Bounded FIFO of pending request handles with deadlines.

    ``push`` refuses (``queue_full``) once ``max_depth`` requests wait —
    admission backpressure instead of unbounded memory. ``pop`` returns
    the oldest still-live handle; handles whose deadline lapsed while
    queued are returned by ``expired()`` for the service to fail with a
    ``timeout`` rejection. Deadlines use the monotonic clock.
    """

    def __init__(self, max_depth: int):
        self.max_depth = int(max_depth)
        self._q: deque = deque()

    def __len__(self) -> int:
        return len(self._q)

    def push(self, handle, timeout_s: Optional[float]) -> None:
        if len(self._q) >= self.max_depth:
            raise Rejected.make(
                "queue_full",
                f"request queue is full ({self.max_depth} pending); "
                f"retry later",
                max_depth=self.max_depth)
        handle.deadline = (time.monotonic() + timeout_s
                           if timeout_s is not None else None)
        self._q.append(handle)

    def expired(self, now: Optional[float] = None) -> list:
        """Remove and return every queued handle past its deadline."""
        now = time.monotonic() if now is None else now
        out = [h for h in self._q
               if h.deadline is not None and now > h.deadline]
        for h in out:
            self._q.remove(h)
        return out

    def pop(self):
        """The oldest live handle, or None when empty."""
        return self._q.popleft() if self._q else None
