"""SessionPool: a bounded LRU of live Workspace sessions, keyed by study.

The paper's economics inverted the bottleneck: when every analysis is a
cache-resident pass, the server's scarce resource is no longer compute
but *resident hoists* — each pooled study is exactly its ``HoistCache``
(condensed distances, operator means, ranks, moments, coordinates), and
``HoistCache.nbytes()`` prices it. This pool is therefore an LRU over
hoist bytes:

* ``admit`` creates (or refreshes) the study's ``Workspace`` — a
  re-upload routes through ``Workspace.refresh``, which drops every
  cached artifact and bumps ``generation``, so in-flight work pinned to
  the old generation keeps its own (still-alive) arrays while new
  requests see only the new data;
* ``get`` touches LRU order, so actively-served studies stay resident;
* eviction enforces both a session-count cap and a byte budget,
  skipping studies with in-flight work (the scheduler's pin set) —
  evicting a study only drops the *cache*; a later upload rebuilds it.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Optional

from repro.api.config import ExecConfig
from repro.api.workspace import Workspace


class SessionPool:
    """LRU pool of ``Workspace`` sessions (see module docstring).

    ``max_sessions`` bounds the count; ``max_bytes`` (None = unbounded)
    bounds the summed ``HoistCache.nbytes()`` — checked after each admit
    and on ``evict()``, oldest-touched first.
    """

    def __init__(self, max_sessions: int = 8,
                 max_bytes: Optional[int] = None):
        self.max_sessions = int(max_sessions)
        self.max_bytes = max_bytes
        self._sessions: "OrderedDict[str, Workspace]" = OrderedDict()
        self.evictions = 0

    # -- admission ---------------------------------------------------------
    def admit(self, study_id: str, config: ExecConfig, *, dm=None,
              features=None, metric=None) -> Workspace:
        """Create the study's session, or refresh it on re-upload.

        Validation/canonicalization is the Workspace's own admission
        path; the refresh path bumps ``generation`` so every scheduler
        lane keyed on the old generation stays internally consistent
        while new requests bind the new data. The new/refreshed session
        is touched most-recently-used, then the budgets are enforced
        (never evicting the session just admitted).
        """
        if study_id in self._sessions:
            ws = self._sessions[study_id]
            ws.refresh(dm=dm, features=features, metric=metric)
            self._sessions.move_to_end(study_id)
        else:
            if features is not None:
                ws = Workspace.from_features(features, metric=metric,
                                             config=config)
            else:
                ws = Workspace(dm, config=config)
            self._sessions[study_id] = ws
        self.evict(exclude={study_id})
        return ws

    def get(self, study_id: str) -> Optional[Workspace]:
        """The study's live session (touching LRU order), or None."""
        ws = self._sessions.get(study_id)
        if ws is not None:
            self._sessions.move_to_end(study_id)
        return ws

    # -- accounting --------------------------------------------------------
    def nbytes(self) -> int:
        """Summed resident hoist bytes across every pooled session."""
        return sum(ws.cache.nbytes() for ws in self._sessions.values())

    def nbytes_by_study(self) -> dict:
        return {sid: ws.cache.nbytes()
                for sid, ws in self._sessions.items()}

    def __len__(self) -> int:
        return len(self._sessions)

    def __contains__(self, study_id: str) -> bool:
        return study_id in self._sessions

    def studies(self):
        return list(self._sessions.keys())

    # -- eviction ----------------------------------------------------------
    def drop(self, study_id: str) -> bool:
        """Force-remove one session unconditionally (the fault plane's
        eviction-race lever; ordinary budget pressure uses ``evict``).
        The caller owns the consequences — in-flight requests bound to
        the dropped study must be terminated via the scheduler's
        ``invalidate_study``, which is exactly what the service does."""
        if study_id in self._sessions:
            del self._sessions[study_id]
            self.evictions += 1
            return True
        return False

    def shed(self, exclude=frozenset()) -> Optional[str]:
        """Evict ONE least-recently-used victim outside ``exclude`` —
        the allocator-pressure response (a real or injected OOM wants
        bytes back *now*, not budget convergence). Returns the evicted
        study id, or None when every session is excluded."""
        for sid in self._sessions:
            if sid not in exclude:
                del self._sessions[sid]
                self.evictions += 1
                return sid
        return None

    def evict(self, exclude=frozenset()) -> list:
        """Enforce both budgets, least-recently-used first; ``exclude``
        names studies that must survive (the just-admitted session, the
        scheduler's in-flight pins). Returns the evicted study ids. May
        leave the pool over budget when everything else is excluded —
        correctness over the cap: never drop a session mid-request."""
        evicted = []

        def victims():
            return [sid for sid in self._sessions if sid not in exclude]

        while len(self._sessions) > self.max_sessions and victims():
            sid = victims()[0]
            del self._sessions[sid]
            evicted.append(sid)
        if self.max_bytes is not None:
            while self.nbytes() > self.max_bytes and victims():
                sid = victims()[0]
                del self._sessions[sid]
                evicted.append(sid)
        self.evictions += len(evicted)
        return evicted
