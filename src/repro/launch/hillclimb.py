"""RETIRED: the variant hillclimb is superseded by ``repro.tune``.

This runner compiled one seed-era model cell under hand-listed config /
sharding variants and recorded the three roofline terms for each — the
measure step of a manual hypothesis → change → measure loop. The repo
grew principled replacements for both halves of that loop:

* *choosing* variants is now ``repro.tune``'s job: ``tune.model`` prices
  every legal tile size analytically and ``tune.search`` picks the
  winner per backend — no hand-listed variant files;
* *measuring* a choice is now ``repro.obs.probe`` (ahead-of-time
  compilation of the production entry points, scan-corrected byte
  counts) plus ``repro.obs.drift``, which reconciles measurement against
  the cost model and flags any configuration whose compiled traffic
  leaves the modeled envelope — the regression the hillclimb watched
  for by eye.

The probe-backed calibration the hillclimb never had::

    from repro.tune.budget import calibrate
    budget = calibrate(mode="probe")     # deterministic, clock-free

Nothing is exported; importing this module is harmless (it no longer
sets ``XLA_FLAGS`` or imports the retired dry-run).
"""
