import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb runner: compile a cell under config/sharding VARIANTS
and record the three roofline terms for each — the measure step of the
hypothesis → change → measure loop (EXPERIMENTS.md §Perf Part B).

    PYTHONPATH=src python -m repro.launch.hillclimb \
        --arch qwen3-8b --shape train_4k --mesh single \
        --variant name=dp_tp fsdp=0 \
        --variant name=dp_tp_nosp fsdp=0 seq_shard_activations=0

Each --variant is a space-separated k=v list; keys are ModelConfig fields
(plus the special 'fsdp' and 'name'). Results append to
results/hillclimb_<arch>_<shape>.json.
"""

import argparse
import dataclasses
import json

import jax

from repro.configs import ARCHS, SHAPES
from repro.launch import dryrun as dr
from repro.launch.mesh import make_production_mesh, mesh_chips


def parse_variant(tokens):
    out = {}
    for t in tokens:
        k, v = t.split("=", 1)
        out[k] = v
    return out


def apply_variant(cfg, variant: dict):
    fields = {f.name: f.type for f in dataclasses.fields(cfg)}
    updates = {}
    for k, v in variant.items():
        if k in ("name", "fsdp", "zero1"):
            continue
        if k not in fields:
            raise KeyError(f"unknown config field {k}")
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            updates[k] = v not in ("0", "false", "False")
        elif isinstance(cur, int):
            updates[k] = int(v)
        elif isinstance(cur, float):
            updates[k] = float(v)
        else:
            updates[k] = v
    return dataclasses.replace(cfg, **updates)


def run_variant(arch: str, sname: str, mesh_name: str, mesh, variant: dict):
    from repro.sharding.rules import make_rules
    cfg = apply_variant(ARCHS[arch], variant)
    fsdp = variant.get("fsdp", "1") not in ("0", "false", "False")

    # monkey-patchless: dryrun.lower_cell builds rules itself, so inline
    # the same flow with our rules here.
    import time
    from repro.launch.inputs import input_specs
    from repro.optim.adamw import AdamWConfig
    from repro.runtime.serve import make_decode_step, make_prefill_step
    from repro.runtime.train import abstract_train_state, make_train_step

    shape = SHAPES[sname]
    rules = make_rules(mesh, fsdp=fsdp)
    zero1 = variant.get("zero1", "0") not in ("0", "false", "False")
    opt_rules = make_rules(mesh, fsdp=True) if zero1 else None
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            params, opt_state = abstract_train_state(cfg)
            batch = input_specs(cfg, shape)
            step = make_train_step(cfg, AdamWConfig(), mesh, rules, params,
                                   opt_state, batch, opt_rules=opt_rules)
            compiled = step.lower(params, opt_state, batch).compile()
        elif shape.kind == "prefill":
            params, _ = abstract_train_state(cfg)
            batch = input_specs(cfg, shape)
            step = make_prefill_step(cfg, mesh, rules, params, batch,
                                     max_len=shape.seq_len)
            compiled = step.lower(params, batch).compile()
        else:
            params, _ = abstract_train_state(cfg)
            token, cache = input_specs(cfg, shape)
            step = make_decode_step(cfg, mesh, rules, params, cache)
            compiled = step.lower(params, token, cache).compile()

    from repro.roofline.hlo import (collective_bytes_per_device,
                                    cpu_bf16_carry_artifact_bytes)
    from repro.roofline.model import step_costs
    from repro.roofline.terms import roofline_terms

    chips = mesh_chips(mesh)
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes_per_device(hlo, chips)
    artifact = cpu_bf16_carry_artifact_bytes(hlo)
    cost = step_costs(cfg, shape, chips)
    terms = roofline_terms(cost.flops_executed, cost.flops_model,
                           cost.bytes_hbm_per_device, coll.get("total", 0),
                           chips)
    rec = {
        "variant": variant.get("name", "variant"),
        "overrides": variant,
        "mesh": mesh_name,
        "compile_s": round(time.time() - t0, 1),
        "collective_bytes": coll,
        "peak_bytes": int(ma.argument_size_in_bytes + ma.temp_size_in_bytes
                          + ma.output_size_in_bytes
                          - ma.alias_size_in_bytes),
        "peak_adjusted": int(ma.argument_size_in_bytes
                             + ma.temp_size_in_bytes
                             + ma.output_size_in_bytes
                             - ma.alias_size_in_bytes - artifact),
        "roofline": terms.as_dict(),
    }
    print(f"[{rec['variant']:16s}] compute={terms.compute_s:.4f}s "
          f"memory={terms.memory_s:.4f}s collective={terms.collective_s:.4f}s"
          f" dominant={terms.dominant} mfu={terms.mfu_bound:.3f} "
          f"peak_adj={rec['peak_adjusted'] / 1e9:.1f}GB "
          f"wire={coll.get('total', 0) / 1e9:.1f}GB")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--variant", nargs="+", action="append", required=True)
    ap.add_argument("--out", default="results")
    args = ap.parse_args()

    mesh_name = ("multi_pod_2x16x16" if args.mesh == "multi"
                 else "single_pod_16x16")
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))

    out_path = os.path.join(args.out,
                            f"hillclimb_{args.arch}_{args.shape}.json")
    recs = []
    if os.path.exists(out_path):
        with open(out_path) as f:
            recs = json.load(f)
    for v in args.variant:
        variant = parse_variant(v)
        recs = [r for r in recs if not (r["variant"] == variant.get("name")
                                        and r["mesh"] == mesh_name)]
        recs.append(run_variant(args.arch, args.shape, mesh_name, mesh,
                                variant))
        with open(out_path, "w") as f:
            json.dump(recs, f, indent=1)


if __name__ == "__main__":
    main()
