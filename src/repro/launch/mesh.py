"""Production mesh construction (harness spec, MULTI-POD DRY-RUN §1).

``make_production_mesh`` is a FUNCTION — importing this module never
touches jax device state. Any caller that wants a simulated multi-pod
mesh must set ``XLA_FLAGS=--xla_force_host_platform_device_count=512``
before the first jax import; smoke tests and benchmarks see the real
single device. (The retired ``launch.dryrun`` was the last such caller;
nothing in-tree sets the override today.)

Hardware model (TPU v5e targets; ``HBM_BW`` is also the bandwidth
column of ``tune.budget``'s static TPU budget):
    197 TFLOP/s bf16 / chip · 819 GB/s HBM · ~50 GB/s/link ICI.
"""

from __future__ import annotations

import jax

# v5e constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(shape=(1, 1), axes=("data", "model")):
    """Small mesh over whatever devices exist (tests, examples)."""
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def mesh_chips(mesh) -> int:
    return mesh.devices.size
