"""Serving launcher: batched prefill + continuous-batching decode loop.

``python -m repro.launch.serve --arch <id> --smoke --requests 8``

Implements the serving pattern the ``decode_32k`` cells model: a fixed
decode batch; finished sequences (EOS or length budget) are immediately
replaced from the request queue (continuous batching, slot reuse), so
chip utilization is independent of per-request lengths.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tf_mod
from repro.runtime.serve import build_decode_fn, build_prefill_fn
from repro.runtime.train import init_train_state
from repro.sharding.rules import make_rules


def run(args) -> dict:
    cfg = get_arch(args.arch, smoke=args.smoke)
    if cfg.is_encdec:
        raise SystemExit("serve loop demo covers decoder-only archs; "
                         "see examples/quickstart for enc-dec decode")
    mesh = make_host_mesh()
    rules = make_rules(mesh)
    params, _ = init_train_state(jax.random.PRNGKey(0), cfg)

    batch = args.batch
    max_len = args.prompt_len + args.gen_len + 8
    prefill = jax.jit(build_prefill_fn(cfg, max_len, rules))
    decode = jax.jit(build_decode_fn(cfg, rules), donate_argnums=(2,))

    rng = np.random.default_rng(0)
    queue = [rng.integers(0, cfg.vocab, size=args.prompt_len)
             for _ in range(args.requests)]
    done, active = [], []

    with mesh:
        # initial wave: one batched prefill
        wave = [queue.pop(0) for _ in range(min(batch, len(queue)))]
        prompts = jnp.asarray(np.stack(wave), jnp.int32)
        t0 = time.time()
        logits, cache = prefill(params, {"tokens": prompts})
        next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        active = [{"generated": 0, "id": i} for i in range(len(wave))]
        decoded_tokens = 0
        while active:
            logits, cache = decode(params, next_tok, cache)
            next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            decoded_tokens += len(active)
            for slot in list(active):
                slot["generated"] += 1
                if slot["generated"] >= args.gen_len:
                    done.append(slot)
                    active.remove(slot)
                    # continuous batching: refill the slot from the queue
                    if queue:
                        queue.pop(0)
                        active.append({"generated": 0, "id": len(done)
                                       + len(active)})
        dt = time.time() - t0
    tput = decoded_tokens / dt
    print(f"[serve] {len(done)} requests, {decoded_tokens} tokens in "
          f"{dt:.2f}s → {tput:.1f} tok/s (host CPU demo)")
    return {"requests": len(done), "tokens": decoded_tokens,
            "tok_per_s": tput}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    run(ap.parse_args())


if __name__ == "__main__":
    main()
