"""Serving launcher: the ``repro.serve`` analysis front door, end to end.

``python -m repro.launch.serve --smoke``

Drives an ``AnalysisService`` through a synthetic multi-tenant workload:
several studies are uploaded, a mixed bag of concurrent requests (the
full battery — pcoa, permanova, anosim, permdisp, mantel,
partial_mantel — at mixed per-request K) is submitted, the coalescing
tile loop drains them, and the ``serve_report()`` summary prints.

This replaces the old token-decoding continuous-batching demo, which was
dead code with a real bug: its slot-refill path popped the queued prompt
and appended a fresh slot WITHOUT running a prefill, so a "refilled"
request decoded against the previous occupant's stale KV cache. The
permutation-tile scheduler keeps the idiom that demo was after — a
finished request's tile rows are refilled from the queue on the very
next tile — with the refill done correctly by construction: every row
carries its own permutation order, so there is no per-slot state to
forget to reset.
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from repro.serve import AnalysisService, ServeConfig


def run(args) -> dict:
    rng = np.random.default_rng(args.seed)
    svc = AnalysisService(ServeConfig(batch_size=args.batch,
                                      timeout_s=None,
                                      max_sessions=max(4, args.studies)))

    # uploads: half feature-backed, half square-backed
    study_ids = [f"study{i}" for i in range(args.studies)]
    for i, sid in enumerate(study_ids):
        feats = rng.random((args.n, 8)).astype(np.float32)
        if i % 2:
            from repro.api.workspace import Workspace
            dm = np.asarray(
                Workspace.from_features(feats).dm.data)
            svc.upload(sid, dm)
        else:
            svc.upload(sid, features=feats)

    grouping = np.arange(args.n) % 3
    methods = ("permanova", "anosim", "permdisp", "mantel",
               "partial_mantel", "pcoa")
    handles = []
    for r in range(args.requests):
        sid = study_ids[r % len(study_ids)]
        method = methods[r % len(methods)]
        kw = {"permutations": args.permutations // (1 + r % 3),
              "key": r}
        if method in ("permanova", "anosim", "permdisp"):
            kw["grouping"] = grouping
        if method in ("mantel", "partial_mantel"):
            kw["other"] = study_ids[(r + 1) % len(study_ids)]
        if method == "partial_mantel":
            kw["control"] = study_ids[(r + 2) % len(study_ids)]
        if method == "pcoa":
            kw = {"dimensions": 3}
        handles.append(svc.submit(sid, method, **kw))

    svc.run()
    report = svc.report()
    ok = sum(h.status == "done" for h in handles)
    g = report["gauges"]
    print(f"[serve] {ok}/{len(handles)} requests done | "
          f"{report['scheduler']['tiles_run']} tiles of B={args.batch} | "
          f"{report['pool']['sessions']} sessions, "
          f"{report['pool']['nbytes']} hoist bytes resident | "
          f"throughput {g['throughput_rps']:.1f} req/s")
    for h in handles[: args.show]:
        print(f"  {h.request_id:>4} {h.method:<14} {h.status:<8}"
              + (f" p={h.result.p_value:.4f}"
                 if getattr(h.result, "p_value", None) is not None else ""))
    if args.json:
        print(json.dumps({"gauges": g, "pool": report["pool"],
                          "scheduler": report["scheduler"]}, indent=2,
                         default=str))
    return report


def main():
    ap = argparse.ArgumentParser(
        description="drive the repro.serve analysis front door")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes (CI-friendly)")
    ap.add_argument("--studies", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--n", type=int, default=128)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--permutations", type=int, default=999)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--show", type=int, default=12,
                    help="per-request lines to print")
    ap.add_argument("--json", action="store_true",
                    help="dump the gauge/pool/scheduler sections as JSON")
    args = ap.parse_args()
    if args.smoke:
        args.n = min(args.n, 32)
        args.permutations = min(args.permutations, 99)
    run(args)


if __name__ == "__main__":
    main()
