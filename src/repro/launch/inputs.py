"""``input_specs()`` — ShapeDtypeStruct stand-ins for every model input
(harness MULTI-POD DRY-RUN §2): weak-type-correct, shardable, no device
allocation. The modality frontends are STUBS per the assignment: vision
supplies precomputed CLIP patch embeddings, audio supplies precomputed
w2v-BERT frame embeddings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.runtime.serve import abstract_cache


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    specs = {}
    if cfg.is_encdec:
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, s // cfg.enc_len_ratio, cfg.frontend_dim), jnp.bfloat16)
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        specs["targets"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return specs
    if cfg.frontend == "vision":
        # patches fold into the sequence: text tokens fill the remainder
        s_text = s - cfg.n_patches
        specs["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.n_patches, cfg.frontend_dim), jnp.bfloat16)
        specs["tokens"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
        specs["targets"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
        return specs
    specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    specs["targets"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return specs


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    specs = train_input_specs(cfg, shape)
    specs.pop("targets")
    return specs


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """→ (token_spec, cache_spec_tree). Cache depth = shape.seq_len."""
    b, s = shape.global_batch, shape.seq_len
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    enc_len = (s // cfg.enc_len_ratio) if cfg.is_encdec else 0
    cache = abstract_cache(cfg, b, s, enc_len=enc_len)
    return token, cache


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    if shape.kind == "train":
        return train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape)
