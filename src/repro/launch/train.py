"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

Wires together every substrate layer: config registry → data pipeline →
sharded train step → checkpoint manager (atomic/async/elastic) →
straggler monitor. Sharding profiles (--profile) expose the §Perf
hillclimb winners; cross-pod gradient compression utilities live in
optim/compression.py (validated in tests/test_distributed.py). On this
container it runs smoke-scale configs on the host device; on a real pod
the same script runs the full config (the mesh shape is the only knob).

Fault tolerance drill (tests/test_integration.py runs it):
    train 5 steps → kill → relaunch → resumes from step 5 with identical
    loss trajectory (deterministic data pipeline keyed by step).
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import SHAPES, get_arch
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.optim.adamw import AdamWConfig
from repro.runtime.monitor import StepMonitor
from repro.runtime.train import init_train_state, make_train_step
from repro.sharding.rules import make_rules


def build_argparser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--decay-steps", type=int, default=0,
                    help="cosine decay horizon (default: --steps); set it\n"
                         "explicitly when a run will be interrupted+resumed\n"
                         "so the schedule is restart-invariant")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--profile", default="fsdp",
                    choices=["fsdp", "dp_tp", "zero1"],
                    help="sharding profile (EXPERIMENTS §Perf): fsdp = "
                         "ZeRO-3 weights (memory-min baseline); dp_tp = "
                         "replicated weights + TP (collective-min); zero1 "
                         "= dp_tp weights with FSDP-sharded Adam moments "
                         "(the §Perf winner)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    return ap


def run(args) -> dict:
    cfg = get_arch(args.arch, smoke=args.smoke)
    cfg = dataclasses.replace(cfg, microbatches=min(cfg.microbatches,
                                                    max(args.batch // 2, 1)))
    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    rules = make_rules(mesh, fsdp=(args.profile == "fsdp"))
    opt_rules = make_rules(mesh, fsdp=True) if args.profile == "zero1" else None
    horizon = args.decay_steps or args.steps
    opt = AdamWConfig(peak_lr=args.lr, warmup_steps=max(horizon // 10, 1),
                      decay_steps=horizon)

    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch, seed=args.seed)
    params, opt_state = init_train_state(jax.random.PRNGKey(args.seed), cfg)

    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        if args.resume and ckpt.latest_step() is not None:
            from repro.sharding.rules import param_specs
            specs = param_specs(cfg, params, rules)
            o_specs = {"m": specs, "v": specs,
                       "step": jax.sharding.PartitionSpec()}
            state = {"params": params, "opt": opt_state}
            state, meta = ckpt.restore(
                state, mesh=mesh,
                specs={"params": specs, "opt": o_specs})
            params, opt_state = state["params"], state["opt"]
            start_step = meta["step"]
            print(f"[resume] from step {start_step}")

    def make_batch(step):
        b = pipe.batch(step)
        extras = {}
        if cfg.is_encdec:
            k = jax.random.fold_in(jax.random.PRNGKey(args.seed + 1), step)
            extras["frames"] = jax.random.normal(
                k, (args.batch, max(args.seq // cfg.enc_len_ratio, 1),
                    cfg.frontend_dim), jnp.float32)
        if cfg.frontend == "vision":
            k = jax.random.fold_in(jax.random.PRNGKey(args.seed + 2), step)
            extras["patches"] = jax.random.normal(
                k, (args.batch, cfg.n_patches, cfg.frontend_dim), jnp.float32)
        return {**b, **extras}

    batch0 = make_batch(start_step)
    with mesh:
        step_fn = make_train_step(cfg, opt, mesh, rules, params, opt_state,
                                  batch0, opt_rules=opt_rules)
        monitor = StepMonitor()
        losses = []
        for step in range(start_step, args.steps):
            batch = make_batch(step)
            monitor.start()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            rec = monitor.stop(step)
            losses.append(loss)
            if step % args.log_every == 0:
                flag = " STRAGGLER" if rec.straggler else ""
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{rec.seconds * 1e3:.0f}ms{flag}")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt_state},
                          metadata={"arch": cfg.name}, blocking=False)
        if ckpt:
            ckpt.save(args.steps, {"params": params, "opt": opt_state},
                      metadata={"arch": cfg.name})
            ckpt.wait()
    print(f"[monitor] {monitor.summary()}")
    return {"losses": losses, "monitor": monitor.summary(),
            "final_step": args.steps}


def main():
    run(build_argparser().parse_args())


if __name__ == "__main__":
    main()
