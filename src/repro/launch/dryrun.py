"""RETIRED: the multi-pod dry-run is superseded by ``repro.obs.probe``.

This entry point compiled every (architecture × input shape) cell of the
old token-model harness on simulated 512-device meshes and recorded
``cost_analysis()`` / ``memory_analysis()`` per cell. Two things made it
dead weight:

* the cells it enumerated belonged to the seed-era language-model
  harness, not the beta-diversity stack this repo now reproduces — none
  of its compiled programs are the programs the paper's pipeline runs;
* its measurement idea — compile ahead-of-time, read the compiled
  program's costs instead of the wall clock — was the right one, and it
  now lives where the real entry points are: ``repro.obs.probe`` lowers
  the *production* jitted programs (``kernels.permute_reduce``,
  ``dist.panel_stats``, the stats engine, the matrix-free PCoA) against
  symbolic avals and returns scan-corrected byte counts, flops, and peak
  memory per program. ``repro.obs.drift`` reconciles those measurements
  against the analytic ledger / cost models, and ``Workspace.report()``
  carries the verdicts.

For the measurement surface this module used to provide::

    from repro.obs.probe import probe_session, probe_table
    records = probe_session(workspace)   # one ProbeRecord per entry point
    print(probe_table(records))

Nothing is exported; importing this module is harmless (it no longer
touches ``XLA_FLAGS`` or device state — the 512-device override died
with the dry-run, and ``tests/conftest.py`` documents that tests see
the real device).
"""
