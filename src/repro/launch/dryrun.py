import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (harness deliverable (e)).

Lowers + compiles every (architecture × input shape) cell on the
single-pod (16, 16) and multi-pod (2, 16, 16) production meshes, records
``memory_analysis()`` / ``cost_analysis()`` / parsed collective bytes /
analytic roofline terms, and appends each cell to a resumable JSON.

The XLA_FLAGS line above MUST stay the first statement — jax locks the
device count at first init (harness MULTI-POD DRY-RUN §0). Only this
entry point sets it; tests and benchmarks see the real device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun               # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --list
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES
from repro.launch.inputs import input_specs
from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.optim.adamw import AdamWConfig
from repro.roofline.hlo import collective_bytes_per_device
from repro.roofline.model import step_costs
from repro.roofline.terms import roofline_terms
from repro.runtime.serve import make_decode_step, make_prefill_step
from repro.runtime.train import abstract_train_state, make_train_step
from repro.sharding.rules import make_rules

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results")


def cell_list():
    cells = []
    for arch, cfg in ARCHS.items():
        for sname, shape in SHAPES.items():
            if not cfg.supports_shape(shape):
                continue
            cells.append((arch, sname))
    return cells


def lower_cell(cfg, shape, mesh):
    """→ (lowered, compiled) for the cell's step function."""
    rules = make_rules(mesh)
    with mesh:
        if shape.kind == "train":
            params, opt_state = abstract_train_state(cfg)
            batch = input_specs(cfg, shape)
            step = make_train_step(cfg, AdamWConfig(), mesh, rules,
                                   params, opt_state, batch)
            lowered = step.lower(params, opt_state, batch)
        elif shape.kind == "prefill":
            params, _ = abstract_train_state(cfg)
            batch = input_specs(cfg, shape)
            step = make_prefill_step(cfg, mesh, rules, params, batch,
                                     max_len=shape.seq_len)
            lowered = step.lower(params, batch)
        else:
            params, _ = abstract_train_state(cfg)
            token, cache = input_specs(cfg, shape)
            step = make_decode_step(cfg, mesh, rules, params, cache)
            lowered = step.lower(params, token, cache)
        compiled = lowered.compile()
    return lowered, compiled


def analyze_cell(arch: str, sname: str, mesh_name: str, mesh) -> dict:
    cfg = ARCHS[arch]
    shape = SHAPES[sname]
    chips = mesh_chips(mesh)
    rec = {"arch": arch, "shape": sname, "mesh": mesh_name, "chips": chips}

    t0 = time.time()
    lowered, compiled = lower_cell(cfg, shape, mesh)
    rec["compile_s"] = round(time.time() - t0, 1)

    ma = compiled.memory_analysis()
    rec["memory_analysis"] = {
        "argument_bytes_per_device": int(ma.argument_size_in_bytes),
        "output_bytes_per_device": int(ma.output_size_in_bytes),
        "temp_bytes_per_device": int(ma.temp_size_in_bytes),
        "alias_bytes_per_device": int(ma.alias_size_in_bytes),
        "peak_bytes_per_device": int(ma.argument_size_in_bytes
                                     + ma.temp_size_in_bytes
                                     + ma.output_size_in_bytes
                                     - ma.alias_size_in_bytes),
    }
    print(f"  memory_analysis: {ma}")

    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    ca = ca or {}
    rec["cost_analysis_raw"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "note": "scan bodies counted once by XLA (see EXPERIMENTS §Method)",
    }
    print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
          f"bytes={ca.get('bytes accessed', 0):.3e}")

    hlo = compiled.as_text()
    coll = collective_bytes_per_device(hlo, chips)
    rec["collectives"] = coll
    rec["hlo_bytes"] = len(hlo)

    # CPU-host compile artifact: f32 shadow copies of bf16 while-carries
    # (bf16-dot emulation). Absent on TPU — record and adjust (§Method).
    from repro.roofline.hlo import cpu_bf16_carry_artifact_bytes
    artifact = cpu_bf16_carry_artifact_bytes(hlo)
    rec["cpu_bf16_artifact_bytes"] = int(artifact)
    rec["memory_analysis"]["peak_adjusted_bytes_per_device"] = int(
        rec["memory_analysis"]["peak_bytes_per_device"] - artifact)

    cost = step_costs(cfg, shape, chips)
    rec["analytic"] = {
        "flops_executed": cost.flops_executed,
        "flops_model": cost.flops_model,
        "bytes_hbm_per_device": cost.bytes_hbm_per_device,
        "params_total": cost.params_total,
        **{f"detail_{k}": v for k, v in cost.breakdown.items()},
    }
    terms = roofline_terms(cost.flops_executed, cost.flops_model,
                           cost.bytes_hbm_per_device,
                           coll.get("total", 0), chips)
    rec["roofline"] = terms.as_dict()
    print(f"  roofline: compute={terms.compute_s:.4f}s "
          f"memory={terms.memory_s:.4f}s collective={terms.collective_s:.4f}s"
          f" dominant={terms.dominant} mfu_bound={terms.mfu_bound:.3f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="recompute cells already in the results file")
    args = ap.parse_args()

    cells = cell_list()
    if args.arch:
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape:
        cells = [c for c in cells if c[1] == args.shape]
    if args.list:
        for c in cells:
            print(f"{c[0]} × {c[1]}")
        print(f"{len(cells)} runnable cells "
              f"(+ skips documented in DESIGN §Arch-applicability)")
        return

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16",
                       make_production_mesh(multi_pod=True)))

    out_dir = args.out or os.path.abspath(RESULTS_DIR)
    os.makedirs(out_dir, exist_ok=True)

    for mesh_name, mesh in meshes:
        out_path = os.path.join(out_dir, f"dryrun_{mesh_name}.json")
        results = {}
        if os.path.exists(out_path):
            with open(out_path) as f:
                results = json.load(f)
        for arch, sname in cells:
            key = f"{arch}/{sname}"
            if key in results and not args.force \
                    and "error" not in results[key]:
                print(f"[skip] {key} on {mesh_name} (cached)")
                continue
            print(f"[cell] {key} on {mesh_name}")
            try:
                results[key] = analyze_cell(arch, sname, mesh_name, mesh)
            except Exception as e:
                traceback.print_exc()
                results[key] = {"arch": arch, "shape": sname,
                                "mesh": mesh_name,
                                "error": f"{type(e).__name__}: {e}"}
            with open(out_path, "w") as f:
                json.dump(results, f, indent=1)
        ok = sum(1 for v in results.values() if "error" not in v)
        print(f"[done] {mesh_name}: {ok}/{len(results)} cells OK → {out_path}")


if __name__ == "__main__":
    main()
