"""Encoder-decoder assembly (seamless-m4t): bidirectional encoder over
stubbed audio-frame embeddings + causal decoder with cross-attention.

Same scan-over-layers discipline as the decoder-only stack. The decoder
cache holds per-layer self-attention K/V plus the precomputed
cross-attention K/V (encoder keys never change during decode — computed
once at prefill, the enc-dec analogue of the paper's "hoist the
permutation-invariant part out of the loop").
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models.layers import (apply_norm, embed_tokens, init_embed,
                                 init_mlp, init_norm, mlp)
from repro.sharding import ctx as shard_ctx


def _init_enc_block(key, cfg):
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    return {"ln1": init_norm(cfg, d), "attn": attn_mod.init_attn(ks[0], cfg),
            "ln2": init_norm(cfg, d), "mlp": init_mlp(ks[1], cfg, d, cfg.d_ff)}


def _init_dec_block(key, cfg):
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    return {"ln1": init_norm(cfg, d), "self": attn_mod.init_attn(ks[0], cfg),
            "ln_x": init_norm(cfg, d), "cross": attn_mod.init_attn(ks[1], cfg),
            "ln2": init_norm(cfg, d), "mlp": init_mlp(ks[2], cfg, d, cfg.d_ff)}


def init_params_encdec(key, cfg) -> dict:
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": init_embed(ks[2], cfg),
        "frontend": {"proj": (jax.random.normal(ks[3], (cfg.frontend_dim,
                                                        cfg.d_model))
                              * cfg.frontend_dim ** -0.5).astype(cfg.dtype())},
        "enc_blocks": jax.vmap(lambda k: _init_enc_block(k, cfg))(enc_keys),
        "enc_norm": init_norm(cfg, cfg.d_model),
        "dec_blocks": jax.vmap(lambda k: _init_dec_block(k, cfg))(dec_keys),
        "final_norm": init_norm(cfg, cfg.d_model),
    }


def encode(params, frames, cfg):
    """frames: (B, S_enc, frontend_dim) stub embeddings → (B, S_enc, D)."""
    x = jnp.einsum("bpf,fd->bpd", frames.astype(cfg.dtype("compute")),
                   params["frontend"]["proj"])
    positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                                 x.shape[:2])

    seq_dim = 1 if cfg.seq_shard_activations else None

    def body(x, bp):
        x = shard_ctx.constrain_batch(x, seq_dim=seq_dim)
        h, _ = attn_mod.attn_forward(bp["attn"], apply_norm(bp["ln1"], x, cfg),
                                     positions, cfg, causal=False)
        x = x + h
        x = x + mlp(bp["mlp"], apply_norm(bp["ln2"], x, cfg), cfg)
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc_blocks"])
    return apply_norm(params["enc_norm"], x, cfg)


def _dec_block(bp, x, enc_out, positions, cfg):
    h, _ = attn_mod.attn_forward(bp["self"], apply_norm(bp["ln1"], x, cfg),
                                 positions, cfg, causal=True)
    x = x + h
    h, _ = attn_mod.attn_forward(bp["cross"], apply_norm(bp["ln_x"], x, cfg),
                                 None, cfg, causal=False, kv_x=enc_out,
                                 kv_positions=None)
    x = x + h
    return x + mlp(bp["mlp"], apply_norm(bp["ln2"], x, cfg), cfg)


def forward_train_encdec(params, frames, tokens, cfg):
    """→ (decoder hidden (B,S_dec,D), aux=0)."""
    enc_out = encode(params, frames, cfg)
    x = embed_tokens(params["embed"], tokens, cfg)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                                 x.shape[:2])

    seq_dim = 1 if cfg.seq_shard_activations else None

    def body(x, bp):
        x = shard_ctx.constrain_batch(x, seq_dim=seq_dim)
        return _dec_block(bp, x, enc_out, positions, cfg), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["dec_blocks"])
    return apply_norm(params["final_norm"], x, cfg), jnp.zeros((), jnp.float32)


def prefill_encdec(params, frames, tokens, cfg, max_len: Optional[int] = None):
    """Encode + run the decoder prompt; build self + cross caches."""
    enc_out = encode(params, frames, cfg)
    x = embed_tokens(params["embed"], tokens, cfg)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    s = x.shape[1]
    max_len = max_len or s
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), x.shape[:2])

    seq_dim = 1 if cfg.seq_shard_activations else None

    def body(x, bp):
        x = shard_ctx.constrain_batch(x, seq_dim=seq_dim)
        norm_x = apply_norm(bp["ln1"], x, cfg)
        h, (k, v) = attn_mod.attn_forward(bp["self"], norm_x, positions, cfg,
                                          causal=True)
        x = x + h
        self_cache = attn_mod.init_attn_cache(cfg, x.shape[0], max_len)
        self_cache = attn_mod.fill_cache_from_prefill(self_cache, k, v)
        h, (ck, cv) = attn_mod.attn_forward(
            bp["cross"], apply_norm(bp["ln_x"], x, cfg), None, cfg,
            causal=False, kv_x=enc_out, kv_positions=None)
        x = x + h
        x = x + mlp(bp["mlp"], apply_norm(bp["ln2"], x, cfg), cfg)
        return x, {"self": self_cache, "cross_k": ck, "cross_v": cv}

    x, caches = jax.lax.scan(body, x, params["dec_blocks"])
    cache = {"dec": caches, "pos": jnp.asarray(s, jnp.int32)}
    return apply_norm(params["final_norm"], x, cfg), cache


def init_cache_encdec(cfg, batch: int, max_len: int, enc_len: int) -> dict:
    def one(_):
        return {"self": attn_mod.init_attn_cache(cfg, batch, max_len),
                "cross_k": jnp.zeros((batch, enc_len, cfg.n_kv_heads,
                                      cfg.head_dim), cfg.dtype("compute")),
                "cross_v": jnp.zeros((batch, enc_len, cfg.n_kv_heads,
                                      cfg.head_dim), cfg.dtype("compute"))}
    return {"dec": jax.vmap(one)(jnp.arange(cfg.n_layers)),
            "pos": jnp.zeros((), jnp.int32)}


def decode_step_encdec(params, token, cache, cfg):
    pos = cache["pos"]
    x = embed_tokens(params["embed"], token, cfg)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    def body(x, args):
        bp, c = args
        x = shard_ctx.constrain_batch(x)
        c = jax.lax.optimization_barrier(c)   # see transformer.decode_step
        h, self_cache = attn_mod.attn_decode(
            bp["self"], apply_norm(bp["ln1"], x, cfg), c["self"], pos, cfg)
        x = x + h
        h = attn_mod.attn_decode_cross(
            bp["cross"], apply_norm(bp["ln_x"], x, cfg),
            (c["cross_k"], c["cross_v"]), cfg)
        x = x + h
        x = x + mlp(bp["mlp"], apply_norm(bp["ln2"], x, cfg), cfg)
        return x, {"self": self_cache, "cross_k": c["cross_k"],
                   "cross_v": c["cross_v"]}

    x, new_caches = jax.lax.scan(body, x, (params["dec_blocks"], cache["dec"]))
    return (apply_norm(params["final_norm"], x, cfg),
            {"dec": new_caches, "pos": pos + 1})
