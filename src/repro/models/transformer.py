"""Decoder-only transformer assembly for all non-enc-dec archs.

Layers are grouped into *pattern periods* (e.g. recurrentgemma's
(rec, rec, local)) and scanned: params are stacked (n_periods, ...) so HLO
size and compile time are depth-independent; remainder layers (when the
pattern does not divide n_layers) run unrolled after the scan.

Three entry points with identical signatures across block types:
  forward_train  — full-sequence causal forward → final hidden states
  prefill        — forward + cache construction (inference)
  decode_step    — one token through all layers against the cache
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rec_mod
from repro.models import ssd as ssd_mod
from repro.models.layers import (apply_norm, embed_tokens, init_embed,
                                 init_mlp, init_norm, mlp)
from repro.sharding import ctx as shard_ctx


# --------------------------------------------------------------------------
# per-block init / forward / prefill / decode
# --------------------------------------------------------------------------
def init_block(key, cfg, btype: str) -> dict:
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    if btype in ("attn", "local"):
        return {"ln1": init_norm(cfg, d), "attn": attn_mod.init_attn(ks[0], cfg),
                "ln2": init_norm(cfg, d), "mlp": init_mlp(ks[1], cfg, d, cfg.d_ff)}
    if btype == "moe":
        return {"ln1": init_norm(cfg, d), "attn": attn_mod.init_attn(ks[0], cfg),
                "ln2": init_norm(cfg, d), "moe": moe_mod.init_moe(ks[1], cfg)}
    if btype == "rec":
        return {"ln1": init_norm(cfg, d), "rec": rec_mod.init_rec(ks[0], cfg),
                "ln2": init_norm(cfg, d), "mlp": init_mlp(ks[1], cfg, d, cfg.d_ff)}
    if btype == "ssd":
        return {"ln": init_norm(cfg, d), "ssd": ssd_mod.init_ssd(ks[0], cfg)}
    raise ValueError(f"unknown block type {btype!r}")


def block_forward(p, x, positions, cfg, btype: str):
    """→ (x, aux_loss)."""
    if btype in ("attn", "local"):
        window = cfg.window if btype == "local" else 0
        h, _ = attn_mod.attn_forward(p["attn"], apply_norm(p["ln1"], x, cfg),
                                     positions, cfg, window=window)
        x = x + h
        x = x + mlp(p["mlp"], apply_norm(p["ln2"], x, cfg), cfg)
        return x, jnp.zeros((), jnp.float32)
    if btype == "moe":
        h, _ = attn_mod.attn_forward(p["attn"], apply_norm(p["ln1"], x, cfg),
                                     positions, cfg)
        x = x + h
        h, aux = moe_mod.moe_ffn(p["moe"], apply_norm(p["ln2"], x, cfg), cfg)
        return x + h, aux
    if btype == "rec":
        h, _ = rec_mod.rec_forward(p["rec"], apply_norm(p["ln1"], x, cfg), cfg)
        x = x + h
        x = x + mlp(p["mlp"], apply_norm(p["ln2"], x, cfg), cfg)
        return x, jnp.zeros((), jnp.float32)
    if btype == "ssd":
        h, _ = ssd_mod.ssd_forward(p["ssd"], apply_norm(p["ln"], x, cfg), cfg)
        return x + h, jnp.zeros((), jnp.float32)
    raise ValueError(btype)


def init_block_cache(cfg, btype: str, batch: int, max_len: int):
    if btype == "attn":
        return attn_mod.init_attn_cache(cfg, batch, max_len)
    if btype == "local":
        return attn_mod.init_attn_cache(cfg, batch, max_len, window=cfg.window)
    if btype == "moe":
        return attn_mod.init_attn_cache(cfg, batch, max_len)
    if btype == "rec":
        return rec_mod.init_rec_cache(cfg, batch)
    if btype == "ssd":
        return ssd_mod.init_ssd_cache(cfg, batch)
    raise ValueError(btype)


def block_prefill(p, x, positions, cfg, btype: str, max_len: int):
    """→ (x, cache). Like forward but keeps the inference cache."""
    if btype in ("attn", "local", "moe"):
        window = cfg.window if btype == "local" else 0
        norm_x = apply_norm(p["ln1"], x, cfg)
        h, (k, v) = attn_mod.attn_forward(p["attn"], norm_x, positions, cfg,
                                          window=window)
        x = x + h
        cache = attn_mod.init_attn_cache(cfg, x.shape[0], max_len,
                                         window=window)
        cache = attn_mod.fill_cache_from_prefill(cache, k, v, window=window)
        if btype == "moe":
            h, _ = moe_mod.moe_ffn(p["moe"], apply_norm(p["ln2"], x, cfg), cfg)
        else:
            h = mlp(p["mlp"], apply_norm(p["ln2"], x, cfg), cfg)
        return x + h, cache
    if btype == "rec":
        h, (conv, h_last) = rec_mod.rec_forward(
            p["rec"], apply_norm(p["ln1"], x, cfg), cfg)
        x = x + h
        x = x + mlp(p["mlp"], apply_norm(p["ln2"], x, cfg), cfg)
        return x, {"conv": conv, "h": h_last}
    if btype == "ssd":
        h, cache = ssd_mod.ssd_forward(p["ssd"], apply_norm(p["ln"], x, cfg),
                                       cfg)
        return x + h, cache
    raise ValueError(btype)


def block_decode(p, x, cache, pos, cfg, btype: str):
    """→ (x, new_cache). x: (B, 1, D)."""
    if btype in ("attn", "local", "moe"):
        window = cfg.window if btype == "local" else 0
        h, cache = attn_mod.attn_decode(p["attn"], apply_norm(p["ln1"], x, cfg),
                                        cache, pos, cfg, window=window)
        x = x + h
        if btype == "moe":
            h, _ = moe_mod.moe_ffn(p["moe"], apply_norm(p["ln2"], x, cfg), cfg)
        else:
            h = mlp(p["mlp"], apply_norm(p["ln2"], x, cfg), cfg)
        return x + h, cache
    if btype == "rec":
        h, cache = rec_mod.rec_decode(p["rec"], apply_norm(p["ln1"], x, cfg),
                                      cache, cfg)
        x = x + h
        x = x + mlp(p["mlp"], apply_norm(p["ln2"], x, cfg), cfg)
        return x, cache
    if btype == "ssd":
        h, cache = ssd_mod.ssd_decode(p["ssd"], apply_norm(p["ln"], x, cfg),
                                      cache, cfg)
        return x + h, cache
    raise ValueError(btype)


# --------------------------------------------------------------------------
# stack layout: scanned periods + unrolled remainder
# --------------------------------------------------------------------------
def _layout(cfg):
    period = len(cfg.pattern)
    n_full = cfg.n_layers // period
    rem = cfg.pattern[: cfg.n_layers % period]
    return period, n_full, rem


def init_params(key, cfg) -> dict:
    """Full parameter pytree. Scanned block params are stacked (n_full, ...)."""
    period, n_full, rem = _layout(cfg)
    keys = jax.random.split(key, 4)

    def stack_one(pos):
        ks = jax.random.split(jax.random.fold_in(keys[0], pos), n_full)
        return jax.vmap(lambda k: init_block(k, cfg, cfg.pattern[pos]))(ks)

    params = {
        "embed": init_embed(keys[1], cfg),
        "blocks": tuple(stack_one(i) for i in range(period)),
        "rem": tuple(init_block(jax.random.fold_in(keys[2], i), cfg, t)
                     for i, t in enumerate(rem)),
        "final_norm": init_norm(cfg, cfg.d_model),
    }
    if cfg.frontend != "none":
        params["frontend"] = {
            "proj": (jax.random.normal(keys[3], (cfg.frontend_dim, cfg.d_model))
                     * cfg.frontend_dim ** -0.5).astype(cfg.dtype())}
    return params


def _embed_inputs(params, tokens, cfg, extra_embeds):
    x = embed_tokens(params["embed"], tokens, cfg)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.frontend != "none" and extra_embeds is not None:
        patches = jnp.einsum("bpf,fd->bpd",
                             extra_embeds.astype(cfg.dtype("compute")),
                             params["frontend"]["proj"])
        x = jnp.concatenate([patches, x], axis=1)
    return x


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)


def forward_train(params, tokens, cfg, extra_embeds=None):
    """→ (hidden (B,S,D), aux_loss). Loss/logits live in runtime (vocab-
    sharded logits are computed against the embedding there)."""
    x = _embed_inputs(params, tokens, cfg, extra_embeds)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                                 x.shape[:2])
    period, n_full, rem = _layout(cfg)

    seq_dim = 1 if cfg.seq_shard_activations else None

    def body(carry, layer_params):
        x, aux = carry
        # pin batch (and, under SP, the seq axis) on the residual stream:
        # the scan-saved carry inherits this sharding → 16× stash cut
        x = shard_ctx.constrain_batch(x, seq_dim=seq_dim)
        for btype, bp in zip(cfg.pattern, layer_params):
            x, a = block_forward(bp, x, positions, cfg, btype)
            aux = aux + a
        return (x, aux), None

    if n_full:
        (x, aux), _ = jax.lax.scan(_remat(body, cfg),
                                   (x, jnp.zeros((), jnp.float32)),
                                   params["blocks"])
    else:
        aux = jnp.zeros((), jnp.float32)
    for btype, bp in zip(rem, params["rem"]):
        x, a = block_forward(bp, x, positions, cfg, btype)
        aux = aux + a
    x = shard_ctx.constrain_batch(x)
    return apply_norm(params["final_norm"], x, cfg), aux


def prefill(params, tokens, cfg, extra_embeds=None, max_len: Optional[int] = None):
    """→ (hidden, cache). max_len: cache capacity (≥ prompt length)."""
    x = _embed_inputs(params, tokens, cfg, extra_embeds)
    max_len = max_len or x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                                 x.shape[:2])
    period, n_full, rem = _layout(cfg)

    def body(x, layer_params):
        x = shard_ctx.constrain_batch(x)
        caches = []
        for btype, bp in zip(cfg.pattern, layer_params):
            x, c = block_prefill(bp, x, positions, cfg, btype, max_len)
            caches.append(c)
        return x, tuple(caches)

    if n_full:
        x, scan_caches = jax.lax.scan(body, x, params["blocks"])
    else:
        scan_caches = ()
    rem_caches = []
    for btype, bp in zip(rem, params["rem"]):
        x, c = block_prefill(bp, x, positions, cfg, btype, max_len)
        rem_caches.append(c)
    cache = {"blocks": scan_caches, "rem": tuple(rem_caches),
             "pos": jnp.asarray(x.shape[1], jnp.int32)}
    return apply_norm(params["final_norm"], x, cfg), cache


def init_cache(cfg, batch: int, max_len: int) -> dict:
    """Empty cache (decode-from-scratch, or shape/sharding template)."""
    period, n_full, rem = _layout(cfg)

    def stacked(pos):
        return jax.vmap(
            lambda _: init_block_cache(cfg, cfg.pattern[pos], batch, max_len)
        )(jnp.arange(n_full))

    return {"blocks": tuple(stacked(i) for i in range(period)) if n_full else (),
            "rem": tuple(init_block_cache(cfg, t, batch, max_len) for t in rem),
            "pos": jnp.zeros((), jnp.int32)}


def decode_step(params, token, cache, cfg):
    """token: (B, 1) int32 → (hidden (B,1,D), new_cache)."""
    pos = cache["pos"]
    x = embed_tokens(params["embed"], token, cfg)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    period, n_full, rem = _layout(cfg)

    def body(x, args):
        layer_params, layer_cache = args
        x = shard_ctx.constrain_batch(x)
        # barrier: stops XLA from hoisting per-layer dtype converts of the
        # cache out of the scan (the CPU backend emulates bf16 dots in f32
        # and would otherwise materialize the WHOLE stacked cache in f32 —
        # 2× HBM; a host-compile artifact, absent on real TPUs, but the
        # barrier keeps the dry-run memory model honest either way)
        layer_cache = jax.lax.optimization_barrier(layer_cache)
        new_caches = []
        for btype, bp, c in zip(cfg.pattern, layer_params, layer_cache):
            x, nc = block_decode(bp, x, c, pos, cfg, btype)
            new_caches.append(nc)
        return x, tuple(new_caches)

    if n_full:
        x, scan_caches = jax.lax.scan(body, x,
                                      (params["blocks"], cache["blocks"]))
    else:
        scan_caches = ()
    rem_caches = []
    for btype, bp, c in zip(rem, params["rem"], cache["rem"]):
        x, nc = block_decode(bp, x, c, pos, cfg, btype)
        rem_caches.append(nc)
    new_cache = {"blocks": scan_caches, "rem": tuple(rem_caches),
                 "pos": pos + 1}
    return apply_norm(params["final_norm"], x, cfg), new_cache
