"""Mamba-2 SSD (state-space duality) mixer — arXiv:2405.21060.

DESIGN §Arch-applicability: SSD is the assigned arch where the paper's
insight applies *directly* — the chunked SSD algorithm replaces a
length-S sequential recurrence with cache/VMEM-sized blocked matmuls
(intra-chunk, MXU-friendly) plus an O(S/Q) inter-chunk recurrence: the
same "split into cache-sized portions and fuse" transformation the paper
applies to centering.

Chunked semantics (chunk length Q, fp32 state):
  dA_t   = Δ_t · A                                   (per head, A < 0)
  cs     = within-chunk cumsum of dA
  intra:  Y_i += Σ_{j≤i}  (C_i·B_j) · e^{cs_i−cs_j} · Δ_j · x_j
  state:  S_c  = Σ_j  e^{cs_Q−cs_j} · Δ_j · B_j ⊗ x_j
  inter:  h_c  = e^{cs_Q} h_{c−1} + S_c;   Y_i += (C_i·h_{c−1}) · e^{cs_i}
  out:    y = RMSNorm(Y ⊙ SiLU(z)) W_out + D ⊙ x
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm


def init_ssd(key, cfg) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    g, n, nh = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    w = cfg.conv_width
    dt = cfg.dtype()
    ks = jax.random.split(key, 8)
    conv_dim = di + 2 * g * n
    # Δ bias: softplus(bias) ∈ [1e-3, 1e-1] (mamba2 init)
    u = jax.random.uniform(ks[6], (nh,), minval=1e-3, maxval=1e-1)
    dt_bias = jnp.log(jnp.expm1(u))
    return {
        "w_x": (jax.random.normal(ks[0], (d, di)) * d ** -0.5).astype(dt),
        "w_z": (jax.random.normal(ks[1], (d, di)) * d ** -0.5).astype(dt),
        "w_b": (jax.random.normal(ks[2], (d, g * n)) * d ** -0.5).astype(dt),
        "w_c": (jax.random.normal(ks[3], (d, g * n)) * d ** -0.5).astype(dt),
        "w_dt": (jax.random.normal(ks[4], (d, nh)) * d ** -0.5).astype(dt),
        "dt_bias": dt_bias.astype(jnp.float32),
        "a_log": jnp.log(jax.random.uniform(ks[7], (nh,), minval=1.0,
                                            maxval=16.0)),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "conv_w": (jax.random.normal(ks[5], (w, conv_dim))
                   * w ** -0.5).astype(dt),
        "norm_w": jnp.zeros((di,), dt),
        "w_out": (jax.random.normal(jax.random.fold_in(key, 11), (di, d))
                  * di ** -0.5).astype(dt),
    }


def _conv_split(p, x, cfg, conv_state=None):
    """Shared projection + causal conv + split into (xh, B, C, z, dt)."""
    from repro.models.rglru import causal_conv
    di = cfg.d_inner
    g, n, nh = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    z = jnp.einsum("bsd,de->bse", x, p["w_z"])
    u = jnp.concatenate([
        jnp.einsum("bsd,de->bse", x, p["w_x"]),
        jnp.einsum("bsd,de->bse", x, p["w_b"]),
        jnp.einsum("bsd,de->bse", x, p["w_c"]),
    ], axis=-1)
    u, conv_state = causal_conv(u, p["conv_w"], conv_state)
    u = jax.nn.silu(u)
    xh = u[..., :di]
    b_ = u[..., di:di + g * n]
    c_ = u[..., di + g * n:]
    s = x.shape[1]
    xh = xh.reshape(*xh.shape[:2], nh, cfg.ssm_headdim)
    b_ = b_.reshape(*b_.shape[:2], g, n)
    c_ = c_.reshape(*c_.shape[:2], g, n)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["w_dt"]).astype(jnp.float32)
        + p["dt_bias"])
    return xh, b_, c_, z, dt, conv_state


def ssd_forward(p, x, cfg, cache=None):
    """Train/prefill. x: (B,S,D) → (out (B,S,D), cache)."""
    bsz, s, d = x.shape
    g, n, nh, hd = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    hpg = nh // g
    q = min(cfg.ssm_chunk, s)
    if s % q:
        q = s
    nc = s // q

    conv_state = cache["conv"] if cache else None
    h0 = cache["h"] if cache else None
    xh, b_, c_, z, dt, conv_state = _conv_split(p, x, cfg, conv_state)

    a = -jnp.exp(p["a_log"])                        # (nh,) fp32, negative
    da = dt * a                                     # (B,S,nh)

    # chunk views
    xc = xh.reshape(bsz, nc, q, g, hpg, hd).astype(jnp.float32)
    bc = b_.reshape(bsz, nc, q, g, n).astype(jnp.float32)
    cc = c_.reshape(bsz, nc, q, g, n).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, q, g, hpg)
    dac = da.reshape(bsz, nc, q, g, hpg)
    cs = jnp.cumsum(dac, axis=2)                    # (B,nc,Q,g,hpg)

    # ---- intra-chunk (blocked matmuls — the MXU-friendly form) ----
    scores = jnp.einsum("bzqgn,bzkgn->bzgqk", cc, bc)        # (B,nc,g,Q,Q)
    decay = jnp.exp(cs[:, :, :, None] - cs[:, :, None])      # (B,nc,Q,Q,g,hpg)
    causal = (jnp.arange(q)[:, None] >= jnp.arange(q)[None, :])
    w_intra = jnp.where(causal[None, None, :, :, None, None], decay, 0.0)
    w_intra = w_intra * dtc[:, :, None]                      # Δ_j at axis k
    y = jnp.einsum("bzgqk,bzqkgh,bzkghd->bzqghd", scores, w_intra, xc)

    # ---- chunk states ----
    w_state = jnp.exp(cs[:, :, -1:, :, :] - cs) * dtc        # (B,nc,Q,g,hpg)
    s_c = jnp.einsum("bzkgh,bzkgn,bzkghd->bzghnd", w_state, bc, xc)

    # ---- inter-chunk recurrence over nc chunks ----
    chunk_decay = jnp.exp(cs[:, :, -1])                      # (B,nc,g,hpg)
    if h0 is None:
        h0 = jnp.zeros((bsz, g, hpg, n, hd), jnp.float32)
    else:
        h0 = h0.reshape(bsz, g, hpg, n, hd).astype(jnp.float32)

    def step(h, args):
        dec, sc = args
        h_prev = h
        h = h * dec[..., None, None] + sc
        return h, h_prev

    h_last, h_prevs = jax.lax.scan(
        step, h0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(s_c, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                    # (B,nc,g,hpg,N,hd)

    y_inter = jnp.einsum("bzqgn,bzghnd->bzqghd", cc, h_prevs) \
        * jnp.exp(cs)[..., None]
    y = y + y_inter
    y = y.reshape(bsz, s, nh, hd) + p["d_skip"][None, None, :, None] \
        * xh.astype(jnp.float32)

    # gated RMSNorm + out projection
    y = y.reshape(bsz, s, cfg.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm_w"])
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    new_cache = {"conv": conv_state,
                 "h": h_last.reshape(bsz, nh, n, hd)}
    return out, new_cache


def init_ssd_cache(cfg, batch: int) -> dict:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim),
                          cfg.dtype("compute")),
        "h": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_state,
                        cfg.ssm_headdim), jnp.float32),
    }


def ssd_decode(p, x, cache, cfg):
    """One decode step — O(1) state update. x: (B,1,D)."""
    bsz = x.shape[0]
    g, n, nh, hd = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    hpg = nh // g
    xh, b_, c_, z, dt, conv_state = _conv_split(p, x, cfg, cache["conv"])

    a = -jnp.exp(p["a_log"])
    da = (dt * a)[:, 0]                                  # (B,nh)
    h = cache["h"].astype(jnp.float32)                   # (B,nh,N,hd)
    xf = xh[:, 0].astype(jnp.float32)                    # (B,nh,hd)
    bf = b_[:, 0].astype(jnp.float32)                    # (B,g,N)
    cf = c_[:, 0].astype(jnp.float32)
    dtf = dt[:, 0]                                       # (B,nh)

    # broadcast group-level B/C to head level (head h ↦ group h // hpg)
    bh = jnp.repeat(bf, hpg, axis=1)                     # (B,nh,N)
    ch = jnp.repeat(cf, hpg, axis=1)
    h = h * jnp.exp(da)[..., None, None] \
        + (dtf[..., None, None] * bh[..., None] * xf[:, :, None, :])
    y = jnp.einsum("bhn,bhnd->bhd", ch, h) \
        + p["d_skip"][None, :, None] * xf

    y = y.reshape(bsz, 1, cfg.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm_w"])
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, {"conv": conv_state, "h": h}
