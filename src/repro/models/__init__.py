"""LM substrate: pure-JAX model definitions for the 10 assigned archs.

Scan-over-layers keeps HLO size and compile time independent of depth;
every block type exposes the same three entry points (train forward,
prefill, single-token decode) so ``runtime/`` can drive any arch through
any assigned input shape.
"""

from repro.models.transformer import (
    init_params,
    forward_train,
    prefill,
    decode_step,
    init_cache,
)

__all__ = ["init_params", "forward_train", "prefill", "decode_step",
           "init_cache"]
