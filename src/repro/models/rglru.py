"""RG-LRU recurrent block (Griffin / recurrentgemma).

Block structure (Griffin Fig. 2): input → two linear branches —
(a) GeLU gate branch, (b) temporal-conv (width 4) → RG-LRU — multiplied
together → output projection.

RG-LRU (fp32 recurrence):
    r_t = σ(W_a u_t + b_a)                 recurrence gate
    i_t = σ(W_x u_t + b_x)                 input gate
    log a_t = -c · softplus(Λ) · r_t       (c = 8)
    h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ u_t)

Train/prefill uses ``lax.associative_scan`` over time — O(log S) depth,
the TPU-native parallel form of the recurrence (DESIGN §4: like SSD, a
blocked reformulation of a sequential loop — the paper's locality insight
applied to sequence mixing). Decode is a single fused step.

Deviation (DESIGN §Arch-applicability): gate weights W_a/W_x are dense
d_rnn×d_rnn (upstream recurrentgemma uses block-diagonal).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_C = 8.0


def init_rec(key, cfg) -> dict:
    d, r = cfg.d_model, cfg.lru_width_actual
    w = cfg.conv_width
    dt = cfg.dtype()
    ks = jax.random.split(key, 6)
    # Λ init so that a ∈ [0.9, 0.999] at r=1 (Griffin appendix)
    u = jax.random.uniform(ks[5], (r,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))   # softplus^{-1}(-log u / c)
    return {
        "w_gate_branch": (jax.random.normal(ks[0], (d, r)) * d ** -0.5).astype(dt),
        "w_rec_branch": (jax.random.normal(ks[1], (d, r)) * d ** -0.5).astype(dt),
        "conv_w": (jax.random.normal(ks[2], (w, r)) * w ** -0.5).astype(dt),
        "w_a": (jax.random.normal(ks[3], (r, r)) * r ** -0.5).astype(dt),
        "b_a": jnp.zeros((r,), dt),
        "w_x": (jax.random.normal(ks[4], (r, r)) * r ** -0.5).astype(dt),
        "b_x": jnp.zeros((r,), dt),
        "lambda": lam.astype(jnp.float32),
        "w_out": (jax.random.normal(jax.random.fold_in(key, 9), (r, d))
                  * r ** -0.5).astype(dt),
    }


def causal_conv(u: jax.Array, w: jax.Array, state=None):
    """Depthwise causal conv over time. u: (B,S,R); w: (W,R).
    state: (B, W-1, R) prior context (decode/chunk continuation) or None.
    Returns (out (B,S,R), new_state (B, W-1, R))."""
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((u.shape[0], width - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([state, u], axis=1)          # (B, S+W-1, R)
    out = sum(ext[:, i:i + u.shape[1]] * w[i] for i in range(width))
    new_state = ext[:, -(width - 1):] if width > 1 else state
    return out, new_state


def _rglru_coeffs(p, u, cfg):
    """a_t, b_t of the linear recurrence h_t = a_t h + b_t (fp32)."""
    uf = u.astype(jnp.float32)
    r_gate = jax.nn.sigmoid(uf @ p["w_a"].astype(jnp.float32)
                            + p["b_a"].astype(jnp.float32))
    i_gate = jax.nn.sigmoid(uf @ p["w_x"].astype(jnp.float32)
                            + p["b_x"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lambda"]) * r_gate
    a = jnp.exp(log_a)
    # √(1−a²) computed stably: 1−a² = -expm1(2 log a)
    b = jnp.sqrt(-jnp.expm1(2.0 * log_a)) * (i_gate * uf)
    return a, b


def _combine(l, r):
    al, bl = l
    ar, br = r
    return al * ar, ar * bl + br


_CHUNK = 512   # bounds the associative-scan tree the AD pass must save


def rglru_scan(p, u, cfg, h0=None):
    """u: (B,S,R) → (h (B,S,R), h_last (B,R)).

    Chunked parallel scan (the paper's blocked-pass discipline applied to
    the recurrence): a plain ``associative_scan`` over the full sequence
    makes reverse-mode AD save its log₂(S)-deep combine tree —
    ~12 × (B,S,R) fp32 per layer at 4k (observed 20 GB/device on the
    dry-run). Chunking to 512 runs the log-tree inside VMEM-scale chunks
    and carries only (B,R) between chunks; identical math (the carried
    state folds into each chunk's first offset)."""
    bsz, s, r = u.shape
    a, b = _rglru_coeffs(p, u, cfg)
    if h0 is not None:
        # fold the carried state into the first step's offset
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    q = _CHUNK if (s % _CHUNK == 0 and s > _CHUNK) else s
    if q == s:
        _, h = jax.lax.associative_scan(_combine, (a, b), axis=1)
        return h.astype(u.dtype), h[:, -1]

    nc = s // q
    ac = jnp.moveaxis(a.reshape(bsz, nc, q, r), 1, 0)
    bc = jnp.moveaxis(b.reshape(bsz, nc, q, r), 1, 0)

    def chunk(h, args):
        ai, bi = args
        bi = bi.at[:, 0].add(ai[:, 0] * h)
        _, hi = jax.lax.associative_scan(_combine, (ai, bi), axis=1)
        return hi[:, -1], hi

    h_last, hs = jax.lax.scan(chunk, jnp.zeros((bsz, r), jnp.float32),
                              (ac, bc))
    h = jnp.moveaxis(hs, 0, 1).reshape(bsz, s, r)
    return h.astype(u.dtype), h_last


def rglru_step(p, u, h, cfg):
    """One decode step. u: (B,1,R); h: (B,R) fp32 carried state."""
    a, b = _rglru_coeffs(p, u, cfg)
    h_new = a[:, 0] * h + b[:, 0]
    return h_new.astype(u.dtype)[:, None], h_new


# --------------------------------------------------------------------------
# full recurrent block
# --------------------------------------------------------------------------
def rec_forward(p, x, cfg, conv_state=None, h0=None):
    """Train/prefill. x: (B,S,D) → (out, (conv_state, h_last))."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, p["w_gate_branch"]),
                       approximate=True)
    u = jnp.einsum("bsd,dr->bsr", x, p["w_rec_branch"])
    u, conv_state = causal_conv(u, p["conv_w"], conv_state)
    h, h_last = rglru_scan(p, u, cfg, h0)
    out = jnp.einsum("bsr,rd->bsd", gate * h, p["w_out"])
    return out, (conv_state, h_last)


def init_rec_cache(cfg, batch: int) -> dict:
    r = cfg.lru_width_actual
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, r), cfg.dtype("compute")),
        "h": jnp.zeros((batch, r), jnp.float32),
    }


def rec_decode(p, x, cache, cfg):
    """One decode step. x: (B,1,D)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, p["w_gate_branch"]),
                       approximate=True)
    u = jnp.einsum("bsd,dr->bsr", x, p["w_rec_branch"])
    u, conv_state = causal_conv(u, p["conv_w"], cache["conv"])
    h_seq, h = rglru_step(p, u, cache["h"], cfg)
    out = jnp.einsum("bsr,rd->bsd", gate * h_seq, p["w_out"])
    return out, {"conv": conv_state, "h": h}
