"""Mixture-of-Experts FFN: top-k routing with per-chunk capacity
(GShard-style dispatch/combine einsums — the GSPMD-partitionable form).

Memory discipline (DESIGN §4): the dispatch tensor is the MoE analogue of
the paper's "large intermediate buffer" — it is never materialized for
the full sequence. The sequence is processed in ``cfg.moe_chunk`` chunks
(a lax.scan), bounding the live dispatch tensor to
(B, chunk, E, capacity) exactly like the paper bounds its working set to
cache-sized tiles. Capacity is per (batch-row, chunk): tokens beyond an
expert's capacity in a chunk are dropped (standard Switch semantics).

Sharding: experts → 'model' when E divides the axis (granite, 32e), else
the expert FFN hidden dim → 'model' (grok, 8e) — rules in sharding/rules.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_moe(key, cfg) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = cfg.dtype()
    ks = jax.random.split(key, 4)
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * d ** -0.5).astype(jnp.float32),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) * d ** -0.5).astype(dt),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) * f ** -0.5).astype(dt),
    }
    if cfg.mlp_act != "sq_relu":
        p["w_gate"] = (jax.random.normal(ks[1], (e, d, f)) * d ** -0.5).astype(dt)
    return p


def _capacity(cfg, chunk: int) -> int:
    cap = int(chunk * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(cap, cfg.top_k)


def _expert_ffn(p, x, cfg):
    """x: (E, ..., D) → (E, ..., D), batched over the expert dim."""
    if cfg.mlp_act == "sq_relu":
        h = jnp.square(jax.nn.relu(jnp.einsum("e...d,edf->e...f", x, p["w_up"])))
    else:
        g = jnp.einsum("e...d,edf->e...f", x, p["w_gate"])
        u = jnp.einsum("e...d,edf->e...f", x, p["w_up"])
        act = jax.nn.silu if cfg.mlp_act == "silu_glu" else \
            (lambda v: jax.nn.gelu(v, approximate=True))
        h = act(g) * u
    return jnp.einsum("e...f,efd->e...d", h, p["w_down"])


def _moe_chunk(p, x, cfg):
    """x: (B, C, D) one sequence chunk → (B, C, D), plus aux loss stats."""
    b, c, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(cfg, c)

    logits = jnp.einsum("bcd,de->bce", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, ids = jax.lax.top_k(probs, k)                   # (B, C, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # position-in-expert within (batch-row, chunk): running count over (C, k)
    oh = jax.nn.one_hot(ids, e, dtype=jnp.int32)               # (B, C, k, E)
    flat = oh.reshape(b, c * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                      # tokens ahead
    pos = jnp.sum(pos.reshape(b, c, k, e) * oh, axis=-1)       # (B, C, k)
    keep = pos < cap

    # dispatch/combine (B, C, E, cap) — the chunk-bounded buffer.
    # slot[b,c,k,e,x] = 1 iff token (b,c) routes its k-th choice to expert e
    # at capacity slot x (and survived the capacity cut).
    slot = (oh[..., None].astype(cfg.dtype("compute"))          # (B,C,k,E,1)
            * jax.nn.one_hot(pos, cap, dtype=cfg.dtype("compute"))[..., None, :])
    slot = jnp.where(keep[..., None, None], slot, 0.0)          # (B,C,k,E,cap)
    disp = jnp.sum(slot, axis=2)                                # (B,C,E,cap)
    comb = jnp.sum(slot * gate_vals.astype(cfg.dtype("compute"))[..., None, None],
                   axis=2)                                      # (B,C,E,cap)

    xin = jnp.einsum("bcex,bcd->bexd", disp, x)                 # (B,E,cap,D)
    xin = jnp.swapaxes(xin, 0, 1)                               # (E,B,cap,D)
    out = _expert_ffn(p, xin, cfg)                             # (E,B,cap,D)
    out = jnp.swapaxes(out, 0, 1)                              # (B,E,cap,D)
    y = jnp.einsum("bcex,bexd->bcd", comb, out)

    # load-balance aux loss (Switch): E * Σ_e fraction_e * prob_e
    frac = jnp.mean(jnp.sum(oh[:, :, 0, :], axis=1).astype(jnp.float32)
                    / c, axis=0)                               # top-1 fraction
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac * mean_prob)
    return y, aux


def moe_ffn(p, x, cfg):
    """x: (B, S, D) → (B, S, D). Scans sequence chunks to bound dispatch
    memory (paper's tiling rule)."""
    b, s, d = x.shape
    chunk = min(cfg.moe_chunk, s)
    if s % chunk:
        chunk = s                                   # smoke shapes
    nc = s // chunk
    if nc == 1:
        y, aux = _moe_chunk(p, x, cfg)
        return y, aux

    xc = jnp.moveaxis(x.reshape(b, nc, chunk, d), 1, 0)

    def body(_, xi):
        y, aux = _moe_chunk(p, xi, cfg)
        return None, (y, aux)

    _, (yc, auxes) = jax.lax.scan(body, None, xc)
    return jnp.moveaxis(yc, 0, 1).reshape(b, s, d), jnp.mean(auxes)
