"""Attention: GQA/MQA/MHA with RoPE, qk-norm, QKV bias, logit softcap,
sliding windows, cross-attention — covering every assigned arch's variant.

Memory discipline (the paper's rule, applied to the S×S intermediate):

* ``train`` at moderate S uses one fused masked attention (XLA keeps the
  fp32 scores transient; remat recomputes them in backward);
* long-S paths (``prefill_32k``) never materialize S×S — a scan over query
  chunks bounds the live scores buffer to (chunk, S), the direct analogue
  of the paper's "split the problem into cache-sized portions";
* ``decode`` is a single fused dot over the cache; the cache's sequence
  axis is sharded over the TP axis (flash-decoding style — the softmax
  reductions over the sharded axis become two tiny all-reduces, DESIGN §5).
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, rmsnorm

_NEG_INF = -1e30


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------
def init_attn(key, cfg) -> dict:
    d, h, k_, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.dtype()
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, h, hd)) * s).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, k_, hd)) * s).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, k_, hd)) * s).astype(dt),
        "wo": (jax.random.normal(ks[3], (h, hd, d)) * (h * hd) ** -0.5).astype(dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dt)
        p["bk"] = jnp.zeros((k_, hd), dt)
        p["bv"] = jnp.zeros((k_, hd), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dt)
        p["k_norm"] = jnp.zeros((hd,), dt)
    return p


# --------------------------------------------------------------------------
# projections
# --------------------------------------------------------------------------
def _project_q(p, x, positions, cfg):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
    if positions is not None:          # cross-attention queries carry no rope
        q = apply_rope(q, positions, cfg.rope_theta)
    return q


def _project_kv(p, x, positions, cfg):
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        k = rmsnorm(k, p["k_norm"])
    if positions is not None:           # cross-attention keys carry no rope
        k = apply_rope(k, positions, cfg.rope_theta)
    return k, v


# --------------------------------------------------------------------------
# core scores → output (GQA grouping, softcap, fp32 softmax)
# --------------------------------------------------------------------------
def _attend(q, k, v, mask, cfg):
    """q: (B,Sq,H,hd); k,v: (B,Skv,K,hd); mask: broadcastable (B,1,Sq,Skv)
    boolean (True = attend) or None.

    GQA is computed by expanding K/V to the full head count (a repeat)
    rather than the (K, G)-grouped einsum: reshaping a TP-sharded head
    axis into (K, G) forces GSPMD into involuntary resharding (verified
    on the dry-run — 50 GB/device of replicated transients); the repeat
    keeps every tensor sharded on one clean head axis. The expanded K/V
    transient is (B, S, H, hd)/|mesh| per layer — VMEM-scale after
    sharding (EXPERIMENTS §Perf, iteration 0)."""
    b, sq, h, hd = q.shape
    n_kv = k.shape[2]
    if n_kv != h and sq == 1:
        # decode: heads are NOT TP-sharded (the cache's seq axis is), so
        # the grouped einsum is shard-safe here and avoids materializing
        # the G×-expanded K/V against the whole cache (nemotron: 12×).
        g = h // n_kv
        qg = q.reshape(b, sq, n_kv, g, hd)
        scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
        scores = scores * (hd ** -0.5)
        if cfg.attn_softcap:
            scores = cfg.attn_softcap * jnp.tanh(scores / cfg.attn_softcap)
        if mask is not None:
            scores = jnp.where(mask[:, :, None], scores, _NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
        return out.reshape(b, sq, h, hd)
    if n_kv != h:
        g = h // n_kv
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bshk,bthk->bhst", q, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    if cfg.attn_softcap:
        scores = cfg.attn_softcap * jnp.tanh(scores / cfg.attn_softcap)
    if mask is not None:
        scores = jnp.where(mask, scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhst,bthk->bshk", probs, v)
    return out


def _causal_mask(sq: int, skv: int, offset: int = 0, window: int = 0):
    """(1, 1, sq, skv) boolean; query i attends key j iff
    j <= i+offset and (window == 0 or j > i+offset-window)."""
    qi = jnp.arange(sq)[:, None] + offset
    kj = jnp.arange(skv)[None, :]
    m = kj <= qi
    if window:
        m = m & (kj > qi - window)
    return m[None, None]


# --------------------------------------------------------------------------
# train / prefill forward
# --------------------------------------------------------------------------
def attn_forward(p, x, positions, cfg, *, causal: bool = True,
                 window: int = 0, kv_x: Optional[jax.Array] = None,
                 kv_positions=None):
    """Full attention over the sequence. Self-attention when kv_x is None.
    Chunks queries when the S×S buffer would exceed the VMEM-scale budget
    (the paper's tiling rule)."""
    q = _project_q(p, x, positions, cfg)
    if kv_x is None:
        k, v = _project_kv(p, x, positions, cfg)
    else:
        k, v = _project_kv(p, kv_x, kv_positions, cfg)
    sq, skv = q.shape[1], k.shape[1]

    chunk = cfg.attn_chunk
    if sq <= max(chunk, 2048):
        mask = _causal_mask(sq, skv, window=window) if causal else None
        out = _attend(q, k, v, mask, cfg)
    else:
        # scan over query chunks: live scores buffer is (chunk, skv)
        nc = sq // chunk
        qc = q.reshape(q.shape[0], nc, chunk, *q.shape[2:])
        qc = jnp.moveaxis(qc, 1, 0)                     # (nc, B, chunk, H, hd)

        def one_chunk(carry, args):
            ci, qi = args
            mask = (_causal_mask(chunk, skv, offset=ci * chunk, window=window)
                    if causal else None)
            return carry, _attend(qi, k, v, mask, cfg)

        _, oc = jax.lax.scan(one_chunk, None, (jnp.arange(nc), qc))
        out = jnp.moveaxis(oc, 0, 1).reshape(q.shape[0], sq, *q.shape[2:])
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), (k, v)


# --------------------------------------------------------------------------
# caches (optionally int8-quantized: §Perf Cell B's "next lever" — halves
# the decode memory floor; per-(b,t,head) symmetric scales, dequantized at
# read; a TPU deployment would fuse the dequant into the attention kernel)
# --------------------------------------------------------------------------
def _quantize_kv(x: jax.Array):
    """x: (B, S, K, hd) → (int8 values, f32 scales (B, S, K))."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1),
                        1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def init_attn_cache(cfg, batch: int, max_len: int, window: int = 0) -> dict:
    """window > 0 → ring buffer of size window (local attention)."""
    size = min(window, max_len) if window else max_len
    dt = cfg.dtype("compute")
    shape = (batch, size, cfg.n_kv_heads, cfg.head_dim)
    if cfg.kv_quant:
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:3], jnp.float32),
            "v_scale": jnp.zeros(shape[:3], jnp.float32),
            "pos": jnp.full((size,), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros(shape, dt),
        "v": jnp.zeros(shape, dt),
        "pos": jnp.full((size,), -1, jnp.int32),   # global position per slot
    }


def fill_cache_from_prefill(cache: dict, k: jax.Array, v: jax.Array,
                            window: int = 0) -> dict:
    """Store prefill K/V into the (possibly ring, possibly int8) cache."""
    s = k.shape[1]
    size = cache["k"].shape[1]
    quant = "k_scale" in cache
    if window and s > size:
        k, v = k[:, -size:], v[:, -size:]
        pos = jnp.arange(s - size, s, dtype=jnp.int32)
        slot = pos % size                      # ring layout
        order = jnp.argsort(slot)
        out = dict(cache)
        if quant:
            kq, ks = _quantize_kv(k[:, order])
            vq, vs = _quantize_kv(v[:, order])
            out.update(k=cache["k"].at[:, slot[order]].set(kq),
                       v=cache["v"].at[:, slot[order]].set(vq),
                       k_scale=cache["k_scale"].at[:, slot[order]].set(ks),
                       v_scale=cache["v_scale"].at[:, slot[order]].set(vs))
        else:
            out.update(k=cache["k"].at[:, slot[order]].set(k[:, order]),
                       v=cache["v"].at[:, slot[order]].set(v[:, order]))
        out["pos"] = cache["pos"].at[slot[order]].set(pos[order])
        return out
    pos = jnp.arange(s, dtype=jnp.int32)
    out = dict(cache)
    if quant:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        out.update(
            k=jax.lax.dynamic_update_slice(cache["k"], kq, (0, 0, 0, 0)),
            v=jax.lax.dynamic_update_slice(cache["v"], vq, (0, 0, 0, 0)),
            k_scale=jax.lax.dynamic_update_slice(cache["k_scale"], ks,
                                                 (0, 0, 0)),
            v_scale=jax.lax.dynamic_update_slice(cache["v_scale"], vs,
                                                 (0, 0, 0)))
    else:
        out.update(
            k=jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0)),
            v=jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0)))
    out["pos"] = cache["pos"].at[:s].set(pos)
    return out


# --------------------------------------------------------------------------
# decode: one token against the cache
# --------------------------------------------------------------------------
def attn_decode(p, x, cache, pos, cfg, *, window: int = 0):
    """x: (B, 1, D); pos: scalar int32 (position of the new token).
    Returns (out (B,1,D), new_cache)."""
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q = _project_q(p, x, positions, cfg)
    k_new, v_new = _project_kv(p, x, positions, cfg)

    size = cache["k"].shape[1]
    slot = (pos % size) if window else pos
    quant = "k_scale" in cache
    new_cache_extra = {}
    if quant:
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        k_store = jax.lax.dynamic_update_slice(cache["k"], kq, (0, slot, 0, 0))
        v_store = jax.lax.dynamic_update_slice(cache["v"], vq, (0, slot, 0, 0))
        k_sc = jax.lax.dynamic_update_slice(cache["k_scale"], ks, (0, slot, 0))
        v_sc = jax.lax.dynamic_update_slice(cache["v_scale"], vs, (0, slot, 0))
        k = _dequantize_kv(k_store, k_sc, x.dtype)
        v = _dequantize_kv(v_store, v_sc, x.dtype)
        new_cache_extra = {"k_scale": k_sc, "v_scale": v_sc}
    else:
        k_store = k = jax.lax.dynamic_update_slice(cache["k"], k_new,
                                                   (0, slot, 0, 0))
        v_store = v = jax.lax.dynamic_update_slice(cache["v"], v_new,
                                                   (0, slot, 0, 0))
    cpos = jax.lax.dynamic_update_slice(cache["pos"],
                                        jnp.full((1,), pos, jnp.int32), (slot,))

    valid = (cpos >= 0) & (cpos <= pos)
    if window:
        valid = valid & (cpos > pos - window)
    mask = valid[None, None, None, :]          # (1,1,1,size)
    # barrier between the cache WRITE (bf16, becomes the scan carry) and
    # the attention READ: the CPU backend emulates bf16 dots in f32, and
    # without the barrier XLA promotes the whole cache carry to f32 —
    # doubling the dominant memory term (host-compile artifact; on TPU
    # the MXU reads bf16 natively and the barrier is a no-op).
    k_read, v_read = jax.lax.optimization_barrier((k, v))
    out = _attend(q, k_read, v_read, mask, cfg)
    new_cache = {"k": k_store, "v": v_store, "pos": cpos, **new_cache_extra}
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


def attn_decode_cross(p, x, cross_kv, cfg):
    """Cross-attention decode: static precomputed encoder K/V (no rope)."""
    q = _project_q(p, x, None, cfg)
    k, v = cross_kv
    out = _attend(q, k, v, None, cfg)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])
