"""Shared layers: norms, rotary embeddings, MLP variants, embedding/head.

Numerics follow production practice: bf16 params/activations with fp32
norm statistics, fp32 softmax/logsumexp, fp32 rotary. The fused-RMSNorm
Pallas kernel (repro.kernels.rmsnorm) is the TPU-target twin of
``rmsnorm`` below; models call the pure-jnp version so the same code
lowers on the TPU-less dry-run host (DESIGN §7).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """fp32 statistics, bf16 data path.

    The activations are deliberately NOT upcast wholesale: converting the
    full (B,S,D) residual to f32 lets XLA hoist the convert into the
    layer-scan's saved carry (observed: an extra f32[L,B,S,D] stash,
    +9.7 GB/device on qwen3 train_4k — EXPERIMENTS §Perf iteration 1).
    Only the O(B·S) statistic is f32; the scale is cast back before the
    multiply, keeping every saved tensor bf16."""
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + eps).astype(x.dtype)
    return (x * inv) * (1.0 + w).astype(x.dtype)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array,
              eps: float = 1e-6) -> jax.Array:
    """Same bf16-pure data path as rmsnorm (f32 statistics only, one-pass
    moments so no f32 copy of x survives to the scan carry)."""
    mu = jnp.mean(x.astype(jnp.float32), axis=-1, keepdims=True)
    ex2 = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    var = jnp.maximum(ex2 - jnp.square(mu), 0.0)
    y = (x - mu.astype(x.dtype)) * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return y * w.astype(x.dtype) + b.astype(x.dtype)


def apply_norm(params: dict, x: jax.Array, cfg) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(x, params["w"], params["b"])
    return rmsnorm(x, params["w"])


def init_norm(cfg, d: int) -> dict:
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((d,), cfg.dtype()), "b": jnp.zeros((d,), cfg.dtype())}
    return {"w": jnp.zeros((d,), cfg.dtype())}  # '1+w' convention


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S) int32. fp32 rotation."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLP variants
# --------------------------------------------------------------------------
def init_mlp(key, cfg, d: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    dt = cfg.dtype()
    scale_in = d ** -0.5
    scale_out = d_ff ** -0.5
    if cfg.mlp_act == "sq_relu":
        return {
            "w_up": (jax.random.normal(k1, (d, d_ff)) * scale_in).astype(dt),
            "w_down": (jax.random.normal(k3, (d_ff, d)) * scale_out).astype(dt),
        }
    return {
        "w_gate": (jax.random.normal(k1, (d, d_ff)) * scale_in).astype(dt),
        "w_up": (jax.random.normal(k2, (d, d_ff)) * scale_in).astype(dt),
        "w_down": (jax.random.normal(k3, (d_ff, d)) * scale_out).astype(dt),
    }


def mlp(params: dict, x: jax.Array, cfg) -> jax.Array:
    if cfg.mlp_act == "sq_relu":
        h = jnp.einsum("...d,df->...f", x, params["w_up"])
        h = jnp.square(jax.nn.relu(h))
    else:
        g = jnp.einsum("...d,df->...f", x, params["w_gate"])
        u = jnp.einsum("...d,df->...f", x, params["w_up"])
        act = jax.nn.silu if cfg.mlp_act == "silu_glu" else \
            (lambda v: jax.nn.gelu(v, approximate=True))
        h = act(g) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


# --------------------------------------------------------------------------
# Embedding / LM head — one-hot einsum so a vocab-sharded table partitions
# without an all-gather (the production TPU pattern; DESIGN §5)
# --------------------------------------------------------------------------
def init_embed(key, cfg) -> dict:
    dt = cfg.dtype()
    p = {"table": (jax.random.normal(key, (cfg.vocab, cfg.d_model))
                   * cfg.d_model ** -0.5).astype(dt)}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["head"] = (jax.random.normal(k2, (cfg.vocab, cfg.d_model))
                     * cfg.d_model ** -0.5).astype(dt)
    return p


def embed_tokens(params: dict, tokens: jax.Array, cfg) -> jax.Array:
    """tokens (B, S) int32 → (B, S, D). one-hot@table partitions over a
    vocab-sharded table with a psum instead of an all-gathered table.

    The one-hot's vocab axis MUST be pinned to the TP axis: left to
    propagation it stays unsharded and GSPMD all-gathers the full table
    (9.4 GB bf16 for nemotron) and emits full-size (V, D) fp32 table
    grads in backward (18.9 GB/device — EXPERIMENTS §Perf iteration 2)."""
    from repro.sharding import ctx as shard_ctx
    one_hot = jax.nn.one_hot(tokens, cfg.vocab, dtype=cfg.dtype("compute"))
    one_hot = shard_ctx.constrain(one_hot, "dp", None, "tp")
    return jnp.einsum("bsv,vd->bsd", one_hot, params["table"])


def lm_logits(params: dict, x: jax.Array, cfg) -> jax.Array:
    table = params["table"] if cfg.tie_embeddings else params["head"]
    return jnp.einsum("bsd,vd->bsv", x, table)
