"""Serving step builders: batched prefill and single-token decode.

``decode_step`` is the unit the ``decode_32k``/``long_500k`` dry-run
cells lower: one new token against a seq_len-deep KV cache. Cache
sharding follows sharding/rules (sequence over the TP axis for deep
full-attention caches — flash-decoding; head/state channels for
local/recurrent/SSD caches).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import encdec as encdec_mod
from repro.models import transformer as tf_mod
from repro.models.layers import lm_logits
from repro.sharding.rules import (ShardingRules, batch_spec, cache_specs,
                                  named, param_specs)


def build_prefill_fn(cfg, max_len: int, rules: Optional[ShardingRules] = None):
    def prefill_step(params, batch):
        from repro.sharding import ctx as shard_ctx
        with shard_ctx.use_rules(rules):
            return _prefill_inner(params, batch)

    def _prefill_inner(params, batch):
        if cfg.is_encdec:
            hidden, cache = encdec_mod.prefill_encdec(
                params, batch["frames"], batch["tokens"], cfg,
                max_len=max_len)
        elif cfg.frontend == "vision":
            hidden, cache = tf_mod.prefill(params, batch["tokens"], cfg,
                                           extra_embeds=batch["patches"],
                                           max_len=max_len)
        else:
            hidden, cache = tf_mod.prefill(params, batch["tokens"], cfg,
                                           max_len=max_len)
        # only the last position's logits are needed to start decoding
        logits = lm_logits(params["embed"], hidden[:, -1:], cfg)
        return logits, cache

    return prefill_step


def build_decode_fn(cfg, rules: Optional[ShardingRules] = None):
    def decode_step(params, token, cache):
        from repro.sharding import ctx as shard_ctx
        with shard_ctx.use_rules(rules):
            return _decode_inner(params, token, cache)

    def _decode_inner(params, token, cache):
        if cfg.is_encdec:
            hidden, cache = encdec_mod.decode_step_encdec(params, token,
                                                          cache, cfg)
        else:
            hidden, cache = tf_mod.decode_step(params, token, cache, cfg)
        logits = lm_logits(params["embed"], hidden, cfg)
        return logits, cache

    return decode_step


def abstract_cache(cfg, batch: int, max_len: int, enc_len: int = 0):
    if cfg.is_encdec:
        return jax.eval_shape(
            lambda: encdec_mod.init_cache_encdec(cfg, batch, max_len,
                                                 enc_len))
    return jax.eval_shape(lambda: tf_mod.init_cache(cfg, batch, max_len))


def make_prefill_step(cfg, mesh, rules: ShardingRules, params_tree,
                      batch_tree, max_len: int):
    fn = build_prefill_fn(cfg, max_len, rules)
    p_specs = param_specs(cfg, params_tree, rules)
    b_specs = jax.tree_util.tree_map_with_path(
        lambda path, leaf: batch_spec(rules, leaf.shape[0],
                                      rank=len(leaf.shape)), batch_tree)
    # out_shardings matter: without them the returned KV cache settles
    # batch-only sharded (26.8 GB/device for qwen1.5 prefill_32k instead
    # of 1.7 GB with the seq axis on the TP axis).
    bsz = jax.tree.leaves(batch_tree)[0].shape[0]
    _, cache_shape = jax.eval_shape(fn, params_tree, batch_tree)
    c_specs = cache_specs(cfg, cache_shape, rules)
    from jax.sharding import PartitionSpec as P
    logits_sp = batch_spec(rules, bsz, rank=3)
    return jax.jit(fn,
                   in_shardings=(named(mesh, p_specs), named(mesh, b_specs)),
                   out_shardings=(named(mesh, logits_sp),
                                  named(mesh, c_specs)))


def make_decode_step(cfg, mesh, rules: ShardingRules, params_tree,
                     cache_tree):
    fn = build_decode_fn(cfg, rules)
    p_specs = param_specs(cfg, params_tree, rules)
    c_specs = cache_specs(cfg, cache_tree, rules)
    bsz = _cache_batch(cache_tree)
    tok_spec = batch_spec(rules, bsz, rank=2)
    logits_sp = batch_spec(rules, bsz, rank=3)
    return jax.jit(
        fn,
        in_shardings=(named(mesh, p_specs), named(mesh, tok_spec),
                      named(mesh, c_specs)),
        out_shardings=(named(mesh, logits_sp), named(mesh, c_specs)),
        donate_argnums=(2,),
    )


def _cache_batch(cache_tree) -> int:
    for path, leaf in jax.tree_util.tree_flatten_with_path(cache_tree)[0]:
        if len(leaf.shape) >= 2 and leaf.shape[0] != 0:
            names = [getattr(e, "key", None) for e in path]
            if "pos" not in names:
                # stacked leaves: (L, B, ...); unstacked: (B, ...)
                keys = [str(getattr(e, "key", getattr(e, "idx", ""))) for e in path]
                if any(k in ("blocks", "dec") for k in keys):
                    return leaf.shape[1]
                return leaf.shape[0]
    raise ValueError("could not infer batch from cache tree")
