"""Straggler detection and step-time accounting.

At 1000+ nodes the dominant availability hazards are (a) hosts that die
(handled by checkpoint/restart + elastic re-shard) and (b) hosts that
*slow down* — stragglers stretch every synchronous collective. This
monitor implements the detection half that any TPU-pod runner needs:

* rolling median step time with MAD-based outlier flagging
  (``threshold = median · k``);
* a deadline watchdog: a callable heartbeat that raises after
  ``deadline_factor × median`` so the launcher can checkpoint + evict
  (the eviction itself is the cluster scheduler's job);
* per-step records exportable for the perf logs.

Since the ``repro.obs`` subsystem the monitor is refolded on the span
stream: every step is a ``phase="step"`` span on an ``obs.trace.Tracer``
(the monitor's own by default, or a shared session tracer passed in),
so step timings ride the same export surface as the analysis spans —
JSON, Chrome ``trace_event``, ``Tracer.total("step")`` — and the
``StepRecord`` view is derived from the spans, not stored beside them.

The same watchdog covers serving: ``repro.serve``'s tile scheduler
times every permutation-tile execution through a ``StepMonitor``
(``start()``/``stop()`` per tile), and the front door calls
``heartbeat()`` between tiles so a stalled tile — one that began but
never reached ``stop()`` — trips the deadline instead of hanging the
serve loop silently.

tests/test_runtime.py injects synthetic delays to verify flagging.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import List, Optional

from repro.obs.trace import Span, Tracer


@dataclasses.dataclass
class StepRecord:
    step: int
    seconds: float
    straggler: bool


@dataclasses.dataclass(frozen=True)
class EscalationRecord:
    """One watchdog escalation: the structured record the serve retry
    path consumes (instead of parsing a ``TimeoutError`` message).

    ``elapsed_s`` is how long the offending step had been open,
    ``deadline_s``/``median_s`` the watchdog state at escalation time,
    ``reason`` the trigger, ``aborted_open_step`` whether an open step
    span was force-closed as part of the escalation.
    """

    elapsed_s: float
    deadline_s: float
    median_s: float
    reason: str
    aborted_open_step: bool

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class DeadlineExceeded(TimeoutError):
    """``check_deadline``'s raise, now carrying the structured
    :class:`EscalationRecord` (``.record``) so the caller's recovery
    path consumes data, not a message string. Subclasses
    ``TimeoutError`` — existing ``except TimeoutError`` callers keep
    working unchanged."""

    def __init__(self, message: str, record: EscalationRecord):
        super().__init__(message)
        self.record = record


class StepMonitor:
    """Step timer + straggler flagger over a span stream.

    ``tracer`` defaults to a private ``Tracer``; pass a session's tracer
    (e.g. ``workspace.obs.tracer``) to interleave step spans with the
    analysis spans in one exported timeline.
    """

    def __init__(self, k: float = 3.0, warmup: int = 3,
                 deadline_factor: float = 10.0,
                 tracer: Optional[Tracer] = None):
        self.k = k
        self.warmup = warmup
        self.deadline_factor = deadline_factor
        self.tracer = tracer if tracer is not None else Tracer()
        self._spans: List[Span] = []         # this monitor's step spans
        self._open: Optional[Span] = None
        self.escalations: List[EscalationRecord] = []

    # -- timing ---------------------------------------------------------
    def start(self):
        self._open = self.tracer.span("step", phase="step").begin()

    def stop(self, step: int) -> StepRecord:
        if self._open is None:
            raise RuntimeError(
                "StepMonitor.stop() called before start() — call start() "
                "at the top of the step (or use record(step, seconds) "
                "for pre-measured durations)")
        span = self._open.end()
        self._open = None
        return self._flag(span, step)

    def record(self, step: int, seconds: float) -> StepRecord:
        """Append a pre-measured step (the caller timed it itself)."""
        return self._flag(
            self.tracer.record("step", seconds, phase="step"), step)

    def _flag(self, span: Span, step: int) -> StepRecord:
        base = [s.duration for s in self._spans
                if not s.attrs.get("straggler")]
        flagged = (len(base) >= self.warmup
                   and span.duration > self.k * statistics.median(base))
        span.add(step=step, straggler=flagged)
        self._spans.append(span)
        return StepRecord(step, span.duration, flagged)

    # -- watchdog ---------------------------------------------------------
    def elapsed(self) -> Optional[float]:
        """Seconds the currently-open step has been running, or ``None``
        when no step is open (between ``stop()`` and the next
        ``start()``)."""
        if self._open is None or self._open.t0 is None:
            return None
        return time.perf_counter() - self._open.t0

    def heartbeat(self) -> None:
        """The between-steps watchdog hook: if a step is open and has
        already outlived the straggler deadline, raise ``TimeoutError``.
        Drivers that interleave other work with timed steps (the
        ``repro.serve`` tile loop) call this at their loop head, so a
        tile that began but never completed is detected the next time
        the loop turns instead of stalling the service silently. A
        no-op when no step is open or no median exists yet."""
        e = self.elapsed()
        if e is not None:
            self.check_deadline(e)

    # -- queries ----------------------------------------------------------
    @property
    def records(self) -> List[StepRecord]:
        """The span stream, viewed as StepRecords."""
        return [StepRecord(s.attrs["step"], s.duration,
                           s.attrs["straggler"]) for s in self._spans]

    @property
    def median(self) -> float:
        base = [s.duration for s in self._spans
                if not s.attrs.get("straggler")]
        return statistics.median(base) if base else float("nan")

    def stragglers(self) -> List[StepRecord]:
        return [r for r in self.records if r.straggler]

    def deadline(self) -> float:
        """Per-step watchdog deadline (seconds)."""
        m = self.median
        return (m * self.deadline_factor) if m == m else float("inf")

    def check_deadline(self, elapsed: float,
                       reason: str = "straggler deadline exceeded"):
        """Raise :class:`DeadlineExceeded` when ``elapsed`` outlives the
        deadline — but first *emit* the structured
        :class:`EscalationRecord` (appended to ``escalations`` and
        carried on the exception), so a recovery path consumes the
        record rather than re-deriving state from a message. The open
        step span, if any, is left open: the caller decides whether to
        ``abort()`` it (retry path) or tear the loop down."""
        d = self.deadline()
        if elapsed > d:
            rec = EscalationRecord(
                elapsed_s=elapsed, deadline_s=d, median_s=self.median,
                reason=reason, aborted_open_step=False)
            self.escalations.append(rec)
            raise DeadlineExceeded(
                f"step exceeded straggler deadline ({elapsed:.1f}s > "
                f"{d:.1f}s) — checkpoint and evict", rec)

    def abort(self, reason: str = "aborted") -> None:
        """Force-close the open step span without scoring it.

        The span still lands in the tracer (tagged ``aborted``) so the
        timeline shows the failed attempt, but it is excluded from the
        monitor's records/median — a half-run tile must not drag the
        straggler baseline."""
        if self._open is not None:
            span = self._open
            self._open = None
            span.add(aborted=True, reason=reason)
            span.end()

    def escalate(self, reason: str) -> EscalationRecord:
        """Escalate the open step *unconditionally* (no deadline check):
        emit the structured record and abort the open span. The serve
        scheduler uses this when it already *knows* a tile stalled (the
        step span survived to the next loop turn) but no median exists
        yet to arm the deadline — a watchdog that cannot fire before
        warmup would let a first-tile stall hang the service."""
        rec = EscalationRecord(
            elapsed_s=self.elapsed() or 0.0, deadline_s=self.deadline(),
            median_s=self.median, reason=reason,
            aborted_open_step=self._open is not None)
        self.escalations.append(rec)
        self.abort(reason)
        return rec

    def summary(self) -> dict:
        """Step-time distribution: exact median/p90 (kept for
        compatibility with earlier reports) plus p50/p95/p99 estimated
        through a fixed-bucket ``obs.metrics.Histogram`` — the same
        primitive the serve latency metrics use, so a monitor folded
        into ``serve_report()`` speaks the same percentile dialect."""
        from repro.obs.metrics import Histogram

        secs = [s.duration for s in self._spans]
        hist = Histogram("step_seconds")
        for s in secs:
            hist.record(s)
        pct = hist.percentiles()
        return {
            "steps": len(secs),
            "median_s": self.median,
            "p90_s": (statistics.quantiles(secs, n=10)[-1]
                      if len(secs) >= 10 else max(secs, default=float("nan"))),
            "p50_s": pct.get("p50"),
            "p95_s": pct.get("p95"),
            "p99_s": pct.get("p99"),
            "stragglers": len(self.stragglers()),
            "escalations": len(self.escalations),
        }
