"""Straggler detection and step-time accounting.

At 1000+ nodes the dominant availability hazards are (a) hosts that die
(handled by checkpoint/restart + elastic re-shard) and (b) hosts that
*slow down* — stragglers stretch every synchronous collective. This
monitor implements the detection half that any TPU-pod runner needs:

* rolling median step time with MAD-based outlier flagging
  (``threshold = median · k``);
* a deadline watchdog: a callable heartbeat that raises after
  ``deadline_factor × median`` so the launcher can checkpoint + evict
  (the eviction itself is the cluster scheduler's job);
* per-step records exportable for the roofline/§Perf logs.

tests/test_runtime.py injects synthetic delays to verify flagging.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import List, Optional


@dataclasses.dataclass
class StepRecord:
    step: int
    seconds: float
    straggler: bool


class StepMonitor:
    def __init__(self, k: float = 3.0, warmup: int = 3,
                 deadline_factor: float = 10.0):
        self.k = k
        self.warmup = warmup
        self.deadline_factor = deadline_factor
        self.records: List[StepRecord] = []
        self._t0: Optional[float] = None

    # -- timing ---------------------------------------------------------
    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> StepRecord:
        dt = time.perf_counter() - self._t0
        return self.record(step, dt)

    def record(self, step: int, seconds: float) -> StepRecord:
        flagged = False
        base = [r.seconds for r in self.records if not r.straggler]
        if len(base) >= self.warmup:
            med = statistics.median(base)
            flagged = seconds > self.k * med
        rec = StepRecord(step, seconds, flagged)
        self.records.append(rec)
        return rec

    # -- queries ----------------------------------------------------------
    @property
    def median(self) -> float:
        base = [r.seconds for r in self.records if not r.straggler]
        return statistics.median(base) if base else float("nan")

    def stragglers(self) -> List[StepRecord]:
        return [r for r in self.records if r.straggler]

    def deadline(self) -> float:
        """Per-step watchdog deadline (seconds)."""
        m = self.median
        return (m * self.deadline_factor) if m == m else float("inf")

    def check_deadline(self, elapsed: float):
        if elapsed > self.deadline():
            raise TimeoutError(
                f"step exceeded straggler deadline ({elapsed:.1f}s > "
                f"{self.deadline():.1f}s) — checkpoint and evict")

    def summary(self) -> dict:
        secs = [r.seconds for r in self.records]
        return {
            "steps": len(secs),
            "median_s": self.median,
            "p90_s": (statistics.quantiles(secs, n=10)[-1]
                      if len(secs) >= 10 else max(secs, default=float("nan"))),
            "stragglers": len(self.stragglers()),
        }
