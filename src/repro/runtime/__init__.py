from repro.runtime.train import make_train_step, init_train_state
from repro.runtime.serve import make_prefill_step, make_decode_step
from repro.runtime.monitor import StepMonitor

__all__ = ["make_train_step", "init_train_state", "make_prefill_step",
           "make_decode_step", "StepMonitor"]
