"""LM loss with vocab-sharded logits.

The (B, S, V) logits tensor is the largest activation in any LM step
(qwen3 train_4k: 16 × 4096 × 151936 × 2 B ≈ 20 GB/device unsharded!).
It is never materialized replicated: a sharding constraint pins the vocab
axis to the TP axis, the log-softmax reduction over the sharded axis
lowers to two small all-reduces, and the gold-logit gather is a one-hot
einsum that partitions the same way — the paper's "never materialize the
big intermediate" rule applied to the loss (DESIGN §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.rules import ShardingRules, logits_spec


_LOSS_CHUNK = 1024


def _nll_block(table, hidden, targets, cfg, rules):
    """Mean-able NLL sum over one (B, C) block. Vocab-sharded logits."""
    logits = jnp.einsum("bsd,vd->bsv", hidden, table)
    if rules is not None:
        logits = jax.lax.with_sharding_constraint(
            logits, logits_spec(rules, targets.shape[0], cfg.vocab))
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)                       # (B, C)
    from repro.sharding import ctx as shard_ctx
    one_hot = jax.nn.one_hot(targets, cfg.vocab, dtype=jnp.float32)
    one_hot = shard_ctx.constrain(one_hot, "dp", None, "tp")  # match logits
    gold = jnp.einsum("bsv,bsv->bs", one_hot, logits)
    return jnp.sum(logz - gold)


def lm_loss(embed_params: dict, hidden: jax.Array, targets: jax.Array,
            cfg, rules: ShardingRules = None):
    """hidden: (B, S, D); targets: (B, S) int32 → scalar mean NLL.

    The sequence is scanned in chunks so the (B, S, V) logits (and their
    f32 cotangents) never materialize — the full-length loss stack was
    the single largest live set in the train step (≈8 GB/device at
    B=256, qwen3 — §Perf A, iteration hc-A4). For the VLM arch the
    hidden sequence is longer than the targets (patch positions
    prepended); loss is computed on the trailing text positions only.
    """
    s_text = targets.shape[1]
    if hidden.shape[1] != s_text:
        hidden = hidden[:, -s_text:]
    table = embed_params["table"] if cfg.tie_embeddings else embed_params["head"]

    b, s = targets.shape
    c = _LOSS_CHUNK
    if s % c or s <= c:
        return _nll_block(table, hidden, targets, cfg, rules) / (b * s)

    nc = s // c
    hs = jnp.moveaxis(hidden.reshape(b, nc, c, hidden.shape[-1]), 1, 0)
    ts = jnp.moveaxis(targets.reshape(b, nc, c), 1, 0)

    def body(acc, args):
        h_i, t_i = args
        return acc + _nll_block(table, h_i, t_i, cfg, rules), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ts))
    return total / (b * s)
