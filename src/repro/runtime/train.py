"""Training step builder: microbatched grad accumulation, remat, AdamW,
pjit sharding — one code path for every assigned arch.

Memory discipline (the paper's rule at training-step scale):

* the global batch is scanned in ``cfg.microbatches`` slices so the live
  activation set is one microbatch (nemotron needs 16× accumulation to
  fit 16 GB/chip, DESIGN §5);
* grads accumulate in fp32 (stable) but are produced reduce-scattered by
  GSPMD under FSDP — no full gradient replica ever exists;
* ``donate_argnums`` recycles params+opt buffers in place.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.encdec import forward_train_encdec, init_params_encdec
from repro.models.transformer import forward_train, init_params
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.runtime.loss import lm_loss
from repro.sharding.rules import (ShardingRules, batch_spec, named,
                                  param_specs)

_AUX_WEIGHT = 0.01     # MoE load-balance loss weight


# --------------------------------------------------------------------------
# loss over one microbatch
# --------------------------------------------------------------------------
def _loss_fn(params, batch, cfg, rules):
    if cfg.is_encdec:
        hidden, aux = forward_train_encdec(params, batch["frames"],
                                           batch["tokens"], cfg)
    elif cfg.frontend == "vision":
        hidden, aux = forward_train(params, batch["tokens"], cfg,
                                    extra_embeds=batch["patches"])
    else:
        hidden, aux = forward_train(params, batch["tokens"], cfg)
    loss = lm_loss(params["embed"], hidden, batch["targets"], cfg, rules)
    return loss + _AUX_WEIGHT * aux, loss


# --------------------------------------------------------------------------
# the step
# --------------------------------------------------------------------------
def build_train_step_fn(cfg, opt: AdamWConfig, rules: Optional[ShardingRules]):
    """Returns train_step(params, opt_state, batch) → (params, opt_state,
    metrics). Pure function — jit/lower handled by the caller."""

    def train_step(params, opt_state, batch):
        from repro.sharding import ctx as shard_ctx
        with shard_ctx.use_rules(rules):    # active during tracing
            return _train_step_inner(params, opt_state, batch)

    def _constrain_like_params(tree, params):
        """Pin gradient/accumulator shardings to the param specs — without
        this, the fp32 accumulator (and per-microbatch grads) can settle
        REPLICATED through the accumulation scan (observed: nemotron's
        untied embedding grad at 18.9 GB/device f32)."""
        if rules is None:
            return tree
        from jax.sharding import NamedSharding
        specs = param_specs(cfg, params, rules)
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, NamedSharding(rules.mesh, s)), tree, specs)

    def _train_step_inner(params, opt_state, batch):
        m = cfg.microbatches

        def grads_of(mb):
            (total, loss), g = jax.value_and_grad(
                _loss_fn, has_aux=True)(params, mb, cfg, rules)
            return _constrain_like_params(g, params), loss

        # accumulation dtype follows the optimizer-state dtype choice:
        # f32 default; the ≥100B archs pick bf16 m/v for the 16 GB/chip
        # budget and accumulate in bf16 too (grads are pre-averaged /m so
        # the bf16 mantissa loss is on the noise floor).
        acc_dt = cfg.dtype("opt")

        if m == 1:
            grads, loss = grads_of(batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            # split batch leading dim into m microbatches and scan
            def split(x):
                return x.reshape(m, x.shape[0] // m, *x.shape[1:])

            mbs = jax.tree.map(split, batch)
            acc0 = _constrain_like_params(
                jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt),
                             params), params)

            def body(carry, mb):
                acc, loss_sum = carry
                g, loss = grads_of(mb)
                acc = jax.tree.map(
                    lambda a, gi: (a.astype(jnp.float32)
                                   + gi.astype(jnp.float32) / m).astype(acc_dt),
                    acc, g)
                acc = _constrain_like_params(acc, params)
                return (acc, loss_sum + loss / m), None

            (grads, loss), _ = jax.lax.scan(
                body, (acc0, jnp.zeros((), jnp.float32)), mbs)

        new_params, new_opt, metrics = adamw_update(grads, opt_state,
                                                    params, opt)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_train_step(cfg, opt: AdamWConfig, mesh, rules: ShardingRules,
                    params_tree, opt_tree, batch_tree, opt_rules=None):
    """jit'd, sharded train step. The *_tree arguments may be real arrays
    or ShapeDtypeStructs (dry-run).

    ``opt_rules``: optional separate sharding rules for the Adam moments —
    pass FSDP rules while ``rules`` is pure DP/TP to get ZeRO-1 (replicated
    params, sharded optimizer state, one param all-gather per step)."""
    fn = build_train_step_fn(cfg, opt, rules)
    p_specs = param_specs(cfg, params_tree, rules)
    o_p_specs = param_specs(cfg, params_tree, opt_rules or rules)
    o_specs = {"m": o_p_specs, "v": o_p_specs,
               "step": jax.sharding.PartitionSpec()}
    b_specs = _batch_specs_tree(cfg, batch_tree, rules)
    from jax.sharding import PartitionSpec as P
    m_specs = {"loss": P(), "lr": P(), "grad_norm": P()}
    step = jax.jit(
        fn,
        in_shardings=(named(mesh, p_specs), named(mesh, o_specs),
                      named(mesh, b_specs)),
        out_shardings=(named(mesh, p_specs), named(mesh, o_specs),
                       named(mesh, m_specs)),
        donate_argnums=(0, 1),
    )
    return step


def _batch_specs_tree(cfg, batch_tree, rules):
    def one(path, leaf):
        return batch_spec(rules, leaf.shape[0], rank=len(leaf.shape))
    return jax.tree_util.tree_map_with_path(one, batch_tree)


# --------------------------------------------------------------------------
# state init (smoke/examples; dry-run uses eval_shape instead)
# --------------------------------------------------------------------------
def init_train_state(key, cfg, opt_dtype=None):
    params = (init_params_encdec(key, cfg) if cfg.is_encdec
              else init_params(key, cfg))
    opt_state = init_opt_state(params, opt_dtype or cfg.dtype("opt"))
    return params, opt_state


def abstract_train_state(cfg):
    """ShapeDtypeStruct trees for params/opt state — no allocation."""
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(
        lambda k: (init_params_encdec(k, cfg) if cfg.is_encdec
                   else init_params(k, cfg)), key)
    opt_state = jax.eval_shape(
        partial(init_opt_state, dtype=cfg.dtype("opt")), params)
    return params, opt_state
