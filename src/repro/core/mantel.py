"""Mantel test: paper §4.2, Algorithms 3, 4 & 5.

The Mantel test correlates two distance matrices; significance comes from a
Monte-Carlo null distribution over K row/column permutations (default 999).

* ``mantel_ref`` — Algorithms 3+4 verbatim: per permutation, materialize the
  permuted condensed form and call a black-box ``pearsonr`` (eager, multi-pass:
  subtract mean, norm, divide, dot — each a DRAM round-trip).
* ``mantel`` — Algorithm 5's two hoisting observations plus fusion, expressed
  as a ``repro.stats.engine.Statistic`` (this module is a thin client of the
  shared permutation engine; the same split powers PERMANOVA, ANOSIM and the
  partial Mantel test in ``repro.stats``):
    1. the second argument never changes ⇒ normalize ``y`` once;
    2. mean and norm are permutation-invariant ⇒ compute ``x̄``, ``‖x−x̄‖`` once.
  One further algebraic step (DESIGN §2): ``ŷ`` is centered ⇒ ``Σŷ = 0`` ⇒ the
  ``−x̄`` term vanishes from the inner product, leaving
      ``r_p = ⟨x_perm, ŷ⟩ / ‖x−x̄‖ = vdot(x[p][:,p], Ŷ_full) / (2‖x−x̄‖)``
  where ``Ŷ_full`` is the full symmetric centered-normalized matrix (diag 0).
  The inner loop is a single fused gather+multiply+reduce — the TPU-native
  form of the paper's Cython loop (row gathers are contiguous; the VPU does
  the reduction). Explicit VMEM tiling in ``repro.kernels.mantel_corr``.
* ``mantel_distributed`` — permutations sharded over ('pod','data'), matrix
  columns over 'model': each device reduces its column block, one psum.
  (The engine's ``permutation_test_distributed`` shards only the permutation
  axis; this path additionally splits the matrix columns, so it stays
  specialized here.)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distance_matrix import DistanceMatrix, condensed_to_square
from repro.stats import engine


# --------------------------------------------------------------------------
# Algorithm 4 — SciPy pearsonr (the black box the original code calls)
# --------------------------------------------------------------------------
def pearsonr_ref(x_flat: jax.Array, y_flat: jax.Array) -> jax.Array:
    """Eager multi-pass Pearson correlation, mirroring scipy.stats.pearsonr."""
    xm = x_flat - x_flat.mean()
    ym = y_flat - y_flat.mean()
    normxm = jnp.linalg.norm(xm)
    normym = jnp.linalg.norm(ym)
    xnorm = xm / normxm
    ynorm = ym / normym
    return jnp.dot(xnorm, ynorm)


# --------------------------------------------------------------------------
# Algorithm 3 — original mantel (black-box pearsonr per permutation)
# --------------------------------------------------------------------------
_permutation_orders = engine.permutation_orders    # owned by the engine now


def mantel_ref(x: DistanceMatrix, y: DistanceMatrix, permutations: int = 999,
               key=None, alternative: str = "two-sided"):
    """Original implementation: the permuted matrix is fully materialized and
    pearsonr re-derives mean/norm from scratch every iteration."""
    key = engine.as_key(key)
    x_flat = x.condensed_form()
    y_flat = y.condensed_form()
    orig_stat = pearsonr_ref(x_flat, y_flat)

    orders = _permutation_orders(key, permutations, len(x))
    permuted_stats = []
    for p in range(permutations):                      # eager python loop, like NumPy
        x_perm_flat = x.permute(np.asarray(orders[p]), condensed=True)
        permuted_stats.append(pearsonr_ref(x_perm_flat, y_flat))
    permuted_stats = jnp.stack(permuted_stats)
    return _finish(orig_stat, permuted_stats, permutations, alternative, len(x))


# --------------------------------------------------------------------------
# Algorithm 5 — hoisted + fused mantel, as an engine Statistic
# --------------------------------------------------------------------------
@jax.jit
def condensed_moments_vec(flat: jax.Array) -> dict:
    """``condensed_moments`` for distances already in condensed layout —
    the entry point for feature-backed sessions (``repro.dist`` produces
    condensed directly, so the square extraction is skipped)."""
    centered = flat - flat.mean()
    norm = jnp.linalg.norm(centered)
    return {"norm": norm, "hat": centered / norm}


@partial(jax.jit, static_argnames=("n",))
def condensed_moments(data: jax.Array, n: int) -> dict:
    """The O(m) permutation-invariant moments of ONE matrix, cacheable per
    session: centered-condensed norm (the x-side hoist) and the centered-
    normalized condensed vector. Every Mantel-family hoist is assembled
    from these, so a Workspace computes them once per matrix — not once
    per test. The y-side's square symmetric form is the separate (O(n²))
    ``hat_square`` build, cached under its own key so a matrix used only
    as the permuted x-side never pays for it."""
    iu = np.triu_indices(n, k=1)
    return condensed_moments_vec(data[iu])


def hat_square(moments: dict, n: int) -> jax.Array:
    """Square symmetric form (diag 0) of the centered-normalized vector —
    the y-side hoist of every Mantel-family inner product."""
    return condensed_to_square(moments["hat"], n)


@partial(jax.tree_util.register_dataclass,
         data_fields=["x", "y", "pre"], meta_fields=["n"])
@dataclasses.dataclass
class MantelStatistic:
    """Pearson r between permuted x and fixed y, hoisting split per §4.2.

    ``pre`` optionally carries the session-level hoist
    (``{"normxm": ..., "y_full": ...}`` assembled from two Workspaces'
    cached ``condensed_moments``) so repeated tests against one matrix
    skip the per-test normalization passes."""

    x: jax.Array           # (n, n) permuted matrix
    y: jax.Array           # (n, n) held fixed
    n: int
    pre: Optional[dict] = None

    def hoist(self):
        if self.pre is not None:
            return dict(self.pre)
        iu = np.triu_indices(self.n, k=1)
        x_flat = self.x[iu]
        xm = x_flat - x_flat.mean()
        normxm = jnp.linalg.norm(xm)                   # computed exactly once
        y_flat = self.y[iu]
        ym = y_flat - y_flat.mean()
        ynorm = ym / jnp.linalg.norm(ym)               # computed exactly once
        # full symmetric centered-normalized y (diag 0): Σ_uptri == ½ Σ_full
        return {"normxm": normxm,
                "y_full": condensed_to_square(ynorm, self.n)}

    def per_perm(self, inv, order):
        # two contiguous row-wise gathers + one fused multiply-reduce
        xp = self.x[order][:, order]
        return jnp.vdot(xp, inv["y_full"]) / (2.0 * inv["normxm"])


def _finish(orig_stat, permuted_stats, permutations, alternative, n):
    """Legacy tuple-returning finisher; the counting lives in the engine."""
    r = engine.finish(orig_stat, permuted_stats, permutations, alternative, n)
    return r.statistic, r.p_value, n


def mantel(x: DistanceMatrix, y: DistanceMatrix, permutations: int = 999,
           key=None, alternative: str = "two-sided"):
    """Cache-optimized Mantel test (paper Algorithm 5). Same interface and
    semantics as ``mantel_ref``; ~100x less memory traffic. Thin wrapper
    over a one-shot ``api.Workspace`` (which is itself a client of
    ``repro.stats.engine.permutation_test``) — identical p-values per key;
    a session testing one matrix against several should hold its own
    Workspace so the normalization hoists are shared."""
    from repro.api.workspace import Workspace
    # validate=False: trust the DistanceMatrix as constructed, exactly like
    # the pre-session implementation that read x.data directly
    r = Workspace(x, validate=False).mantel(y, permutations=permutations, key=key,
                            alternative=alternative)
    return r.statistic, r.p_value, r.sample_size


# --------------------------------------------------------------------------
# Distributed mantel — permutations over ('pod','data'), columns over 'model'
# --------------------------------------------------------------------------
def mantel_distributed(x: DistanceMatrix, y: DistanceMatrix, mesh,
                       permutations: int = 1024,
                       key: Optional[jax.Array] = None,
                       alternative: str = "two-sided",
                       perm_axes=("data",), col_axis: str = "model"):
    """Permutation-parallel Mantel.

    Each device owns K/|perm_axes| permutations and the full matrix column
    block assigned to its 'model' coordinate; the per-permutation reduction
    is block-local followed by one scalar psum over 'model'. Permutation
    draws use a per-device fold_in so the global null distribution is
    identical regardless of mesh shape (elastic-safe).
    """
    from jax.sharding import PartitionSpec as P
    from repro.stats.engine import _shard_map

    key = engine.as_key(key)
    n = len(x)
    x_data, y_data = x.data, y.data

    # one hoist implementation for host and distributed paths — only the
    # column-sharded reduction below stays specialized; the observed stat
    # is jitted so the identity-order gathers fuse away instead of
    # materializing two full n×n copies eagerly
    stat = MantelStatistic(x_data, y_data, n)

    @jax.jit
    def _hoist_and_observe(s):
        inv = s.hoist()
        return inv, s.per_perm(inv, jnp.arange(s.n))

    inv, orig_stat = _hoist_and_observe(stat)
    normxm, y_full = inv["normxm"], inv["y_full"]

    n_perm_devices = int(np.prod([mesh.shape[a] for a in perm_axes]))
    if permutations % n_perm_devices:
        raise ValueError(f"permutations ({permutations}) must divide over {n_perm_devices} devices")
    per_dev = permutations // n_perm_devices

    def _local(x_local, y_cols, normxm_s):
        # x_local: full matrix (replicated over perm axes); y_cols: (n, n/Pc)
        dev = jax.lax.axis_index(perm_axes[0]) if len(perm_axes) == 1 else (
            jax.lax.axis_index(perm_axes[0]) * mesh.shape[perm_axes[1]]
            + jax.lax.axis_index(perm_axes[1]))
        k = jax.random.fold_in(key, dev)
        orders = _permutation_orders(k, per_dev, n)
        j = jax.lax.axis_index(col_axis)
        c = y_cols.shape[1]

        def one(order):
            col_order = jax.lax.dynamic_slice(order, (j * c,), (c,))
            xp = x_local[order][:, col_order]          # only our column block
            part = jnp.vdot(xp, y_cols)
            return jax.lax.psum(part, axis_name=col_axis) / (2.0 * normxm_s)

        return jax.lax.map(one, orders)

    f = _shard_map(
        _local, mesh=mesh,
        in_specs=(P(), P(None, col_axis), P()),
        out_specs=P(perm_axes[0] if len(perm_axes) == 1 else perm_axes),
    )
    permuted_stats = f(x_data, y_full, normxm)
    return _finish(orig_stat, permuted_stats, permutations, alternative, n)
