"""Mantel test: paper §4.2, Algorithms 3, 4 & 5.

The Mantel test correlates two distance matrices; significance comes from a
Monte-Carlo null distribution over K row/column permutations (default 999).

* ``mantel_ref`` — Algorithms 3+4 verbatim: per permutation, materialize the
  permuted condensed form and call a black-box ``pearsonr`` (eager, multi-pass:
  subtract mean, norm, divide, dot — each a DRAM round-trip).
* ``mantel`` — Algorithm 5's two hoisting observations plus fusion, expressed
  as a ``repro.stats.engine.Statistic`` (this module is a thin client of the
  shared permutation engine; the same split powers PERMANOVA, ANOSIM and the
  partial Mantel test in ``repro.stats``):
    1. the second argument never changes ⇒ normalize ``y`` once;
    2. mean and norm are permutation-invariant ⇒ compute ``x̄``, ``‖x−x̄‖`` once.
  One further algebraic step (DESIGN §2): ``ŷ`` is centered ⇒ ``Σŷ = 0`` ⇒ the
  ``−x̄`` term vanishes from the inner product — and the whole loop is
  SQUARE-FREE: the condensed form of the permuted matrix is an index
  transform of the condensed original,
      ``condensed(X_p)[k] = xc[tri(order[i_k], order[j_k])]``,
  so      ``r_p = ⟨condensed(X_p), ŷ_c⟩ / ‖x−x̄‖``
  is one closed-form gather + one fused multiply-reduce over the
  m = n(n−1)/2 condensed entries — never the n×n gather buffer the PR-4
  loop materialized. Permutations run in batches of B through
  ``kernels.permute_reduce``: the hoisted ŷ_c / triangle-map streams are
  fetched once per tile and reused across all B permutations, leaving
  ~m(1 + 3/B) floats of traffic per permutation vs the square-gather
  loop's ~6n² ≈ 12m (the measured accounting lives in BENCH_mantel.json).
* ``mantel_distributed`` — permutations sharded over ('pod','data'), matrix
  columns over 'model': each device reduces its column block, one psum.
  (The engine's ``permutation_test_distributed`` shards only the permutation
  axis; this path additionally splits the matrix columns, so it stays
  specialized here.)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distance_matrix import (DistanceMatrix, condensed_index,
                                        condensed_to_square, triangle_coords)
from repro.kernels.permute_reduce_ops import permute_reduce
from repro.stats import engine


# --------------------------------------------------------------------------
# Algorithm 4 — SciPy pearsonr (the black box the original code calls)
# --------------------------------------------------------------------------
def pearsonr_ref(x_flat: jax.Array, y_flat: jax.Array) -> jax.Array:
    """Eager multi-pass Pearson correlation, mirroring scipy.stats.pearsonr."""
    xm = x_flat - x_flat.mean()
    ym = y_flat - y_flat.mean()
    normxm = jnp.linalg.norm(xm)
    normym = jnp.linalg.norm(ym)
    xnorm = xm / normxm
    ynorm = ym / normym
    return jnp.dot(xnorm, ynorm)


# --------------------------------------------------------------------------
# Algorithm 3 — original mantel (black-box pearsonr per permutation)
# --------------------------------------------------------------------------
_permutation_orders = engine.permutation_orders    # owned by the engine now


def mantel_ref(x: DistanceMatrix, y: DistanceMatrix, permutations: int = 999,
               key=None, alternative: str = "two-sided"):
    """Original implementation: the permuted matrix is fully materialized and
    pearsonr re-derives mean/norm from scratch every iteration."""
    key = engine.as_key(key)
    x_flat = x.condensed_form()
    y_flat = y.condensed_form()
    orig_stat = pearsonr_ref(x_flat, y_flat)

    orders = _permutation_orders(key, permutations, len(x))
    permuted_stats = []
    for p in range(permutations):                      # eager python loop, like NumPy
        x_perm_flat = x.permute(np.asarray(orders[p]), condensed=True)
        permuted_stats.append(pearsonr_ref(x_perm_flat, y_flat))
    permuted_stats = jnp.stack(permuted_stats)
    return _finish(orig_stat, permuted_stats, permutations, alternative, len(x))


# --------------------------------------------------------------------------
# Algorithm 5 — hoisted + fused mantel, as an engine Statistic
# --------------------------------------------------------------------------
@jax.jit
def condensed_moments_vec(flat: jax.Array) -> dict:
    """``condensed_moments`` for distances already in condensed layout —
    the entry point for feature-backed sessions (``repro.dist`` produces
    condensed directly, so the square extraction is skipped)."""
    centered = flat - flat.mean()
    norm = jnp.linalg.norm(centered)
    return {"norm": norm, "hat": centered / norm}


@partial(jax.jit, static_argnames=("n",))
def condensed_moments(data: jax.Array, n: int) -> dict:
    """The O(m) permutation-invariant moments of ONE matrix, cacheable per
    session: centered-condensed norm (the x-side hoist) and the centered-
    normalized condensed vector. Every Mantel-family hoist is assembled
    from these — BOTH sides, since the condensed batch loop: a fixed side
    contributes its ``hat`` vector directly, so a Workspace computes the
    moments once per matrix and nothing square is ever built (the square
    ``hat_square`` form survives only for ``mantel_distributed``'s
    column-sharded split)."""
    iu = np.triu_indices(n, k=1)
    return condensed_moments_vec(data[iu])


def hat_square(moments: dict, n: int) -> jax.Array:
    """Square symmetric form (diag 0) of the centered-normalized vector.
    Since the condensed batch loop the host-path statistics never need
    it; the one remaining consumer is ``mantel_distributed``, whose
    'model'-axis split shards the square's columns."""
    return condensed_to_square(moments["hat"], n)


def _as_condensed(mat: jax.Array, n: int) -> jax.Array:
    """Condensed view of a square matrix; condensed input passes through.
    The statistics accept both so legacy square-matrix callers keep
    working while sessions feed condensed storage directly."""
    if mat.ndim == 1:
        return mat
    return mat[np.triu_indices(n, k=1)]


@partial(jax.tree_util.register_dataclass,
         data_fields=["x", "y", "pre"],
         meta_fields=["n", "kernel", "interpret", "chunk"])
@dataclasses.dataclass
class MantelStatistic:
    """Pearson r between permuted x and fixed y, hoisting split per §4.2 —
    square-free: every hoist and every per-permutation pass works on the
    m = n(n−1)/2 condensed entries.

    ``x``/``y`` may be square (n, n) matrices or condensed (m,) vectors.
    ``pre`` optionally carries the session-level hoist
    (``{"normxm": ..., "ynorm": ...}`` with ``ynorm`` the CONDENSED
    centered-normalized y, assembled from two Workspaces' cached
    ``condensed_moments``) so repeated tests against one matrix skip the
    per-test normalization passes — and a fixed side never builds any
    square form at all. ``kernel`` picks the batched reduction backend
    (``"xla"``: the lax.scan twin; ``"pallas"``: the explicit-VMEM
    kernel), both routed through ``kernels.permute_reduce``."""

    x: jax.Array           # (n, n) square or (m,) condensed, permuted side
    y: Optional[jax.Array]  # same, held fixed; may be None when pre is given
    n: int
    pre: Optional[dict] = None
    kernel: str = "xla"
    interpret: Optional[bool] = None
    chunk: Optional[int] = None  # condensed stream chunk (None: kernel default)

    def hoist(self):
        # the permuted side's condensed view and the triangle coordinate
        # map are permutation-invariant too — extracted once, outside the
        # Monte-Carlo loop
        inv = {"xc": _as_condensed(self.x, self.n)}
        if self.pre is not None:
            inv.update(self.pre)
        else:
            xm = inv["xc"] - inv["xc"].mean()
            inv["normxm"] = jnp.linalg.norm(xm)        # computed exactly once
            y_flat = _as_condensed(self.y, self.n)
            ym = y_flat - y_flat.mean()
            inv["ynorm"] = ym / jnp.linalg.norm(ym)    # computed exactly once
        inv["ii"], inv["jj"] = triangle_coords(self.n)
        return inv

    def per_perm(self, inv, order):
        # one closed-form condensed gather + one fused multiply-reduce
        # (Σ_uptri == ½ Σ_full and Σŷ = 0, so the full-matrix 2/(2‖x−x̄‖)
        # scaling collapses to 1/‖x−x̄‖ on condensed entries)
        o = order.astype(jnp.int32)
        k = condensed_index(o[inv["ii"]], o[inv["jj"]], self.n)
        return jnp.dot(inv["xc"][k], inv["ynorm"]) / inv["normxm"]

    def per_batch(self, inv, orders):
        # the engine's primary path: all B reductions of one order tile
        # through the batched kernel — the ŷ/triangle streams are fetched
        # once per tile and reused across the whole batch
        stats = permute_reduce(inv["xc"], inv["ynorm"][None, :], orders,
                               inv["ii"], inv["jj"], impl=self.kernel,
                               chunk=self.chunk, interpret=self.interpret)
        return stats[0] / inv["normxm"]


def _finish(orig_stat, permuted_stats, permutations, alternative, n):
    """Legacy tuple-returning finisher; the counting lives in the engine."""
    r = engine.finish(orig_stat, permuted_stats, permutations, alternative, n)
    return r.statistic, r.p_value, n


def mantel(x: DistanceMatrix, y: DistanceMatrix, permutations: int = 999,
           key=None, alternative: str = "two-sided"):
    """Cache-optimized Mantel test (paper Algorithm 5). Same interface and
    semantics as ``mantel_ref``, with the square-free condensed batch
    loop: ~11.0x less per-permutation traffic than the square-gather
    engine loop and ~16.4x less than the eager Algorithm-3 original
    (analytic fp32 bytes at n=2048, B=32, K=999 — the audited accounting
    is the tracked ``BENCH_mantel.json`` artifact, via
    ``benchmarks/run.py --suite mantel``).
    Thin wrapper over a one-shot ``api.Workspace`` (which is itself a
    client of ``repro.stats.engine.permutation_test``) — identical
    p-values per key; a session testing one matrix against several should
    hold its own Workspace so the normalization hoists are shared."""
    from repro.api.workspace import Workspace
    # validate=False: trust the DistanceMatrix as constructed, exactly like
    # the pre-session implementation that read x.data directly
    r = Workspace(x, validate=False).mantel(y, permutations=permutations, key=key,
                            alternative=alternative)
    return r.statistic, r.p_value, r.sample_size


# --------------------------------------------------------------------------
# Distributed mantel — permutations over ('pod','data'), columns over 'model'
# --------------------------------------------------------------------------
def mantel_distributed(x: DistanceMatrix, y: DistanceMatrix, mesh,
                       permutations: int = 1024,
                       key: Optional[jax.Array] = None,
                       alternative: str = "two-sided",
                       perm_axes=("data",), col_axis: str = "model"):
    """Permutation-parallel Mantel.

    Each device owns K/|perm_axes| permutations and the full matrix column
    block assigned to its 'model' coordinate; the per-permutation reduction
    is block-local followed by one scalar psum over 'model'. Permutation
    draws use a per-device fold_in so the global null distribution is
    identical regardless of mesh shape (elastic-safe).
    """
    from jax.sharding import PartitionSpec as P
    from repro.stats.engine import _shard_map

    key = engine.as_key(key)
    n = len(x)
    x_data, y_data = x.data, y.data

    # one hoist implementation for host and distributed paths — only the
    # column-sharded reduction below stays specialized; the shared engine
    # entry point jits hoist + observed together so the identity-order
    # gathers fuse away instead of materializing two full n×n copies
    stat = MantelStatistic(x_data, y_data, n)
    inv, orig_stat = engine.hoist_and_observe(stat)
    normxm = inv["normxm"]
    # this path shards the MATRIX columns over 'model', so it is the one
    # remaining consumer of the square hat form — assembled here from the
    # condensed hoist, not inside the statistic
    y_full = hat_square({"hat": inv["ynorm"]}, n)

    n_perm_devices = int(np.prod([mesh.shape[a] for a in perm_axes]))
    if permutations % n_perm_devices:
        raise ValueError(f"permutations ({permutations}) must divide over {n_perm_devices} devices")
    per_dev = permutations // n_perm_devices

    def _local(x_local, y_cols, normxm_s):
        # x_local: full matrix (replicated over perm axes); y_cols: (n, n/Pc)
        dev = jax.lax.axis_index(perm_axes[0]) if len(perm_axes) == 1 else (
            jax.lax.axis_index(perm_axes[0]) * mesh.shape[perm_axes[1]]
            + jax.lax.axis_index(perm_axes[1]))
        k = jax.random.fold_in(key, dev)
        orders = _permutation_orders(k, per_dev, n)
        j = jax.lax.axis_index(col_axis)
        c = y_cols.shape[1]

        def one(order):
            col_order = jax.lax.dynamic_slice(order, (j * c,), (c,))
            xp = x_local[order][:, col_order]          # only our column block
            part = jnp.vdot(xp, y_cols)
            return jax.lax.psum(part, axis_name=col_axis) / (2.0 * normxm_s)

        return jax.lax.map(one, orders)

    f = _shard_map(
        _local, mesh=mesh,
        in_specs=(P(), P(None, col_axis), P()),
        out_specs=P(perm_axes[0] if len(perm_axes) == 1 else perm_axes),
    )
    permuted_stats = f(x_data, y_full, normxm)
    return _finish(orig_stat, permuted_stats, permutations, alternative, n)
