"""Principal Coordinates Analysis: paper §4.1.

``pcoa = centering + eigendecomposition``. The paper's finding was that the
*centering* dominated runtime in the original scikit-bio implementation; the
eigensolver is the randomized method of Halko et al. 2011 (scikit-bio's
``method="fsvd"``). We reproduce both halves:

* centering through ``core.centering`` (ref / fused / distributed);
* ``method="eigh"`` — exact symmetric eigendecomposition (the oracle);
* ``method="fsvd"`` — randomized range-finder with power iterations
  (Halko et al. 2011, Algs. 4.3/5.3), all matmuls pjit-shardable so the
  solver scales with the mesh.

Output mirrors scikit-bio's ``OrdinationResults``: coordinates scaled by
√λ, eigenvalues, and the proportion of variance explained (negative
eigenvalues — which Gower centering of non-Euclidean distances can produce —
are clamped to zero for the proportions, as scikit-bio does).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import centering
from repro.core.distance_matrix import DistanceMatrix


@dataclasses.dataclass
class PCoAResults:
    coordinates: jax.Array          # (n, k) — samples in ordination space
    eigenvalues: jax.Array          # (k,)
    proportion_explained: jax.Array # (k,)
    method: str = "fsvd"


# --------------------------------------------------------------------------
# Randomized eigensolver (Halko et al. 2011) — pjit-shardable matmuls
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("k", "oversample", "power_iters"))
def _randomized_eigh(a: jax.Array, key, k: int, oversample: int = 10,
                     power_iters: int = 2):
    """Top-k eigenpairs of symmetric ``a`` via randomized subspace iteration.

    Range finder: Y = A Ω, orthonormalize, power-iterate (A is symmetric so
    AᵀA = A²); project T = QᵀAQ (small, (k+p)²); exact eigh of T lifts back.
    Every O(n²k) op is a dense matmul ⇒ shards over a device mesh with the
    matrix in P('data','model') and XLA-inserted collectives.
    """
    n = a.shape[0]
    p = k + oversample
    omega = jax.random.normal(key, (n, p), dtype=a.dtype)
    y = a @ omega
    q, _ = jnp.linalg.qr(y)
    for _ in range(power_iters):
        q, _ = jnp.linalg.qr(a @ q)
    t = q.T @ (a @ q)                      # (p, p) — tiny, host-side cost
    t = 0.5 * (t + t.T)
    evals, evecs = jnp.linalg.eigh(t)
    # eigh returns ascending; take top-k by magnitude of value (descending)
    order = jnp.argsort(-evals)[:k]
    evals = evals[order]
    evecs = q @ evecs[:, order]
    return evals, evecs


@partial(jax.jit, static_argnames=("k",))
def _exact_eigh(a: jax.Array, k: int):
    evals, evecs = jnp.linalg.eigh(a)
    order = jnp.argsort(-evals)[:k]
    return evals[order], evecs[:, order]


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------
def pcoa(dm: DistanceMatrix, dimensions: int = 10, method: str = "fsvd",
         key: Optional[jax.Array] = None, mesh=None,
         centering_impl: str = "fused") -> PCoAResults:
    """Principal Coordinates Analysis of a distance matrix.

    ``centering_impl``: "ref" (Algorithm 1), "fused" (Algorithm 2),
    "distributed" (shard_map over ``mesh``). ``method``: "fsvd" | "eigh".
    """
    if key is None:
        key = jax.random.PRNGKey(42)
    # scikit-bio's pcoa makes an internal copy of the DistanceMatrix — the
    # paper's validation-caching means this copy is free of revalidation.
    dm = dm.copy()
    n = len(dm)
    k = min(dimensions, n)

    if centering_impl == "ref":
        centered = centering.center_distance_matrix_ref(dm.data)
    elif centering_impl == "fused":
        centered = centering.center_distance_matrix(dm.data)
    elif centering_impl == "distributed":
        if mesh is None:
            raise ValueError("distributed centering requires a mesh")
        centered = centering.center_distance_matrix_distributed(dm.data, mesh)
    else:
        raise ValueError(f"unknown centering_impl {centering_impl!r}")

    if method == "fsvd":
        evals, evecs = _randomized_eigh(centered, key, k)
    elif method == "eigh":
        evals, evecs = _exact_eigh(centered, k)
    else:
        raise ValueError(f"unknown method {method!r}")

    pos = jnp.maximum(evals, 0.0)
    coordinates = evecs * jnp.sqrt(pos)[None, :]
    # proportion explained relative to the total positive inertia. With
    # fsvd only k eigenvalues are known; scikit-bio uses the trace of the
    # centered matrix (== Σλ) as the denominator, which we can get exactly.
    total = jnp.trace(centered)
    total = jnp.where(total <= 0, jnp.sum(pos), total)
    proportion = pos / total
    return PCoAResults(coordinates=coordinates, eigenvalues=evals,
                       proportion_explained=proportion, method=method)
