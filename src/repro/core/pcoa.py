"""Principal Coordinates Analysis: paper §4.1, operator-based.

``pcoa = centering + eigendecomposition`` — and since PR 2 the two halves
are *fused*: the default path never materializes the Gower-centered matrix
at all. The paper's finding was that centering dominated runtime because it
is pure off-chip traffic; the operator architecture finishes that argument
by deleting the n² write (and the solver's k re-reads) entirely:

* ``core.operators.CenteredGramOperator`` hoists the row/global means of
  ``E = −½D∘D`` in one read of D and applies
  ``F @ X = E@X − r(1ᵀX) − 1(rᵀX) + m·1(1ᵀX)`` to skinny (n, k+p) blocks,
  with the E-formation fused into each row-blocked matmul (XLA) or
  VMEM-tiled in-register (``kernels.center_matvec``, ``matvec_impl=
  "pallas"``). Sfiligoi et al. 2021 ("Enabling microbiome research on
  personal devices") make the same point from the footprint side: dropping
  the materialized intermediate is what lets large-cohort ordination fit
  on small machines.
* ``method="fsvd"`` — randomized range-finder with power iterations
  (Halko et al. 2011, Algs. 4.3/5.3) driven entirely through
  ``operator.matvec``; ``materialize=True`` restores the old
  materialize-then-solve path (the perf baseline in ``--suite pcoa``).
* ``method="eigh"`` — exact symmetric eigendecomposition: the oracle. It
  needs the full matrix, so it always materializes (via ``centering_impl``:
  "ref" / "fused" / "distributed").
* ``centering_impl="distributed"`` with ``materialize=False`` routes each
  matvec through the shard_map mesh layout of ``core.centering``
  (``operators.centered_gram_matvec_distributed``) — no n² tensor crosses
  the interconnect, or even exists per device beyond the D blocks.

Output mirrors scikit-bio's ``OrdinationResults``: coordinates scaled by
√λ, eigenvalues, and the proportion of variance explained. Convention for
non-Euclidean distances (which Gower centering can take to negative
eigenvalues): the numerator clamps negative eigenvalues to zero, as
scikit-bio does, while the denominator is the **exact** total inertia
``Σλ = tr(F)`` from ``operator.trace()`` — previously a materialized
``jnp.trace`` whose ``total <= 0`` fallback silently renormalized by only
the top-k inertia. ``tr(F) ≥ 0`` always (E ≤ 0 entrywise), with equality
only for the all-zero matrix, where the proportions are defined as 0.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import centering
from repro.core.distance_matrix import DistanceMatrix
from repro.core.operators import (CenteredGramOperator,
                                  centered_gram_matvec_distributed)


@dataclasses.dataclass
class PCoAResults:
    coordinates: jax.Array          # (n, k) — samples in ordination space
    eigenvalues: jax.Array          # (k,)
    proportion_explained: jax.Array # (k,)
    method: str = "fsvd"


# --------------------------------------------------------------------------
# Randomized eigensolver (Halko et al. 2011) — matvec-driven
# --------------------------------------------------------------------------
def _subspace_iteration(matvec, n: int, dtype, key, k: int, oversample: int,
                        power_iters: int):
    """Top-k eigenpairs of a symmetric operator given only ``matvec``.

    Range finder: Y = A Ω, orthonormalize, power-iterate (A symmetric ⇒
    AᵀA = A²); project T = QᵀAQ (small, (k+p)²); exact eigh of T lifts
    back. Every O(n²k)-flop step is a single fused matvec — the operator
    decides whether that is a sharded matmul, a row-blocked XLA sweep or
    the Pallas kernel.
    """
    p = min(k + oversample, n)
    omega = jax.random.normal(key, (n, p), dtype=dtype)
    q, _ = jnp.linalg.qr(matvec(omega))
    for _ in range(power_iters):
        q, _ = jnp.linalg.qr(matvec(q))
    t = q.T @ matvec(q)                    # (p, p) — tiny, host-side cost
    t = 0.5 * (t + t.T)
    evals, evecs = jnp.linalg.eigh(t)
    # eigh returns ascending; take top-k by value (descending)
    order = jnp.argsort(-evals)[:k]
    return evals[order], (q @ evecs)[:, order]


@partial(jax.jit, static_argnames=("k", "oversample", "power_iters"))
def _randomized_eigh_matfree(op: CenteredGramOperator, key, k: int,
                             oversample: int = 10, power_iters: int = 2):
    """Matrix-free fsvd: the operator pytree crosses the jit boundary with
    its tiling metadata static, so repeated solves of one shape reuse the
    executable."""
    return _subspace_iteration(op.matvec, op.n, op.dtype, key, k,
                               oversample, power_iters)


@partial(jax.jit, static_argnames=("k", "oversample", "power_iters"))
def _randomized_eigh(a: jax.Array, key, k: int, oversample: int = 10,
                     power_iters: int = 2):
    """Materialized fsvd — the baseline the benchmarks race against."""
    return _subspace_iteration(lambda x: a @ x, a.shape[0], a.dtype, key, k,
                               oversample, power_iters)


@partial(jax.jit, static_argnames=("k",))
def _exact_eigh(a: jax.Array, k: int):
    evals, evecs = jnp.linalg.eigh(a)
    order = jnp.argsort(-evals)[:k]
    return evals[order], evecs[:, order]


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------
def _materialized_gram(dm_data: jax.Array, centering_impl: str, mesh):
    if centering_impl == "ref":
        return centering.center_distance_matrix_ref(dm_data)
    if centering_impl == "fused":
        return centering.center_distance_matrix(dm_data)
    if centering_impl == "distributed":
        if mesh is None:
            raise ValueError("distributed centering requires a mesh")
        return centering.center_distance_matrix_distributed(dm_data, mesh)
    raise ValueError(f"unknown centering_impl {centering_impl!r}")


def pcoa(dm: DistanceMatrix, dimensions: int = 10, method: str = "fsvd",
         key: Optional[jax.Array] = None, mesh=None,
         centering_impl: str = "fused", materialize: bool = False,
         matvec_impl: str = "xla", block: int = 256) -> PCoAResults:
    """Principal Coordinates Analysis of a distance matrix.

    ``method="fsvd"`` (default) runs **matrix-free** against a
    ``CenteredGramOperator`` — no n×n intermediate is ever written; pass
    ``materialize=True`` for the legacy materialize-then-solve path (the
    benchmark baseline). ``method="eigh"`` is the exact oracle and always
    materializes. ``centering_impl`` ("ref" | "fused" | "distributed")
    selects the centering for materialized paths; with
    ``materialize=False`` only "distributed" changes behaviour, routing
    each matvec through the shard_map mesh. ``matvec_impl``: "xla"
    (row-blocked) | "pallas" (``kernels.center_matvec``).
    """
    if key is None:
        key = jax.random.PRNGKey(42)
    # scikit-bio's pcoa makes an internal copy of the DistanceMatrix — the
    # paper's validation-caching means this copy is free of revalidation.
    dm = dm.copy()
    n = len(dm)
    k = min(dimensions, n)

    if method == "eigh":
        centered = _materialized_gram(dm.data, centering_impl, mesh)
        evals, evecs = _exact_eigh(centered, k)
        total = jnp.trace(centered)          # exact: the matrix exists
    elif method == "fsvd":
        if materialize:
            centered = _materialized_gram(dm.data, centering_impl, mesh)
            evals, evecs = _randomized_eigh(centered, key, k)
            total = jnp.trace(centered)
        elif centering_impl == "distributed":
            if mesh is None:
                raise ValueError("distributed matvec requires a mesh")
            evals, evecs = _subspace_iteration(
                lambda x: centered_gram_matvec_distributed(dm.data, x, mesh),
                n, dm.data.dtype, key, k, oversample=10, power_iters=2)
            total = CenteredGramOperator.from_distance(dm.data).trace()
        else:
            op = CenteredGramOperator.from_distance(dm.data, block=block,
                                                    impl=matvec_impl)
            evals, evecs = _randomized_eigh_matfree(op, key, k)
            total = op.trace()
    else:
        raise ValueError(f"unknown method {method!r}")

    pos = jnp.maximum(evals, 0.0)
    coordinates = evecs * jnp.sqrt(pos)[None, :]
    # proportion explained: clamped eigenvalues over the EXACT total
    # inertia Σλ = tr(F) — from the operator's hoisted sums on matrix-free
    # paths, jnp.trace of the already-materialized matrix otherwise. With
    # fsvd only k eigenvalues are known, so a top-k denominator would
    # silently overstate every proportion. tr(F) = 0 only for the all-zero
    # matrix.
    proportion = jnp.where(total > 0, pos / total, jnp.zeros_like(pos))
    return PCoAResults(coordinates=coordinates, eigenvalues=evals,
                       proportion_explained=proportion, method=method)
