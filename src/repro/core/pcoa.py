"""Principal Coordinates Analysis: paper §4.1, operator-based.

``pcoa = centering + eigendecomposition`` — and since PR 2 the two halves
are *fused*: the default path never materializes the Gower-centered matrix
at all. The paper's finding was that centering dominated runtime because it
is pure off-chip traffic; the operator architecture finishes that argument
by deleting the n² write (and the solver's k re-reads) entirely:

* ``core.operators.CenteredGramOperator`` hoists the row/global means of
  ``E = −½D∘D`` in one read of D and applies
  ``F @ X = E@X − r(1ᵀX) − 1(rᵀX) + m·1(1ᵀX)`` to skinny (n, k+p) blocks,
  with the E-formation fused into each row-blocked matmul (XLA) or
  VMEM-tiled in-register (``kernels.center_matvec``, ``matvec_impl=
  "pallas"``). Sfiligoi et al. 2021 ("Enabling microbiome research on
  personal devices") make the same point from the footprint side: dropping
  the materialized intermediate is what lets large-cohort ordination fit
  on small machines.
* ``method="fsvd"`` — randomized range-finder with power iterations
  (Halko et al. 2011, Algs. 4.3/5.3) driven entirely through
  ``operator.matvec``; ``materialize=True`` restores the old
  materialize-then-solve path (the perf baseline in ``--suite pcoa``).
* ``method="eigh"`` — exact symmetric eigendecomposition: the oracle. It
  needs the full matrix, so it always materializes (via ``centering_impl``:
  "ref" / "fused" / "distributed").
* ``centering_impl="distributed"`` with ``materialize=False`` routes each
  matvec through the shard_map mesh layout of ``core.centering``
  (``operators.centered_gram_matvec_distributed``) — no n² tensor crosses
  the interconnect, or even exists per device beyond the D blocks.

Output mirrors scikit-bio's ``OrdinationResults``: coordinates scaled by
√λ, eigenvalues, and the proportion of variance explained. Convention for
non-Euclidean distances (which Gower centering can take to negative
eigenvalues): the numerator clamps negative eigenvalues to zero, as
scikit-bio does, while the denominator is the **exact** total inertia
``Σλ = tr(F)`` from ``operator.trace()`` — previously a materialized
``jnp.trace`` whose ``total <= 0`` fallback silently renormalized by only
the top-k inertia. ``tr(F) ≥ 0`` always (E ≤ 0 entrywise), with equality
only for the all-zero matrix, where the proportions are defined as 0.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.api.config import ExecConfig
from repro.api.results import OrdinationResult
from repro.core import centering
from repro.core.distance_matrix import DistanceMatrix
from repro.core.operators import (CenteredGramOperator,
                                  centered_gram_matvec_distributed)
from repro.obs.compile import note_trace
from repro.obs.trace import current_obs

# Legacy name for the unified ordination result (same class; the api
# redesign moved it to repro.api.results and added the recorded RNG key).
PCoAResults = OrdinationResult


def resolve_dimensions(dimensions: Optional[int], n: int) -> int:
    """THE validation rule for requested ordination dimensionality.

    ``None`` means "all axes" (n - 1, scikit-bio's PERMDISP convention);
    ``dimensions <= 0`` raises; ``dimensions > n`` clamps to n. Both the
    fsvd and eigh paths (and permdisp's forwarding) route through this one
    helper — previously fsvd and eigh diverged on non-positive input
    (negative k silently sliced from the *bottom* of the spectrum).
    """
    if dimensions is None:
        return max(n - 1, 1)
    d = int(dimensions)
    if d != dimensions:
        raise ValueError(f"dimensions must be an integer, got {dimensions!r}")
    if d <= 0:
        raise ValueError(f"dimensions must be positive, got {d}")
    return min(d, n)


# --------------------------------------------------------------------------
# Randomized eigensolver (Halko et al. 2011) — matvec-driven
# --------------------------------------------------------------------------
def _subspace_iteration(matvec, n: int, dtype, key, k: int, oversample: int,
                        power_iters: int):
    """Top-k eigenpairs of a symmetric operator given only ``matvec``.

    Range finder: Y = A Ω, orthonormalize, power-iterate (A symmetric ⇒
    AᵀA = A²); project T = QᵀAQ (small, (k+p)²); exact eigh of T lifts
    back. Every O(n²k)-flop step is a single fused matvec — the operator
    decides whether that is a sharded matmul, a row-blocked XLA sweep or
    the Pallas kernel.
    """
    p = min(k + oversample, n)
    omega = jax.random.normal(key, (n, p), dtype=dtype)
    q, _ = jnp.linalg.qr(matvec(omega))
    for _ in range(power_iters):
        q, _ = jnp.linalg.qr(matvec(q))
    t = q.T @ matvec(q)                    # (p, p) — tiny, host-side cost
    t = 0.5 * (t + t.T)
    evals, evecs = jnp.linalg.eigh(t)
    # eigh returns ascending; take top-k by value (descending)
    order = jnp.argsort(-evals)[:k]
    return evals[order], (q @ evecs)[:, order]


@partial(jax.jit, static_argnames=("k", "oversample", "power_iters"))
def _randomized_eigh_matfree(op: CenteredGramOperator, key, k: int,
                             oversample: int = 10, power_iters: int = 2):
    """Matrix-free fsvd: the operator pytree crosses the jit boundary with
    its tiling metadata static, so repeated solves of one shape reuse the
    executable."""
    note_trace("pcoa.fsvd_matfree", (op.n, k, oversample, power_iters))
    return _subspace_iteration(op.matvec, op.n, op.dtype, key, k,
                               oversample, power_iters)


@partial(jax.jit, static_argnames=("k", "oversample", "power_iters"))
def _randomized_eigh(a: jax.Array, key, k: int, oversample: int = 10,
                     power_iters: int = 2):
    """Materialized fsvd — the baseline the benchmarks race against."""
    note_trace("pcoa.fsvd_materialized",
               (a.shape[0], k, oversample, power_iters))
    return _subspace_iteration(lambda x: a @ x, a.shape[0], a.dtype, key, k,
                               oversample, power_iters)


@partial(jax.jit, static_argnames=("k",))
def _exact_eigh(a: jax.Array, k: int):
    note_trace("pcoa.eigh", (a.shape[0], k))
    evals, evecs = jnp.linalg.eigh(a)
    order = jnp.argsort(-evals)[:k]
    return evals[order], evecs[:, order]


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------
def materialized_gram(dm_data: jax.Array, centering_impl: str = "fused",
                      mesh=None) -> jax.Array:
    """The full Gower-centered matrix via the selected centering impl —
    the one entry point PERMANOVA's hoist and the eigh/materialized
    ordination paths share (so a Workspace can cache exactly one)."""
    if centering_impl == "ref":
        return centering.center_distance_matrix_ref(dm_data)
    if centering_impl == "fused":
        return centering.center_distance_matrix(dm_data)
    if centering_impl == "distributed":
        if mesh is None:
            raise ValueError("distributed centering requires a mesh")
        return centering.center_distance_matrix_distributed(dm_data, mesh)
    raise ValueError(f"unknown centering_impl {centering_impl!r}")


def pcoa(dm: Optional[DistanceMatrix], dimensions: int = 10,
         method: str = "fsvd", key=None, mesh=None,
         centering_impl: str = "fused", materialize: bool = False,
         matvec_impl: str = "xla", block: int = 256,
         config: Optional[ExecConfig] = None,
         operator: Optional[CenteredGramOperator] = None,
         gram: Optional[jax.Array] = None,
         check_finite: bool = True) -> OrdinationResult:
    """Principal Coordinates Analysis of a distance matrix.

    ``method="fsvd"`` (default) runs **matrix-free** against a
    ``CenteredGramOperator`` — no n×n intermediate is ever written; pass
    ``materialize=True`` for the legacy materialize-then-solve path (the
    benchmark baseline). ``method="eigh"`` is the exact oracle and always
    materializes.

    Execution knobs resolve from ``config`` (an ``api.ExecConfig``) when
    given; the legacy kwargs (``mesh``/``centering_impl``/``materialize``/
    ``matvec_impl``/``block``) are kept for compatibility and are ignored
    when ``config`` is present. ``key`` accepts a PRNG key or int seed
    (``stats.engine.as_key``; None -> the documented seed 42). A Workspace
    passes its cached ``operator`` (matrix-free paths) or ``gram`` (the
    materialized Gower matrix, eigh/materialized paths) so the O(n²)
    hoists run once per session, not once per call; ``dimensions`` is
    validated by ``resolve_dimensions`` (<= 0 raises, > n clamps)
    identically on every path.

    ``dm=None`` is the fully matrix-free entry: a prebuilt ``operator``
    (e.g. the condensed-backed one ``Workspace.from_features`` hoists
    straight out of the ``repro.dist`` tile sweep) stands in for the
    square matrix entirely — only legal for the matrix-free fsvd path,
    since eigh/materialized solves need an actual matrix. Non-finite
    input is rejected up front (``check_finite=False`` for callers that
    already validated, e.g. a Workspace session): a NaN in D otherwise
    propagates silently into the eigenvalues.
    """
    from repro.core.validation import ensure_finite
    from repro.stats.engine import as_key
    cfg = config if config is not None else ExecConfig(
        mesh=mesh, centering_impl=centering_impl, materialize=materialize,
        matvec_impl=matvec_impl, block=block)
    key = as_key(key, default=42)

    if dm is None:
        if operator is None:
            raise ValueError("pcoa needs a DistanceMatrix or a prebuilt "
                             "operator")
        if method != "fsvd" or cfg.materialize or \
                cfg.centering_impl == "distributed":
            raise ValueError("dm=None (operator-only) is limited to the "
                             "matrix-free fsvd path; eigh/materialized/"
                             "distributed solves need the square matrix")
    elif check_finite:
        ensure_finite(dm.data)

    def _gram(data):
        return gram if gram is not None else \
            materialized_gram(data, cfg.centering_impl, cfg.mesh)

    # a prebuilt artifact the taken path would ignore is a caller error —
    # silently dropping the O(n²) hoist they paid for would defeat the
    # entire point of passing it
    needs_gram = method == "eigh" or (method == "fsvd" and cfg.materialize)
    if gram is not None and not needs_gram:
        raise ValueError("a prebuilt gram is only consumed by eigh / "
                         "materialized paths; this call runs matrix-free "
                         "(pass operator= instead)")
    if operator is not None and needs_gram:
        raise ValueError("a prebuilt operator is only consumed by the "
                         "matrix-free fsvd path (pass gram= instead)")

    if dm is not None:
        # scikit-bio's pcoa makes an internal copy of the DistanceMatrix —
        # the paper's validation-caching means this copy is free of
        # revalidation.
        dm = dm.copy()
        n = len(dm)
    else:
        n = operator.n
    k = resolve_dimensions(dimensions, n)

    if method not in ("eigh", "fsvd"):
        raise ValueError(f"unknown method {method!r}")
    with current_obs().span(f"pcoa.{method}", phase="solve", n=n, k=k,
                            materialize=cfg.materialize,
                            impl=cfg.matvec_impl):
        if method == "eigh":
            centered = _gram(dm.data)
            evals, evecs = _exact_eigh(centered, k)
            total = jnp.trace(centered)      # exact: the matrix exists
            key = None                       # deterministic — no RNG used
        elif cfg.materialize:
            centered = _gram(dm.data)
            evals, evecs = _randomized_eigh(centered, key, k)
            total = jnp.trace(centered)
        elif cfg.centering_impl == "distributed":
            if cfg.mesh is None:
                raise ValueError("distributed matvec requires a mesh")
            evals, evecs = _subspace_iteration(
                lambda x: centered_gram_matvec_distributed(dm.data, x,
                                                           cfg.mesh),
                n, dm.data.dtype, key, k, oversample=10, power_iters=2)
            total = (operator if operator is not None else
                     CenteredGramOperator.from_distance(dm.data)).trace()
        else:
            op = operator if operator is not None else \
                CenteredGramOperator.from_distance(
                    dm.data, block=cfg.block, impl=cfg.matvec_impl,
                    interpret=cfg.interpret)
            evals, evecs = _randomized_eigh_matfree(op, key, k)
            total = op.trace()

    pos = jnp.maximum(evals, 0.0)
    coordinates = evecs * jnp.sqrt(pos)[None, :]
    # proportion explained: clamped eigenvalues over the EXACT total
    # inertia Σλ = tr(F) — from the operator's hoisted sums on matrix-free
    # paths, jnp.trace of the already-materialized matrix otherwise. With
    # fsvd only k eigenvalues are known, so a top-k denominator would
    # silently overstate every proportion. tr(F) = 0 only for the all-zero
    # matrix.
    proportion = jnp.where(total > 0, pos / total, jnp.zeros_like(pos))
    return OrdinationResult(coordinates=coordinates, eigenvalues=evals,
                            proportion_explained=proportion, method=method,
                            key=key)
