"""PCoA matrix centering: paper §4.1, Algorithms 1 & 2.

Gower double-centering:  ``F = E - rowmean(E) - colmean(E) + mean(E)`` with
``E = -0.5 * D * D``.

Three implementations:

* ``center_distance_matrix_ref`` — Algorithm 1 verbatim: eager, one NumPy-style
  op at a time. 8 matrix reads + 5 matrix writes of off-chip traffic.
* ``center_distance_matrix`` — Algorithm 2's *fusion*, expressed as a single
  jit region: pass 1 computes E, its row sums and the global sum in one sweep
  (symmetry ⇒ row means == col means, the paper's trick); pass 2 applies the
  centering. 2 reads + 2 writes. The explicitly VMEM-tiled version is the
  Pallas kernel in ``repro.kernels.center``.
* ``center_distance_matrix_distributed`` — the pod-scale analogue (DESIGN §2):
  matrix 2-D block-sharded over ('data','model'); each pass is block-local
  with exactly one ``psum`` of the O(n) means vector. No matrix-sized tensor
  ever crosses ICI.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# --------------------------------------------------------------------------
# Algorithm 1 — original scikit-bio implementation (eager, memory-bound)
# --------------------------------------------------------------------------
def e_matrix_ref(distance_matrix: jax.Array) -> jax.Array:
    return distance_matrix * distance_matrix / -2


def f_matrix_ref(e_mat: jax.Array) -> jax.Array:
    row_means = e_mat.mean(axis=1, keepdims=True)
    col_means = e_mat.mean(axis=0, keepdims=True)
    matrix_mean = e_mat.mean()
    return e_mat - row_means - col_means + matrix_mean


def center_distance_matrix_ref(distance_matrix: jax.Array) -> jax.Array:
    """Eager multi-pass centering, mirroring NumPy's evaluation order."""
    # jax.block_until_ready between steps is not needed for correctness;
    # eager dispatch already materializes every intermediate like NumPy does.
    return f_matrix_ref(e_matrix_ref(distance_matrix))


# --------------------------------------------------------------------------
# Algorithm 2 — fused two-pass centering
# --------------------------------------------------------------------------
@jax.jit
def center_distance_matrix(distance_matrix: jax.Array) -> jax.Array:
    """Fused centering. One jit region ⇒ XLA keeps E in registers/VMEM between
    the elementwise map and the row reduction; the symmetric-matrix trick
    (row means == col means) halves the reduction work exactly as in the
    paper's ``e_matrix_means_cy``."""
    # pass 1: E, row sums, global sum in one sweep
    e = -0.5 * distance_matrix * distance_matrix
    row_means = jnp.mean(e, axis=1)            # symmetric ⇒ also the col means
    global_mean = jnp.mean(row_means)
    # pass 2: tiled application (XLA fuses sub+add into one traversal)
    return e - row_means[:, None] - row_means[None, :] + global_mean


@partial(jax.jit, static_argnames=("block",))
def center_distance_matrix_blocked(distance_matrix: jax.Array, block: int = 1024) -> jax.Array:
    """Structurally faithful port of Algorithm 2's two Cython loops, with
    explicit row-block tiling (`prange(n_samples)` → scan over row blocks).
    Exists to validate the tiling logic the Pallas kernel uses.

    ``n % block != 0`` is handled by zero-padding the trailing block: padded
    entries contribute 0 to E (−½·0² = 0), so every sum over the *true* n
    is unchanged; the means divide by the true n explicitly and the padded
    rows/columns are sliced off at the end."""
    n = distance_matrix.shape[0]
    # clamp the block so a small n is never padded to a full default-sized
    # block (n=100 with block=1024 would scan ~105x the real data)
    block = min(block, ((n + 7) // 8) * 8)
    pad = (-n) % block
    if pad:
        distance_matrix = jnp.pad(distance_matrix, ((0, pad), (0, pad)))
    n_padded = n + pad
    nb = n_padded // block

    # pass 1: e_matrix_means — compute E row-block at a time, accumulate sums
    def pass1(carry, i):
        del carry
        rows = jax.lax.dynamic_slice(distance_matrix, (i * block, 0),
                                     (block, n_padded))
        e_rows = -0.5 * rows * rows
        return None, (e_rows, jnp.sum(e_rows, axis=1))

    _, (e_blocks, row_sum_blocks) = jax.lax.scan(pass1, None, jnp.arange(nb))
    e = e_blocks.reshape(n_padded, n_padded)
    row_sums = row_sum_blocks.reshape(n_padded)
    row_means = row_sums / n                       # true n, not n_padded
    global_mean = jnp.sum(row_sums) / (n * n)

    # pass 2: f_matrix_inplace — tiled centering
    def pass2(carry, i):
        del carry
        e_rows = jax.lax.dynamic_slice(e, (i * block, 0), (block, n_padded))
        rm = jax.lax.dynamic_slice(row_means, (i * block,), (block,))
        out = e_rows + (global_mean - rm)[:, None] - row_means[None, :]
        return None, out

    _, out_blocks = jax.lax.scan(pass2, None, jnp.arange(nb))
    out = out_blocks.reshape(n_padded, n_padded)
    return out[:n, :n] if pad else out


# --------------------------------------------------------------------------
# Distributed centering — the paper's blocking argument at pod scale
# --------------------------------------------------------------------------
def center_distance_matrix_distributed(distance_matrix: jax.Array, mesh,
                                       row_axis: str = "data",
                                       col_axis: str = "model") -> jax.Array:
    """shard_map centering over a 2-D block-sharded matrix.

    Each device holds an (n/Pr, n/Pc) block. Pass 1 computes its E block and
    the block-local row sums; one ``psum`` over the column axis yields global
    row means (symmetry ⇒ no column reduction needed); a second scalar psum
    yields the global mean. Pass 2 is entirely local. Only O(n) bytes cross
    the interconnect — the ICI version of "read the matrix only twice".
    """
    n = distance_matrix.shape[0]

    def _local(block):
        e = -0.5 * block * block
        local_row_sums = jnp.sum(e, axis=1)
        row_sums = jax.lax.psum(local_row_sums, axis_name=col_axis)       # O(n/Pr) each
        row_means = row_sums / n
        global_sum = jax.lax.psum(jnp.sum(local_row_sums), axis_name=(row_axis, col_axis))
        global_mean = global_sum / (n * n)
        # col means for this block are the row means of the *column* owner;
        # with symmetric D they equal row_means indexed by global column. We
        # need the column-block slice of the full row-means vector: broadcast
        # via psum of a one-hot placement (cheap: O(n)).
        col_slice = jax.lax.all_gather(row_means, axis_name=row_axis, tiled=True)
        # col_slice is the full row-means vector (length n); take our columns
        j = jax.lax.axis_index(col_axis)
        cm = jax.lax.dynamic_slice(col_slice, (j * block.shape[1],), (block.shape[1],))
        return e - row_means[:, None] - cm[None, :] + global_mean

    f = jax.shard_map(
        _local, mesh=mesh,
        in_specs=P(row_axis, col_axis),
        out_specs=P(row_axis, col_axis),
    )
    return f(distance_matrix)
