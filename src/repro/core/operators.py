"""Matrix-free centered-Gram operators: the §4.1 traffic argument, finished.

``pcoa`` historically materialized the full Gower-centered matrix
``F = E − r·1ᵀ − 1·rᵀ + m`` (with ``E = −½ D∘D``, ``r`` its row means and
``m`` its global mean) before the randomized eigensolver touched it — one
whole n² write plus k re-reads of off-chip traffic. But the Halko solver
only ever consumes ``F`` through products with skinny (n, k+p) blocks, and
every term of ``F`` is cheap to apply on the fly:

    F @ X = E @ X − r (1ᵀX) − 1 (rᵀX) + m·1 (1ᵀX)

``CenteredGramOperator`` hoists ``r`` and ``m`` once (a single read of D,
no n² intermediate: the row means of E are ``−½·mean(D∘D, axis=1)``, which
XLA fuses into the reduction) and then exposes:

* ``matvec(x)``   — ``F @ x`` with the elementwise E-formation and the
  rank-1 centering corrections fused into each row-blocked matmul; peak
  extra memory is one (block, n) strip, never n².
* ``trace()``     — the exact total inertia Σλ from the hoisted sums:
  ``tr(F) = tr(E) − n·m`` (and ``tr(E) = 0`` for a hollow D), so
  ``proportion_explained`` needs no materialized matrix.
* ``materialize()`` — the full F via ``core.centering`` (the eigh oracle
  path).

``centered_gram_matvec_distributed`` is the pod-scale analogue: the same
matvec through the 2-D block-sharded mesh layout of
``core.centering.center_distance_matrix_distributed`` — only O(n·k) bytes
ever cross the interconnect.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.distance_matrix import MAX_TRIANGLE_N, condensed_index

try:                                    # jax >= 0.6 exports it at top level
    _shard_map = jax.shard_map
except AttributeError:                  # this container's 0.4.x lineage
    from jax.experimental.shard_map import shard_map as _shard_map


@partial(jax.tree_util.register_dataclass,
         data_fields=["d", "row_means", "global_mean"],
         meta_fields=["n", "block", "impl", "interpret"])
@dataclasses.dataclass
class CenteredGramOperator:
    """The Gower-centered Gram matrix of a distance matrix, as an operator.

    A pytree (``register_dataclass``) so it can cross ``jax.jit`` boundaries:
    ``d``/``row_means``/``global_mean`` are traced, the tiling metadata is
    static. ``impl`` selects the matvec backend: ``"xla"`` (row-blocked jnp,
    the default) or ``"pallas"`` (the VMEM-tiled ``kernels.center_matvec``).
    """

    d: jax.Array            # (n, n) distance matrix — the ONLY n² buffer
    row_means: jax.Array    # (n,)  row means of E = −½ D∘D (== col means)
    global_mean: jax.Array  # ()    global mean of E
    n: int
    block: int = 256
    impl: str = "xla"
    interpret: Optional[bool] = None    # Pallas only; None = auto by backend

    @classmethod
    def from_distance(cls, d: jax.Array, *, block: int = 256,
                      impl: str = "xla",
                      interpret: Optional[bool] = None) -> "CenteredGramOperator":
        """Hoist r and m in one read of D — no n² intermediate is written."""
        if impl not in ("xla", "pallas"):
            raise ValueError(f"unknown matvec impl {impl!r}")
        n = d.shape[0]
        # mean-of-square fuses the elementwise map into the row reduction
        row_means = -0.5 * jnp.mean(d * d, axis=1)
        return cls(d, row_means, jnp.mean(row_means), n, block, impl,
                   interpret)

    @property
    def dtype(self):
        return self.d.dtype

    # -- the operator interface --------------------------------------------
    def matvec(self, x: jax.Array) -> jax.Array:
        """``F @ x`` without materializing F (or even E).

        ``x``: (n, k) block (a 1-D vector is promoted and squeezed back).
        The rank-1 corrections cost O(nk); the E product is applied one
        (block, n) row strip at a time so the elementwise −½D∘D feeds the
        matmul straight from registers/cache.
        """
        squeeze = x.ndim == 1
        if squeeze:
            x = x[:, None]
        if self.impl == "pallas":
            # the kernel wrapper hoists its own correction vectors
            from repro.kernels.center_matvec_ops import center_matvec_pallas
            out = center_matvec_pallas(
                self.d, x, self.row_means, self.global_mean,
                block_m=self.block, block_n=self.block,
                interpret=self.interpret)
        else:
            colsum = jnp.sum(x, axis=0)                  # 1ᵀX   (k,)
            corr = self.global_mean * colsum - self.row_means @ x  # m·1ᵀX − rᵀX
            b = max(min(self.block, self.n), 1)
            parts = []
            for i0 in range(0, self.n, b):               # static row strips
                rows = self.d[i0:i0 + b]
                e_rows = -0.5 * rows * rows              # fused into the dot
                parts.append(e_rows @ x
                             - self.row_means[i0:i0 + b, None] * colsum[None, :]
                             + corr[None, :])
            out = jnp.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]
        return out[:, 0] if squeeze else out

    def trace(self) -> jax.Array:
        """Exact ``tr(F) = Σλ`` from the hoisted sums — no matrix needed.

        ``tr(F) = tr(E) − 2·Σr + n·m`` and ``Σr = n·m``, so
        ``tr(F) = tr(E) − n·m``; for a hollow D, ``tr(E) = 0`` and the
        total inertia is simply ``−n·m`` (≥ 0, since E ≤ 0 entrywise).
        The diagonal term is kept for robustness to non-hollow input.
        """
        tr_e = -0.5 * jnp.sum(jnp.diagonal(self.d) ** 2)
        return tr_e - self.n * self.global_mean

    def materialize(self) -> jax.Array:
        """The full F — the oracle path (``method="eigh"`` needs it)."""
        from repro.core.centering import center_distance_matrix
        return center_distance_matrix(self.d)


# --------------------------------------------------------------------------
# Condensed-backed operator — the repro.dist fusion target
# --------------------------------------------------------------------------
@partial(jax.tree_util.register_dataclass,
         data_fields=["dc", "row_means", "global_mean"],
         meta_fields=["n", "block"])
@dataclasses.dataclass
class CondensedCenteredGramOperator:
    """The same centered-Gram operator, backed by the CONDENSED distances.

    ``repro.dist`` produces distances tile-by-tile and accumulates the
    row/global means of E = −½ D∘D while doing so; this operator is what
    those artifacts plug into — the m = n(n−1)/2 condensed vector is the
    **only** large buffer (half a square's bytes, and never an n×n
    allocation), and each matvec row strip is gathered from it on the fly
    with closed-form triangle indexing:

        k(i, j) = i(2n − i − 1)/2 + (j − i − 1)   for i < j  (scipy layout)

    The strip gather is O(b·n) int arithmetic + one vectorized gather —
    the same formulation as ``condensed_to_square`` (XLA:CPU scalarizes
    the scatter inverse ~70x), but per-strip, so no n×n position map is
    ever built either. Index arithmetic is int32 — the peak intermediate
    ``lo·(2n − lo − 1)`` is < n², exact only for n ≤ 46340, and an
    overflow would CLAMP the wrapped gather indices into silently wrong
    distances — so construction refuses larger n outright (the x64-off
    container has no int64 escape hatch; out-of-core production is the
    ROADMAP path past this bound anyway).

    D is hollow by construction (the diagonal is identically 0), so
    ``trace`` needs no diagonal term: tr(F) = −n·m̄.
    """

    dc: jax.Array           # (m,) condensed distances — the ONLY big buffer
    row_means: jax.Array    # (n,)  row means of E = −½ D∘D
    global_mean: jax.Array  # ()    global mean of E
    n: int
    block: int = 256

    # the single shared int32-exact bound (kernels.permute_reduce
    # enforces the same constant); kept as a class attribute for callers
    # that introspect it
    _MAX_N = MAX_TRIANGLE_N

    def __post_init__(self):
        if self.n > self._MAX_N:
            raise ValueError(
                f"CondensedCenteredGramOperator supports n <= "
                f"{self._MAX_N} (int32 triangle indexing would overflow "
                f"and silently corrupt the gather); got n={self.n}")

    @classmethod
    def from_production(cls, prod: dict, *,
                        block: int = 256) -> "CondensedCenteredGramOperator":
        """Wrap a ``repro.dist.pairwise_condensed`` result — the means were
        already accumulated during the distance production, so this costs
        nothing."""
        return cls(prod["condensed"], prod["row_means"],
                   prod["global_mean"], prod["n"], block)

    @property
    def dtype(self):
        return self.dc.dtype

    def row_panel(self, i0: int, b: int) -> jax.Array:
        """Rows [i0, i0+b) of D gathered from the condensed vector."""
        if self.dc.shape[0] == 0:            # n <= 1: no off-diagonal pairs
            return jnp.zeros((b, self.n), dtype=self.dtype)
        r = jnp.arange(i0, i0 + b, dtype=jnp.int32)[:, None]
        c = jnp.arange(self.n, dtype=jnp.int32)[None, :]
        k = condensed_index(r, c, self.n)
        on_diag = r == c
        return jnp.where(on_diag, 0.0, self.dc[jnp.where(on_diag, 0, k)])

    # -- the operator interface (duck-typed with CenteredGramOperator) ------
    def matvec(self, x: jax.Array) -> jax.Array:
        """``F @ x`` with each D row strip gathered from condensed storage;
        peak extra memory is one (block, n) strip, never n²."""
        squeeze = x.ndim == 1
        if squeeze:
            x = x[:, None]
        colsum = jnp.sum(x, axis=0)                  # 1ᵀX   (k,)
        corr = self.global_mean * colsum - self.row_means @ x  # m·1ᵀX − rᵀX
        b = max(min(self.block, self.n), 1)
        parts = []
        for i0 in range(0, self.n, b):               # static row strips
            bi = min(b, self.n - i0)
            rows = self.row_panel(i0, bi)
            e_rows = -0.5 * rows * rows              # fused into the dot
            parts.append(e_rows @ x
                         - self.row_means[i0:i0 + bi, None] * colsum[None, :]
                         + corr[None, :])
        out = jnp.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]
        return out[:, 0] if squeeze else out

    def trace(self) -> jax.Array:
        """Exact ``tr(F) = Σλ``: the condensed form is hollow by
        construction, so tr(E) = 0 and tr(F) = −n·m̄."""
        return -self.n * self.global_mean

    def to_square(self) -> jax.Array:
        """The full symmetric hollow D — only for callers that explicitly
        demand a square hoist (gram/ranks); defeats the point otherwise."""
        from repro.core.distance_matrix import condensed_to_square
        return condensed_to_square(self.dc, self.n)

    def materialize(self) -> jax.Array:
        """The full Gower-centered F (the eigh oracle path)."""
        from repro.core.centering import center_distance_matrix
        return center_distance_matrix(self.to_square())


# --------------------------------------------------------------------------
# Distributed matvec — the shard_map mesh layout of core.centering
# --------------------------------------------------------------------------
def centered_gram_matvec_distributed(d: jax.Array, x: jax.Array, mesh,
                                     row_axis: str = "data",
                                     col_axis: str = "model") -> jax.Array:
    """``F @ x`` over a 2-D block-sharded D, no n² tensor anywhere.

    Same mesh layout as ``center_distance_matrix_distributed``: each device
    holds an (n/Pr, n/Pc) block of D. Per call it forms its E block in
    VMEM/cache, contracts against its column slice of X, and one ``psum``
    over the column axis assembles the row strip of E@X; the centering
    corrections need only O(n)+O(k) collectives (row sums over the column
    axis, 1ᵀX and rᵀX over the row axis). The hoisted statistics are
    recomputed per matvec — each device's share is O(n²/P) flops on a block
    it is already streaming, which keeps the function self-contained and
    the interconnect traffic at O(n·k).
    """
    n = d.shape[0]

    def _local(d_blk, x_col, x_row):
        e = -0.5 * d_blk * d_blk
        part = jax.lax.psum(e @ x_col, axis_name=col_axis)    # (n/Pr, k)
        local_row_sums = jnp.sum(e, axis=1)
        rm = jax.lax.psum(local_row_sums, axis_name=col_axis) / n
        gm = jax.lax.psum(jnp.sum(local_row_sums),
                          axis_name=(row_axis, col_axis)) / (n * n)
        colsum = jax.lax.psum(jnp.sum(x_row, axis=0), axis_name=row_axis)
        rmx = jax.lax.psum(rm @ x_row, axis_name=row_axis)
        return part - rm[:, None] * colsum[None, :] \
            + (gm * colsum - rmx)[None, :]

    f = _shard_map(
        _local, mesh=mesh,
        in_specs=(P(row_axis, col_axis), P(col_axis, None), P(row_axis, None)),
        out_specs=P(row_axis, None),
    )
    return f(d, x, x)
