"""DistanceMatrix: the object both paper workloads operate on.

Mirrors scikit-bio's ``DistanceMatrix`` semantics that matter for the paper:

* construction validates the buffer (symmetric + hollow) — §4.3 of the paper
  shows validation itself is a memory-bound hot spot, so validation goes
  through the fused single-pass implementation in ``core.validation``;
* the paper's final optimization — *validation caching* — is reproduced:
  ``copy()`` and any internally-produced permutation skip re-validation,
  because the source object is known-good (this directly sped up ``pcoa``,
  which copies the matrix internally).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import validation


class DistanceMatrixError(ValueError):
    """Raised when a buffer fails symmetric/hollow validation."""


@dataclasses.dataclass
class DistanceMatrix:
    """A validated, symmetric, hollow distance matrix.

    ``data`` is a square ``jnp.ndarray``. ``_validated`` implements the
    paper's §4.3 caching: objects derived from a validated matrix do not
    pay the validation pass again.
    """

    data: jax.Array
    ids: Optional[tuple] = None
    _validated: bool = dataclasses.field(default=False, repr=False)

    def __init__(self, data, ids=None, validate: bool = True, _skip_validation: bool = False):
        data = jnp.asarray(data)
        if data.ndim != 2 or data.shape[0] != data.shape[1]:
            raise DistanceMatrixError(f"expected a square 2-D buffer, got {data.shape}")
        self.data = data
        self.ids = tuple(ids) if ids is not None else tuple(range(data.shape[0]))
        if len(self.ids) != data.shape[0]:
            raise DistanceMatrixError("ids length does not match matrix size")
        self._validated = bool(_skip_validation)
        if validate and not self._validated:
            is_sym, is_hollow = validation.is_symmetric_and_hollow(self.data)
            if not bool(is_sym):
                raise DistanceMatrixError("matrix is not symmetric")
            if not bool(is_hollow):
                raise DistanceMatrixError("matrix is not hollow (non-zero diagonal)")
            self._validated = True

    # -- shape helpers -----------------------------------------------------
    @property
    def shape(self):
        return self.data.shape

    def __len__(self):
        return self.data.shape[0]

    # -- the paper's validation-caching trick ------------------------------
    def copy(self) -> "DistanceMatrix":
        """Copy without re-validating — paper §4.3 last paragraph."""
        return DistanceMatrix(self.data, ids=self.ids, _skip_validation=self._validated)

    # -- views --------------------------------------------------------------
    def condensed_form(self) -> jax.Array:
        """Upper-triangle (k=1) flattened view, like scipy squareform."""
        n = self.data.shape[0]
        iu = np.triu_indices(n, k=1)
        return self.data[iu]

    def permute(self, order, condensed: bool = False):
        """Permute rows+columns by ``order``. Permutation of a valid matrix
        is valid, so the result skips validation (paper §4.3)."""
        order = jnp.asarray(order)
        permuted = self.data[order][:, order]
        if condensed:
            n = self.data.shape[0]
            iu = np.triu_indices(n, k=1)
            return permuted[iu]
        return DistanceMatrix(permuted, ids=self.ids, _skip_validation=self._validated)


# int32 triangle indexing is exact only while lo*(2n - lo - 1) < 2**31:
# past this n the closed-form condensed index would silently wrap (and a
# wrapped gather CLAMPS into plausible-but-wrong distances), so every
# condensed-indexed path refuses larger n outright. floor(sqrt(2^31)).
MAX_TRIANGLE_N = 46340


def condensed_index(i, j, n: int):
    """Closed-form scipy-layout condensed index of pair ``(i, j)``:

        k(i, j) = lo*(2n - lo - 1)/2 + (hi - lo - 1),  lo = min, hi = max

    Vectorized over ``i``/``j`` (int32 arrays). Valid for ``i != j`` and
    ``n <= MAX_TRIANGLE_N`` (int32-exact); the diagonal has no condensed
    position, so callers mask it themselves."""
    lo = jnp.minimum(i, j)
    hi = jnp.maximum(i, j)
    return lo * (2 * n - lo - 1) // 2 + (hi - lo - 1)


def triangle_coords(n: int) -> tuple:
    """(ii, jj) int32 arrays of length m = n(n-1)/2: the (row, col) pair of
    every condensed position, in scipy ``pdist`` order.

    The inverse of ``condensed_index``, built with a searchsorted over the
    n hoisted row starts S(i) = i(2n-i-1)/2 instead of materializing an
    (n, n) position map — O(m log n), no n² intermediate, and no giant
    host constant baked into jitted hoists."""
    m = n * (n - 1) // 2
    if n < 2:
        z = jnp.zeros((0,), dtype=jnp.int32)
        return z, z
    i_all = jnp.arange(n, dtype=jnp.int32)
    row_starts = i_all * (2 * n - i_all - 1) // 2      # S(i), increasing
    k = jnp.arange(m, dtype=jnp.int32)
    ii = jnp.searchsorted(row_starts, k, side="right").astype(jnp.int32) - 1
    jj = k - row_starts[ii] + ii + 1
    return ii, jj


def condensed_to_square(condensed: jax.Array, n: int) -> jax.Array:
    """Inverse of ``condensed_form``: symmetric matrix with zero diagonal.

    Formulated as a gather through a host-precomputed (n, n) position map
    rather than an ``.at[iu].set`` scatter: XLA:CPU scalarizes the 2M-element
    scatter (~70x slower than the vectorized gather at n=2048), and this
    runs inside every hoist pass of the stats engine."""
    if n < 2:                              # empty triangle: nothing to gather
        return jnp.zeros((n, n), dtype=condensed.dtype)
    iu = np.triu_indices(n, k=1)
    pos = np.zeros((n, n), dtype=np.int32)
    pos[iu] = np.arange(iu[0].size, dtype=np.int32)
    pos = pos + pos.T                      # symmetric map; diagonal stays 0
    off_diag = ~np.eye(n, dtype=bool)
    return jnp.where(off_diag, condensed[pos], 0)


def random_distance_matrix(key, n: int, dim: int = 8, dtype=jnp.float32) -> DistanceMatrix:
    """A *valid* random distance matrix: Euclidean distances of random points.

    Guarantees symmetry, hollowness and (unlike uniform noise) a meaningful
    low-rank structure for PCoA to find.
    """
    pts = jax.random.normal(key, (n, dim), dtype=dtype)
    sq = jnp.sum(pts * pts, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (pts @ pts.T)
    d2 = jnp.maximum(d2, 0.0)
    d = jnp.sqrt(d2)
    d = 0.5 * (d + d.T)  # enforce exact symmetry against fp noise
    d = d - jnp.diag(jnp.diag(d))  # enforce exact hollowness
    return DistanceMatrix(d, _skip_validation=True)
