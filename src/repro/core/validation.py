"""Distance-matrix validation: paper §4.3, Algorithms 6 & 7.

``is_symmetric_and_hollow_ref`` reproduces the original scikit-bio code
*including its memory behaviour*: each NumPy-style op runs eagerly, so the
matrix buffer crosses main memory several times (``mat.T != mat`` allocates a
full boolean intermediate; ``trace`` is a separate pass).

``is_symmetric_and_hollow`` is the paper's Algorithm 7 adapted to JAX: both
checks fused into a single jit'd reduction, so XLA emits one pass over the
buffer and no boolean intermediate. The explicitly-tiled VMEM version lives in
``repro.kernels.symhollow``.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@jax.jit
def _all_finite(arr: jax.Array):
    # one fused reduction — no boolean intermediate reaches HBM/DRAM
    return jnp.all(jnp.isfinite(arr))


def ensure_finite(arr: jax.Array, what: str = "distance matrix") -> None:
    """Raise ``ValueError`` if ``arr`` contains NaN/Inf.

    The shared admission check of the analysis entry points (``Workspace``,
    ``pcoa``, ``Workspace.from_features``): a NaN in D otherwise propagates
    *silently* — into eigenvalues (LAPACK returns NaN spectra without
    complaint) and into permutation-test p-values (NaN comparisons are all
    False, which under-counts exceedances). One fused single-pass
    reduction, same discipline as the symmetric/hollow check.
    """
    if not bool(_all_finite(arr)):
        raise ValueError(
            f"{what} contains non-finite values (nan/inf); distances and "
            f"feature tables must be finite — clean the input (e.g. drop "
            f"or impute the offending samples) before analysis")


def is_symmetric_and_hollow_ref(mat: jax.Array):
    """Algorithm 6 — original scikit-bio implementation (eager, multi-pass)."""
    # Eager ops mirror NumPy's step-at-a-time evaluation: a full boolean
    # matrix is materialized, then reduced; the trace is yet another pass.
    not_sym = bool((mat.T != mat).any())
    not_hollow = bool(jnp.trace(mat) != 0)
    return (not not_sym), (not not_hollow)


@jax.jit
def _fused_sym_hollow(mat: jax.Array):
    # One fused kernel: equality compare against the transpose and the
    # diagonal-zero test share a single traversal; XLA fuses the two
    # reductions, no intermediate boolean buffer is written to HBM/DRAM.
    is_sym = jnp.all(mat == mat.T)
    is_hollow = jnp.all(jnp.diagonal(mat) == 0)
    return is_sym, is_hollow


def is_symmetric_and_hollow(mat: jax.Array):
    """Algorithm 7 — fused single-pass validation."""
    is_sym, is_hollow = _fused_sym_hollow(mat)
    return is_sym, is_hollow


@partial(jax.jit, static_argnames=("block",))
def is_symmetric_and_hollow_blocked(mat: jax.Array, block: int = 512):
    """Explicitly-tiled variant mirroring Algorithm 7's loop structure.

    Visits (i, j) tiles and compares against the transposed (j, i) tile so
    both tiles are resident in cache/VMEM together — the paper's 16x16 CPU
    tiling scaled up to TPU-friendly block sizes. Used as the structural
    reference for the Pallas kernel; on CPU it demonstrates that tiling and
    full fusion agree.
    """
    n = mat.shape[0]
    if n % block != 0:
        return _fused_sym_hollow(mat)
    nb = n // block

    def body(carry, idx):
        is_sym, is_hollow = carry
        i, j = idx // nb, idx % nb
        a = jax.lax.dynamic_slice(mat, (i * block, j * block), (block, block))
        b = jax.lax.dynamic_slice(mat, (j * block, i * block), (block, block))
        is_sym = jnp.logical_and(is_sym, jnp.all(a == b.T))
        diag_ok = jnp.all(jnp.diagonal(a) == 0)
        is_hollow = jnp.logical_and(is_hollow, jnp.where(i == j, diag_ok, True))
        return (is_sym, is_hollow), None

    (is_sym, is_hollow), _ = jax.lax.scan(
        body, (jnp.array(True), jnp.array(True)), jnp.arange(nb * nb)
    )
    return is_sym, is_hollow
