"""Core paper contribution: memory-access-optimized distance-matrix analytics.

Paper: Sfiligoi, McDonald, Knight — "Accelerating key bioinformatics tasks
100-fold by improving memory access" (PEARC '21).
"""

from repro.core.distance_matrix import (
    DistanceMatrix,
    DistanceMatrixError,
    condensed_to_square,
    random_distance_matrix,
)
from repro.core.validation import (
    ensure_finite,
    is_symmetric_and_hollow,
    is_symmetric_and_hollow_blocked,
    is_symmetric_and_hollow_ref,
)
from repro.core.centering import (
    center_distance_matrix,
    center_distance_matrix_blocked,
    center_distance_matrix_distributed,
    center_distance_matrix_ref,
)
from repro.core.operators import (
    CenteredGramOperator,
    CondensedCenteredGramOperator,
    centered_gram_matvec_distributed,
)
from repro.core.mantel import (condensed_moments, condensed_moments_vec,
                               hat_square, mantel, mantel_distributed,
                               mantel_ref, pearsonr_ref)
from repro.core.pcoa import (OrdinationResult, PCoAResults,
                             materialized_gram, pcoa, resolve_dimensions)

__all__ = [
    "DistanceMatrix", "DistanceMatrixError", "condensed_to_square",
    "random_distance_matrix",
    "ensure_finite", "is_symmetric_and_hollow",
    "is_symmetric_and_hollow_blocked", "is_symmetric_and_hollow_ref",
    "center_distance_matrix", "center_distance_matrix_blocked",
    "center_distance_matrix_distributed", "center_distance_matrix_ref",
    "CenteredGramOperator", "CondensedCenteredGramOperator",
    "centered_gram_matvec_distributed",
    "condensed_moments", "condensed_moments_vec", "hat_square", "mantel",
    "mantel_distributed", "mantel_ref", "pearsonr_ref",
    "OrdinationResult", "PCoAResults", "materialized_gram", "pcoa",
    "resolve_dimensions",
]
