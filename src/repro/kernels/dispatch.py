"""Shared backend-dispatch and tile-snapping policy for every kernel.

Before this module the four kernel dispatchers (``center_matvec_ops``,
``mantel_corr_ops``, ``pairwise_ops``, ``permute_reduce_ops``) each
carried their own copy of the same three decisions:

* **interpret resolution** — ``None`` means "TPU-native on a TPU
  backend, the Pallas interpreter everywhere else" (this container's
  CPU);
* **lane geometry** — TPU-native tiles need lane-aligned (multiple of
  128) trailing dims while the interpreter is happy with the fp32
  sublane multiple of 8, so every tile knob is snapped down to the
  backend's lane before use;
* **tile snapping** — the largest multiple-of-lane block ``<=``
  requested, clamped to the problem size, with a floor for tiny inputs.

``repro.tune`` (the cost-model autotuner) consumes the SAME helpers, so
the tile sizes the solver models are exactly the tile sizes the kernels
execute — a lane-width change lands in the model and the dispatchers
simultaneously, and the two can never drift.

``center_matvec_ops`` re-exports ``pick_block``/``resolve_interpret``
for backward compatibility; new code should import from here.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

#: fp32 sublane multiple — the snap unit in interpreter mode (and for
#: row-ish dims everywhere).
SUBLANE = 8
#: TPU-native Mosaic lane width — trailing tile dims must be multiples
#: of this when ``interpret=False`` resolves on a TPU backend.
TPU_LANE = 128


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """None = auto: native on TPU, interpreter everywhere else."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def lane_geometry(interpret: Optional[bool]) -> Tuple[int, int]:
    """(lane, floor) for trailing tile dims under the resolved dispatch
    mode: interpreter tiles snap to the fp32 sublane (8) and may shrink
    to 1 for tiny inputs; TPU-native tiles must stay lane-legal (128
    both as snap unit and floor)."""
    if resolve_interpret(interpret):
        return SUBLANE, 1
    return TPU_LANE, TPU_LANE


def pick_block(n: int, requested: int, lane: int = SUBLANE,
               floor: int = 1) -> int:
    """Largest multiple-of-``lane`` block <= requested (tiny n falls back
    to ``floor``; native TPU callers pass floor=lane to keep tiles
    lane-legal). THE single home of the lane-snapping rule — every
    kernel dispatcher and the ``repro.tune`` solver route through it, so
    modeled tiles and executed tiles are the same numbers."""
    b = min(requested, n)
    if b >= lane:
        b -= b % lane
    return max(b, floor)


def clamp_block(n: int, requested: int) -> int:
    """The un-laned clamp used by the pure-XLA row-panel paths
    (``dist.driver``, the operator row blocks): any block in [1, n] is
    legal there, so the policy is just ``max(min(requested, n), 1)``."""
    return max(min(requested, n), 1)


def snap_chunk(m: int, chunk: int) -> Tuple[int, int]:
    """(chunk, m_pad) for a 1-D condensed stream of length ``m``: snap
    the chunk to the 8-aligned condensed length so tiny problems don't
    pad 630 entries up to 65536, then pad ``m`` up to a chunk multiple.
    Shared by ``permute_reduce_ops`` and the tuner's chunk model."""
    m8 = -(-max(m, 1) // SUBLANE) * SUBLANE
    chunk = max(min(chunk, m8), 1)
    return chunk, -(-m // chunk) * chunk
