"""Pallas kernel: batched permuted-Pearson reduction (paper §4.2, Algorithm 5).

TPU-native formulation of the paper's fused Mantel inner loop (DESIGN §2):

* the permutation-invariant statistics (x̄, ‖x−x̄‖, ŷ) are hoisted by the
  caller — the paper's two big wins;
* the permutation *gathers* run in XLA (contiguous row gathers — the same
  locality argument the paper's Cython loop makes for its row-resident
  access); scalar random access does not vectorize on the VPU;
* this kernel fuses everything downstream: the multiply-reduce of a *batch*
  of B permuted matrices against the shared Ŷ, tiled so each Ŷ tile is
  fetched into VMEM **once per (i,j) and reused across all B permutations**
  (the b grid dimension is innermost; Pallas elides the re-fetch when the
  BlockSpec index is unchanged between consecutive steps). HBM traffic per
  permutation: n² (its own Xp) + n²/B (its share of Ŷ) — vs the original's
  ~5·n² per permutation (fresh mean/norm/divide/dot passes).

The mean-subtraction drops out entirely: Σŷ = 0 ⇒
``r_p = ⟨x_p, ŷ⟩ / ‖x−x̄‖`` (DESIGN §3.2), so the kernel is a pure
fused multiply-accumulate — ideal VPU work.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.center_matvec_ops import resolve_interpret


def _mantel_kernel(xp_ref, y_ref, out_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    xp = xp_ref[...]          # (1, bm, bn) — this permutation's tile
    y = y_ref[...]            # (bm, bn)    — shared, VMEM-resident across b
    out_ref[...] += jnp.sum(xp[0] * y)[None]


def mantel_corr(xp: jax.Array, yhat: jax.Array, *, block_m: int,
                block_n: int, interpret: Optional[bool] = None) -> jax.Array:
    """stats[b] = Σ_ij xp[b,i,j]·yhat[i,j]; caller divides by 2‖x−x̄‖.

    xp: (B, n, n) batch of row+col permuted X. yhat: (n, n) symmetric
    centered-normalized Y with zero diagonal. ``interpret=None`` resolves
    by backend: native Mosaic lowering on a TPU, the Pallas interpreter
    everywhere else (this container's CPU).
    """
    interpret = resolve_interpret(interpret)
    b_perms, n, _ = xp.shape
    grid = (n // block_m, n // block_n, b_perms)   # b innermost → Y-tile reuse
    return pl.pallas_call(
        _mantel_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, block_n), lambda i, j, b: (b, i, j)),
            pl.BlockSpec((block_m, block_n), lambda i, j, b: (i, j)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i, j, b: (b,)),
        out_shape=jax.ShapeDtypeStruct((b_perms,), xp.dtype),
        interpret=interpret,
    )(xp, yhat)
