"""Pure-jnp oracle for the validation kernel (paper Algorithm 6 semantics)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def is_symmetric_and_hollow_ref(mat: jax.Array):
    """Returns (is_sym: bool array, is_hollow: bool array)."""
    not_sym = (mat.T != mat).any()
    not_hollow = jnp.trace(jnp.abs(mat)) != 0  # |.| guards cancelling +/- diag
    return jnp.logical_not(not_sym), jnp.logical_not(not_hollow)
