"""jit'd public wrapper for the fused centering kernel.

Handles block-size selection (VMEM budget + (8,128) fp32 native-tile
alignment), non-divisible shapes (pad to block multiple — padding rows
contribute zeros to sums because D is padded with zeros and E = -D²/2),
and the mean normalizations that the kernels leave as sums.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.center import center_pass1, center_pass2
# snapping policy shared with every dispatcher and the repro.tune solver
from repro.kernels.dispatch import pick_block as _pick_block

# VMEM is ~16 MiB/core on v5e; pass 1 holds one D tile + one E tile.
# 512x512 fp32 = 1 MiB per tile: comfortable with double buffering.
_DEFAULT_BLOCK = 512


@partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def center_distance_matrix_pallas(d: jax.Array, *, block_m: int = _DEFAULT_BLOCK,
                                  block_n: int = _DEFAULT_BLOCK,
                                  interpret: bool = True) -> jax.Array:
    """Fused two-pass centering via the Pallas kernel.

    ``interpret=True`` executes the kernel body on CPU (this container);
    on a real TPU pass ``interpret=False``.
    """
    n = d.shape[0]
    bm = _pick_block(n, block_m)
    bn = _pick_block(n, block_n)
    pad_m = (-n) % bm
    pad_n = (-n) % bn
    pad = max(pad_m, pad_n)  # keep it square
    np_ = n + pad
    bm = _pick_block(np_, bm)
    bn = _pick_block(np_, bn)
    d_p = jnp.pad(d, ((0, pad), (0, pad))) if pad else d

    e, row_sums, gsum = center_pass1(d_p, block_m=bm, block_n=bn,
                                     interpret=interpret)
    # normalize with the TRUE n (padding rows/cols are zero in E and sums)
    row_means = row_sums / n
    global_mean = (gsum / n) / n
    f = center_pass2(e, row_means, global_mean, block_m=bm, block_n=bn,
                     interpret=interpret)
    if pad:
        f = f[:n, :n]
        # padded rows contributed rm=0 so the interior is exact; nothing to fix
    return f
