"""jit'd public wrapper for the batched Mantel-correlation kernel.

Implements the full optimized pipeline of paper Algorithm 5:
hoist (x̄, ‖x−x̄‖, ŷ) → per-batch XLA row/col gathers → Pallas fused
multiply-reduce with Ŷ-tile reuse → scale by 1/(2‖x−x̄‖).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.mantel_corr import mantel_corr

_DEFAULT_BLOCK = 256


@partial(jax.jit, static_argnames=("perm_batch", "block", "interpret"))
def mantel_corr_pallas(x: jax.Array, y: jax.Array, orders: jax.Array,
                       *, perm_batch: int = 8, block: int = _DEFAULT_BLOCK,
                       interpret: bool = True) -> jax.Array:
    """Pearson r for every permutation in ``orders`` ((K, n) int array).

    x, y: full symmetric hollow distance matrices (n, n).
    Returns stats (K,). Peak memory: one (perm_batch, n, n) gather buffer.
    """
    n = x.shape[0]
    k_perms = orders.shape[0]
    iu = np.triu_indices(n, k=1)

    # --- hoisted permutation-invariant statistics (the paper's tricks) ---
    x_flat = x[iu]
    xm = x_flat - x_flat.mean()
    normxm = jnp.linalg.norm(xm)
    y_flat = y[iu]
    ym = y_flat - y_flat.mean()
    ynorm = ym / jnp.linalg.norm(ym)

    # full symmetric Ŷ with zero diagonal (Σ_uptri = ½ Σ_full)
    yhat = jnp.zeros((n, n), x.dtype).at[iu].set(ynorm)
    yhat = yhat + yhat.T

    b = min(block, n)
    if b >= 8:
        b -= b % 8
    b = max(b, 1)
    pad = (-n) % b
    yhat_p = jnp.pad(yhat, ((0, pad), (0, pad))) if pad else yhat

    if k_perms % perm_batch:
        raise ValueError(f"permutations ({k_perms}) must be divisible by "
                         f"perm_batch ({perm_batch})")

    def one_batch(order_block):
        # contiguous row gathers (XLA), then the fused Pallas reduction
        xp = jax.vmap(lambda o: x[o][:, o])(order_block)
        if pad:
            xp = jnp.pad(xp, ((0, 0), (0, pad), (0, pad)))
        return mantel_corr(xp, yhat_p, block_m=b, block_n=b,
                           interpret=interpret)

    order_blocks = orders.reshape(k_perms // perm_batch, perm_batch, n)
    stats = jax.lax.map(one_batch, order_blocks)   # streams: one batch live
    return stats.reshape(k_perms) / (2.0 * normxm)
