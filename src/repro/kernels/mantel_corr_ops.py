"""jit'd public wrapper for the batched Mantel-correlation kernel.

Implements the full optimized pipeline of paper Algorithm 5:
hoist (x̄, ‖x−x̄‖, ŷ) → per-batch XLA row/col gathers → Pallas fused
multiply-reduce with Ŷ-tile reuse → scale by 1/(2‖x−x̄‖).

``interpret=None`` (default) dispatches by backend: TPU-native Mosaic
lowering under ``jax.default_backend() == "tpu"`` (lane-aligned 128-column
tiles), the Pallas interpreter elsewhere.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.dispatch import (lane_geometry, pick_block,
                                    resolve_interpret)
from repro.kernels.mantel_corr import mantel_corr
from repro.obs.compile import note_trace

_DEFAULT_BLOCK = 256


@partial(jax.jit, static_argnames=("perm_batch", "block", "interpret"))
def mantel_corr_pallas(x: jax.Array, y: jax.Array, orders: jax.Array,
                       *, perm_batch: int = 8, block: int = _DEFAULT_BLOCK,
                       interpret: Optional[bool] = None) -> jax.Array:
    """Pearson r for every permutation in ``orders`` ((K, n) int array).

    x, y: full symmetric hollow distance matrices (n, n).
    Returns stats (K,). Peak memory: one (perm_batch, n, n) gather buffer.
    """
    # deferred: importing repro.core at module scope would cycle through
    # the package inits (core → mantel → stats → kernels)
    from repro.core.distance_matrix import condensed_to_square

    interpret = resolve_interpret(interpret)
    n = x.shape[0]
    k_perms = orders.shape[0]
    note_trace("kernels.mantel_corr",
               (n, k_perms, perm_batch, block, interpret))
    iu = np.triu_indices(n, k=1)

    # --- hoisted permutation-invariant statistics (the paper's tricks) ---
    x_flat = x[iu]
    xm = x_flat - x_flat.mean()
    normxm = jnp.linalg.norm(xm)
    y_flat = y[iu]
    ym = y_flat - y_flat.mean()
    ynorm = ym / jnp.linalg.norm(ym)

    # full symmetric Ŷ with zero diagonal (Σ_uptri = ½ Σ_full), built as a
    # position-map gather — XLA:CPU scalarizes the equivalent ``.at[iu]
    # .set`` scatter (~70x slower than the gather at n=2048)
    yhat = condensed_to_square(ynorm, n)

    # TPU-native tiles need lane-aligned (multiple-of-128) columns
    lane, floor = lane_geometry(interpret)
    b = pick_block(n, block, lane, floor=floor)
    pad = (-n) % b
    yhat_p = jnp.pad(yhat, ((0, pad), (0, pad))) if pad else yhat

    if k_perms % perm_batch:
        raise ValueError(f"permutations ({k_perms}) must be divisible by "
                         f"perm_batch ({perm_batch})")

    def one_batch(order_block):
        # contiguous row gathers (XLA), then the fused Pallas reduction
        xp = jax.vmap(lambda o: x[o][:, o])(order_block)
        if pad:
            xp = jnp.pad(xp, ((0, 0), (0, pad), (0, pad)))
        return mantel_corr(xp, yhat_p, block_m=b, block_n=b,
                           interpret=interpret)

    order_blocks = orders.reshape(k_perms // perm_batch, perm_batch, n)
    stats = jax.lax.map(one_batch, order_blocks)   # streams: one batch live
    return stats.reshape(k_perms) / (2.0 * normxm)
