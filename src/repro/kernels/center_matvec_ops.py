"""jit'd public wrapper for the fused center-matvec kernel.

Hoists the O(k) correction vectors, handles block-size selection and
padding (zero rows/cols of D contribute 0 to E, zero rows of X contribute
0 to the products, so the interior of the result is exact), and resolves
the backend dispatch: ``interpret=None`` runs TPU-native on a TPU backend
and falls back to the Pallas interpreter elsewhere (this container's CPU).
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.center_matvec import center_matvec
# the snapping/dispatch policy lives in kernels.dispatch now (shared with
# the repro.tune solver); re-exported here for backward compatibility
from repro.kernels.dispatch import (lane_geometry, pick_block,  # noqa: F401
                                    resolve_interpret)
from repro.obs.compile import note_trace

_DEFAULT_BLOCK = 512


@partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def center_matvec_pallas(d: jax.Array, x: jax.Array, row_means: jax.Array,
                         global_mean: jax.Array, *,
                         block_m: int = _DEFAULT_BLOCK,
                         block_n: int = _DEFAULT_BLOCK,
                         interpret: Optional[bool] = None) -> jax.Array:
    """``F @ x`` via the fused Pallas kernel, F never materialized.

    d: (n, n) distance matrix; x: (n, k); row_means/global_mean: the
    operator's hoisted statistics of E = −½D∘D.
    """
    interpret = resolve_interpret(interpret)
    n, k = d.shape[0], x.shape[1]
    note_trace("kernels.center_matvec",
               (n, k, block_m, block_n, interpret))
    # TPU-native tiles need lane-aligned columns; the interpreter is free
    lane_n, floor_n = lane_geometry(interpret)
    bm = pick_block(n, block_m)
    bn = pick_block(n, block_n, lane_n, floor=floor_n)
    pad = max((-n) % bm, (-n) % bn)      # keep D square
    np_ = n + pad
    bm = pick_block(np_, bm)
    bn = pick_block(np_, bn, lane_n, floor=floor_n)
    pad_k = (-k) % lane_n

    # hoisted O(k) corrections — computed on the TRUE operands, pre-padding
    colsum = jnp.sum(x, axis=0)
    corr = global_mean * colsum - row_means @ x

    if pad:
        d = jnp.pad(d, ((0, pad), (0, pad)))
        x = jnp.pad(x, ((0, pad), (0, 0)))
        row_means = jnp.pad(row_means, (0, pad))
    if pad_k:
        x = jnp.pad(x, ((0, 0), (0, pad_k)))
        colsum = jnp.pad(colsum, (0, pad_k))
        corr = jnp.pad(corr, (0, pad_k))

    out = center_matvec(d, x, row_means, colsum, corr,
                        block_m=bm, block_n=bn, interpret=interpret)
    return out[:n, :k]
