"""Pallas TPU kernels for the paper's memory-bound hot spots.

Each kernel family has three files (harness convention):

* ``<name>.py``      — the ``pl.pallas_call`` kernel with explicit BlockSpec
                       VMEM tiling (TPU is the *target*; validated on CPU
                       with ``interpret=True``);
* ``<name>_ops.py``  — the jit'd public wrapper (padding, dtype handling,
                       block-size selection);
* ``<name>_ref.py``  — the pure-jnp oracle used by the allclose tests.

Kernels (paper hot spots only — DESIGN §3):

* ``center``       — two-pass fused PCoA centering (paper Algorithm 2).
* ``center_matvec``— fused center-matvec for matrix-free PCoA: E-formation
                     and the rank-1 centering corrections applied
                     in-register against a skinny (n, k) block.
* ``symhollow``    — fused symmetric+hollow validation (paper Algorithm 7).
* ``mantel_corr``  — batched permuted-Pearson reduction with Y-tile reuse
                     (paper Algorithm 5, TPU-native formulation; square
                     operands — kept as the materialized baseline).
* ``permute_reduce`` — the square-free successor: B permuted condensed
                     multiply-reduces per tile, the invariant streams
                     through VMEM once per chunk and the permuted gather
                     is closed-form triangle indexing — the Mantel/ANOSIM
                     permutation hot loop with no n² buffer anywhere.
* ``pairwise``     — tiled pairwise-distance row panel: the ``repro.dist``
                     metric reduce fused in-register against VMEM-resident
                     Xᵢ/Xⱼ feature blocks.
* ``rmsnorm``      — the paper's fusion discipline applied to the LM stack's
                     most common memory-bound op (3 passes → 1).
"""

from repro.kernels.center_ops import center_distance_matrix_pallas
from repro.kernels.center_matvec_ops import center_matvec_pallas
from repro.kernels.symhollow_ops import is_symmetric_and_hollow_pallas
from repro.kernels.mantel_corr_ops import mantel_corr_pallas
from repro.kernels.pairwise_ops import pairwise_panel_pallas
from repro.kernels.permute_reduce_ops import permute_reduce
from repro.kernels.rmsnorm_ops import rmsnorm_pallas

__all__ = [
    "center_distance_matrix_pallas",
    "center_matvec_pallas",
    "is_symmetric_and_hollow_pallas",
    "mantel_corr_pallas",
    "pairwise_panel_pallas",
    "permute_reduce",
    "rmsnorm_pallas",
]
