"""Pallas kernel: fused RMSNorm — the paper's discipline applied to the LM
stack's most common memory-bound op.

Naive RMSNorm is three HBM passes (square+mean, rsqrt-scale, weight-mul);
fused it is one: each row block is read once, the mean-square reduction and
the normalize+scale happen while the block is VMEM-resident — exactly the
paper's ``e_matrix_means_cy`` pattern (compute the statistic and the
transform in the same sweep).

Block shape: (block_rows, d) — the full feature dimension stays resident
(one row of nemotron's d=18432 fp32 is 72 KiB; 64 rows = 4.5 MiB ≪ VMEM).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * (1.0 + w_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
            block_rows: int = 64, interpret: bool = True) -> jax.Array:
    """x: (rows, d); w: (d,) — '1+w' convention (Gemma/RG style)."""
    rows, d = x.shape
    br = min(block_rows, rows)
    grid = (rows // br,)
    from functools import partial
    return pl.pallas_call(
        partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, w)
