"""Pallas kernel: fused center-matvec for matrix-free PCoA (paper §4.1).

Computes ``out = F @ X`` for the Gower-centered ``F = E − r·1ᵀ − 1·rᵀ + m``
(``E = −½ D∘D``) without ever materializing F or E:

* grid (n/bm, n/bn), the **column dimension innermost** — the (bm, k)
  output strip stays VMEM-resident across the whole j sweep (Pallas elides
  the re-fetch when the BlockSpec index is unchanged between consecutive
  steps), so each output element is written to HBM exactly once;
* per (i, j) tile: the D block is squared/halved **in-register** and fed
  straight to the MXU against the (bn, k) X block — the paper's "compute
  while the data is already in cache", applied to the E-formation;
* on the last column step the rank-1 centering corrections are applied
  in-register: ``− r_i·(1ᵀX) + (m·1ᵀX − rᵀX)``, both O(k) vectors the
  caller hoisted once.

HBM traffic: one read of D, one read of X per row strip, one write of the
(n, k) result — vs materialize-then-multiply's extra n² write + n² read.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _center_matvec_kernel(d_ref, x_ref, rm_ref, colsum_ref, corr_ref,
                          out_ref):
    """out[i] = Σ_j (−½ D_ij∘D_ij) @ X_j − r_i·colsumᵀ + corrᵀ."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    d = d_ref[...]
    e = -0.5 * d * d                     # E tile formed in-register
    out_ref[...] += jnp.dot(e, x_ref[...],
                            preferred_element_type=out_ref.dtype)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():                       # rank-1 corrections, in-register
        out_ref[...] += corr_ref[...][None, :] \
            - rm_ref[...][:, None] * colsum_ref[...][None, :]


def center_matvec(d: jax.Array, x: jax.Array, row_means: jax.Array,
                  colsum: jax.Array, corr: jax.Array, *, block_m: int,
                  block_n: int, interpret: bool = True) -> jax.Array:
    """Tiled ``F @ X``. All operands pre-padded to block multiples.

    d: (n, n); x: (n, k); row_means: (n,) row means of E;
    colsum: (k,) ``1ᵀX``; corr: (k,) ``m·1ᵀX − rᵀX``.
    """
    n = d.shape[0]
    k = x.shape[1]
    grid = (n // block_m, n // block_n)  # j innermost → out-strip residency
    return pl.pallas_call(
        _center_matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_n, k), lambda i, j: (j, 0)),
            pl.BlockSpec((block_m,), lambda i, j: (i,)),
            pl.BlockSpec((k,), lambda i, j: (0,)),
            pl.BlockSpec((k,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), d.dtype),
        interpret=interpret,
    )(d, x, row_means, colsum, corr)
