"""Pallas kernel: fused symmetric+hollow validation (paper §4.3, Algorithm 7).

The paper tiles 16x16 so the (i,j) and (j,i) cache lines are resident
together. The TPU analogue: the grid walks (i,j) tiles and the second input
BlockSpec uses a *swapped index map* ``lambda i, j: (j, i)`` so the DMA
engine fetches the transposed-partner tile into VMEM alongside — one pass
over the matrix, both checks fused, no boolean intermediate in HBM.

Results accumulate into two (1,)-shaped int32 flags (min-accumulated: 1 =
holds, 0 = violated) revisited by every grid step — sequential TPU grid
semantics make this race-free.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _symhollow_kernel(a_ref, at_ref, sym_ref, hollow_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        sym_ref[...] = jnp.ones_like(sym_ref)
        hollow_ref[...] = jnp.ones_like(hollow_ref)

    a = a_ref[...]            # tile (i, j)
    b = at_ref[...]           # tile (j, i)
    tile_sym = jnp.all(a == b.T)
    sym_ref[...] = jnp.minimum(sym_ref[...], tile_sym.astype(jnp.int32)[None])

    # diagonal blocks: fused hollowness check while the tile is in VMEM
    @pl.when(i == j)
    def _diag():
        m = a.shape[0]
        eye = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0) == \
              jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
        diag_zero = jnp.all(jnp.where(eye, a, 0.0) == 0.0)
        hollow_ref[...] = jnp.minimum(hollow_ref[...],
                                      diag_zero.astype(jnp.int32)[None])


def symhollow(mat: jax.Array, *, block: int, interpret: bool = True):
    """Returns (is_sym[1] int32, is_hollow[1] int32)."""
    n = mat.shape[0]
    grid = (n // block, n // block)
    return pl.pallas_call(
        _symhollow_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j: (i, j)),
            pl.BlockSpec((block, block), lambda i, j: (j, i)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(mat, mat)
