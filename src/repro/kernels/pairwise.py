"""Pallas kernel: tiled pairwise-distance row panel (repro.dist hot loop).

The O(n²·d) step upstream of every analysis in this repo is building the
distance matrix itself — "Enabling microbiome research on personal
devices" (Sfiligoi et al. 2021) shows it dominating real workflows, and
it is the same memory-access story as the paper's §4 kernels: a naive
NumPy composition materializes (n, n, d) broadcast intermediates (or
re-streams X from DRAM once per output row), where the tiled form reads
each X block into fast memory once per tile pair and fuses the metric's
elementwise reduce in-register.

This kernel produces ONE row panel ``out[i0:i0+bm, :]`` so the driver can
stream panels straight into the condensed form and the fused hoist
accumulators without a square n×n ever existing:

* grid ``(n/bn,)`` over column blocks; the Xᵢ panel (bm, d) has a
  constant BlockSpec index, so Pallas keeps it VMEM-resident across the
  whole j sweep (the re-fetch is elided when the index is unchanged);
* per step the (bn, d) Xⱼ block is fetched once and the metric's
  accumulators are built chunk-by-chunk over the feature axis — the
  (bm, bn, dc) broadcast term lives only in registers/VMEM for one chunk,
  never in HBM;
* ``metric.finish`` runs on the summed accumulators while the tile is
  still resident, writing the finished (bm, bn) distance tile exactly
  once.

HBM traffic per panel: bm·d (Xᵢ, once) + n·d (Xⱼ blocks) + bm·n (the
output) — vs the broadcast form's bm·n·d intermediate write+read.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.dist.metrics import Metric, merge_acc


def _pairwise_kernel(metric: Metric, feature_block: int,
                     xi_ref, xj_ref, out_ref):
    xi = xi_ref[...]                     # (bm, d) — resident across j
    xj = xj_ref[...]                     # (bn, d) — this column block
    d = xi.shape[-1]
    acc = None
    for c0 in range(0, d, feature_block):      # static chunk loop: the
        a = xi[:, c0:c0 + feature_block]       # (bm, bn, dc) broadcast
        b = xj[:, c0:c0 + feature_block]       # term never leaves VMEM
        part = metric.accumulate(a, b)
        acc = part if acc is None else merge_acc(acc, part)
    out_ref[...] = metric.finish(acc).astype(out_ref.dtype)


def pairwise_panel(xi: jax.Array, xj: jax.Array, metric: Metric, *,
                   block_n: int, feature_block: int,
                   interpret: bool = True) -> jax.Array:
    """Distance row panel ``d(xi, xj)``: (bm, d) × (n, d) → (bm, n).

    All operands pre-padded by the caller: ``xj`` rows to a ``block_n``
    multiple, features of both to a ``feature_block`` multiple (zero
    features are identity for every metric's accumulators — see
    ``repro.dist.metrics``). ``metric`` must be hashable (the frozen
    dataclass instances are); the kernel specializes per metric.
    """
    bm, d = xi.shape
    n = xj.shape[0]
    grid = (n // block_n,)
    kernel = lambda a, b, o: _pairwise_kernel(metric, feature_block,
                                              a, b, o)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda j: (0, 0)),
            pl.BlockSpec((block_n, d), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, block_n), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((bm, n), xi.dtype),
        interpret=interpret,
    )(xi, xj)
