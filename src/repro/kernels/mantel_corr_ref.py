"""Pure-jnp oracle for the mantel_corr kernel: per-permutation Pearson r
computed the original way (scipy pearsonr semantics, paper Algorithm 3+4)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def mantel_corr_ref(x: jax.Array, yhat_flat_unnormalized: jax.Array,
                    orders: jax.Array) -> jax.Array:
    """r[p] = pearsonr(condensed(x[perm_p][:, perm_p]), y_flat).

    ``yhat_flat_unnormalized`` is the raw condensed y (the oracle re-derives
    mean/norm from scratch each call, like the original implementation).
    """
    n = x.shape[0]
    iu = np.triu_indices(n, k=1)
    ym = yhat_flat_unnormalized - yhat_flat_unnormalized.mean()
    ynorm = ym / jnp.linalg.norm(ym)

    def one(order):
        xp = x[order][:, order]
        xf = xp[iu]
        xm = xf - xf.mean()
        return jnp.dot(xm / jnp.linalg.norm(xm), ynorm)

    return jax.vmap(one)(orders)
