"""jit'd public wrapper for the fused validation kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.symhollow import symhollow

_DEFAULT_BLOCK = 512


@partial(jax.jit, static_argnames=("block", "interpret"))
def is_symmetric_and_hollow_pallas(mat: jax.Array, *, block: int = _DEFAULT_BLOCK,
                                   interpret: bool = True):
    """Fused single-pass validation. Returns (is_sym, is_hollow) booleans.

    Zero-padding to a block multiple preserves both properties: a zero
    border is symmetric and adds zero diagonal entries.
    """
    n = mat.shape[0]
    b = min(block, n)
    pad = (-n) % b
    m = jnp.pad(mat, ((0, pad), (0, pad))) if pad else mat
    is_sym, is_hollow = symhollow(m, block=b, interpret=interpret)
    return is_sym[0] == 1, is_hollow[0] == 1
