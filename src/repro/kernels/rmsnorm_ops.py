"""jit'd public wrapper for the fused RMSNorm kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm import rmsnorm


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_pallas(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
                   block_rows: int = 64, interpret: bool = True) -> jax.Array:
    """Fused RMSNorm over the last axis of arbitrary-rank ``x``."""
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = rmsnorm(x2, w, eps=eps, block_rows=br, interpret=interpret)
    if pad:
        out = out[:rows]
    return out.reshape(shape)
