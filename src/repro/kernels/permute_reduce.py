"""Pallas kernel: batched permuted-gather-reduce over CONDENSED storage.

The last square-matrix dependence of the Mantel-family hot loop (paper
§4.2, Algorithm 5). The engine's PR-4 loop materialized the permuted
square ``X[o][:, o]`` and streamed a square invariant per permutation —
~6·n² floats of traffic each. But the statistic only needs the condensed
inner product, and the condensed form of the permuted matrix is a pure
index transform of the condensed original:

    condensed(X_p)[k] = xc[ tri(order[i_k], order[j_k]) ]
    tri(i, j)         = lo*(2n - lo - 1)/2 + (hi - lo - 1)

so the whole per-permutation pass is one closed-form gather + one fused
multiply-reduce over m = n(n-1)/2 entries. This kernel batches it:

* grid = (condensed chunks, B permutations), **b innermost** — the chunk
  of the streamed invariants (``ys``, plus the hoisted triangle map
  ``ii``/``jj``) is fetched into VMEM once per chunk and reused across
  all B permutations (Pallas elides the re-fetch while the BlockSpec
  index is unchanged between consecutive steps), exactly the Ŷ-tile
  trick of ``mantel_corr`` — minus the n² gather buffer;
* the condensed source ``xc`` is a single VMEM-resident block (it is the
  *only* large buffer left: half a square's bytes);
* per (chunk, b) step: gather ``order`` at the chunk's triangle coords,
  fold through ``tri``, gather ``xc`` in-register, multiply-reduce
  against every ``ys`` row, accumulate into the (S, 1) output block.

Analytic traffic per permutation: m (its own xc gather) + 3m/B (its
share of the ys/ii/jj streams) + n (its order row) — vs the square
loop's ~6n² ≈ 12m. S invariant rows share one gather, so the partial
Mantel test's two reductions cost one pass.

``interpret=None`` dispatches by backend like every kernel here: native
Mosaic lowering on a TPU, the Pallas interpreter elsewhere (this
container's CPU; the production CPU path is the pure-XLA twin in
``permute_reduce_ops``).
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.center_matvec_ops import resolve_interpret


def _permute_reduce_kernel(n, ii_ref, jj_ref, ys_ref, orders_ref, xc_ref,
                           out_ref):
    c = pl.program_id(0)

    @pl.when(c == 0)
    def _init():                 # first visit of this permutation's block
        out_ref[...] = jnp.zeros_like(out_ref)

    order = orders_ref[0]        # (n,)   — this permutation's order row
    i_idx = ii_ref[...]          # (bk,)  — chunk triangle rows, streamed
    j_idx = jj_ref[...]          # (bk,)  — chunk triangle cols, streamed
    # closed-form triangle index, inlined (importing core.distance_matrix
    # at kernel module scope would cycle core → mantel → stats → kernels);
    # int32-exact for n <= MAX_TRIANGLE_N, enforced by the ops wrapper
    oi, oj = order[i_idx], order[j_idx]
    lo = jnp.minimum(oi, oj)
    hi = jnp.maximum(oi, oj)
    k = lo * (2 * n - lo - 1) // 2 + (hi - lo - 1)
    xg = xc_ref[...][k]          # in-register permuted-condensed gather
    ys = ys_ref[...]             # (S, bk) — shared, VMEM-resident across b
    out_ref[...] += jnp.sum(ys * xg[None, :], axis=1)[:, None]


def permute_reduce_kernel(xc: jax.Array, ys: jax.Array, ii: jax.Array,
                          jj: jax.Array, orders: jax.Array, *, chunk: int,
                          interpret: Optional[bool] = None) -> jax.Array:
    """out[s, b] = sum_k ys[s, k] * xc[tri(orders[b, ii[k]], orders[b, jj[k]])].

    xc: (m,) condensed source (gathered; the only unchunked operand).
    ys/ii/jj: (S, m_pad)/(m_pad,)/(m_pad,) streamed invariants, m_pad a
    multiple of ``chunk`` with zero-``ys`` padding (the ops wrapper owns
    the padding so padded positions contribute exactly 0). orders: (B, n)
    int32. Returns (S, B) in xc's dtype.
    """
    interpret = resolve_interpret(interpret)
    s, m_pad = ys.shape
    b_perms, n = orders.shape
    m = xc.shape[0]
    grid = (m_pad // chunk, b_perms)     # b innermost → chunk-stream reuse
    return pl.pallas_call(
        partial(_permute_reduce_kernel, n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk,), lambda c, b: (c,)),
            pl.BlockSpec((chunk,), lambda c, b: (c,)),
            pl.BlockSpec((s, chunk), lambda c, b: (0, c)),
            pl.BlockSpec((1, n), lambda c, b: (b, 0)),
            pl.BlockSpec((m,), lambda c, b: (0,)),
        ],
        out_specs=pl.BlockSpec((s, 1), lambda c, b: (0, b)),
        out_shape=jax.ShapeDtypeStruct((s, b_perms), xc.dtype),
        interpret=interpret,
    )(ii, jj, ys, orders, xc)
