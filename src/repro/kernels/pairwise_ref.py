"""Pure-jnp oracle for the pairwise kernel: naive full-broadcast pairwise
distances, each metric written out longhand the way scipy.spatial.distance
documents it (the memory behaviour the tiled kernel exists to avoid — the
(n, m, d) broadcast intermediate is materialized whole)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _guarded(num: jax.Array, den: jax.Array) -> jax.Array:
    return jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), 0.0)


def pairwise_ref(x: jax.Array, y: jax.Array, metric: str) -> jax.Array:
    """Distance matrix d(x_i, y_j): (n, d) × (m, d) → (n, m), eager
    broadcast formulas (0/0 conventions as pinned in repro.dist.metrics)."""
    a = x[:, None, :]
    b = y[None, :, :]
    if metric == "euclidean":
        return jnp.sqrt(jnp.maximum(jnp.sum((a - b) ** 2, -1), 0.0))
    if metric == "cityblock":
        return jnp.sum(jnp.abs(a - b), -1)
    if metric == "canberra":
        return jnp.sum(_guarded(jnp.abs(a - b), jnp.abs(a) + jnp.abs(b)), -1)
    if metric == "braycurtis":
        return _guarded(jnp.sum(jnp.abs(a - b), -1),
                        jnp.sum(jnp.abs(a + b), -1))
    if metric == "jaccard":
        dt = x.dtype
        return _guarded(jnp.sum((a != b).astype(dt), -1),
                        jnp.sum(((a != 0) | (b != 0)).astype(dt), -1))
    raise ValueError(f"unknown metric {metric!r}")
