"""jit'd public wrapper for the tiled pairwise-distance kernel.

Handles block selection (lane-snapped per backend via ``pick_block``, the
shared rule), zero-padding of the column-block and feature axes (zero
features are identity for every registered metric's accumulators; padded
Xⱼ rows produce junk columns that are sliced off), and the backend
dispatch: ``interpret=None`` runs TPU-native on a TPU backend and falls
back to the Pallas interpreter elsewhere (this container's CPU).
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.metrics import Metric
from repro.kernels.dispatch import (lane_geometry, pick_block,
                                    resolve_interpret)
from repro.kernels.pairwise import pairwise_panel
from repro.obs.compile import note_trace

_DEFAULT_BLOCK = 256
_DEFAULT_FEATURE_BLOCK = 128


@partial(jax.jit, static_argnames=("metric", "block_n", "feature_block",
                                   "interpret"))
def pairwise_panel_pallas(xi: jax.Array, x: jax.Array, *, metric: Metric,
                          block_n: int = _DEFAULT_BLOCK,
                          feature_block: int = _DEFAULT_FEATURE_BLOCK,
                          interpret: Optional[bool] = None) -> jax.Array:
    """One distance row panel via the Pallas kernel: (bm, d) × (n, d) →
    (bm, n), the metric's elementwise reduce fused in-register.

    ``xi`` is the row panel (its padding, if any, is the caller's — junk
    output *rows* are the caller's to slice); ``x`` is the full feature
    table. Column blocks and the feature axis are padded here.
    """
    interpret = resolve_interpret(interpret)
    n, d = x.shape
    note_trace("kernels.pairwise_panel",
               (tuple(xi.shape), n, d, metric.name, block_n, feature_block,
                interpret))
    # TPU-native tiles need lane-aligned (multiple-of-128) trailing dims
    lane, floor = lane_geometry(interpret)
    bn = pick_block(n, block_n, lane, floor=floor)
    pad_n = (-n) % bn
    fb = min(feature_block, d)
    pad_d = (-d) % fb

    if pad_d:
        xi = jnp.pad(xi, ((0, 0), (0, pad_d)))
        x = jnp.pad(x, ((0, 0), (0, pad_d)))
    if pad_n:
        x = jnp.pad(x, ((0, pad_n), (0, 0)))

    out = pairwise_panel(xi, x, metric, block_n=bn, feature_block=fb,
                         interpret=interpret)
    return out[:, :n]
