"""jit'd public wrapper for the batched permuted-gather-reduce.

One entry point, two implementations with identical semantics and the
same analytic traffic profile (the tests pin them against each other and
against ``permute_reduce_ref``):

* ``impl="pallas"`` — the explicit-VMEM kernel in ``permute_reduce.py``
  (TPU-native when ``jax.default_backend() == "tpu"``, the interpreter
  elsewhere, like every kernel in this package);
* ``impl="xla"``   — a ``lax.scan`` over the same condensed chunks: the
  streamed invariants enter one (S, chunk) tile at a time, the permuted
  gather is a single vectorized (B, chunk) take, and the multiply-reduce
  is one small matmul. Peak extra memory is one (B, chunk) gather tile —
  never (B, m), and never any n² buffer. This is the production CPU
  path (XLA:CPU vectorizes the gather; the Pallas interpreter does not).

The wrapper owns the hoistable geometry: the triangle coordinate map
(ii, jj) via ``triangle_coords`` — callers may pass a precomputed pair to
keep it inside their own hoist — plus chunk padding (padded positions
carry zero ``ys``, so they contribute exactly 0) and the int32 bound
(``n <= MAX_TRIANGLE_N``; beyond it the closed-form index would wrap and
CLAMP into silently wrong gathers, so we refuse loudly like
``CondensedCenteredGramOperator``).
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import snap_chunk
from repro.kernels.permute_reduce import permute_reduce_kernel
from repro.obs.compile import note_trace

# condensed chunk streamed per grid step. 64k floats = 256 KiB per ys row:
# big enough that the (B, chunk) gather tile amortizes loop overhead,
# small enough to stay cache/VMEM-resident alongside the xc block.
# ``repro.tune`` solves this knob from the measured budget instead when
# ``ExecConfig(auto=True)``; callers pass chunk=None to keep the default.
DEFAULT_CHUNK = 65536
_DEFAULT_CHUNK = DEFAULT_CHUNK            # backward-compat alias

# the chunk/padding geometry is the shared ``kernels.dispatch.snap_chunk``
# policy (also consumed by the tuner's resident-set model)
_chunk_geometry = snap_chunk


def _reduce_xla(xc, ys, ii, jj, orders, n: int, chunk: int) -> jax.Array:
    """The lax.scan twin: same chunking, same math, pure XLA."""
    s, m_pad = ys.shape
    num_chunks = m_pad // chunk
    ii_c = ii.reshape(num_chunks, chunk)
    jj_c = jj.reshape(num_chunks, chunk)
    ys_c = jnp.moveaxis(ys.reshape(s, num_chunks, chunk), 1, 0)

    def body(acc, operands):
        ic, jc, yc = operands                      # (chunk,), (S, chunk)
        oi = jnp.take(orders, ic, axis=1)          # (B, chunk) order gather
        oj = jnp.take(orders, jc, axis=1)
        lo = jnp.minimum(oi, oj)
        hi = jnp.maximum(oi, oj)
        k = lo * (2 * n - lo - 1) // 2 + (hi - lo - 1)
        xg = jnp.take(xc, k)                       # (B, chunk) xc gather
        return acc + yc @ xg.T, None               # (S, B) accumulate

    acc0 = jnp.zeros((s, orders.shape[0]), dtype=xc.dtype)
    out, _ = jax.lax.scan(body, acc0, (ii_c, jj_c, ys_c))
    return out


@partial(jax.jit, static_argnames=("impl", "chunk", "interpret"))
def _permute_reduce_jit(xc: jax.Array, ys: jax.Array, orders: jax.Array,
                        ii: Optional[jax.Array], jj: Optional[jax.Array], *,
                        impl: str, chunk: int,
                        interpret: Optional[bool]) -> jax.Array:
    """All B permuted condensed multiply-reduces of one invariant stack.

    out[s, b] = sum_k ys[s, k] * xc[tri(orders[b, i_k], orders[b, j_k])]
              = <condensed(X[orders[b]][:, orders[b]]), ys[s]>

    xc: (m,) condensed source, m = n(n-1)/2. ys: (S, m) permutation-
    invariant streams (S reductions share ONE gather). orders: (B, n)
    int permutation tile. ii/jj: optional precomputed ``triangle_coords``
    (hoist them once per test; recomputed here when omitted).
    Returns (S, B) in xc's dtype.

    This is the jitted body — call through ``permute_reduce``, which owns
    the chunk-default normalization (so ``chunk=None`` and an explicit
    ``chunk=DEFAULT_CHUNK`` share ONE jit cache entry and one sentinel
    program).
    """
    # deferred: importing repro.core at module scope would cycle through
    # the package inits (core → mantel → stats → kernels)
    from repro.core.distance_matrix import MAX_TRIANGLE_N, triangle_coords

    if impl not in ("xla", "pallas"):
        raise ValueError(f"unknown permute_reduce impl {impl!r}")
    b_perms, n = orders.shape
    if n > MAX_TRIANGLE_N:
        raise ValueError(
            f"permute_reduce supports n <= {MAX_TRIANGLE_N} (int32 "
            f"triangle indexing would overflow and silently corrupt the "
            f"gather); got n={n}")
    m = n * (n - 1) // 2
    if xc.shape != (m,):
        raise ValueError(f"xc must be condensed length m={m} for n={n}, "
                         f"got {xc.shape}")
    if ys.ndim != 2 or ys.shape[1] != m:
        raise ValueError(f"ys must be (S, {m}), got {ys.shape}")
    # trace-time only: THE padded per_batch kernel entry — one program
    # per (n, B, S, impl, chunk) whatever K the engine runs (nested-jit
    # bodies trace once per distinct avals even across outer retraces)
    note_trace("kernels.permute_reduce",
               (n, b_perms, ys.shape[0], impl, chunk, interpret))
    if m == 0:                                     # n < 2: empty triangle
        return jnp.zeros((ys.shape[0], b_perms), dtype=xc.dtype)

    if ii is None or jj is None:
        ii, jj = triangle_coords(n)
    orders = orders.astype(jnp.int32)
    ii = ii.astype(jnp.int32)
    jj = jj.astype(jnp.int32)

    chunk, m_pad = _chunk_geometry(m, chunk)
    pad = m_pad - m
    if pad:
        # padded ys is zero ⇒ padded positions contribute exactly 0; the
        # padded coords are the valid pair (0, 1) so the dead gather stays
        # in range instead of wrapping
        ys = jnp.pad(ys, ((0, 0), (0, pad)))
        ii = jnp.pad(ii, (0, pad))
        jj = jnp.pad(jj, (0, pad), constant_values=1)

    if impl == "pallas":
        return permute_reduce_kernel(xc, ys, ii, jj, orders, chunk=chunk,
                                     interpret=interpret)
    return _reduce_xla(xc, ys, ii, jj, orders, n, chunk)


def permute_reduce(xc: jax.Array, ys: jax.Array, orders: jax.Array,
                   ii: Optional[jax.Array] = None,
                   jj: Optional[jax.Array] = None, *, impl: str = "xla",
                   chunk: Optional[int] = None,
                   interpret: Optional[bool] = None) -> jax.Array:
    """All B permuted condensed multiply-reduces of one invariant stack
    (see ``_permute_reduce_jit`` for the exact semantics and shapes).

    ``chunk=None`` keeps ``DEFAULT_CHUNK``; the ``repro.tune`` solver
    passes a budget-solved value instead. Normalizing here — outside the
    jit boundary — keeps None and the explicit default on one cache
    entry and one sentinel program.
    """
    return _permute_reduce_jit(
        xc, ys, orders, ii, jj, impl=impl,
        chunk=DEFAULT_CHUNK if chunk is None else int(chunk),
        interpret=interpret)
