"""Eager oracle for the batched permuted-gather-reduce kernel.

Deliberately takes the long way round (the PR-4 square-gather loop shape):
per permutation, rebuild the full permuted square ``X[o][:, o]``, extract
its condensed triangle, and dot it against every streamed invariant row.
``permute_reduce`` and its Pallas kernel must agree with this to fp
tolerance — it is the ground truth that the closed-form triangle indexing
``xc[k(order[i], order[j])]`` really is the condensed form of the permuted
matrix.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distance_matrix import condensed_to_square


def permute_reduce_ref(xc: jax.Array, ys: jax.Array,
                       orders: jax.Array) -> jax.Array:
    """out[s, b] = <condensed(X[orders[b]][:, orders[b]]), ys[s]>.

    xc: (m,) condensed X. ys: (S, m) streamed invariants. orders: (B, n)
    with m = n(n-1)/2. Returns (S, B) float like ``xc``.
    """
    b_perms, n = orders.shape
    x_sq = np.asarray(condensed_to_square(xc, n))
    ys_np = np.asarray(ys, dtype=np.float64)
    iu = np.triu_indices(n, k=1)
    out = np.zeros((ys_np.shape[0], b_perms))
    for b in range(b_perms):
        o = np.asarray(orders[b])
        xp_c = x_sq[o][:, o][iu]
        out[:, b] = ys_np @ xp_c
    return jnp.asarray(out, dtype=xc.dtype)
