"""Pallas kernel: two-pass fused PCoA centering (paper §4.1, Algorithm 2).

TPU adaptation of the paper's Cython kernels (DESIGN §2):

* pass 1 (``e_matrix_means_cy``): one sweep over D computing
  ``E = -0.5 * D * D``, the per-row sums and the global sum. The row-sum and
  global-sum outputs *revisit* the same block across the column grid
  dimension — TPU grids iterate sequentially (last dim fastest), so the
  accumulation is race-free. This is the Pallas idiom for the paper's
  "compute the means while the data is already in cache".
* pass 2 (``f_matrix_inplace_cy``): tiled application of
  ``F = E - rm[i] - rm[j] + gm``. The paper's 16x16 CPU tiles (64-byte cache
  lines) become (block_m, block_n) VMEM tiles aligned to the fp32 native
  (8, 128) tile; the row-means vector plays the role of the cache-resident
  ``row_means`` buffer.

The symmetry trick is preserved exactly: row means are also the column
means, so pass 1 reduces along one axis only.

HBM traffic: read D once, write E once (pass 1); read E, write F (pass 2)
= 2 reads + 2 writes of the matrix + O(n) vectors — the paper's bound.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pass1_kernel(d_ref, e_ref, rowsum_ref, gsum_ref):
    """E = -0.5 * D * D, accumulating row sums and the global sum."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    d = d_ref[...]
    e = -0.5 * d * d
    e_ref[...] = e

    # rowsum block is indexed by i only: zero it on the first column step.
    @pl.when(j == 0)
    def _init_rowsum():
        rowsum_ref[...] = jnp.zeros_like(rowsum_ref)

    # global-sum block is shared by the whole grid: zero it once.
    @pl.when((i == 0) & (j == 0))
    def _init_gsum():
        gsum_ref[...] = jnp.zeros_like(gsum_ref)

    rowsum_ref[...] += jnp.sum(e, axis=1)
    gsum_ref[...] += jnp.sum(e)[None]


def _pass2_kernel(e_ref, rm_row_ref, rm_col_ref, gm_ref, out_ref):
    """F = E - rm[i] - rm[j] + gm, one VMEM tile at a time."""
    e = e_ref[...]
    rm_i = rm_row_ref[...]          # (block_m,)  — this tile's row means
    rm_j = rm_col_ref[...]          # (block_n,)  — this tile's col means (= row means, symmetry)
    gm = gm_ref[0]
    out_ref[...] = e - rm_i[:, None] - rm_j[None, :] + gm


def center_pass1(d: jax.Array, *, block_m: int, block_n: int,
                 interpret: bool = True):
    """Returns (E, row_sums, global_sum[1])."""
    n = d.shape[0]
    grid = (n // block_m, n // block_n)
    return pl.pallas_call(
        _pass1_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_m, block_n), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_m,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, n), d.dtype),
            jax.ShapeDtypeStruct((n,), d.dtype),
            jax.ShapeDtypeStruct((1,), d.dtype),
        ],
        interpret=interpret,
    )(d)


def center_pass2(e: jax.Array, row_means: jax.Array, global_mean: jax.Array,
                 *, block_m: int, block_n: int, interpret: bool = True):
    """Returns F. ``global_mean`` is a (1,) array."""
    n = e.shape[0]
    grid = (n // block_m, n // block_n)
    return pl.pallas_call(
        _pass2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_m,), lambda i, j: (i,)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), e.dtype),
        interpret=interpret,
    )(e, row_means, row_means, global_mean)
