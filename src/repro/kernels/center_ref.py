"""Pure-jnp oracle for the centering kernel (paper Algorithm 1 semantics)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def center_distance_matrix_ref(d: jax.Array) -> jax.Array:
    """Gower double-centering: F = E - rowmean - colmean + mean, E = -D²/2."""
    e = d * d / -2.0
    row_means = e.mean(axis=1, keepdims=True)
    col_means = e.mean(axis=0, keepdims=True)
    matrix_mean = e.mean()
    return e - row_means - col_means + matrix_mean
