"""Pure-jnp oracle for the fused center-matvec kernel: materialize the
Gower-centered matrix the eager way, then multiply — exactly the traffic
pattern the kernel exists to eliminate."""

from __future__ import annotations

import jax

from repro.kernels.center_ref import center_distance_matrix_ref


def center_matvec_ref(d: jax.Array, x: jax.Array) -> jax.Array:
    """``center(D) @ x`` with the full n² matrix materialized."""
    return center_distance_matrix_ref(d) @ x
