"""repro.stats — distance-matrix permutation tests on one shared engine.

The paper (§4.2) accelerates the Mantel test by hoisting permutation-
invariant work out of the Monte-Carlo loop and fusing the per-permutation
remainder into a single pass over the matrix. This package applies that
recipe to the whole family of tests that dominate microbiome workloads
(cf. Sfiligoi et al. 2021, "Enabling microbiome research on personal
devices"):

* ``engine``         — the shared loop: ``Statistic`` protocol
                       (hoist/per_perm split, with the batch-fused
                       ``per_batch`` hook as the primary path — padded
                       full-size order tiles, one trace for any K),
                       p-value finishing, shard_map permutation-axis
                       distribution.
* ``permanova``      — pseudo-F from the centered Gower matrix
                       (``SS_total = tr(G)`` hoisted; per-permutation
                       gather-matmul).
* ``anosim``         — Clarke's R with the rank transform hoisted and
                       kept CONDENSED: the batched loop gathers the
                       within-indicator by closed-form triangle indexing
                       (``kernels.permute_reduce``) — no rank matrix.
* ``permdisp``       — Anderson's dispersion-homogeneity F with the whole
                       ordination hoisted (matrix-free PCoA coordinates;
                       per-permutation only centroids + distances move).
* ``partial_mantel`` — three-matrix partial correlation with ŷ
                       residualized once, square-free: both fused inner
                       products stack as rows of ONE batched
                       ``kernels.permute_reduce`` call sharing a single
                       condensed gather.

``core.mantel.mantel`` is a thin client of the same engine. Each test
ships a deliberately eager ``*_ref`` oracle mirroring scikit-bio's
multi-pass evaluation; ``benchmarks/bench_stats.py`` sweeps ref vs fused.
"""

from repro.stats.engine import (
    PermutationTestResult,
    Statistic,
    as_key,
    permutation_orders,
    permutation_test,
    permutation_test_distributed,
)
from repro.stats.anosim import AnosimStatistic, anosim, anosim_ref, \
    rank_transform, rank_transform_condensed
from repro.stats.partial_mantel import (
    PartialMantelPallasStatistic,
    PartialMantelStatistic,
    partial_mantel,
    partial_mantel_ref,
)
from repro.stats.permanova import (
    PermanovaOperatorStatistic,
    PermanovaStatistic,
    permanova,
    permanova_ref,
)
from repro.stats.permdisp import PermdispStatistic, permdisp, permdisp_ref

__all__ = [
    "PermutationTestResult", "Statistic", "as_key", "permutation_orders",
    "permutation_test", "permutation_test_distributed",
    "AnosimStatistic", "anosim", "anosim_ref", "rank_transform",
    "rank_transform_condensed",
    "PartialMantelPallasStatistic", "PartialMantelStatistic",
    "partial_mantel", "partial_mantel_ref",
    "PermanovaOperatorStatistic", "PermanovaStatistic", "permanova",
    "permanova_ref",
    "PermdispStatistic", "permdisp", "permdisp_ref",
]
