"""Partial Mantel test (Smouse, Long & Sokal 1986) on the hoisted engine.

Correlates distance matrices x and y while controlling for a third matrix
z: the statistic is the first-order partial correlation

    r_xy·z = (r_xy − r_yz·r_xz) / √((1 − r_xz²)(1 − r_yz²))

under row/column permutations of x only. The paper §4.2 split is richer
here than for the plain Mantel test:

* **hoisted** (computed once): x̄ and ‖x−x̄‖; the centered-normalized ŷ
  and ẑ; ``r_yz`` (y and z are never permuted, so it is a constant of the
  null distribution!); and the *residualized* numerator matrix
  ``ŷ_res = (ŷ − r_yz·ẑ)/√(1−r_yz²)`` — the regression of ŷ on ẑ is done
  exactly once, not per permutation.
* **per permutation**: two fused gather-multiply-reduces over the same
  permuted X — ``⟨x_p, ŷ_res⟩`` (the numerator, pre-residualized) and
  ``⟨x_p, ẑ⟩`` (= r_xz) — then a scalar finish ``num/√(1−r_xz²)``. Both
  inner products use Mantel's Σŷ=0 algebra (the mean term vanishes), so
  each is exactly the reduction ``kernels.mantel_corr`` implements;
  ``PartialMantelPallasStatistic.per_batch`` routes them through that
  Pallas kernel with Ŷ-tile reuse across the batch.

``partial_mantel_ref`` mirrors the classical eager evaluation (vegan /
scikit-bio style): per permutation it materializes the permuted condensed
x and calls black-box multi-pass ``pearsonr`` three times.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distance_matrix import DistanceMatrix, condensed_to_square
from repro.kernels.mantel_corr import mantel_corr
from repro.stats import engine
from repro.stats.engine import PermutationTestResult


@partial(jax.tree_util.register_dataclass,
         data_fields=["x", "y", "z", "pre"], meta_fields=["n"])
@dataclasses.dataclass
class PartialMantelStatistic:
    """r_xy·z with ŷ residualized against ẑ once, outside the loop.

    ``pre`` optionally carries the session-level hoist (the invariants
    dict assembled from three Workspaces' cached ``condensed_moments`` by
    ``Workspace.partial_mantel``) so repeated tests reuse the
    normalization and residualization passes."""

    x: jax.Array           # (n, n) permuted matrix
    y: jax.Array           # (n, n) held fixed
    z: jax.Array           # (n, n) held fixed (the control)
    n: int
    pre: Optional[dict] = None

    def hoist(self):
        if self.pre is not None:
            return dict(self.pre)
        iu = np.triu_indices(self.n, k=1)
        x_flat = self.x[iu]
        xm = x_flat - x_flat.mean()
        normxm = jnp.linalg.norm(xm)

        def _hat(mat):
            flat = mat[iu]
            centered = flat - flat.mean()
            return centered / jnp.linalg.norm(centered)

        yhat, zhat = _hat(self.y), _hat(self.z)
        r_yz = jnp.dot(yhat, zhat)                   # permutation-invariant
        y_res = (yhat - r_yz * zhat) / jnp.sqrt(1.0 - r_yz * r_yz)
        return {"normxm": normxm, "r_yz": r_yz,
                "y_res_full": condensed_to_square(y_res, self.n),
                "z_full": condensed_to_square(zhat, self.n)}

    def per_perm(self, inv, order):
        xp = self.x[order][:, order]                 # contiguous row gathers
        scale = 2.0 * inv["normxm"]                  # Σŷ_res = Σẑ = 0
        num = jnp.vdot(xp, inv["y_res_full"]) / scale
        r_xz = jnp.vdot(xp, inv["z_full"]) / scale
        return num / jnp.sqrt(1.0 - r_xz * r_xz)


@partial(jax.tree_util.register_dataclass,
         data_fields=["x", "y", "z", "pre"],
         meta_fields=["n", "block", "interpret"])
@dataclasses.dataclass
class PartialMantelPallasStatistic(PartialMantelStatistic):
    """Same statistic; per-batch path through ``kernels.mantel_corr``.

    ``interpret=None`` dispatches by backend (TPU-native on a TPU, the
    interpreter on CPU) — lane width follows the resolved mode."""

    block: int = 256
    interpret: Optional[bool] = None

    def _tile(self):
        # pad n to the next lane multiple *before* choosing the tile, so a
        # small n never ends up with pad ≈ b−1 (e.g. n=100 now tiles as one
        # 104-block with pad 4, not 96-blocks with pad 92 → ~4x the work).
        # Native TPU lowering needs 128-wide lanes; the interpreter is free.
        from repro.kernels.center_matvec_ops import (pick_block,
                                                     resolve_interpret)
        lane = 8 if resolve_interpret(self.interpret) else 128
        padded = -(-self.n // lane) * lane
        b = pick_block(padded, self.block, lane, floor=lane)
        padded = -(-padded // b) * b
        return b, padded - self.n

    def hoist(self):
        # the padded ŷ_res/ẑ are permutation-invariant too — pad once here,
        # not inside the per-batch loop body
        inv = super().hoist()
        _, pad = self._tile()
        widths = ((0, pad), (0, pad))
        inv["y_res_pad"] = jnp.pad(inv["y_res_full"], widths) if pad \
            else inv["y_res_full"]
        inv["z_pad"] = jnp.pad(inv["z_full"], widths) if pad \
            else inv["z_full"]
        return inv

    def per_batch(self, inv, orders):
        b, pad = self._tile()
        xp = jax.vmap(lambda o: self.x[o][:, o])(orders)
        if pad:
            xp = jnp.pad(xp, ((0, 0), (0, pad), (0, pad)))
        scale = 2.0 * inv["normxm"]
        corr = partial(mantel_corr, block_m=b, block_n=b,
                       interpret=self.interpret)
        num = corr(xp, inv["y_res_pad"]) / scale     # two fused reductions
        r_xz = corr(xp, inv["z_pad"]) / scale        # over one gathered Xp
        return num / jnp.sqrt(1.0 - r_xz * r_xz)


def partial_mantel(x: DistanceMatrix, y: DistanceMatrix, z: DistanceMatrix,
                   permutations: int = 999,
                   key=None,
                   alternative: str = "two-sided",
                   batch_size: int = 8,
                   kernel: str = "xla") -> PermutationTestResult:
    """Hoisted+fused partial Mantel. ``kernel="pallas"`` routes the two
    inner products through the batched Pallas reduction (interpret mode on
    CPU; the TPU-native path at scale). Thin wrapper over a one-shot
    ``api.Workspace`` — identical p-values per key; sessions hold their
    own Workspace to share the normalization hoists."""
    from repro.api.config import ExecConfig
    from repro.api.workspace import Workspace
    cfg = ExecConfig(kernel=kernel)      # validates the kernel name too
    # validate=False: trust the DistanceMatrix as constructed, exactly like
    # the pre-session implementation that read x.data directly
    return Workspace(x, config=cfg, validate=False).partial_mantel(
        y, z, permutations=permutations, key=key, alternative=alternative,
        batch_size=batch_size)


# --------------------------------------------------------------------------
# Oracle — eager multi-pass evaluation, black-box pearsonr per permutation
# --------------------------------------------------------------------------
def partial_mantel_ref(x: DistanceMatrix, y: DistanceMatrix,
                       z: DistanceMatrix, permutations: int = 999,
                       key=None,
                       alternative: str = "two-sided"
                       ) -> PermutationTestResult:
    """Per permutation: materialize the permuted condensed x and call
    multi-pass ``pearsonr`` three times (r_xy, r_xz and — wastefully —
    r_yz, which never changes)."""
    # deferred: core.mantel is an engine client, so a top-level import here
    # would close the stats ↔ core.mantel cycle during package init
    from repro.core.mantel import pearsonr_ref
    key = engine.as_key(key)
    n = len(x)
    y_flat = y.condensed_form()
    z_flat = z.condensed_form()

    def r_partial(x_flat):
        r_xy = pearsonr_ref(x_flat, y_flat)
        r_xz = pearsonr_ref(x_flat, z_flat)
        r_yz = pearsonr_ref(y_flat, z_flat)          # recomputed every time
        return ((r_xy - r_yz * r_xz)
                / jnp.sqrt((1.0 - r_xz ** 2) * (1.0 - r_yz ** 2)))

    observed = r_partial(x.condensed_form())
    orders = engine.permutation_orders(key, permutations, n)
    permuted = jnp.stack([
        r_partial(x.permute(np.asarray(orders[p]), condensed=True))
        for p in range(permutations)])
    return engine.finish(observed, permuted, permutations, alternative, n)
