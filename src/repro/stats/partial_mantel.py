"""Partial Mantel test (Smouse, Long & Sokal 1986) on the hoisted engine.

Correlates distance matrices x and y while controlling for a third matrix
z: the statistic is the first-order partial correlation

    r_xy·z = (r_xy − r_yz·r_xz) / √((1 − r_xz²)(1 − r_yz²))

under row/column permutations of x only. The paper §4.2 split is richer
here than for the plain Mantel test:

* **hoisted** (computed once): x̄ and ‖x−x̄‖; the centered-normalized ŷ
  and ẑ; ``r_yz`` (y and z are never permuted, so it is a constant of the
  null distribution!); and the *residualized* numerator vector
  ``ŷ_res = (ŷ − r_yz·ẑ)/√(1−r_yz²)`` — the regression of ŷ on ẑ is done
  exactly once, not per permutation. Every hoist is CONDENSED (m =
  n(n−1)/2): no square form of any operand is ever built.
* **per permutation**: ONE closed-form condensed gather of the permuted
  x, shared by both multiply-reduces — ``⟨x_p, ŷ_res⟩`` (the numerator,
  pre-residualized) and ``⟨x_p, ẑ⟩`` (= r_xz) — then a scalar finish
  ``num/√(1−r_xz²)``. Both inner products use Mantel's Σŷ=0 algebra (the
  mean term vanishes). The engine's batch path stacks (ŷ_res, ẑ) as two
  rows of one ``kernels.permute_reduce`` call, so the B-permutation tile
  streams each invariant once and gathers x once for the pair.

``partial_mantel_ref`` mirrors the classical eager evaluation (vegan /
scikit-bio style): per permutation it materializes the permuted condensed
x and calls black-box multi-pass ``pearsonr`` three times.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distance_matrix import (DistanceMatrix, condensed_index,
                                        triangle_coords)
from repro.kernels.permute_reduce_ops import permute_reduce
from repro.stats import engine
from repro.stats.engine import PermutationTestResult


@partial(jax.tree_util.register_dataclass,
         data_fields=["x", "y", "z", "pre"],
         meta_fields=["n", "kernel", "interpret", "chunk"])
@dataclasses.dataclass
class PartialMantelStatistic:
    """r_xy·z with ŷ residualized against ẑ once, outside the loop —
    square-free like ``MantelStatistic``.

    ``x``/``y``/``z`` may be square (n, n) matrices or condensed (m,)
    vectors. ``pre`` optionally carries the session-level hoist
    (``{"normxm", "r_yz", "y_res", "z"}`` — all condensed — assembled
    from three Workspaces' cached ``condensed_moments`` by
    ``Workspace.partial_mantel``) so repeated tests reuse the
    normalization and residualization passes and the fixed sides never
    build a square form. ``kernel`` picks the ``permute_reduce`` backend
    for the batched path (``"xla"`` / ``"pallas"``)."""

    x: jax.Array           # permuted side
    y: Optional[jax.Array]  # held fixed; may be None when pre is given
    z: Optional[jax.Array]  # held fixed (the control); ditto
    n: int
    pre: Optional[dict] = None
    kernel: str = "xla"
    interpret: Optional[bool] = None
    chunk: Optional[int] = None  # condensed stream chunk (None: kernel default)

    def hoist(self):
        from repro.core.mantel import _as_condensed
        inv = {"xc": _as_condensed(self.x, self.n)}
        if self.pre is not None:
            inv.update(self.pre)
        else:
            xm = inv["xc"] - inv["xc"].mean()
            inv["normxm"] = jnp.linalg.norm(xm)

            def _hat(mat):
                flat = _as_condensed(mat, self.n)
                centered = flat - flat.mean()
                return centered / jnp.linalg.norm(centered)

            yhat, zhat = _hat(self.y), _hat(self.z)
            r_yz = jnp.dot(yhat, zhat)               # permutation-invariant
            inv["r_yz"] = r_yz
            inv["y_res"] = (yhat - r_yz * zhat) / jnp.sqrt(1.0 - r_yz * r_yz)
            inv["z"] = zhat
        inv["ii"], inv["jj"] = triangle_coords(self.n)
        return inv

    def per_perm(self, inv, order):
        o = order.astype(jnp.int32)
        k = condensed_index(o[inv["ii"]], o[inv["jj"]], self.n)
        xg = inv["xc"][k]                            # ONE gather, two dots
        num = jnp.dot(xg, inv["y_res"]) / inv["normxm"]
        r_xz = jnp.dot(xg, inv["z"]) / inv["normxm"]
        return num / jnp.sqrt(1.0 - r_xz * r_xz)

    def per_batch(self, inv, orders):
        # (ŷ_res, ẑ) stacked: the tile's x gather is shared by both
        # reductions, and each invariant streams once per B permutations
        ys = jnp.stack([inv["y_res"], inv["z"]])
        stats = permute_reduce(inv["xc"], ys, orders, inv["ii"], inv["jj"],
                               impl=self.kernel, chunk=self.chunk,
                               interpret=self.interpret)
        num = stats[0] / inv["normxm"]
        r_xz = stats[1] / inv["normxm"]
        return num / jnp.sqrt(1.0 - r_xz * r_xz)


@partial(jax.tree_util.register_dataclass,
         data_fields=["x", "y", "z", "pre"],
         meta_fields=["n", "kernel", "interpret", "chunk"])
@dataclasses.dataclass
class PartialMantelPallasStatistic(PartialMantelStatistic):
    """Same statistic with the Pallas ``permute_reduce`` backend pinned —
    kept as a named class for the ``kernel="pallas"`` dispatch and
    backward compatibility."""

    kernel: str = "pallas"


def partial_mantel(x: DistanceMatrix, y: DistanceMatrix, z: DistanceMatrix,
                   permutations: int = 999,
                   key=None,
                   alternative: str = "two-sided",
                   batch_size: int = 32,
                   kernel: str = "xla") -> PermutationTestResult:
    """Hoisted+fused partial Mantel on the condensed batch loop.
    ``kernel="pallas"`` routes the stacked inner products through the
    explicit-VMEM ``permute_reduce`` kernel (interpret mode on CPU; the
    TPU-native path at scale) instead of its XLA twin. Thin wrapper over
    a one-shot ``api.Workspace`` — identical p-values per key; sessions
    hold their own Workspace to share the normalization hoists."""
    from repro.api.config import ExecConfig
    from repro.api.workspace import Workspace
    cfg = ExecConfig(kernel=kernel)      # validates the kernel name too
    # validate=False: trust the DistanceMatrix as constructed, exactly like
    # the pre-session implementation that read x.data directly
    return Workspace(x, config=cfg, validate=False).partial_mantel(
        y, z, permutations=permutations, key=key, alternative=alternative,
        batch_size=batch_size)


# --------------------------------------------------------------------------
# Oracle — eager multi-pass evaluation, black-box pearsonr per permutation
# --------------------------------------------------------------------------
def partial_mantel_ref(x: DistanceMatrix, y: DistanceMatrix,
                       z: DistanceMatrix, permutations: int = 999,
                       key=None,
                       alternative: str = "two-sided"
                       ) -> PermutationTestResult:
    """Per permutation: materialize the permuted condensed x and call
    multi-pass ``pearsonr`` three times (r_xy, r_xz and — wastefully —
    r_yz, which never changes)."""
    # deferred: core.mantel is an engine client, so a top-level import here
    # would close the stats ↔ core.mantel cycle during package init
    from repro.core.mantel import pearsonr_ref
    key = engine.as_key(key)
    n = len(x)
    y_flat = y.condensed_form()
    z_flat = z.condensed_form()

    def r_partial(x_flat):
        r_xy = pearsonr_ref(x_flat, y_flat)
        r_xz = pearsonr_ref(x_flat, z_flat)
        r_yz = pearsonr_ref(y_flat, z_flat)          # recomputed every time
        return ((r_xy - r_yz * r_xz)
                / jnp.sqrt((1.0 - r_xz ** 2) * (1.0 - r_yz ** 2)))

    observed = r_partial(x.condensed_form())
    orders = engine.permutation_orders(key, permutations, n)
    permuted = jnp.stack([
        r_partial(x.permute(np.asarray(orders[p]), condensed=True))
        for p in range(permutations)])
    return engine.finish(observed, permuted, permutations, alternative, n)
