"""Shared permutation-test engine: paper §4.2's recipe, generalized.

The paper's Mantel speedup (Algorithm 3 → Algorithm 5) is really two
observations that apply to *every* distance-matrix permutation test:

1. **hoist** — most of each Monte-Carlo iteration is permutation-invariant
   (means, norms, ranks, the centered Gower matrix, group sizes). Compute
   those exactly once, outside the loop.
2. **fuse** — what remains per permutation should be a single pass over the
   matrix (one gather+multiply-reduce, or one small gather-matmul), not a
   chain of eager NumPy ops each costing a DRAM round-trip.

This module owns the loop so each statistic only declares the split:

* ``Statistic`` — the protocol: ``hoist() -> invariants`` runs once;
  ``per_perm(invariants, order) -> scalar`` runs K times inside a batched
  ``lax.map`` (and is auto-vmapped over each batch). Implementations are
  ``jax.tree_util.register_dataclass`` pytrees so the jitted engine caches
  its trace per statistic *class* (+ static metadata), not per call.
  The ``per_batch(invariants, orders) -> (B,)`` hook is the engine's
  PRIMARY execution path when a statistic defines it: the engine
  generates the (K, n) orders once, pads them up to full
  ``batch_size``-row tiles (wrapping real permutations, so ONE jit trace
  serves every K — no trailing-block recompile), and hands each tile to
  the statistic, which typically routes it through the batched
  ``repro.kernels.permute_reduce`` so the hoisted invariant streams once
  per tile instead of once per permutation. ``ExecConfig.batch_size`` is
  exactly the kernel's B grid dimension.
* ``permutation_test`` — permutation-order generation, batched execution,
  p-value finishing. Clients: ``core.mantel.mantel``, ``stats.permanova``,
  ``stats.anosim``, ``stats.partial_mantel``.
* ``permutation_test_distributed`` — the permutation axis through
  ``shard_map``, with a per-device ``fold_in`` exactly like
  ``core.mantel.mantel_distributed`` so the null distribution is
  mesh-shape-invariant (elastic-safe).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import ExecConfig
from repro.obs.compile import note_trace
from repro.obs.trace import current_obs

try:                                    # jax >= 0.6 exports it at top level
    _shard_map = jax.shard_map
except AttributeError:                  # this container's 0.4.x lineage
    from jax.experimental.shard_map import shard_map as _shard_map


# --------------------------------------------------------------------------
# RNG coercion — THE one documented key-handling rule for every entry point
# --------------------------------------------------------------------------
def as_key(key, default: int = 0) -> jax.Array:
    """Coerce ``key: jax.Array | int | None`` to a jax PRNG key.

    Every permutation-test and ordination entry point accepts any of:

    * ``None``            — the entry point's documented default seed
                            (``jax.random.PRNGKey(default)``);
    * a Python/NumPy int  — treated as a seed: ``PRNGKey(int(key))``;
    * a PRNG key array    — raw ``uint32[2]`` or new-style typed key,
                            passed through unchanged.

    This is the single home of the coercion rule; before it existed,
    ``seed`` ints and key arrays were accepted inconsistently across the
    API. Two calls with ``key=7`` and ``key=jax.random.PRNGKey(7)`` are
    guaranteed to draw identical permutations.
    """
    if key is None:
        return jax.random.PRNGKey(default)
    if isinstance(key, (int, np.integer)):
        return jax.random.PRNGKey(int(key))
    return jnp.asarray(key)


# --------------------------------------------------------------------------
# Protocol
# --------------------------------------------------------------------------
@runtime_checkable
class Statistic(Protocol):
    """A permutation-test statistic, split at the paper's hoisting boundary.

    ``n`` is the permutation domain size (number of samples). ``hoist``
    returns a pytree of permutation-invariant values, computed once per
    test; ``per_perm`` maps (invariants, order) to the scalar statistic and
    must be the *only* work that scales with K. The observed statistic is
    ``per_perm(invariants, identity)`` — one code path, no drift between
    observed and null evaluation.
    """

    n: int

    def hoist(self) -> Any: ...

    def per_perm(self, invariants: Any, order: jax.Array) -> jax.Array: ...


@dataclasses.dataclass(frozen=True)
class PermutationTestResult:
    """What every ``repro.stats`` test returns.

    ``method`` names the test ("permanova", "anosim", ...) and ``key``
    records the *resolved* RNG key (post ``as_key``) that drew the
    permutations — together with ``permutations`` they make the result
    self-describing and exactly replayable.
    """

    statistic: float
    p_value: float
    sample_size: int
    permutations: int
    method: str = ""
    key: Optional[jax.Array] = dataclasses.field(default=None, compare=False)


# --------------------------------------------------------------------------
# Pieces hoisted out of core/mantel.py (and generalized)
# --------------------------------------------------------------------------
def permutation_orders(key, permutations: int, n: int) -> jax.Array:
    """(K, n) int array of independent uniform permutations of range(n).

    One batched draw + one batched argsort (a random permutation is the
    argsort of iid random words) — ~2x faster than K vmapped
    ``random.permutation`` calls, which dispatch per-row threefry. A
    32-bit tie (probability ~n²/2³³ per row) resolves by stable sort
    order; at test resolution 1/(K+1) the bias is immaterial."""
    words = jax.random.bits(key, (permutations, n), dtype=jnp.uint32)
    return jnp.argsort(words, axis=-1)


def count_better(orig_stat: jax.Array, permuted_stats: jax.Array,
                 alternative: str) -> jax.Array:
    """How many null draws are at least as extreme as the observed value."""
    if alternative == "two-sided":
        return jnp.sum(jnp.abs(permuted_stats) >= jnp.abs(orig_stat))
    if alternative == "greater":
        return jnp.sum(permuted_stats >= orig_stat)
    if alternative == "less":
        return jnp.sum(permuted_stats <= orig_stat)
    raise ValueError(f"unknown alternative {alternative!r}")


def finish(orig_stat, permuted_stats, permutations: int, alternative: str,
           n: int, method: str = "",
           key: Optional[jax.Array] = None) -> PermutationTestResult:
    """Monte-Carlo p-value with the standard +1 correction. A NaN observed
    statistic propagates to a NaN p-value — NaN comparisons are all False,
    which would otherwise count zero exceedances and report the *most*
    significant p possible for a degenerate input."""
    c = count_better(orig_stat, permuted_stats, alternative)
    p_value = (c + 1) / (permutations + 1)
    orig_stat = float(orig_stat)
    return PermutationTestResult(
        orig_stat, float("nan") if np.isnan(orig_stat) else float(p_value),
        n, permutations, method, key)


# --------------------------------------------------------------------------
# The engine — plus the two tile-level entry points the serving front door
# (`repro.serve`) schedules through. `_null_distribution` remains the
# whole-test fast path; `hoist_and_observe` + `tile_statistics` expose the
# same split at tile granularity so a scheduler can interleave tiles from
# many concurrent requests while reusing the identical traces.
# --------------------------------------------------------------------------
@jax.jit
def hoist_and_observe(stat):
    """``(invariants, observed)`` for ``stat``, one jit region.

    The hoist and the identity-order observed evaluation fuse together
    (the identity gathers fold away instead of materializing full n×n
    copies eagerly). Shared by the distributed engine and by
    ``repro.serve`` admission, which hoists once per pooled session and
    then streams tiles through ``tile_statistics``.
    """
    note_trace("stats.engine.hoist_and_observe",
               (type(stat).__name__, stat.n))
    inv = stat.hoist()
    return inv, stat.per_perm(inv, jnp.arange(stat.n))


@jax.jit
def tile_statistics(stat, invariants, orders):
    """(B,) null statistics for one padded tile of permutation orders.

    The serve scheduler's execution primitive: every tile it assembles —
    regardless of which requests' permutations fill the rows — runs
    through this one trace per (statistic class, n, B) signature, so the
    one-program-per-K sentinel invariant extends across requests. Rows
    are independent (``per_batch`` reduces each order against the same
    hoisted invariants), which is what makes coalescing bitwise-neutral:
    a request's draws do not depend on its tile-mates.
    """
    note_trace("stats.engine.tile",
               (type(stat).__name__, stat.n, orders.shape[0]))
    per_batch = getattr(stat, "per_batch", None)
    if per_batch is not None:
        return per_batch(invariants, orders)
    return jax.vmap(lambda o: stat.per_perm(invariants, o))(orders)


@partial(jax.jit, static_argnames=("permutations", "batch_size"))
def _null_distribution(stat, key, permutations: int, batch_size: int):
    """observed statistic + (K,) null draws, one jit region.

    ``stat`` is a pytree: its arrays are traced, its static metadata (n,
    group count, …) keys the jit cache, so repeated tests of the same
    shape reuse the compiled executable.
    """
    # trace-time only (a jitted body runs once per distinct signature):
    # the sentinel's count of engine programs, free at execution time
    note_trace("stats.engine.null_distribution",
               (type(stat).__name__, stat.n, permutations, batch_size))
    invariants = stat.hoist()                      # runs exactly once
    observed = stat.per_perm(invariants, jnp.arange(stat.n))

    orders = permutation_orders(key, permutations, stat.n)
    per_batch = getattr(stat, "per_batch", None)
    if per_batch is not None and permutations:
        # ONE trace serves every K: orders are padded up to full
        # batch_size tiles by wrapping real permutations (each row must
        # stay a valid order for the statistic's gathers), every tile
        # goes through the same per_batch trace, and the padded tail is
        # masked off before finishing. The pre-PR-5 trailing-block
        # special case traced a SECOND jit program whenever batch_size
        # didn't divide K (the canonical 999 vs batch 32) — same math,
        # double the compile time and cache footprint.
        # K is deliberately NOT in this signature: the padded path's
        # contract is that programs stays 1 across every K at fixed
        # (statistic, n, B) — the sentinel makes that assertable
        note_trace("stats.engine.per_batch",
                   (type(stat).__name__, stat.n, batch_size))
        num_tiles = -(-permutations // batch_size)
        total = num_tiles * batch_size
        if total != permutations:
            orders = orders[jnp.arange(total) % permutations]
        tiles = orders.reshape(num_tiles, batch_size, stat.n)
        permuted = jax.lax.map(lambda o: per_batch(invariants, o),
                               tiles).reshape(total)[:permutations]
    else:
        # lax.map auto-vmaps per_perm over each batch: the batched gathers
        # + one fused reduce, with peak memory of one batch of matrices.
        permuted = jax.lax.map(lambda o: stat.per_perm(invariants, o),
                               orders, batch_size=batch_size)
    return observed, permuted


def permutation_test(stat: Statistic, permutations: int = 999,
                     key=None, alternative: str = "two-sided",
                     batch_size: Optional[int] = None,
                     config: Optional[ExecConfig] = None,
                     method: str = "") -> PermutationTestResult:
    """Run a hoisted+fused Monte-Carlo permutation test for ``stat``.

    ``key`` follows the unified coercion rule (``as_key``: key array, int
    seed, or None -> PRNGKey(0)). ``batch_size`` resolves as explicit arg >
    ``config.batch_size`` > 8; a still-unresolved ``"auto"`` (a config
    that never went through ``ExecConfig.resolve``/Workspace admission)
    is solved here against the statistic's n — from (n, budget) only,
    never K, so the one padded per-batch program keeps serving every K.
    ``method`` is recorded on the result.
    """
    if alternative not in ("two-sided", "greater", "less"):
        raise ValueError(f"unknown alternative {alternative!r}")
    key = as_key(key)
    bs = (config or ExecConfig()).resolve_batch_size(batch_size, 8)
    if bs == "auto":
        from repro.tune.solve import solve_tiles
        bs = solve_tiles(stat.n).batch_size
    obs = current_obs()          # the ambient session (NULL_OBS when none)
    batched = getattr(stat, "per_batch", None) is not None
    tiles = -(-permutations // bs) if permutations else 0
    with obs.span(f"engine.{method or type(stat).__name__}",
                  phase="per_perm", n=stat.n, permutations=permutations,
                  batch_size=bs, tiles=tiles, batched=batched):
        observed, permuted = _null_distribution(stat, key, permutations, bs)
    if batched and permutations:
        # the batched loop IS the condensed_fused traffic model — the
        # padded tail rows are real gathers, so they are charged too
        obs.charge_perm_batch(method or type(stat).__name__, stat.n,
                              tiles * bs, bs)
    return finish(observed, permuted, permutations, alternative, stat.n,
                  method=method, key=key)


# --------------------------------------------------------------------------
# Distributed engine — permutation axis through shard_map
# --------------------------------------------------------------------------
def permutation_test_distributed(stat: Statistic, mesh,
                                 permutations: int = 1024,
                                 key=None,
                                 alternative: str = "two-sided",
                                 perm_axes=("data",),
                                 batch_size: Optional[int] = None,
                                 config: Optional[ExecConfig] = None,
                                 method: str = "") -> PermutationTestResult:
    """Permutation-parallel engine: K/|devices| permutations per device.

    The invariants are hoisted once and replicated; each device draws its
    own permutations via ``fold_in(key, device_index)`` — the same
    elastic-safe construction as ``mantel_distributed``, so the global
    null distribution does not depend on the mesh shape.
    """
    from jax.sharding import PartitionSpec as P

    if alternative not in ("two-sided", "greater", "less"):
        raise ValueError(f"unknown alternative {alternative!r}")
    key = as_key(key)
    batch_size = (config or ExecConfig()).resolve_batch_size(batch_size, 8)

    n_perm_devices = int(np.prod([mesh.shape[a] for a in perm_axes]))
    if permutations % n_perm_devices:
        raise ValueError(f"permutations ({permutations}) must divide over "
                         f"{n_perm_devices} devices")
    per_dev = permutations // n_perm_devices

    invariants, observed = hoist_and_observe(stat)

    def _local(inv):
        dev = 0                     # row-major rank over ALL perm axes, so
        for a in perm_axes:         # no two devices fold_in the same index
            dev = dev * mesh.shape[a] + jax.lax.axis_index(a)
        k = jax.random.fold_in(key, dev)
        orders = permutation_orders(k, per_dev, stat.n)
        return jax.lax.map(lambda o: stat.per_perm(inv, o), orders,
                           batch_size=min(batch_size, per_dev))

    f = _shard_map(
        _local, mesh=mesh,
        in_specs=(P(),),                           # invariants replicated
        out_specs=P(perm_axes[0] if len(perm_axes) == 1 else perm_axes),
    )
    permuted = f(invariants)
    return finish(observed, permuted, permutations, alternative, stat.n,
                  method=method, key=key)


# --------------------------------------------------------------------------
# Shared helpers for grouping-based statistics (PERMANOVA, ANOSIM)
# --------------------------------------------------------------------------
def encode_grouping(grouping) -> tuple[np.ndarray, int]:
    """Map arbitrary hashable labels to int codes in [0, num_groups)."""
    codes = np.unique(np.asarray(grouping), return_inverse=True)[1]
    num_groups = int(codes.max()) + 1
    if num_groups < 2:
        raise ValueError("grouping must contain at least two groups")
    if num_groups == codes.size:
        raise ValueError("grouping must have at least one group of size > 1")
    return codes.astype(np.int32), num_groups
