"""PERMDISP (Anderson 2006) on the hoisted-permutation engine.

Homogeneity-of-dispersions test: ordinate the distance matrix (PCoA),
measure each sample's distance to its group centroid in ordination space,
and compare those dispersions across groups with a one-way ANOVA F whose
null distribution comes from permuting the group labels.

The paper §4.2 split, with the ordination itself as the headline hoist:

* **hoisted** (computed once): the PCoA **coordinates** — produced by the
  matrix-free operator pipeline (``core.pcoa``), so the hoist never
  materializes the n×n *centered* matrix. Note the ordination cost scales
  with the requested dimensionality: the scikit-bio-parity default
  (``dimensions=None`` → all n−1 axes) runs the range-finder at full rank
  — (n, n) blocks and O(n²·n) flops — so at large n pass a small
  ``dimensions`` (≈10–50) to stay in the skinny-block regime the operator
  exists for. Also hoisted: the one-hot design ``Z`` and the group sizes.
* **per permutation**: centroids move with the labels, so each draw is
  ``C = Z_pᵀX / sizes`` (one (g, k) gather-matmul), the distances
  ``v_i = ‖x_i − C_{g(i)}‖`` (one fused O(n·k) pass), and the ANOVA F of
  ``v`` — O(n·g) more. Nothing per-permutation touches anything bigger
  than the hoisted (n, k) coordinates.

``permdisp_ref`` is the eager scikit-bio-style oracle: full ``eigh`` PCoA
in NumPy, then per permutation a Python loop over groups with black-box
``scipy.stats.f_oneway``. Identical keys ⇒ identical permutation orders ⇒
identical p-values.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distance_matrix import DistanceMatrix
from repro.stats import engine
from repro.stats.engine import PermutationTestResult


@partial(jax.tree_util.register_dataclass,
         data_fields=["coords", "grouping"], meta_fields=["n", "num_groups"])
@dataclasses.dataclass
class PermdispStatistic:
    """ANOVA F over distances-to-centroid, coordinates hoisted."""

    coords: jax.Array      # (n, k) PCoA coordinates (the expensive hoist)
    grouping: jax.Array    # (n,) int group codes in [0, num_groups)
    n: int
    num_groups: int

    def hoist(self):
        z = jax.nn.one_hot(self.grouping, self.num_groups,
                           dtype=self.coords.dtype)
        return {"x": self.coords, "z": z, "sizes": jnp.sum(z, axis=0)}

    def per_perm(self, inv, order):
        z = inv["z"][order]                          # O(n·g) label gather
        centroids = (z.T @ inv["x"]) / inv["sizes"][:, None]
        dev = inv["x"] - z @ centroids               # x_i − C_{g(i)}
        v = jnp.sqrt(jnp.maximum(jnp.sum(dev * dev, axis=1), 0.0))
        # one-way ANOVA F over the dispersions v
        group_means = (z.T @ v) / inv["sizes"]
        grand = jnp.mean(v)
        ss_between = jnp.sum(inv["sizes"] * (group_means - grand) ** 2)
        resid = v - z @ group_means
        ss_within = jnp.sum(resid * resid)
        dof_between = self.num_groups - 1
        dof_within = self.n - self.num_groups
        return (ss_between / dof_between) / (ss_within / dof_within)


def permdisp(dm: DistanceMatrix, grouping, permutations: int = 999,
             key=None,
             dimensions: Optional[int] = None, method: str = "fsvd",
             batch_size: int = 32) -> PermutationTestResult:
    """Hoisted+fused PERMDISP; one-sided (greater), like scikit-bio.

    Thin wrapper over a one-shot ``api.Workspace`` — identical p-values
    per key; a session should hold its own Workspace so the ordination
    hoist is shared with ``ws.pcoa()``. ``dimensions=None`` ordinates into
    the full n−1 axes (scikit-bio's behaviour — exact, but the hoist then
    runs the range-finder at full rank, O(n²·n)); a small ``dimensions``
    (≈10–50) trades a truncated dispersion measure for the skinny-block
    cost that makes large n tractable. ``method`` is forwarded to
    ``core.pcoa`` — the default "fsvd" runs matrix-free through
    ``CenteredGramOperator``, so no n² intermediate is built even once.
    ``key`` drives only the permutation orders (the fsvd range-finder uses
    pcoa's fixed internal key), so fused and ref agree
    permutation-for-permutation under one key.
    """
    # deferred: workspace imports core+stats; a top-level import here would
    # close that cycle during package init
    from repro.api.workspace import Workspace
    # validate=False: trust the DistanceMatrix as constructed, exactly like
    # the pre-session implementation that read dm.data directly
    return Workspace(dm, validate=False).permdisp(grouping, permutations=permutations,
                                  key=key, dimensions=dimensions,
                                  method=method, batch_size=batch_size)


# --------------------------------------------------------------------------
# Oracle — scikit-bio's evaluation order, deliberately eager and multi-pass
# --------------------------------------------------------------------------
def permdisp_ref(dm: DistanceMatrix, grouping, permutations: int = 999,
                 key: Optional[jax.Array] = None,
                 dimensions: Optional[int] = None) -> PermutationTestResult:
    """Full eager ``eigh`` PCoA, then per permutation a Python loop over
    groups (centroid, distances) and black-box ``scipy.stats.f_oneway``."""
    from scipy.stats import f_oneway

    from repro.core.centering import center_distance_matrix_ref
    from repro.core.pcoa import resolve_dimensions

    key = engine.as_key(key)
    codes, num_groups = engine.encode_grouping(grouping)
    n = len(dm)
    if codes.size != n:
        raise ValueError("grouping length does not match distance matrix")
    dims = resolve_dimensions(dimensions, n)

    centered = np.asarray(center_distance_matrix_ref(dm.data),
                          dtype=np.float64)
    evals, evecs = np.linalg.eigh(centered)
    order = np.argsort(-evals)[:dims]
    coords = evecs[:, order] * np.sqrt(np.maximum(evals[order], 0.0))

    def f_stat(perm):
        g_p = codes[np.asarray(perm)]
        v = np.empty(n)
        for g in range(num_groups):                  # one pass per group
            mask = g_p == g
            c = coords[mask].mean(axis=0)
            v[mask] = np.linalg.norm(coords[mask] - c, axis=1)
        return f_oneway(*(v[g_p == g] for g in range(num_groups))).statistic

    observed = f_stat(np.arange(n))
    orders = np.asarray(engine.permutation_orders(key, permutations, n))
    permuted = jnp.asarray([f_stat(orders[p]) for p in range(permutations)])
    return engine.finish(jnp.asarray(observed, dtype=permuted.dtype),
                         permuted, permutations, "greater", n)
