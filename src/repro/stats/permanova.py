"""PERMANOVA (Anderson 2001) on the hoisted-permutation engine.

Pseudo-F for a one-way design over a distance matrix. The scikit-bio
implementation re-walks the condensed distance vector once per group per
permutation; here the paper §4.2 recipe applies cleanly:

* **hoisted** (computed once): the centered Gower matrix
  ``G = -½ J D∘D J`` via the fused ``core.centering`` pass, its trace
  (``SS_total`` — permutation-invariant by McArdle & Anderson 2001!), the
  one-hot group design ``Z`` and the group sizes.
* **per permutation**: permuting sample labels permutes the *rows of Z*,
  not the n×n matrix — an O(n·k) gather. Then
  ``SS_among = Σ_g (Z_pᵀ G Z_p)_gg / n_g`` is one gather-matmul whose only
  large operand is ``G``, read once per permutation batch (the engine
  vmaps the batch, so XLA streams each ``G`` tile against all B designs).
  ``F = (SS_among/(k−1)) / ((SS_total − SS_among)/(n−k))``.

``permanova_ref`` mirrors scikit-bio's eager multi-pass evaluation
(condensed d², boolean group masks, one pass per group per permutation)
and is the oracle for the tests and ``benchmarks/bench_stats.py``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.centering import center_distance_matrix
from repro.core.distance_matrix import DistanceMatrix
from repro.stats import engine
from repro.stats.engine import PermutationTestResult


@partial(jax.tree_util.register_dataclass,
         data_fields=["dm", "grouping", "pre"],
         meta_fields=["n", "num_groups"])
@dataclasses.dataclass
class PermanovaStatistic:
    """Pseudo-F with the permutation-invariant pieces hoisted.

    ``pre`` optionally carries a session-level hoist (``{"g": <centered
    Gower matrix>}`` from a Workspace's ``HoistCache``) so back-to-back
    tests on one matrix share the O(n²) centering pass instead of
    re-deriving it inside every ``hoist``.
    """

    dm: jax.Array          # (n, n) validated distance matrix
    grouping: jax.Array    # (n,) int group codes in [0, num_groups)
    n: int
    num_groups: int
    pre: Optional[dict] = None   # optional pre-hoisted {"g": ...}

    def hoist(self):
        g = self.pre["g"] if self.pre is not None else \
            center_distance_matrix(self.dm)          # fused: 2 reads, 2 writes
        z = jax.nn.one_hot(self.grouping, self.num_groups, dtype=g.dtype)
        sizes = jnp.sum(z, axis=0)
        return {"g": g, "z": z, "sizes": sizes, "ss_total": jnp.trace(g)}

    def per_perm(self, inv, order):
        z = inv["z"][order]                          # O(n·k) label gather
        s = jnp.sum(z * (inv["g"] @ z), axis=0)      # (k,) quadratic forms
        ss_among = jnp.sum(s / inv["sizes"])
        ss_within = inv["ss_total"] - ss_among
        dof_among = self.num_groups - 1
        dof_within = self.n - self.num_groups
        return (ss_among / dof_among) / (ss_within / dof_within)


@partial(jax.tree_util.register_dataclass,
         data_fields=["op", "grouping"],
         meta_fields=["n", "num_groups"])
@dataclasses.dataclass
class PermanovaOperatorStatistic:
    """Pseudo-F with the Gower centering held as an OPERATOR, not a matrix.

    The quadratic forms PERMANOVA consumes — ``diag(Z_pᵀ G Z_p)`` — only
    ever touch G through products with the skinny (n, k) permuted design,
    and ``SS_total = tr(G)`` comes exactly from the operator's hoisted
    means (McArdle & Anderson 2001). So when the distances were produced
    by ``repro.dist`` (``Workspace.from_features``), the per-permutation
    pass is ``op.matvec(Z_p)`` against the **condensed** storage: the
    square n×n Gower matrix — the one hoist the materialized statistic
    cannot avoid — never exists, and each permutation batch streams
    (block, n) strips instead (roughly half the bytes of a square-G
    read, with the E-formation fused into the strip sweep).

    ``op`` is any centered-Gram operator pytree
    (``core.operators.CenteredGramOperator`` or the condensed-backed
    ``CondensedCenteredGramOperator``); its tiling metadata is static, so
    the jitted engine caches per (operator type, shape).
    """

    op: object             # centered-Gram operator pytree (G as an operator)
    grouping: jax.Array    # (n,) int group codes in [0, num_groups)
    n: int
    num_groups: int

    def hoist(self):
        z = jax.nn.one_hot(self.grouping, self.num_groups,
                           dtype=self.op.dtype)
        sizes = jnp.sum(z, axis=0)
        return {"z": z, "sizes": sizes, "ss_total": self.op.trace()}

    def per_perm(self, inv, order):
        z = inv["z"][order]                          # O(n·k) label gather
        s = jnp.sum(z * self.op.matvec(z), axis=0)   # (k,) quadratic forms
        ss_among = jnp.sum(s / inv["sizes"])
        ss_within = inv["ss_total"] - ss_among
        dof_among = self.num_groups - 1
        dof_within = self.n - self.num_groups
        return (ss_among / dof_among) / (ss_within / dof_within)


def permanova(dm: DistanceMatrix, grouping, permutations: int = 999,
              key=None, batch_size: int = 32) -> PermutationTestResult:
    """Hoisted+fused PERMANOVA; one-sided (greater), like scikit-bio.

    Thin wrapper over a one-shot ``api.Workspace`` — identical p-values
    per key; a session running several tests should hold its own
    Workspace so the centering hoist is shared. Default batch 32 (vs
    mantel's 8): the per-perm operand here is the (n, k) design, not an
    (n, n) gathered matrix, so a bigger batch amortizes the Gower-matrix
    read at negligible memory cost."""
    from repro.api.workspace import Workspace
    # validate=False: trust the DistanceMatrix as constructed, exactly like
    # the pre-session implementation that read dm.data directly
    return Workspace(dm, validate=False).permanova(grouping, permutations=permutations,
                                   key=key, batch_size=batch_size)


# --------------------------------------------------------------------------
# Oracle — scikit-bio's evaluation order, deliberately eager and multi-pass
# --------------------------------------------------------------------------
def permanova_ref(dm: DistanceMatrix, grouping, permutations: int = 999,
                  key=None) -> PermutationTestResult:
    """Per permutation: rebuild the pair masks and walk the condensed d²
    vector once per group — each step an eager full-vector pass."""
    key = engine.as_key(key)
    codes, num_groups = engine.encode_grouping(grouping)
    n = len(dm)
    if codes.size != n:
        raise ValueError("grouping length does not match distance matrix")
    d2 = dm.condensed_form() ** 2
    iu = np.triu_indices(n, k=1)
    sizes = np.bincount(codes, minlength=num_groups)
    ss_total = float(jnp.sum(d2)) / n
    dof_among = num_groups - 1
    dof_within = n - num_groups

    def f_stat(order):
        g_p = codes[np.asarray(order)]
        gi, gj = g_p[iu[0]], g_p[iu[1]]
        same = gi == gj
        ss_within = 0.0
        for g in range(num_groups):                  # one pass per group
            mask = same & (gi == g)
            ss_within += float(jnp.sum(jnp.where(mask, d2, 0.0))) / sizes[g]
        ss_among = ss_total - ss_within
        return (ss_among / dof_among) / (ss_within / dof_within)

    observed = f_stat(np.arange(n))
    orders = np.asarray(engine.permutation_orders(key, permutations, n))
    permuted = jnp.asarray([f_stat(orders[p]) for p in range(permutations)])
    return engine.finish(observed, permuted, permutations, "greater", n)
