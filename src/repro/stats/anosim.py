"""ANOSIM (Clarke 1993) on the hoisted-permutation engine.

R = (mean between-group rank − mean within-group rank) / (n(n−1)/4), over
the ranks of the condensed distances. The paper §4.2 split:

* **hoisted** (computed once): the *ranks* — the expensive O(m log m) sort
  happens exactly once, never per permutation — plus their square
  symmetric form ``Rk`` (diag 0), the one-hot design ``Z``, the total rank
  sum, and the within-pair count ``Σ_g n_g(n_g−1)/2`` (group sizes are
  permutation-invariant, so both denominators are too).
* **per permutation**: only the *within-group rank sum* changes. With
  permuted design rows ``Z_p`` it is ``½ Σ_g (Z_pᵀ Rk Z_p)_gg`` — the same
  one-pass gather-matmul shape as PERMANOVA's ``SS_among``; the between
  sum falls out by subtraction from the hoisted total.

``anosim_ref`` mirrors scikit-bio's eager evaluation: per permutation it
rebuilds the within-pair boolean mask over all m = n(n−1)/2 pairs and
takes two masked means — several full passes over the condensed vector.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.stats import rankdata

from repro.core.distance_matrix import DistanceMatrix, condensed_to_square
from repro.stats import engine
from repro.stats.engine import PermutationTestResult


def _rank_average(v: jax.Array) -> jax.Array:
    """scipy ``rankdata(method="average")``, via one sort + two binary
    searches instead of ``jax.scipy.stats.rankdata``'s argsort path (~25%
    cheaper at 2M elements — this is the fused test's dominant fixed
    cost). Ranks are half-integers below 2²⁴, so the two agree bitwise."""
    sv = jnp.sort(v)
    lo = jnp.searchsorted(sv, v, side="left")
    hi = jnp.searchsorted(sv, v, side="right")
    return 0.5 * (lo + hi + 1).astype(v.dtype)


@partial(jax.jit, static_argnames=("n",))
def rank_transform_condensed(flat: jax.Array, n: int) -> dict:
    """The rank hoist straight from a condensed vector — the entry point
    for feature-backed sessions (``Workspace.from_features``), whose
    distances live in ``repro.dist``'s condensed layout: the square
    distance matrix is never formed; only the rank matrix itself (which
    ANOSIM's per-permutation gather-matmul genuinely consumes) is
    square."""
    ranks = _rank_average(flat)                      # ranked exactly once
    return {"rank_full": condensed_to_square(ranks, n),
            "total_sum": jnp.sum(ranks)}


@partial(jax.jit, static_argnames=("n",))
def rank_transform(dm_data: jax.Array, n: int) -> dict:
    """The O(m log m) rank hoist, split out so a Workspace can cache it.

    Returns the square symmetric rank matrix (diag 0) and the total rank
    sum — everything about the ranks that ANOSIM's per-permutation pass
    consumes. Bitwise-identical whether computed here (once per session)
    or inside ``AnosimStatistic.hoist`` (once per test)."""
    iu = np.triu_indices(n, k=1)
    return rank_transform_condensed(dm_data[iu], n)


@partial(jax.tree_util.register_dataclass,
         data_fields=["dm", "grouping", "pre"],
         meta_fields=["n", "num_groups"])
@dataclasses.dataclass
class AnosimStatistic:
    """Clarke's R with ranks hoisted out of the Monte-Carlo loop.

    ``pre`` optionally carries the session-level rank hoist (the
    ``rank_transform`` dict from a Workspace's ``HoistCache``) so
    back-to-back tests on one matrix sort the condensed distances once."""

    dm: jax.Array          # (n, n) validated distance matrix
    grouping: jax.Array    # (n,) int group codes in [0, num_groups)
    n: int
    num_groups: int
    pre: Optional[dict] = None   # optional pre-hoisted rank_transform dict

    def hoist(self):
        rt = self.pre if self.pre is not None else \
            rank_transform(self.dm, self.n)
        rank_full = rt["rank_full"]
        z = jax.nn.one_hot(self.grouping, self.num_groups,
                           dtype=rank_full.dtype)
        sizes = jnp.sum(z, axis=0)
        m = self.n * (self.n - 1) / 2.0
        return {"rank_full": rank_full, "z": z,
                "total_sum": rt["total_sum"],
                "within_count": jnp.sum(sizes * (sizes - 1)) / 2.0,
                "between_count": m - jnp.sum(sizes * (sizes - 1)) / 2.0,
                "divisor": self.n * (self.n - 1) / 4.0}

    def per_perm(self, inv, order):
        z = inv["z"][order]                          # O(n·k) label gather
        w_sum = 0.5 * jnp.sum(z * (inv["rank_full"] @ z))
        r_w = w_sum / inv["within_count"]
        r_b = (inv["total_sum"] - w_sum) / inv["between_count"]
        return (r_b - r_w) / inv["divisor"]


def anosim(dm: DistanceMatrix, grouping, permutations: int = 999,
           key=None, batch_size: int = 32) -> PermutationTestResult:
    """Hoisted+fused ANOSIM; one-sided (greater), like scikit-bio.

    Thin wrapper over a one-shot ``api.Workspace`` — identical p-values
    per key; a session running several tests should hold its own
    Workspace so the rank hoist is shared. Default batch 32 (vs mantel's
    8): the per-perm operand here is the (n, k) design, not an (n, n)
    gathered matrix, so a bigger batch amortizes the rank-matrix read at
    negligible memory cost."""
    from repro.api.workspace import Workspace
    # validate=False: trust the DistanceMatrix as constructed, exactly like
    # the pre-session implementation that read dm.data directly
    return Workspace(dm, validate=False).anosim(grouping, permutations=permutations,
                                key=key, batch_size=batch_size)


# --------------------------------------------------------------------------
# Oracle — scikit-bio's evaluation order, deliberately eager and multi-pass
# --------------------------------------------------------------------------
def anosim_ref(dm: DistanceMatrix, grouping, permutations: int = 999,
               key=None) -> PermutationTestResult:
    """Per permutation: rebuild the within mask over all pairs, then two
    masked means — each an eager full-vector pass."""
    key = engine.as_key(key)
    codes, num_groups = engine.encode_grouping(grouping)
    n = len(dm)
    if codes.size != n:
        raise ValueError("grouping length does not match distance matrix")
    iu = np.triu_indices(n, k=1)
    ranks = rankdata(dm.condensed_form())            # skbio also ranks once
    divisor = n * (n - 1) / 4.0

    def r_stat(order):
        g_p = codes[np.asarray(order)]
        within = jnp.asarray(g_p[iu[0]] == g_p[iu[1]])
        w_n = jnp.sum(within)
        r_w = jnp.sum(jnp.where(within, ranks, 0.0)) / w_n
        r_b = jnp.sum(jnp.where(within, 0.0, ranks)) / (ranks.size - w_n)
        return (r_b - r_w) / divisor

    observed = r_stat(np.arange(n))
    orders = np.asarray(engine.permutation_orders(key, permutations, n))
    permuted = jnp.asarray([r_stat(orders[p]) for p in range(permutations)])
    return engine.finish(observed, permuted, permutations, "greater", n)
