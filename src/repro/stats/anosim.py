"""ANOSIM (Clarke 1993) on the hoisted-permutation engine.

R = (mean between-group rank − mean within-group rank) / (n(n−1)/4), over
the ranks of the condensed distances. The paper §4.2 split:

* **hoisted** (computed once): the *ranks* — the expensive O(m log m) sort
  happens exactly once, never per permutation — kept CONDENSED, plus the
  condensed within-group indicator of the ORIGINAL labels
  (``w[k] = [codes[i_k] == codes[j_k]]``), the total rank sum, and the
  within-pair count ``Σ_g n_g(n_g−1)/2`` (group sizes are
  permutation-invariant, so both denominators are too).
* **per permutation**: only the *within-group rank sum* changes — and
  relabelling the samples by ``order`` makes display pair (i, j) a
  within-pair iff the ORIGINAL pair (order[i], order[j]) is one, so

      w_sum(p) = Σ_k ranks[k] · w[tri(order[i_k], order[j_k])]

  is exactly the ``kernels.permute_reduce`` shape: the rank vector
  streams once per B-permutation tile while the indicator is gathered by
  closed-form triangle indexing. The square rank matrix the PR-1 loop
  multiplied per permutation (``Z_pᵀ Rk Z_p``) is gone from the hot path
  entirely — no n×n buffer survives anywhere in the test.

``anosim_ref`` mirrors scikit-bio's eager evaluation: per permutation it
rebuilds the within-pair boolean mask over all m = n(n−1)/2 pairs and
takes two masked means — several full passes over the condensed vector.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.stats import rankdata

from repro.core.distance_matrix import (DistanceMatrix, condensed_index,
                                        triangle_coords)
from repro.kernels.permute_reduce_ops import permute_reduce
from repro.stats import engine
from repro.stats.engine import PermutationTestResult


def _rank_average(v: jax.Array) -> jax.Array:
    """scipy ``rankdata(method="average")``, via one sort + two binary
    searches instead of ``jax.scipy.stats.rankdata``'s argsort path (~25%
    cheaper at 2M elements — this is the fused test's dominant fixed
    cost). Ranks are half-integers below 2²⁴, so the two agree bitwise."""
    sv = jnp.sort(v)
    lo = jnp.searchsorted(sv, v, side="left")
    hi = jnp.searchsorted(sv, v, side="right")
    return 0.5 * (lo + hi + 1).astype(v.dtype)


@partial(jax.jit, static_argnames=("n",))
def rank_transform_condensed(flat: jax.Array, n: int = 0) -> dict:
    """The rank hoist straight from a condensed vector — everything about
    the ranks that ANOSIM's per-permutation pass consumes, and nothing
    square: since the batched loop gathers the condensed within-indicator
    directly, the rank matrix is never materialized (``n`` is accepted
    for backward compatibility but no longer needed)."""
    ranks = _rank_average(flat)                      # ranked exactly once
    return {"ranks": ranks, "total_sum": jnp.sum(ranks)}


@partial(jax.jit, static_argnames=("n",))
def rank_transform(dm_data: jax.Array, n: int) -> dict:
    """The O(m log m) rank hoist from a square matrix, split out so a
    Workspace can cache it. Bitwise-identical whether computed here (once
    per session) or inside ``AnosimStatistic.hoist`` (once per test)."""
    iu = np.triu_indices(n, k=1)
    return rank_transform_condensed(dm_data[iu])


@partial(jax.tree_util.register_dataclass,
         data_fields=["dm", "grouping", "pre"],
         meta_fields=["n", "num_groups", "kernel", "interpret", "chunk"])
@dataclasses.dataclass
class AnosimStatistic:
    """Clarke's R with ranks hoisted out of the Monte-Carlo loop, on the
    condensed batch-fused path.

    ``dm`` may be a square (n, n) matrix, a condensed (m,) vector, or
    ``None`` when ``pre`` carries the session-level rank hoist (the
    ``rank_transform`` dict from a Workspace's ``HoistCache``) so
    back-to-back tests on one matrix sort the condensed distances once.
    ``kernel`` picks the ``permute_reduce`` backend for the batched
    path."""

    dm: Optional[jax.Array]   # (n, n) square / (m,) condensed / None w/ pre
    grouping: jax.Array       # (n,) int group codes in [0, num_groups)
    n: int
    num_groups: int
    pre: Optional[dict] = None   # optional pre-hoisted rank_transform dict
    kernel: str = "xla"
    interpret: Optional[bool] = None
    chunk: Optional[int] = None  # condensed stream chunk (None: kernel default)

    def hoist(self):
        from repro.core.mantel import _as_condensed
        if self.pre is not None:
            rt = self.pre
        else:
            rt = rank_transform_condensed(_as_condensed(self.dm, self.n))
        ii, jj = triangle_coords(self.n)
        codes = self.grouping.astype(jnp.int32)
        # condensed within-indicator over the ORIGINAL labels: permuting
        # the samples only permutes which pair is looked up, so this is
        # the one gatherable hoist the whole null distribution needs
        within = (codes[ii] == codes[jj]).astype(rt["ranks"].dtype)
        sizes = jnp.zeros(self.num_groups,
                          dtype=rt["ranks"].dtype).at[codes].add(1.0)
        m = self.n * (self.n - 1) / 2.0
        within_count = jnp.sum(sizes * (sizes - 1)) / 2.0
        return {"ranks": rt["ranks"], "within": within, "ii": ii, "jj": jj,
                "total_sum": rt["total_sum"],
                "within_count": within_count,
                "between_count": m - within_count,
                "divisor": self.n * (self.n - 1) / 4.0}

    def _finish_r(self, inv, w_sum):
        r_w = w_sum / inv["within_count"]
        r_b = (inv["total_sum"] - w_sum) / inv["between_count"]
        return (r_b - r_w) / inv["divisor"]

    def per_perm(self, inv, order):
        o = order.astype(jnp.int32)
        k = condensed_index(o[inv["ii"]], o[inv["jj"]], self.n)
        w_sum = jnp.dot(inv["ranks"], inv["within"][k])
        return self._finish_r(inv, w_sum)

    def per_batch(self, inv, orders):
        w_sums = permute_reduce(inv["within"], inv["ranks"][None, :],
                                orders, inv["ii"], inv["jj"],
                                impl=self.kernel, chunk=self.chunk,
                                interpret=self.interpret)
        return self._finish_r(inv, w_sums[0])


def anosim(dm: DistanceMatrix, grouping, permutations: int = 999,
           key=None, batch_size: int = 32) -> PermutationTestResult:
    """Hoisted+fused ANOSIM; one-sided (greater), like scikit-bio.

    Thin wrapper over a one-shot ``api.Workspace`` — identical p-values
    per key; a session running several tests should hold its own
    Workspace so the rank hoist is shared. Batches of 32 permutations
    share each streamed pass over the hoisted condensed ranks."""
    from repro.api.workspace import Workspace
    # validate=False: trust the DistanceMatrix as constructed, exactly like
    # the pre-session implementation that read dm.data directly
    return Workspace(dm, validate=False).anosim(grouping, permutations=permutations,
                                key=key, batch_size=batch_size)


# --------------------------------------------------------------------------
# Oracle — scikit-bio's evaluation order, deliberately eager and multi-pass
# --------------------------------------------------------------------------
def anosim_ref(dm: DistanceMatrix, grouping, permutations: int = 999,
               key=None) -> PermutationTestResult:
    """Per permutation: rebuild the within mask over all pairs, then two
    masked means — each an eager full-vector pass."""
    key = engine.as_key(key)
    codes, num_groups = engine.encode_grouping(grouping)
    n = len(dm)
    if codes.size != n:
        raise ValueError("grouping length does not match distance matrix")
    iu = np.triu_indices(n, k=1)
    ranks = rankdata(dm.condensed_form())            # skbio also ranks once
    divisor = n * (n - 1) / 4.0

    def r_stat(order):
        g_p = codes[np.asarray(order)]
        within = jnp.asarray(g_p[iu[0]] == g_p[iu[1]])
        w_n = jnp.sum(within)
        r_w = jnp.sum(jnp.where(within, ranks, 0.0)) / w_n
        r_b = jnp.sum(jnp.where(within, 0.0, ranks)) / (ranks.size - w_n)
        return (r_b - r_w) / divisor

    observed = r_stat(np.arange(n))
    orders = np.asarray(engine.permutation_orders(key, permutations, n))
    permuted = jnp.asarray([r_stat(orders[p]) for p in range(permutations)])
    return engine.finish(observed, permuted, permutations, "greater", n)
