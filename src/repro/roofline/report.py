"""Render the dry-run JSON results into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.roofline.report [--results results/]
"""

from __future__ import annotations

import argparse
import json
import os

HBM_PER_CHIP = 16e9   # v5e


def load(results_dir: str):
    out = {}
    for name in sorted(os.listdir(results_dir)):
        if name.startswith("dryrun_") and name.endswith(".json"):
            with open(os.path.join(results_dir, name)) as f:
                out[name[len("dryrun_"):-len(".json")]] = json.load(f)
    return out


def fmt_bytes(b):
    if b >= 1e9:
        return f"{b / 1e9:.1f}G"
    if b >= 1e6:
        return f"{b / 1e6:.0f}M"
    return f"{b / 1e3:.0f}K"


def roofline_table(cells: dict, mesh_name: str) -> str:
    rows = []
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| model/exec | mfu_bound | fit(GB) |")
    sep = "|" + "---|" * 9
    rows.append(hdr)
    rows.append(sep)
    for key in sorted(cells):
        r = cells[key]
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR: "
                        f"{r['error'][:60]} | | | | | | |")
            continue
        t = r["roofline"]
        ma = r["memory_analysis"]
        peak = ma.get("peak_adjusted_bytes_per_device",
                      ma["argument_bytes_per_device"]
                      + ma["temp_bytes_per_device"]) / 1e9
        fit = "✓" if peak < HBM_PER_CHIP / 1e9 else "✗"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} "
            f"| {t['memory_s']:.4f} | {t['collective_s']:.4f} "
            f"| **{t['dominant']}** | {t['useful_ratio']:.2f} "
            f"| {t['mfu_bound']:.3f} | {peak:.1f} {fit} |")
    return "\n".join(rows)


def dryrun_table(cells: dict) -> str:
    rows = ["| arch | shape | params | arg B/dev | temp B/dev | collectives "
            "(wire B/dev) | #coll ops | compile_s |",
            "|" + "---|" * 8]
    for key in sorted(cells):
        r = cells[key]
        if "error" in r:
            continue
        ma = r["memory_analysis"]
        co = r["collectives"]
        kinds = ", ".join(f"{k}:{fmt_bytes(v)}" for k, v in sorted(co.items())
                          if k not in ("total", "count") and v > 0)
        rows.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {r['analytic']['params_total'] / 1e9:.1f}B "
            f"| {fmt_bytes(ma['argument_bytes_per_device'])} "
            f"| {fmt_bytes(ma['temp_bytes_per_device'])} "
            f"| {kinds} | {co.get('count', 0)} | {r.get('compile_s', 0)} |")
    return "\n".join(rows)


def summarize(results_dir: str):
    data = load(results_dir)
    for mesh_name, cells in data.items():
        ok = [k for k, v in cells.items() if "error" not in v]
        bad = [k for k, v in cells.items() if "error" in v]
        def peak(k):
            ma = cells[k]["memory_analysis"]
            return ma.get("peak_adjusted_bytes_per_device",
                          ma["argument_bytes_per_device"]
                          + ma["temp_bytes_per_device"])

        over = [k for k in ok if peak(k) > HBM_PER_CHIP]
        print(f"== {mesh_name}: {len(ok)} ok, {len(bad)} errors, "
              f"{len(over)} over 16GB/chip ==")
        for k in bad:
            print(f"   ERROR {k}: {cells[k]['error'][:100]}")
        for k in over:
            ma = cells[k]["memory_analysis"]
            print(f"   OVER {k}: args {fmt_bytes(ma['argument_bytes_per_device'])}"
                  f" + temp {fmt_bytes(ma['temp_bytes_per_device'])}"
                  f" (adjusted {fmt_bytes(peak(k))})")
        doms = {}
        for k in ok:
            d = cells[k]["roofline"]["dominant"]
            doms[d] = doms.get(d, 0) + 1
        print(f"   dominant terms: {doms}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    if args.markdown:
        data = load(args.results)
        for mesh_name, cells in data.items():
            print(f"\n### Roofline — {mesh_name}\n")
            print(roofline_table(cells, mesh_name))
            print(f"\n### Dry-run — {mesh_name}\n")
            print(dryrun_table(cells))
    else:
        summarize(args.results)


if __name__ == "__main__":
    main()
