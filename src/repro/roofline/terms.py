"""The three roofline terms (harness §ROOFLINE ANALYSIS).

    compute_s    = FLOPs / (chips · 197e12)
    memory_s     = HBM bytes / (chips · 819e9)        [per-device bytes · 1]
    collective_s = wire bytes / (chips · links · 50e9)

Links per chip: a v5e chip has 4 ICI links on the 2-D torus; on the
(16, 16) mesh both dimensions are ring-connected, and cross-pod traffic
('pod' axis) rides pod-level interconnect which we model at one link
equivalent (conservative). We report link_count=4 for the intra-pod
collective budget and note the assumption.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS

LINKS_PER_CHIP = 4


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    step_time_s: float            # max of the three (overlap-ideal bound)
    flops_executed: float
    flops_model: float
    useful_ratio: float           # model / executed
    mfu_bound: float              # model flops / (step_time · chips · peak)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline_terms(flops_executed: float, flops_model: float,
                   bytes_hbm_per_device: float,
                   collective_bytes_per_device: float,
                   n_chips: int) -> RooflineTerms:
    compute_s = flops_executed / (n_chips * PEAK_FLOPS)
    memory_s = bytes_hbm_per_device / HBM_BW
    collective_s = collective_bytes_per_device / (LINKS_PER_CHIP * ICI_BW)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    step = max(terms.values())
    mfu = (flops_model / (step * n_chips * PEAK_FLOPS)) if step > 0 else 0.0
    return RooflineTerms(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, step_time_s=step,
        flops_executed=flops_executed, flops_model=flops_model,
        useful_ratio=(flops_model / flops_executed) if flops_executed else 0.0,
        mfu_bound=mfu)
