from repro.roofline.hlo import collective_bytes_per_device, parse_hlo_collectives
from repro.roofline.model import step_costs
from repro.roofline.terms import roofline_terms

__all__ = ["collective_bytes_per_device", "parse_hlo_collectives",
           "step_costs", "roofline_terms"]
