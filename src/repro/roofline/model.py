"""Analytic per-step FLOP/byte model — exact for the einsums this codebase
emits (EXPERIMENTS.md §Method documents why this exists: XLA's
``cost_analysis()`` counts ``lax.scan`` bodies once, undercounting a
96-layer stack 96×; we therefore derive compute/memory terms analytically
and keep cost_analysis as a reported cross-check).

Conventions:

* FLOPs are *executed* FLOPs (including causal-mask waste in chunked
  prefill attention, MoE capacity padding, remat recompute, the one-hot
  embedding matmul) — the honest numerator for "how busy is the MXU".
* ``model_flops`` is the *useful* floor: 6·N_active·tokens for training,
  2·N_active·tokens for inference (+ exact useful attention), so
  executed/useful exposes redundancy (remat, mask waste, dispatch).
* Bytes are per-device HBM traffic with an explicit inventory
  (weights×uses via FSDP all-gather, activation tensors ×(fwd, remat,
  bwd), optimizer state, KV cache reads) — napkin math, stated not
  hidden.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class StepCost:
    flops_executed: float          # whole-step, all devices
    flops_model: float             # useful floor
    bytes_hbm_per_device: float
    params_total: float
    breakdown: Dict[str, float]


def _dtype_bytes(name: str) -> int:
    return {"bfloat16": 2, "float32": 4, "float16": 2}[name]


# --------------------------------------------------------------------------
# per-layer forward matmul FLOPs per token (×2 mult-add inside)
# --------------------------------------------------------------------------
def _attn_proj_flops(cfg) -> float:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return 2 * d * hd * (h + 2 * k) + 2 * h * hd * d


def _attn_score_flops(cfg, s_kv: float) -> float:
    """per token: QKᵀ + AV over s_kv keys."""
    return 4 * s_kv * cfg.n_heads * cfg.head_dim


def _mlp_flops(cfg) -> float:
    n_mats = 2 if cfg.mlp_act == "sq_relu" else 3
    return 2 * n_mats * cfg.d_model * cfg.d_ff


def _moe_flops(cfg) -> float:
    n_mats = 2 if cfg.mlp_act == "sq_relu" else 3
    expert = 2 * n_mats * cfg.d_model * cfg.d_ff
    executed = cfg.top_k * cfg.capacity_factor * expert       # capacity pad
    dispatch = 4 * cfg.top_k * cfg.capacity_factor * cfg.d_model  # disp+comb
    router = 2 * cfg.d_model * cfg.n_experts
    return executed + dispatch + router


def _moe_useful_flops(cfg) -> float:
    n_mats = 2 if cfg.mlp_act == "sq_relu" else 3
    return cfg.top_k * 2 * n_mats * cfg.d_model * cfg.d_ff


def _rec_flops(cfg) -> float:
    d, r = cfg.d_model, cfg.lru_width_actual
    return (2 * d * r * 2        # two branches
            + 2 * r * d          # out proj
            + 2 * r * r * 2      # dense gates (W_a, W_x)
            + 2 * cfg.conv_width * r
            + 12 * r)            # scan elementwise


def _ssd_flops(cfg) -> float:
    d, di = cfg.d_model, cfg.d_inner
    g, n, nh, hd = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    q = cfg.ssm_chunk
    proj = 2 * d * (2 * di + 2 * g * n + nh) + 2 * di * d
    conv = 2 * cfg.conv_width * (di + 2 * g * n)
    intra = 2 * q * g * n + 2 * q * nh * hd + 3 * q * nh   # scores, apply, decay
    state = 4 * nh * hd * n                                 # build + inter emit
    return proj + conv + intra + state


def _ssd_decode_flops(cfg) -> float:
    d, di = cfg.d_model, cfg.d_inner
    g, n, nh, hd = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    proj = 2 * d * (2 * di + 2 * g * n + nh) + 2 * di * d
    return proj + 6 * nh * hd * n


def _layer_flops_fwd(cfg, btype: str, s_kv: float, kind: str) -> float:
    """per-token executed forward FLOPs of one layer."""
    if btype in ("attn", "moe"):
        core = _attn_proj_flops(cfg) + _attn_score_flops(cfg, s_kv)
        return core + (_moe_flops(cfg) if btype == "moe" else _mlp_flops(cfg))
    if btype == "local":
        w_eff = min(cfg.window * (2 if kind == "prefill" else 1), s_kv)
        return _attn_proj_flops(cfg) + _attn_score_flops(cfg, w_eff) \
            + _mlp_flops(cfg)
    if btype == "rec":
        return _rec_flops(cfg) + _mlp_flops(cfg)
    if btype == "ssd":
        return (_ssd_decode_flops(cfg) if kind == "decode"
                else _ssd_flops(cfg))
    raise ValueError(btype)


def _layer_flops_useful(cfg, btype: str, s_kv_exact: float) -> float:
    """useful = 2×active-params matmuls + exact causal attention."""
    if btype in ("attn", "moe"):
        core = _attn_proj_flops(cfg) + _attn_score_flops(cfg, s_kv_exact)
        return core + (_moe_useful_flops(cfg) if btype == "moe"
                       else _mlp_flops(cfg))
    if btype == "local":
        return (_attn_proj_flops(cfg)
                + _attn_score_flops(cfg, min(cfg.window, s_kv_exact))
                + _mlp_flops(cfg))
    if btype == "rec":
        return _rec_flops(cfg) + _mlp_flops(cfg)
    if btype == "ssd":
        return _ssd_flops(cfg)
    raise ValueError(btype)


# --------------------------------------------------------------------------
# activation-byte inventory per layer per token (forward, one pass)
# --------------------------------------------------------------------------
def _layer_act_bytes(cfg, btype: str, kind: str) -> float:
    """Major activation tensors written+read once in forward (bytes/token).
    Chunk-transient score tensors are excluded (they live in VMEM-scale
    chunks by construction — the paper's fused-pass assumption)."""
    b = _dtype_bytes(cfg.compute_dtype)
    d, hd = cfg.d_model, cfg.head_dim
    if btype in ("attn", "local", "moe"):
        qkv = (cfg.n_heads + 2 * cfg.n_kv_heads) * hd * b
        ffn_h = (cfg.d_ff if btype != "moe"
                 else cfg.top_k * cfg.capacity_factor * cfg.d_ff) * b
        glu = 2 if cfg.mlp_act != "sq_relu" else 1
        return 4 * d * b + qkv + glu * ffn_h
    if btype == "rec":
        r = cfg.lru_width_actual
        return 4 * d * b + 5 * r * b + 2 * cfg.d_ff * b
    if btype == "ssd":
        di = cfg.d_inner
        return (3 * d * b + (2 * di + 2 * cfg.ssm_ngroups * cfg.ssm_state) * b
                + 4 * cfg.ssm_nheads)   # dt etc. fp32-ish, minor
    raise ValueError(btype)


# --------------------------------------------------------------------------
# whole-step costs
# --------------------------------------------------------------------------
def step_costs(cfg: ModelConfig, shape: ShapeConfig, n_chips: int) -> StepCost:
    pb = _dtype_bytes(cfg.param_dtype)
    ob = _dtype_bytes(cfg.opt_dtype)
    n_params = cfg.param_count()
    layer_types = cfg.layer_types()
    d, v = cfg.d_model, cfg.vocab

    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        s_kv = shape.seq_len   # full masked attention at 4k (executed = S)
        s_useful = (shape.seq_len + 1) / 2
        fwd = sum(_layer_flops_fwd(cfg, t, s_kv, "train")
                  for t in layer_types)
        if cfg.is_encdec:
            enc_tokens = shape.global_batch * (shape.seq_len
                                               // cfg.enc_len_ratio)
            enc_fwd = cfg.n_enc_layers * (_attn_proj_flops(cfg)
                                          + _attn_score_flops(
                                              cfg, shape.seq_len
                                              // cfg.enc_len_ratio)
                                          + _mlp_flops(cfg))
            cross = cfg.n_layers * (_attn_proj_flops(cfg)
                                    + _attn_score_flops(
                                        cfg, shape.seq_len
                                        // cfg.enc_len_ratio))
            fwd_total_tok = fwd * tokens + (enc_fwd * enc_tokens
                                            + cross * tokens)
        else:
            fwd_total_tok = fwd * tokens
        embed_head = (2 * v * d) * 2 + 2 * v        # one-hot embed + head + loss
        # fwd(1) + remat-fwd(1) + bwd(2) for layers; embed/head: fwd+bwd (3×)
        remat_mult = {"full": 4.0, "dots": 3.5, "none": 3.0}[cfg.remat]
        flops_exec = fwd_total_tok * remat_mult + embed_head * tokens * 3.0

        n_active = cfg.active_param_count()
        useful_attn = sum(
            _attn_score_flops(cfg, s_useful) for t in layer_types
            if t in ("attn", "moe")) + sum(
            _attn_score_flops(cfg, min(cfg.window, s_useful))
            for t in layer_types if t == "local")
        flops_model = (6 * n_active + 3 * useful_attn) * tokens

        # ---- bytes per device ----
        micro = cfg.microbatches
        # weights: all-gathered per microbatch per pass (fwd, remat, bwd)
        w_traffic = n_params * pb * 3 * micro
        act = sum(_layer_act_bytes(cfg, t, "train") for t in layer_types)
        act_traffic = act * tokens / n_chips * 4       # fwd+remat+bwd(2)
        logits_traffic = tokens * v * 2 / n_chips * 2  # bf16 logits fwd+bwd
        opt_traffic = (n_params / n_chips) * (4 * ob + 2 * pb + 4)
        bytes_dev = (w_traffic + act_traffic + logits_traffic + opt_traffic)

        return StepCost(flops_exec, flops_model, bytes_dev, n_params, {
            "fwd_layer_flops_per_tok": fwd,
            "weights_bytes": w_traffic,
            "act_bytes": act_traffic,
            "logits_bytes": logits_traffic,
            "opt_bytes": opt_traffic,
        })

    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        # chunked causal attention executes the full rectangle (waste 2×)
        fwd = sum(_layer_flops_fwd(cfg, t, shape.seq_len, "prefill")
                  for t in layer_types)
        if cfg.is_encdec:
            enc_len = shape.seq_len // cfg.enc_len_ratio
            enc_tokens = shape.global_batch * enc_len
            fwd_total = (fwd * tokens
                         + cfg.n_enc_layers * (_attn_proj_flops(cfg)
                                               + _attn_score_flops(cfg, enc_len)
                                               + _mlp_flops(cfg)) * enc_tokens
                         + cfg.n_layers * (_attn_proj_flops(cfg)
                                           + _attn_score_flops(cfg, enc_len))
                         * tokens)
        else:
            fwd_total = fwd * tokens
        embed_head = 2 * v * d * tokens + 2 * v * d * shape.global_batch
        flops_exec = fwd_total + embed_head

        n_active = cfg.active_param_count()
        s_useful = (shape.seq_len + 1) / 2
        useful_attn = sum(
            _attn_score_flops(cfg, s_useful) for t in layer_types
            if t in ("attn", "moe")) + sum(
            _attn_score_flops(cfg, min(cfg.window, s_useful))
            for t in layer_types if t == "local")
        flops_model = (2 * n_active + useful_attn) * tokens

        act = sum(_layer_act_bytes(cfg, t, "prefill") for t in layer_types)
        cb = _dtype_bytes(cfg.compute_dtype)
        kv_write = sum(2 * cfg.n_kv_heads * cfg.head_dim * cb
                       for t in layer_types if t in ("attn", "moe", "local"))
        bytes_dev = (n_params * pb
                     + (act + kv_write) * tokens / n_chips
                     + 2 * v * d * pb)      # head read
        return StepCost(flops_exec, flops_model, bytes_dev, n_params, {
            "kv_write_bytes": kv_write * tokens / n_chips})

    # ---- decode: one token, cache depth = seq_len ----
    bsz = shape.global_batch
    cb = _dtype_bytes(cfg.compute_dtype)
    fwd = sum(_layer_flops_fwd(cfg, t, shape.seq_len, "decode")
              for t in layer_types)
    if cfg.is_encdec:
        enc_len = shape.seq_len // cfg.enc_len_ratio
        fwd += cfg.n_layers * (_attn_proj_flops(cfg)
                               + _attn_score_flops(cfg, enc_len))
    embed_head = 2 * v * d * 2
    flops_exec = (fwd + embed_head) * bsz
    flops_model = flops_exec                     # decode executes ~exactly
    # bytes: whole active params + the KV cache slice per token
    # int8 KV quantization: 1 byte/element + f32 scale per (t, head)
    kv_b = 1 if cfg.kv_quant else cb
    kv_scale = (4.0 / cfg.head_dim) if cfg.kv_quant else 0.0
    cache_bytes = 0.0
    for t in cfg.layer_types():
        if t in ("attn", "moe"):
            cache_bytes += 2 * shape.seq_len * cfg.n_kv_heads * cfg.head_dim \
                * (kv_b + kv_scale)
        elif t == "local":
            cache_bytes += 2 * min(cfg.window, shape.seq_len) \
                * cfg.n_kv_heads * cfg.head_dim * (kv_b + kv_scale)
        elif t == "rec":
            cache_bytes += cfg.lru_width_actual * (4 + cb * (cfg.conv_width - 1))
        elif t == "ssd":
            cache_bytes += (cfg.ssm_nheads * cfg.ssm_state * cfg.ssm_headdim * 4
                            + (cfg.d_inner + 2 * cfg.ssm_ngroups
                               * cfg.ssm_state) * cb * (cfg.conv_width - 1))
    if cfg.is_encdec:
        enc_len = shape.seq_len // cfg.enc_len_ratio
        cache_bytes += cfg.n_layers * (2 * shape.seq_len + 2 * enc_len) \
            * cfg.n_kv_heads * cfg.head_dim * cb
        cache_bytes -= sum(2 * shape.seq_len * cfg.n_kv_heads * cfg.head_dim
                           * cb for t in cfg.layer_types())
    params_active = cfg.active_param_count()
    # decode reads every active weight shard once and the cache shard once
    bytes_dev = (params_active * pb + cache_bytes * bsz) / n_chips
    return StepCost(flops_exec, flops_model, bytes_dev, n_params, {
        "cache_bytes_total": cache_bytes * bsz})
