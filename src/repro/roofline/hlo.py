"""HLO-text collective parser with while-loop trip-count correction.

The harness asks for collective bytes parsed from the compiled HLO.
One methodological trap (verified empirically, EXPERIMENTS.md §Method):
XLA's ``cost_analysis()`` and a naive text scan both count a while-loop
body ONCE — but our layer stacks are ``lax.scan``s, so a 96-layer model's
collectives would be undercounted 96×. This parser:

1. splits the HLO module into computations,
2. finds every collective op and computes its *wire bytes per device*
   with the standard ring formulas (group size ``g`` from replica_groups):
       all-reduce         2·(g−1)/g · bytes      (ring reduce + broadcast)
       all-gather         (g−1)/g · out_bytes
       reduce-scatter     (g−1)/g · in_bytes
       all-to-all         (g−1)/g · bytes
       collective-permute bytes
3. walks the call graph (while/call/conditional/fusion) multiplying by
   while trip counts extracted from the loop condition's comparison
   constant.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Sum byte sizes of every array shape in a (possibly tuple) type."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    bytes_wire: int          # per-device wire bytes (ring formulas)
    bytes_payload: int       # raw operand/output bytes
    group_size: int
    computation: str


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$",
                     stripped)
        # computation header lines look like: "%name (args) -> type {"
        if stripped.endswith("{") and ("->" in stripped or
                                       stripped.startswith("ENTRY")):
            m2 = re.search(r"%?([\w\.\-]+)\s*\(", stripped)
            cur = m2.group(1) if m2 else f"anon{len(comps)}"
            comps[cur] = []
        elif stripped.startswith("}"):
            cur = None
        elif cur is not None:
            comps[cur].append(stripped)
    return comps


def _group_size(line: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)  # iota format [g,n]
    if m:
        return int(m.group(2))
    return total_devices


def _wire_bytes(kind: str, out_bytes: int, in_bytes: int, g: int) -> int:
    if g <= 1:
        return 0
    if kind == "all-reduce":
        return int(2 * (g - 1) / g * out_bytes)
    if kind == "all-gather":
        return int((g - 1) / g * out_bytes)
    if kind == "reduce-scatter":
        return int((g - 1) / g * in_bytes if in_bytes else (g - 1) * out_bytes)
    if kind == "all-to-all":
        return int((g - 1) / g * out_bytes)
    if kind == "collective-permute":
        return out_bytes
    return out_bytes


def _trip_count(cond_lines: List[str]) -> int:
    """Extract the while trip count from its condition computation:
    jax emits `compare(iter, constant(N)), direction=LT`."""
    consts = []
    for ln in cond_lines:
        if "constant(" in ln and ("s32" in ln or "s64" in ln or "u32" in ln):
            for m in re.finditer(r"constant\((\d+)\)", ln):
                consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def parse_hlo_collectives(hlo: str, total_devices: int
                          ) -> Tuple[List[CollectiveOp], Dict[str, int]]:
    """→ (flat collective list with per-execution wire bytes,
          {computation: trip multiplier from the call graph})."""
    comps = _split_computations(hlo)

    # call graph: computation → [(callee, multiplier)]
    calls: Dict[str, List[Tuple[str, str]]] = {c: [] for c in comps}
    whiles: Dict[str, Tuple[str, str]] = {}
    trip_hints: Dict[str, int] = {}
    for cname, lines in comps.items():
        for ln in lines:
            wm = re.search(r"\bwhile\(.*?condition=%?([\w\.\-]+),\s*"
                           r"body=%?([\w\.\-]+)", ln)
            if wm:
                body = wm.group(2)
                calls[cname].append(("while", body))
                whiles[body] = (cname, wm.group(1))
                # XLA annotates the trip count when it can prove it
                tm = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ln)
                if tm:
                    trip_hints[body] = int(tm.group(1))
                continue
            for cm in re.finditer(r"(?:calls|to_apply|body|branch_computations)"
                                  r"=%?\{?([\w\.\-,\s%]+)\}?", ln):
                for callee in re.split(r"[,\s]+", cm.group(1)):
                    callee = callee.strip().lstrip("%")
                    if callee in comps and callee != cname:
                        calls[cname].append(("call", callee))

    # multiplier per computation, walking down from ENTRY-ish roots
    called = {c for lst in calls.values() for _, c in lst}
    roots = [c for c in comps if c not in called]
    mult: Dict[str, int] = {c: 0 for c in comps}

    def visit(c: str, m: int):
        if m <= 0 or c not in comps:
            return
        mult[c] = mult.get(c, 0) + m
        for kind, callee in calls.get(c, []):
            if kind == "while":
                body = callee
                cond = whiles.get(body, (None, None))[1]
                tc = trip_hints.get(body) or (
                    _trip_count(comps.get(cond, [])) if cond else 1)
                visit(body, m * tc)
                if cond:
                    visit(cond, m)   # negligible, but keep graph complete
            else:
                visit(callee, m)

    for r in roots:
        visit(r, 1)

    ops: List[CollectiveOp] = []
    for cname, lines in comps.items():
        m = mult.get(cname, 1) or 1
        for ln in lines:
            for kind in _COLLECTIVES:
                # match "= TYPE kind(" and async "kind-start("
                if re.search(rf"=\s*[^=]*\s{kind}(?:-start)?\(", ln):
                    out_b = _shape_bytes(ln.split("=", 1)[1].split(kind)[0])
                    g = _group_size(ln, total_devices)
                    wire = _wire_bytes(kind, out_b, out_b, g)
                    for _ in range(m):
                        ops.append(CollectiveOp(kind, wire, out_b, g, cname))
                    break
    return ops, mult


def collective_bytes_per_device(hlo: str, total_devices: int) -> dict:
    """Aggregate wire bytes per device by collective kind (+ 'total')."""
    ops, _ = parse_hlo_collectives(hlo, total_devices)
    out = {}
    for op in ops:
        out[op.kind] = out.get(op.kind, 0) + op.bytes_wire
    out["total"] = sum(out.values())
    out["count"] = len(ops)
    return out


# --------------------------------------------------------------------------
# CPU-backend bf16-emulation artifact detection (EXPERIMENTS §Method Trap 3)
# --------------------------------------------------------------------------
_TUPLE_ITEM = re.compile(r"(\w+)\[([\d,]+)\]")


def cpu_bf16_carry_artifact_bytes(hlo: str) -> int:
    """The CPU backend emulates bf16 dots in f32; for decode steps XLA then
    carries an f32 COPY of the bf16 KV cache through the layer-scan while
    loop (verified by inspecting the while tuple). On TPU the MXU consumes
    bf16 natively and the copy does not exist. This detects f32 while-carry
    entries that shadow an identically-shaped bf16 entry in the same tuple
    and returns their total bytes — subtract from the temp size to get the
    TPU-faithful peak ('peak_adjusted' in the dry-run records)."""
    total = 0
    for line in hlo.splitlines():
        if "= (" not in line or " while(" not in line:
            continue
        sig = line.split("= (", 1)[1].split(") while(", 1)[0]
        items = _TUPLE_ITEM.findall(sig)
        bf16_shapes = {dims for dt, dims in items if dt == "bf16"}
        for dt, dims in items:
            if dt == "f32" and dims in bf16_shapes and dims:
                n = 1
                for d in dims.split(","):
                    n *= int(d)
                if n * 4 > 1e8:          # only cache-scale duplicates
                    total += n * 4
    return total
