"""Crash-safe append-only journal: the storage half of serve recovery.

The ``CheckpointManager`` next door snapshots whole pytrees atomically —
right for model state, wrong for a serving loop where the unit of
progress is one tile's worth of exceedance counters. This journal is
the complementary primitive: an append-only record log where each line
is one self-verifying JSON record,

    ``<crc32 of the json, 8 hex chars> <compact json>\\n``

``append`` writes and flushes (optionally fsyncs — durability vs
throughput is the caller's call); ``replay`` re-reads records in order
and STOPS at the first line that fails its checksum or doesn't parse.
Because the file is append-only, a torn write can only ever be the
final line — a process killed mid-``append`` loses at most the record
being written, never the prefix. No rewrite-in-place, no compaction:
recovery semantics stay trivially auditable, and an *append-only
counter* journaled this way (the serve plane's per-request exceedance
counts and draws-done cursors) makes recovery bitwise-neutral — the
replayed prefix is exactly the state the crashed process had durably
reached.
"""

from __future__ import annotations

import json
import os
import zlib
from typing import Iterator, List, Optional


def _encode(record: dict) -> str:
    data = json.dumps(record, separators=(",", ":"), sort_keys=True)
    return f"{zlib.crc32(data.encode()):08x} {data}\n"


def _decode(line: str) -> Optional[dict]:
    """The record, or None when the line is torn/corrupt."""
    if len(line) < 10 or line[8] != " ":
        return None
    crc_hex, data = line[:8], line[9:].rstrip("\n")
    try:
        if int(crc_hex, 16) != zlib.crc32(data.encode()):
            return None
        return json.loads(data)
    except (ValueError, json.JSONDecodeError):
        return None


class Journal:
    """One append-only record log (see module docstring).

    Opening an existing path continues appending after its valid
    prefix — records live forever (the log is the history); readers
    use :func:`replay` / :meth:`records`.
    """

    def __init__(self, path: str, fsync: bool = False):
        self.path = path
        self.fsync = fsync
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._f = open(path, "a", encoding="utf-8")
        self.appended = 0

    def append(self, record: dict) -> None:
        """Durably append one record (flush always, fsync opt-in)."""
        self._f.write(_encode(record))
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        self.appended += 1

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "Journal":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def records(self) -> List[dict]:
        """This journal's valid prefix, re-read from disk."""
        self._f.flush()
        return list(replay(self.path))


def replay(path: str) -> Iterator[dict]:
    """Yield the journal's records in append order, stopping at the
    first checksum/parse failure (the torn tail of a crashed writer).
    A missing file replays empty — recovery from nothing is a no-op."""
    if not os.path.exists(path):
        return
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            rec = _decode(line)
            if rec is None:
                return
            yield rec
