from repro.checkpoint.journal import Journal, replay
from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager", "Journal", "replay"]
