"""Fault-tolerant checkpointing: atomic, elastic, async.

* **Atomic** — a checkpoint is written to ``step_N.tmp/`` and renamed to
  ``step_N/`` only after every leaf and the manifest are on disk; restore
  considers only renamed directories, so a host killed mid-save can never
  corrupt the restore point.
* **Elastic** — leaves are stored in *unsharded logical layout* (one .npy
  per pytree leaf, path-encoded). Restore takes a target mesh + spec tree
  and ``device_put``s each leaf with its new NamedSharding: resuming on a
  different pod count / mesh shape is transparent re-sharding
  (tests/test_distributed.py exercises 8→4→8 device resumes).
* **Async** — ``save(..., blocking=False)`` snapshots to host memory
  (device_get) and writes on a background thread, overlapping I/O with
  the next training steps; ``wait()`` joins before the next save.
* **Self-pruning** — keeps the newest ``keep`` checkpoints.

At true 1000-node scale each host would write only its address-space
slice (ocp-style); the single-process layout here keeps the same
interface and atomicity protocol. (Noted in DESIGN §5.)
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_LEAF_DIR = "leaves"
_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


def _path_key(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(f"i{e.idx}")
        else:
            parts.append(_SAFE.sub("_", str(e)))
    return "__".join(parts) or "root"


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- discovery ---------------------------------------------------------
    def all_steps(self):
        steps = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.isfile(os.path.join(self.directory, name,
                                                 "manifest.json")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, metadata: Optional[dict] = None,
             blocking: bool = True):
        """Snapshot ``tree`` (device_get now), write (possibly async)."""
        self.wait()
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        host = []
        dtypes = {}
        for p, x in flat:
            k = _path_key(p)
            arr = np.asarray(jax.device_get(x))
            dtypes[k] = str(arr.dtype)
            if arr.dtype.kind not in "biufc":   # ml_dtypes (bf16 etc.):
                arr = arr.view(np.uint16 if arr.itemsize == 2 else np.uint8)
            host.append((k, arr))
        meta = dict(metadata or {})
        meta["step"] = step
        meta["leaves"] = [k for k, _ in host]
        meta["dtypes"] = dtypes

        def _write():
            final = os.path.join(self.directory, f"step_{step}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(os.path.join(tmp, _LEAF_DIR))
            for k, arr in host:
                np.save(os.path.join(tmp, _LEAF_DIR, k + ".npy"), arr)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)          # atomic publish
            self._prune()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _prune(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # -- restore -------------------------------------------------------------
    def restore(self, template: Any, step: Optional[int] = None,
                mesh=None, specs: Any = None):
        """Restore into the structure of ``template`` (values ignored).

        With (mesh, specs): every leaf is device_put with its
        NamedSharding — elastic re-shard onto any mesh. Returns
        (tree, metadata).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        base = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(base, "manifest.json")) as f:
            meta = json.load(f)

        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        spec_leaves = (treedef.flatten_up_to(specs) if specs is not None
                       else [None] * len(flat))
        dtypes = meta.get("dtypes", {})
        out = []
        for (path, tmpl), spec in zip(flat, spec_leaves):
            k = _path_key(path)
            arr = np.load(os.path.join(base, _LEAF_DIR, k + ".npy"))
            true_dt = dtypes.get(k)
            if true_dt and str(arr.dtype) != true_dt:
                import ml_dtypes
                arr = arr.view(np.dtype(true_dt))
            arr = arr.astype(tmpl.dtype) if hasattr(tmpl, "dtype") else arr
            if mesh is not None and spec is not None:
                from jax.sharding import NamedSharding
                out.append(jax.device_put(arr, NamedSharding(mesh, spec)))
            else:
                out.append(jax.numpy.asarray(arr))
        return treedef.unflatten(out), meta
