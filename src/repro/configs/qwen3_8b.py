"""qwen3-8b — dense, qk_norm + GQA.

[hf:Qwen/Qwen3-8B; hf] 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936, per-head RMS qk-norm, rope_theta=1e6.
Quadratic ⇒ skips ``long_500k``.
"""

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12_288,
    vocab=151_936,
    pattern=("attn",),
    qk_norm=True,
    rope_theta=1_000_000.0,
    mlp_act="silu_glu",
    tie_embeddings=False,
    subquadratic=False,
    microbatches=4,
)

SMOKE = ModelConfig(
    name="qwen3-8b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    pattern=("attn",),
    qk_norm=True,
    mlp_act="silu_glu",
    tie_embeddings=False,
    subquadratic=False,
    param_dtype="float32",
    compute_dtype="float32",
)

register(CONFIG, SMOKE)
