"""Config system: ModelConfig (one per assigned architecture), input shapes,
and the arch registry.

Every field that differs across the 10 assigned architectures is explicit
here; per-arch files (``configs/<id>.py``) instantiate exact configs from
the public literature and a ``smoke()`` reduction of the same family.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


# --------------------------------------------------------------------------
# Input shapes (assigned set — same four for every LM arch)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


# --------------------------------------------------------------------------
# Model config
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128

    # attention variants
    qk_norm: bool = False          # qwen3
    qkv_bias: bool = False         # qwen1.5
    attn_softcap: float = 0.0      # grok-style tanh logit cap (0 = off)
    rope_theta: float = 10_000.0
    window: int = 0                # sliding-window size for "local" blocks

    # MLP variants
    mlp_act: str = "silu_glu"      # silu_glu | gelu_glu | sq_relu

    # layer pattern: tiled to n_layers. Types:
    #   attn  — global attention + MLP
    #   local — sliding-window attention + MLP
    #   rec   — RG-LRU recurrent block + MLP (recurrentgemma)
    #   moe   — global attention + MoE FFN
    #   ssd   — Mamba-2 SSD mixer (no separate MLP)
    pattern: Tuple[str, ...] = ("attn",)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_chunk: int = 1_024         # sequence chunk for dispatch memory bound

    # SSM (Mamba-2 SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1
    ssm_chunk: int = 256
    conv_width: int = 4

    # recurrent (RG-LRU)
    lru_width: int = 0             # 0 → d_model

    # encoder-decoder (seamless)
    is_encdec: bool = False
    n_enc_layers: int = 0
    enc_len_ratio: int = 4         # enc_len = seq_len // ratio (audio frames)

    # modality frontend STUB: "none" | "vision" | "audio"
    frontend: str = "none"
    frontend_dim: int = 1_024      # precomputed patch/frame embedding width
    n_patches: int = 1_024         # vision: patches folded into the sequence

    # embeddings / head
    tie_embeddings: bool = True
    norm: str = "rmsnorm"          # rmsnorm | layernorm

    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    opt_dtype: str = "float32"     # Adam m/v (+bf16 for the ≥100B archs)

    # training-step shape knobs
    microbatches: int = 1          # grad-accumulation steps inside train_step
    remat: str = "full"            # full | dots | none
    attn_chunk: int = 1_024        # KV chunk for flash-style attention
    # int8 KV-cache quantization (serving): halves the decode memory
    # floor; per-(b, t, head) symmetric scales (§Perf Cell B)
    kv_quant: bool = False
    # sequence-parallel residual stream (Korthikanti et al.): the scan-saved
    # carry shards its seq axis over the TP axis (16× remat-stash cut);
    # GSPMD inserts the all-gather/reduce-scatter pair per layer.
    seq_shard_activations: bool = True

    # long_500k applicability: quadratic global attention ⇒ skip
    subquadratic: bool = False

    # ------------------------------------------------------------------
    @property
    def lru_width_actual(self) -> int:
        return self.lru_width or self.d_model

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def n_dec_layers(self) -> int:
        return self.n_layers

    def dtype(self, which: str = "param"):
        return jnp.dtype({"param": self.param_dtype,
                          "compute": self.compute_dtype,
                          "opt": self.opt_dtype}[which])

    def layer_types(self) -> Tuple[str, ...]:
        """The pattern tiled out to n_layers (decoder side for enc-dec)."""
        reps = -(-self.n_layers // len(self.pattern))
        return tuple((self.pattern * reps)[: self.n_layers])

    def supports_shape(self, shape: ShapeConfig) -> bool:
        if shape.name == "long_500k" and not self.subquadratic:
            return False
        return True

    def param_count(self) -> int:
        """Analytic parameter count (cross-checked by tests against init)."""
        d, hd = self.d_model, self.head_dim
        attn = (d * self.n_heads * hd) * 2 + (d * self.n_kv_heads * hd) * 2
        if self.qkv_bias:
            attn += (self.n_heads + 2 * self.n_kv_heads) * hd
        if self.qk_norm:
            attn += 2 * hd
        n_mats = 2 if self.mlp_act == "sq_relu" else 3
        mlp = n_mats * d * self.d_ff
        moe = self.n_experts * n_mats * d * self.d_ff + d * self.n_experts
        dr = self.lru_width_actual
        rec = 2 * d * dr + dr * d + 2 * dr * dr + self.conv_width * dr + 3 * dr
        di, g, st, nh = self.d_inner, self.ssm_ngroups, self.ssm_state, self.ssm_nheads
        ssd = (2 * d * di + 2 * d * g * st + d * nh + di * d
               + self.conv_width * (di + 2 * g * st) + 3 * nh + di)
        np_ = 2 * d if self.norm == "layernorm" else d  # params per norm
        per_type = {"attn": attn + mlp + 2 * np_, "local": attn + mlp + 2 * np_,
                    "moe": attn + moe + 2 * np_, "rec": rec + mlp + 2 * np_,
                    "ssd": ssd + np_}
        total = sum(per_type[t] for t in self.layer_types())
        if self.is_encdec:
            enc_layer = attn + mlp + 2 * np_
            dec_layer = 2 * attn + mlp + 3 * np_  # self + cross attention
            total = (self.n_enc_layers * enc_layer
                     + self.n_layers * dec_layer + np_)  # + encoder final norm
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        total += np_  # final norm
        if self.frontend != "none":
            total += self.frontend_dim * d  # projection of stub embeddings
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        n_mats = 2 if self.mlp_act == "sq_relu" else 3
        inactive = ((self.n_experts - self.top_k) * n_mats * self.d_model
                    * self.d_ff)
        n_moe_layers = sum(1 for t in self.layer_types() if t == "moe")
        return self.param_count() - n_moe_layers * inactive


# --------------------------------------------------------------------------
# Registry (populated by configs/__init__.py importing the per-arch files)
# --------------------------------------------------------------------------
ARCHS: dict = {}
SMOKES: dict = {}


def register(cfg: ModelConfig, smoke: ModelConfig):
    ARCHS[cfg.name] = cfg
    SMOKES[cfg.name] = smoke
    return cfg
