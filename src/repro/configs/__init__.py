"""Arch registry: importing this package registers all 10 assigned
architectures (and their smoke reductions) into ``ARCHS`` / ``SMOKES``.

``--arch <id>`` ids use the assignment's spelling (dots/dashes); module
names use underscores.
"""

from repro.configs.base import ARCHS, SMOKES, SHAPES, ModelConfig, ShapeConfig

# importing registers
from repro.configs import recurrentgemma_9b      # noqa: F401
from repro.configs import phi_3_vision_4_2b      # noqa: F401
from repro.configs import grok_1_314b            # noqa: F401
from repro.configs import granite_moe_1b_a400m   # noqa: F401
from repro.configs import qwen3_8b               # noqa: F401
from repro.configs import nemotron_4_340b        # noqa: F401
from repro.configs import llama3_2_3b            # noqa: F401
from repro.configs import qwen1_5_4b             # noqa: F401
from repro.configs import mamba2_1_3b            # noqa: F401
from repro.configs import seamless_m4t_medium    # noqa: F401


def get_arch(name: str, smoke: bool = False) -> ModelConfig:
    table = SMOKES if smoke else ARCHS
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(table)}")
    return table[name]


__all__ = ["ARCHS", "SMOKES", "SHAPES", "ModelConfig", "ShapeConfig",
           "get_arch"]
