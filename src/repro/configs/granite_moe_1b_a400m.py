"""granite-moe-1b-a400m — MoE, 32 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf] 24L d_model=1024 16H
(GQA kv=8) d_ff=512 (per expert) vocab=49155, MoE 32e top-8.
Quadratic ⇒ skips ``long_500k``. 32 experts divide the 16-way model axis
⇒ true expert parallelism (2 experts/device).
"""

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    # true vocab 49155, padded to a multiple of the 16-way TP axis
    vocab=49_168,
    pattern=("moe",),
    n_experts=32,
    top_k=8,
    mlp_act="silu_glu",
    tie_embeddings=True,
    subquadratic=False,
    moe_chunk=512,
    microbatches=2,
)

SMOKE = ModelConfig(
    name="granite-moe-1b-a400m-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=32,
    vocab=256,
    pattern=("moe",),
    n_experts=8,
    top_k=4,
    mlp_act="silu_glu",
    tie_embeddings=True,
    subquadratic=False,
    moe_chunk=16,
    param_dtype="float32",
    compute_dtype="float32",
)

register(CONFIG, SMOKE)
