"""recurrentgemma-9b — hybrid RG-LRU + local attention, 1:2 pattern.

[arXiv:2402.19427 (Griffin); unverified] 38L d_model=4096 16H (GQA kv=1,
MQA) d_ff=12288 vocab=256000. Pattern: (rec, rec, local) tiled — two
RG-LRU recurrent blocks per local-attention block; window 2048.
Sub-quadratic (bounded attention window + O(1) recurrent state) ⇒ runs
``long_500k``.

Deviation noted in DESIGN §Arch-applicability: RG-LRU input/recurrence
gates use dense d_rnn×d_rnn weights here (upstream uses block-diagonal);
param count lands ~9.3B.
"""

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256_000,
    pattern=("rec", "rec", "local"),
    window=2048,
    mlp_act="gelu_glu",
    lru_width=4096,
    tie_embeddings=True,
    subquadratic=True,
    microbatches=4,
    attn_softcap=0.0,
)

SMOKE = ModelConfig(
    name="recurrentgemma-9b-smoke",
    family="hybrid",
    n_layers=4,                     # keeps one full (rec, rec, local) period + 1
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab=256,
    pattern=("rec", "rec", "local"),
    window=16,
    mlp_act="gelu_glu",
    lru_width=64,
    tie_embeddings=True,
    subquadratic=True,
    param_dtype="float32",
    compute_dtype="float32",
)

register(CONFIG, SMOKE)
