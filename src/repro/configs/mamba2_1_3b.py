"""mamba2-1.3b — attention-free SSM (SSD, state-space duality).

[arXiv:2405.21060; unverified] 48L d_model=2048 vocab=50280,
ssm_state=128, headdim=64 ⇒ 64 SSD heads, expand=2 (d_inner=4096),
ngroups=1, conv width 4. Attention-free, O(1) decode state ⇒ runs
``long_500k``.

DESIGN §Arch-applicability: SSD's chunked formulation IS the paper's
memory-locality insight applied to sequence mixing — intra-chunk blocked
matmuls + O(chunks) inter-chunk recurrence instead of a length-N scan.
"""

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    # true vocab 50280, padded to a multiple of the 16-way TP axis
    vocab=50_288,
    pattern=("ssd",),
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_ngroups=1,
    ssm_chunk=256,
    conv_width=4,
    tie_embeddings=True,
    subquadratic=True,
    microbatches=4,
)

SMOKE = ModelConfig(
    name="mamba2-1.3b-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab=256,
    pattern=("ssd",),
    ssm_state=16,
    ssm_headdim=16,
    ssm_expand=2,
    ssm_ngroups=1,
    ssm_chunk=8,
    conv_width=4,
    tie_embeddings=True,
    subquadratic=True,
    param_dtype="float32",
    compute_dtype="float32",
)

register(CONFIG, SMOKE)
