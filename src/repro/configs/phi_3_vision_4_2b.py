"""phi-3-vision-4.2b — phi3-mini backbone + CLIP vision frontend (STUB).

[hf:microsoft/Phi-3-vision-128k-instruct; hf] 32L d_model=3072 32H
(GQA kv=32, i.e. MHA) d_ff=8192 vocab=32064.

Per the harness shape rules, the modality frontend is a STUB:
``input_specs()`` supplies precomputed CLIP patch embeddings
(B, n_patches, 1024) which a learned projection folds into the token
sequence (first n_patches positions). Quadratic attention ⇒ skips
``long_500k``.
"""

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab=32_064,
    pattern=("attn",),
    mlp_act="silu_glu",
    frontend="vision",
    frontend_dim=1024,
    n_patches=1024,
    tie_embeddings=False,
    subquadratic=False,
    microbatches=4,
)

SMOKE = ModelConfig(
    name="phi-3-vision-4.2b-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=256,
    pattern=("attn",),
    mlp_act="silu_glu",
    frontend="vision",
    frontend_dim=32,
    n_patches=8,
    tie_embeddings=False,
    subquadratic=False,
    param_dtype="float32",
    compute_dtype="float32",
)

register(CONFIG, SMOKE)
