"""qwen1.5-4b — dense with QKV bias, MHA (kv == heads).

[hf:Qwen/Qwen1.5-0.5B (family); hf] 40L d_model=2560 20H (GQA kv=20)
d_ff=6912 vocab=151936, QKV bias. Quadratic ⇒ skips ``long_500k``.
20 heads do not divide the 16-way model axis — padded head sharding.
"""

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab=151_936,
    pattern=("attn",),
    qkv_bias=True,
    mlp_act="silu_glu",
    tie_embeddings=False,
    subquadratic=False,
    microbatches=4,
    # 20 heads don't shard over the 16-way TP axis (see llama3.2-3b)
    attn_chunk=512,
)

SMOKE = ModelConfig(
    name="qwen1.5-4b-smoke",
    family="dense",
    n_layers=2,
    d_model=40,
    n_heads=5,
    n_kv_heads=5,
    head_dim=8,
    d_ff=96,
    vocab=256,
    pattern=("attn",),
    qkv_bias=True,
    mlp_act="silu_glu",
    tie_embeddings=False,
    subquadratic=False,
    param_dtype="float32",
    compute_dtype="float32",
)

register(CONFIG, SMOKE)
