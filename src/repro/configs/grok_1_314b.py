"""grok-1-314b — MoE, 8 experts top-2.

[hf:xai-org/grok-1; unverified] 64L d_model=6144 48H (GQA kv=8)
d_ff=32768 (per expert) vocab=131072, MoE 8e top-2, attention logit
softcap 30 (grok-style tanh cap). Quadratic ⇒ skips ``long_500k``.

Experts (8) do not divide the 16-way model axis, so the sharding rules
TP-shard the expert FFN hidden dim instead (DESIGN §5); m/v in bf16 for
the ≥100B memory budget.
"""

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32_768,
    vocab=131_072,
    pattern=("moe",),
    n_experts=8,
    top_k=2,
    attn_softcap=30.0,
    mlp_act="gelu_glu",
    tie_embeddings=True,
    subquadratic=False,
    opt_dtype="bfloat16",
    microbatches=8,
    moe_chunk=512,
)

SMOKE = ModelConfig(
    name="grok-1-314b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab=256,
    pattern=("moe",),
    n_experts=8,
    top_k=2,
    attn_softcap=30.0,
    mlp_act="gelu_glu",
    tie_embeddings=True,
    subquadratic=False,
    moe_chunk=16,
    param_dtype="float32",
    compute_dtype="float32",
)

register(CONFIG, SMOKE)
