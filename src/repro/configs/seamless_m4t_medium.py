"""seamless-m4t-medium — encoder-decoder, audio frontend (STUB).

[arXiv:2308.11596; hf] 12L encoder + 12L decoder, d_model=1024 16H
(kv=16, MHA) d_ff=4096 vocab=256206. The w2v-BERT audio frontend is a
STUB per the harness rules: ``input_specs()`` supplies precomputed frame
embeddings (B, seq/4, 1024); the backbone encoder consumes them through a
learned projection. Decoder: causal self-attention + cross-attention.
Quadratic decoder ⇒ skips ``long_500k``; runs decode shapes (enc-dec has
a decode step).
"""

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,               # decoder layers
    n_enc_layers=12,
    is_encdec=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    # true vocab 256206, padded to a multiple of the 16-way TP axis
    # (standard TP practice; ids ≥ 256206 unused)
    vocab=256_208,
    pattern=("attn",),
    mlp_act="gelu_glu",
    frontend="audio",
    frontend_dim=1024,
    enc_len_ratio=4,
    norm="layernorm",
    tie_embeddings=True,
    subquadratic=False,
    microbatches=2,
)

SMOKE = ModelConfig(
    name="seamless-m4t-medium-smoke",
    family="audio",
    n_layers=2,
    n_enc_layers=2,
    is_encdec=True,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=256,
    pattern=("attn",),
    mlp_act="gelu_glu",
    frontend="audio",
    frontend_dim=32,
    enc_len_ratio=4,
    norm="layernorm",
    tie_embeddings=True,
    subquadratic=False,
    param_dtype="float32",
    compute_dtype="float32",
)

register(CONFIG, SMOKE)
