"""llama3.2-3b — small llama3 dense.

[hf:meta-llama/Llama-3.2-1B (family); unverified] 28L d_model=3072 24H
(GQA kv=8) d_ff=8192 vocab=128256, tied embeddings, rope_theta=500000.
Quadratic ⇒ skips ``long_500k``. 24 heads do not divide the 16-way model
axis — the sharding rules fall back to d_ff/d_model TP with padded head
sharding for attention (DESIGN §5).
"""

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=128_256,
    pattern=("attn",),
    rope_theta=500_000.0,
    mlp_act="silu_glu",
    tie_embeddings=True,
    subquadratic=False,
    microbatches=4,
    # 24 heads don't shard over the 16-way TP axis → prefill scores stay
    # head-replicated; smaller query chunks bound the (C, S) buffer
    attn_chunk=512,
)

SMOKE = ModelConfig(
    name="llama3.2-3b-smoke",
    family="dense",
    n_layers=2,
    d_model=48,
    n_heads=6,      # preserves the non-power-of-two head count family trait
    n_kv_heads=2,
    head_dim=8,
    d_ff=128,
    vocab=256,
    pattern=("attn",),
    mlp_act="silu_glu",
    tie_embeddings=True,
    subquadratic=False,
    param_dtype="float32",
    compute_dtype="float32",
)

register(CONFIG, SMOKE)
