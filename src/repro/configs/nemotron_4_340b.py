"""nemotron-4-340b — dense, GQA + squared-ReLU MLP.

[arXiv:2402.16819; unverified] 96L d_model=18432 96H (GQA kv=8)
d_ff=73728 vocab=256000, squared-ReLU (two-matrix) MLP, untied embeddings.
Quadratic ⇒ skips ``long_500k``.

Largest assigned arch (~341B params): m/v kept in bf16 and 16-way grad
accumulation so the 256-chip pod fits (DESIGN §5 memory budget:
341e9 × 8 B / 256 ≈ 10.7 GB/chip for param+grad+m+v).
"""

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18_432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73_728,
    vocab=256_000,
    pattern=("attn",),
    mlp_act="sq_relu",
    tie_embeddings=False,
    subquadratic=False,
    opt_dtype="bfloat16",
    microbatches=16,
)

SMOKE = ModelConfig(
    name="nemotron-4-340b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab=256,
    pattern=("attn",),
    mlp_act="sq_relu",
    tie_embeddings=False,
    subquadratic=False,
    param_dtype="float32",
    compute_dtype="float32",
)

register(CONFIG, SMOKE)
