"""ObsConfig: the observability switchboard carried by ``ExecConfig``.

A frozen, hashable dataclass — it rides inside ``api.ExecConfig`` (a
leaf-free pytree whose every field is static jit metadata), so it must
compare/hash by value and never hold mutable state. The mutable side of
observability (the span list, the ledger entries) lives in
``obs.report.ObsSession``, which a ``Workspace`` constructs FROM this
config; the config only says what to collect.

``enabled=False`` (the default) is the zero-overhead contract: a
Workspace built with it never constructs a session — every ``span()``
call resolves to the shared no-op singleton (``obs.trace.NULL_SPAN``)
and every ledger charge is a no-op method on ``obs.trace.NULL_OBS``.
The recompile sentinel (``obs.compile``) is the one always-on piece:
it only runs at jit-trace time, so it costs nothing per call.

This module deliberately imports nothing from ``repro`` (and nothing
heavier than ``dataclasses``) so ``api.config`` can import it without
cycles or import-time cost.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """What the observability layer collects for one session.

    Fields
    ------
    enabled:
        Master switch. ``False`` (default): no session is created, every
        span/charge resolves to the no-op fast path — measured session
        overhead is the cost of one attribute lookup per call site.
    spans:
        Collect the nested wall-time span tree (``obs.trace.Tracer``).
    ledger:
        Charge the analytic traffic ledger (``obs.ledger.Ledger``) at the
        instrumented call sites — hoist builds, permutation batches, the
        distance production sweep.
    annotate_xla:
        Bridge each span into ``jax.profiler.TraceAnnotation`` so spans
        line up inside XLA profiles (Perfetto / TensorBoard). Off by
        default: it adds a profiler call per span even when no profile
        is being taken.
    probe:
        Measure the session's jitted entry points at report time
        (``obs.probe``: AOT-compiled flop/byte/peak counts) and
        reconcile them against the analytic models (``obs.drift``) into
        the report's ``measured`` and ``drift`` sections. Probing is
        compile-time-only work at report() — nothing on the execution
        hot path — but it does cost a few ahead-of-time compiles per
        session geometry, so it follows the master switch.
    """

    enabled: bool = False
    spans: bool = True
    ledger: bool = True
    annotate_xla: bool = False
    probe: bool = True

    def __post_init__(self):
        for f in ("enabled", "spans", "ledger", "annotate_xla", "probe"):
            v = getattr(self, f)
            if not isinstance(v, bool):
                raise ValueError(f"ObsConfig.{f} must be a bool, "
                                 f"got {v!r}")
