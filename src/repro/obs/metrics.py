"""Allocation-light metric primitives: Counter / Gauge / Histogram.

``repro.serve`` needs latency *distributions* (p50/p95/p99 for queue
wait, tile execution, end-to-end requests), and ``runtime.monitor``
needs the same percentiles over step durations — but a serve loop that
appends every sample to an unbounded list is a slow leak with a
reporting API. These primitives are fixed-footprint by construction:

* ``Histogram`` — fixed log-spaced buckets allocated once at
  construction; ``record()`` is a bisect + three integer/float updates,
  no allocation on the hot path. Quantiles are interpolated within the
  landing bucket and clamped to the exact observed ``[min, max]``, so
  they are estimates with bounded error (one bucket width) at O(1)
  memory, whatever the sample count.
* ``Counter`` / ``Gauge`` — named scalars with the same ``to_dict`` /
  Prometheus surface, so breach counts and queue depths export beside
  the distributions.
* ``NULL_HISTOGRAM`` — the disabled fast path, mirroring
  ``obs.trace.NULL_SPAN``: a shared singleton whose ``record()`` is a
  no-op method call, allocation-free, so call sites never branch.

Export: ``to_dict()`` everywhere (JSON, rides ``serve_report()``), and
``prometheus_text()`` renders any mix of the three as Prometheus
text-exposition format (cumulative ``_bucket{le=...}`` lines, ``_sum``,
``_count``) for scraping without adding a client library dependency.

This module deliberately imports nothing from ``repro`` (and nothing
heavier than ``bisect``), like ``obs.config`` — any layer may use it.
"""

from __future__ import annotations

from bisect import bisect_left
from typing import Iterable, Optional, Sequence

__all__ = ["Counter", "Gauge", "Histogram", "NULL_HISTOGRAM",
           "DEFAULT_LATENCY_BUCKETS", "prometheus_text"]

#: half-decade log-spaced seconds, 10µs .. 100s — wide enough for a
#: sub-ms tile and a multi-minute drain with one shared shape
DEFAULT_LATENCY_BUCKETS = tuple(10.0 ** (k / 2.0) for k in range(-10, 5))


class Counter:
    """A named monotone count (rejections, SLO breaches, tiles)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str, value: float = 0):
        self.name = name
        self.value = value

    def inc(self, amount: float = 1) -> None:
        self.value += amount

    def to_dict(self) -> dict:
        return {"name": self.name, "type": "counter", "value": self.value}


class Gauge:
    """A named last-written value (queue depth, resident bytes)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str, value: Optional[float] = None):
        self.name = name
        self.value = value

    def set(self, value: float) -> None:
        self.value = value

    def to_dict(self) -> dict:
        return {"name": self.name, "type": "gauge", "value": self.value}


class Histogram:
    """Fixed-bucket distribution (see module docstring).

    ``buckets`` are ascending upper edges; one overflow bucket catches
    everything past the last edge. ``record()`` is the hot path:
    bisect into the pre-allocated count list, update count/sum/min/max.
    """

    __slots__ = ("name", "buckets", "counts", "count", "sum", "min", "max")

    enabled = True

    def __init__(self, name: str,
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS):
        b = tuple(float(x) for x in buckets)
        if not b or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(f"buckets must be ascending, got {buckets!r}")
        self.name = name
        self.buckets = b
        self.counts = [0] * (len(b) + 1)       # + overflow
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    # -- hot path ----------------------------------------------------------
    def record(self, value: float) -> None:
        self.counts[bisect_left(self.buckets, value)] += 1
        self.count += 1
        self.sum += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value

    # -- queries -----------------------------------------------------------
    def quantile(self, q: float) -> Optional[float]:
        """Interpolated q-quantile (0 < q <= 1), clamped to the observed
        [min, max]; ``None`` while empty."""
        if self.count == 0:
            return None
        rank = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= rank:
                lo = self.buckets[i - 1] if i > 0 else 0.0
                hi = (self.buckets[i] if i < len(self.buckets)
                      else self.max)
                frac = (rank - cum) / c
                v = lo + frac * (hi - lo)
                return min(max(v, self.min), self.max)
            cum += c
        return self.max

    def percentiles(self) -> dict:
        return {"count": self.count,
                "p50": self.quantile(0.50),
                "p95": self.quantile(0.95),
                "p99": self.quantile(0.99),
                "mean": (self.sum / self.count) if self.count else None,
                "max": (self.max if self.count else None)}

    def to_dict(self) -> dict:
        return {"name": self.name, "type": "histogram",
                "buckets": list(self.buckets), "counts": list(self.counts),
                **self.percentiles()}


class _NullHistogram:
    """The disabled fast path — record() is a no-op, allocation-free.
    A shared singleton (``NULL_HISTOGRAM``), like ``NULL_SPAN``."""

    __slots__ = ()

    enabled = False
    count = 0
    sum = 0.0

    def record(self, value: float) -> None:
        pass

    def quantile(self, q: float) -> None:
        return None

    def percentiles(self) -> dict:
        return {}

    def to_dict(self) -> dict:
        return {}


NULL_HISTOGRAM = _NullHistogram()


# --------------------------------------------------------------------------
# Prometheus text exposition (no client-library dependency)
# --------------------------------------------------------------------------
def _fmt(v: float) -> str:
    return repr(float(v)) if v == v else "NaN"


def _sanitize(name: str) -> str:
    return "".join(c if (c.isalnum() or c == "_") else "_" for c in name)


def prometheus_text(metrics: Iterable) -> str:
    """Render Counters/Gauges/Histograms as Prometheus text format:
    ``# TYPE`` headers, cumulative ``_bucket{le="..."}`` series with the
    ``+Inf`` bucket, ``_sum`` and ``_count`` — scrapeable as-is."""
    lines = []
    for m in metrics:
        name = _sanitize(m.name)
        if isinstance(m, Histogram):
            lines.append(f"# TYPE {name} histogram")
            cum = 0
            for edge, c in zip(m.buckets, m.counts):
                cum += c
                lines.append(f'{name}_bucket{{le="{_fmt(edge)}"}} {cum}')
            lines.append(f'{name}_bucket{{le="+Inf"}} {m.count}')
            lines.append(f"{name}_sum {_fmt(m.sum)}")
            lines.append(f"{name}_count {m.count}")
        elif isinstance(m, Counter):
            lines.append(f"# TYPE {name} counter")
            lines.append(f"{name} {_fmt(m.value)}")
        elif isinstance(m, Gauge):
            lines.append(f"# TYPE {name} gauge")
            v = m.value if m.value is not None else float("nan")
            lines.append(f"{name} {_fmt(v)}")
    return "\n".join(lines) + ("\n" if lines else "")
