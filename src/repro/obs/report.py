"""ObsSession + RunReport: one object per run, one JSON per run.

``ObsSession`` is the mutable counterpart of ``ObsConfig``: a tracer, a
ledger, and a baseline snapshot of the process-global recompile
sentinel, owned by a ``Workspace`` (or any driver) for one run. Its
``span()`` pushes the session onto the ambient stack
(``obs.trace.current_obs``), which is how the free functions deeper in
the call chain — ``stats.engine``, ``core.pcoa``, ``dist.driver`` —
attach their spans and ledger charges to the session that invoked them
without threading an argument through every signature.

``RunReport`` is the assembled artifact: span tree, ledger totals,
HoistCache hit/miss snapshot, and sentinel deltas, as one JSON document.
``benchmarks/run.py --smoke`` writes one per CI run (uploaded as a
workflow artifact) and gates on its ``compile`` section; the README's
Observability section shows a worked example.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional

from repro.obs.compile import sentinel
from repro.obs.config import ObsConfig
from repro.obs.ledger import Ledger
from repro.obs.trace import NULL_SPAN, Tracer


class ObsSession:
    """One run's live observability state (see module docstring)."""

    enabled = True

    def __init__(self, config: Optional[ObsConfig] = None):
        self.config = config if config is not None else ObsConfig(
            enabled=True)
        self.tracer = Tracer(annotate_xla=self.config.annotate_xla)
        self.ledger = Ledger()
        self.sentinel = sentinel
        self.sentinel_base = sentinel.snapshot()

    # -- spans -------------------------------------------------------------
    def span(self, name: str, phase: Optional[str] = None, **attrs):
        """A session span: entering it also makes this session ambient
        (``current_obs()``) for the enclosed call chain."""
        if not self.config.spans:
            return NULL_SPAN
        return self.tracer.span(name, phase, session=self, **attrs)

    # -- ledger charges (gated on config.ledger) ---------------------------
    def charge(self, op, floats, **params):
        if self.config.ledger:
            return self.ledger.charge(op, floats, **params)

    def charge_hoist(self, artifact, n, table=None):
        if self.config.ledger:
            return self.ledger.charge_hoist(artifact, n, table=table)

    def charge_perm_batch(self, op, n, permutations, batch, **params):
        if self.config.ledger:
            return self.ledger.charge_perm_batch(op, n, permutations,
                                                 batch, **params)

    def charge_production(self, n, d, block, **params):
        if self.config.ledger:
            return self.ledger.charge_production(n, d, block, **params)

    # -- sentinel ----------------------------------------------------------
    def compile_delta(self) -> dict:
        """Traces/programs noted since this session began."""
        return self.sentinel.since(self.sentinel_base)


@dataclasses.dataclass
class RunReport:
    """One run, one document: spans + ledger + cache + compile counts.

    ``meta`` carries provenance (jax version, backend, session shape);
    ``spans`` is the tracer's nested dict tree; ``ledger`` the totals
    plus every entry; ``cache`` the HoistCache hit/miss counters and
    generation; ``compile`` the sentinel's per-entry-point trace and
    program counts for the run's window.
    """

    meta: dict
    spans: list
    ledger: dict
    cache: dict
    compile: dict
    measured: dict = dataclasses.field(default_factory=dict)
    drift: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"meta": self.meta, "spans": self.spans,
                "ledger": self.ledger, "cache": self.cache,
                "compile": self.compile, "measured": self.measured,
                "drift": self.drift}

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, default=str)

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path

    # convenience accessors for the gated quantities
    @property
    def hoist_passes(self) -> float:
        return self.ledger.get("hoist_passes", 0.0)

    @property
    def total_bytes(self) -> float:
        return self.ledger.get("total_bytes", 0.0)

    def programs(self, name: str) -> int:
        return self.compile.get(name, {}).get("programs", 0)

    @property
    def drift_ok(self) -> bool:
        """True when the drift section is absent OR every reconciled
        verdict landed inside its tolerance band."""
        return bool(self.drift.get("within_tolerance", True))


def _cache_section(cache) -> dict:
    """A HoistCache, stringified for JSON (tuple keys become strings)."""
    if cache is None:
        return {}
    return {
        "hits": {str(k): v for k, v in cache.hits.items()},
        "misses": {str(k): v for k, v in cache.misses.items()},
        "keys": sorted(str(k) for k in cache.keys()),
    }


def build_report(session: Optional[ObsSession] = None, cache=None,
                 meta: Optional[dict] = None,
                 measured: Optional[dict] = None,
                 drift: Optional[dict] = None) -> RunReport:
    """Assemble a ``RunReport`` from a session (tracer + ledger +
    sentinel window) and an optional HoistCache. With ``session=None``
    (observability disabled) the report still carries the cache
    counters and the sentinel's full process snapshot — the always-on
    telemetry — with empty spans and ledger.

    ``measured`` is a ``{name: ProbeRecord}`` mapping from
    ``obs.probe.probe_session`` (serialized here); ``drift`` the
    already-built ``DriftSentinel.reconcile`` section."""
    import jax

    base_meta = {"jax": jax.__version__, "backend": jax.default_backend()}
    if meta:
        base_meta.update(meta)
    measured_section = {name: (rec.to_dict() if hasattr(rec, "to_dict")
                               else dict(rec))
                        for name, rec in (measured or {}).items()}
    if session is not None:
        return RunReport(meta=base_meta,
                         spans=session.tracer.to_dicts(),
                         ledger=session.ledger.to_dict(),
                         cache=_cache_section(cache),
                         compile=session.compile_delta(),
                         measured=measured_section,
                         drift=dict(drift or {}))
    return RunReport(meta=base_meta, spans=[], ledger={},
                     cache=_cache_section(cache),
                     compile=sentinel.snapshot(),
                     measured=measured_section,
                     drift=dict(drift or {}))
