"""Compile-time measurement of the jitted entry points (the MEASURED half).

Everything else in ``repro.obs`` is analytic: the ledger prices pass
tables, ``tune.model`` prices tiles, and the BENCH gates compare closed
forms against closed forms. This module asks the compiler what a program
*actually* does: ``jit(...).lower(avals).compile()`` and read back

* ``cost_analysis()``  — flops and HLO-level bytes accessed,
* ``memory_analysis()`` — argument/output/temp/alias sizes (peak
  allocation = arg + out + temp − alias),
* ``as_text()``        — the optimized HLO, for the scan correction.

Probes are **ahead-of-time**: operands are ``jax.ShapeDtypeStruct``
avals, so nothing executes and no n²-or-worse buffer is materialized to
measure a program. Records are keyed by the same entry-point names the
``CompileSentinel`` uses (``kernels.permute_reduce``,
``dist.panel_stats``, ``stats.engine.tile``, ``pcoa.fsvd_matfree``, …),
so a ``RunReport``'s ``measured`` section lines up with its ``compile``
section. Probing necessarily traces, so each probe also counts as one
sentinel trace of its entry point — at the session's own geometry the
signature already exists and the program count does not grow.

The scan-body undercount correction (inherited from the retired
``repro.roofline`` module, which established it for collectives):
XLA's ``cost_analysis()`` counts a while-loop body ONCE, but our hot
loops are ``lax.scan``s — ``kernels.permute_reduce`` streams m/chunk
condensed chunks, the ``dist`` production fallback ``lax.map``s row
sub-panels — so the raw figure undercounts the dominant traffic by the
trip count. ``scan_corrected_bytes`` re-adds ``(trips − 1) ×
body_bytes`` per while body, with trip counts taken from XLA's own
``known_trip_count`` backend-config when present (else parsed from the
loop-condition comparison constant) and body bytes summed per top-level
HLO instruction (operands + output as printed; gathers and dynamic
slices count their slice, not their source operand — the same
convention ``HloCostAnalysis`` uses).

These byte counts are HLO-level: every materialized intermediate (index
tensors, gather results) counts, whether or not it stays cache-resident
— so measured bytes sit a documented implementation factor ABOVE the
ledger's streamed-floats floor. ``obs.drift`` owns those factors and the
tolerance bands; this module only measures.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = [
    "ProbeRecord", "probe_lowered", "scan_corrected_bytes",
    "computation_multipliers",
    "probe_permute_reduce", "probe_panel_stats", "probe_center_matvec",
    "probe_pcoa_matfree", "probe_statistic", "probe_stream_pass",
    "probe_session", "probe_table", "clear_probe_cache",
]

# --------------------------------------------------------------------------
# HLO text parsing (absorbed from the retired repro.roofline.hlo)
# --------------------------------------------------------------------------
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum byte sizes of every array shape in a (possibly tuple) type."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """{computation name: [instruction lines]} from HLO text."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        # computation header lines look like: "%name (args) -> type {"
        if stripped.endswith("{") and ("->" in stripped or
                                       stripped.startswith("ENTRY")):
            m = re.search(r"%?([\w\.\-]+)\s*\(", stripped)
            cur = m.group(1) if m else f"anon{len(comps)}"
            comps[cur] = []
        elif stripped.startswith("}"):
            cur = None
        elif cur is not None:
            comps[cur].append(stripped)
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    """While trip count from its condition computation: jax emits
    ``compare(iter, constant(N)), direction=LT``. Max constant wins
    (there may be several; the bound dominates). Fallback 1."""
    consts = []
    for ln in cond_lines:
        if "constant(" in ln and ("s32" in ln or "s64" in ln or
                                  "u32" in ln):
            for m in re.finditer(r"constant\((\d+)\)", ln):
                consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def computation_multipliers(hlo: str) -> Tuple[Dict[str, int], set]:
    """Call-graph execution multipliers per computation.

    Walks while/call/conditional edges from the root computations,
    multiplying into each while body by its trip count — XLA's
    ``"known_trip_count":{"n":...}`` backend-config when annotated, else
    the condition's comparison constant. Returns ``(multipliers,
    while_bodies)`` where ``while_bodies`` is the set of computations
    entered through a while edge (the ones ``cost_analysis()`` counted
    once but the hardware runs ``multiplier`` times).
    """
    comps = _split_computations(hlo)
    calls: Dict[str, List[Tuple[str, str]]] = {c: [] for c in comps}
    whiles: Dict[str, Tuple[str, str]] = {}
    trip_hints: Dict[str, int] = {}
    for cname, lines in comps.items():
        for ln in lines:
            wm = re.search(r"\bwhile\(.*?condition=%?([\w\.\-]+),\s*"
                           r"body=%?([\w\.\-]+)", ln)
            if wm:
                body = wm.group(2)
                calls[cname].append(("while", body))
                whiles[body] = (cname, wm.group(1))
                tm = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ln)
                if tm:
                    trip_hints[body] = int(tm.group(1))
                continue
            for cm in re.finditer(r"(?:calls|to_apply|body|"
                                  r"branch_computations)"
                                  r"=%?\{?([\w\.\-,\s%]+)\}?", ln):
                for callee in re.split(r"[,\s]+", cm.group(1)):
                    callee = callee.strip().lstrip("%")
                    if callee in comps and callee != cname:
                        calls[cname].append(("call", callee))

    called = {c for lst in calls.values() for _, c in lst}
    roots = [c for c in comps if c not in called]
    mult: Dict[str, int] = {c: 0 for c in comps}
    bodies: set = set()

    def visit(c: str, m: int):
        if m <= 0 or c not in comps:
            return
        mult[c] = mult.get(c, 0) + m
        for kind, callee in calls.get(c, []):
            if kind == "while":
                body = callee
                cond = whiles.get(body, (None, None))[1]
                tc = trip_hints.get(body) or (
                    _trip_count(comps.get(cond, [])) if cond else 1)
                bodies.add(body)
                visit(body, m * tc)
                if cond:
                    visit(cond, m)
            else:
                visit(callee, m)

    for r in roots:
        visit(r, 1)
    return mult, bodies


#: instruction kinds that move no data of their own (aliasing, shape
#: bookkeeping, literals) — excluded from body byte counting
_FREE_OPS = re.compile(
    r"\b(?:parameter|get-tuple-element|tuple|constant|iota|after-all|"
    r"bitcast|copy-start|copy-done|while|conditional|partition-id|"
    r"replica-id)\(")

#: ops whose bytes are the SLICE they touch, not their largest operand —
#: HloCostAnalysis convention (a gather reads output-many elements of
#: its source, not the whole source)
_SLICE_OPS = ("dynamic-update-slice", "dynamic-slice", "gather", "scatter")

#: single-operand aliasing ops an operand may be threaded through before
#: reaching a gather/slice inside a fusion
_ALIAS_OPS = ("bitcast", "copy", "reshape", "transpose")


def _op_output_bytes(seg: str, op: str) -> int:
    """Bytes of the result type printed between '=' and the op token."""
    return _shape_bytes(seg[:seg.find(f" {op}(")])


def _moved_slice_bytes(seg: str, op: str) -> int:
    if op in ("dynamic-update-slice", "scatter"):
        # the moved slice is the smallest array operand printed
        sizes = [s for s in (_shape_bytes(f"{dt}[{dims}]")
                             for dt, dims in _SHAPE_RE.findall(seg))
                 if s > 0]
        return min(sizes) if sizes else 0
    return _op_output_bytes(seg, op)


def _fusion_bytes(line: str, comps: Dict[str, List[str]]) -> int:
    """Boundary traffic of one fusion instruction — the HloCostAnalysis
    convention: root output written once, each operand read in full,
    EXCEPT operands consumed by a gather / dynamic-slice inside the
    fused computation, which are read slice-by-slice and charged the
    total bytes those slicing ops move (a scan body's gather of a
    loop-invariant ``xc`` touches B·chunk elements per iteration, not
    the whole condensed array — counting the printed operand type would
    overcount every iteration by the full array)."""
    cm = re.search(r"calls=%?([\w\.\-]+)", line)
    interior = comps.get(cm.group(1)) if cm else None
    seg = line.split("=", 1)[1]
    out_b = _op_output_bytes(seg, "fusion")
    opseg = seg[seg.find(" fusion(") + len(" fusion("):]
    end = opseg.find("), ")
    opseg = opseg[:end] if end >= 0 else opseg
    operand_bytes = [_shape_bytes(f"{dt}[{dims}]")
                     for dt, dims in _SHAPE_RE.findall(opseg)]
    if not interior:
        return out_b + sum(operand_bytes)
    # interior pass: map %param_i names -> operand position, alias
    # chains, and accumulate sliced-read bytes per operand position
    param_idx: Dict[str, int] = {}
    alias: Dict[str, str] = {}
    for ln in interior:
        pm = re.search(r"%([\w\.\-]+)\s*=\s*[^=]*?\bparameter\((\d+)\)", ln)
        if pm:
            param_idx[pm.group(1)] = int(pm.group(2))
            continue
        for aop in _ALIAS_OPS:
            if f" {aop}(" in ln:
                am = re.search(r"%([\w\.\-]+)\s*=.*?\b" + aop +
                               r"\([^%]*%([\w\.\-]+)", ln)
                if am:
                    alias[am.group(1)] = am.group(2)
                break

    def resolve(name: str) -> Optional[int]:
        for _ in range(8):
            if name in param_idx:
                return param_idx[name]
            if name not in alias:
                return None
            name = alias[name]
        return None

    sliced: Dict[int, int] = {}
    dus_out = 0
    for ln in interior:
        for op in _SLICE_OPS:
            if f" {op}(" not in ln:
                continue
            iseg = ln.split("=", 1)[1] if "=" in ln else ln
            src = re.search(r"\b" + op + r"\([^%]*%([\w\.\-]+)", iseg)
            idx = resolve(src.group(1)) if src else None
            moved = _moved_slice_bytes(iseg, op)
            if op in ("dynamic-update-slice", "scatter"):
                # in-place update: the destination operand aliases the
                # fusion output, so the real traffic is the moved slice
                # (read update + write slot), not the whole buffer
                if idx is not None:
                    sliced[idx] = 0
                dus_out += moved
            elif idx is not None:
                sliced[idx] = sliced.get(idx, 0) + moved
            break
    if dus_out:
        out_b = dus_out
    total = out_b
    for i, b in enumerate(operand_bytes):
        total += sliced[i] if i in sliced else b
    return total


def _instruction_bytes(line: str, comps: Dict[str, List[str]]) -> int:
    """HLO-level bytes accessed by one top-level instruction line:
    operand + output shapes as printed, with fusions charged boundary
    traffic and bare gather/dynamic-slice charged 2× the moved slice."""
    if "=" not in line or _FREE_OPS.search(line):
        return 0
    if " fusion(" in line:
        return _fusion_bytes(line, comps)
    for op in _SLICE_OPS:
        if f" {op}(" in line:
            return 2 * _moved_slice_bytes(line.split("=", 1)[1], op)
    return _shape_bytes(line)


def body_once_bytes(lines: List[str],
                    comps: Dict[str, List[str]]) -> int:
    """One iteration's bytes for a while-body computation."""
    return sum(_instruction_bytes(ln, comps) for ln in lines)


def scan_corrected_bytes(hlo: str, raw_bytes: float) -> Tuple[float, dict]:
    """``raw_bytes`` (the ``cost_analysis()`` figure, while bodies
    counted once) plus ``(trips − 1) × body_bytes`` for every while body
    — the scan-aware correction. Returns ``(corrected, {body: trips})``.
    """
    mult, bodies = computation_multipliers(hlo)
    comps = _split_computations(hlo)
    extra = 0.0
    trips: dict = {}
    for body in bodies:
        m = mult.get(body, 1)
        if m <= 1:
            continue
        once = body_once_bytes(comps.get(body, []), comps)
        extra += (m - 1) * float(once)
        trips[body] = m
    return raw_bytes + extra, trips


# --------------------------------------------------------------------------
# The probe record
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ProbeRecord:
    """One compiled entry point, measured (see module docstring).

    ``bytes_accessed`` is the raw ``cost_analysis()`` figure;
    ``bytes_corrected`` re-adds the while-body trips. ``peak_bytes`` is
    ``argument + output + temp − alias`` from ``memory_analysis()``.
    ``scan_trips`` maps each corrected while body to its trip count
    (empty for scan-free programs, where corrected == raw).
    """

    name: str
    backend: str
    flops: float
    bytes_accessed: float
    bytes_corrected: float
    peak_bytes: int
    argument_bytes: int
    output_bytes: int
    temp_bytes: int
    scan_trips: dict
    params: dict

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def probe_lowered(name: str, lowered, params: Optional[dict] = None
                  ) -> ProbeRecord:
    """Compile a ``jax.jit(...).lower(...)`` result and measure it."""
    import jax

    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):          # jax 0.4.x returns [dict]
        cost = cost[0] if cost else {}
    cost = cost or {}
    flops = float(cost.get("flops", 0.0))
    raw = float(cost.get("bytes accessed", 0.0))
    corrected, trips = scan_corrected_bytes(compiled.as_text(), raw)
    mem = compiled.memory_analysis()
    arg = out = temp = alias = 0
    if mem is not None:
        arg = int(mem.argument_size_in_bytes)
        out = int(mem.output_size_in_bytes)
        temp = int(mem.temp_size_in_bytes)
        alias = int(mem.alias_size_in_bytes)
    return ProbeRecord(
        name=name, backend=jax.default_backend(), flops=flops,
        bytes_accessed=raw, bytes_corrected=corrected,
        peak_bytes=arg + out + temp - alias, argument_bytes=arg,
        output_bytes=out, temp_bytes=temp, scan_trips=trips,
        params=dict(params or {}))


#: process-level memo: repeated ``report()`` calls at one geometry
#: compile each probe once (AOT compiles bypass the jit cache)
_MEMO: dict = {}


def clear_probe_cache() -> None:
    _MEMO.clear()


def _memo_key(name: str, params: dict) -> tuple:
    import jax
    return (name, jax.default_backend(),
            tuple(sorted((k, v) for k, v in params.items())))


# --------------------------------------------------------------------------
# Entry-point probes (aval-only: nothing executes)
# --------------------------------------------------------------------------
def probe_permute_reduce(n: int, batch: int = 32, s: int = 1,
                         chunk: Optional[int] = None, impl: str = "xla",
                         interpret: Optional[bool] = None) -> ProbeRecord:
    """Measure ONE (B, n) tile of the batched condensed reduce — the
    program ``stats.engine``'s ``per_batch`` path runs per tile."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.permute_reduce_ops import (DEFAULT_CHUNK,
                                                  _permute_reduce_jit)

    chunk = DEFAULT_CHUNK if chunk is None else int(chunk)
    params = {"n": n, "batch": batch, "s": s, "chunk": chunk,
              "impl": impl, "interpret": interpret}
    key = _memo_key("kernels.permute_reduce", params)
    if key not in _MEMO:
        m = n * (n - 1) // 2
        f32, i32 = jnp.float32, jnp.int32
        lowered = _permute_reduce_jit.lower(
            jax.ShapeDtypeStruct((m,), f32),
            jax.ShapeDtypeStruct((s, m), f32),
            jax.ShapeDtypeStruct((batch, n), i32),
            jax.ShapeDtypeStruct((m,), i32),
            jax.ShapeDtypeStruct((m,), i32),
            impl=impl, chunk=chunk, interpret=interpret)
        _MEMO[key] = probe_lowered("kernels.permute_reduce", lowered,
                                   params)
    return _MEMO[key]


def probe_panel_stats(n: int, d: int, block: int = 256,
                      feature_block: int = 128,
                      metric: str = "braycurtis", impl: str = "xla",
                      interpret: Optional[bool] = None) -> ProbeRecord:
    """Measure ONE row panel of the distance production sweep (strip +
    fused running sums) — ``dist.driver`` runs ⌈n/block⌉ of these."""
    import jax
    import jax.numpy as jnp

    from repro.dist.driver import _panel_stats
    from repro.dist.metrics import get_metric
    from repro.kernels.dispatch import clamp_block

    b = clamp_block(n, block)
    fb = max(min(feature_block, d), 1)
    params = {"n": n, "d": d, "block": b, "feature_block": fb,
              "metric": metric, "impl": impl, "interpret": interpret}
    key = _memo_key("dist.panel_stats", params)
    if key not in _MEMO:
        lowered = _panel_stats.lower(
            jax.ShapeDtypeStruct((b, d), jnp.float32),
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            metric=get_metric(metric), feature_block=fb, impl=impl,
            interpret=interpret, block=b)
        _MEMO[key] = probe_lowered("dist.panel_stats", lowered, params)
    return _MEMO[key]


def probe_center_matvec(n: int, k: int = 10, block_m: int = 512,
                        block_n: int = 512,
                        interpret: Optional[bool] = None) -> ProbeRecord:
    """Measure one fused center-matvec pass over the square (n, n) D —
    the ``matvec_impl="pallas"`` operator kernel."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.center_matvec_ops import center_matvec_pallas

    params = {"n": n, "k": k, "block_m": block_m, "block_n": block_n,
              "interpret": interpret}
    key = _memo_key("kernels.center_matvec", params)
    if key not in _MEMO:
        f32 = jnp.float32
        lowered = center_matvec_pallas.lower(
            jax.ShapeDtypeStruct((n, n), f32),
            jax.ShapeDtypeStruct((n, k), f32),
            jax.ShapeDtypeStruct((n,), f32),
            jax.ShapeDtypeStruct((), f32),
            block_m=block_m, block_n=block_n, interpret=interpret)
        _MEMO[key] = probe_lowered("kernels.center_matvec", lowered,
                                   params)
    return _MEMO[key]


def probe_pcoa_matfree(op, k: int = 10, oversample: int = 10,
                       power_iters: int = 2) -> ProbeRecord:
    """Measure the matrix-free fsvd solve against a (cached) centered-
    Gram operator — the ``pcoa.fsvd_matfree`` entry point."""
    import jax

    from repro.core.pcoa import _randomized_eigh_matfree

    params = {"n": int(op.n), "k": k, "oversample": oversample,
              "power_iters": power_iters}
    key = _memo_key("pcoa.fsvd_matfree", params)
    if key not in _MEMO:
        lowered = _randomized_eigh_matfree.lower(
            op, jax.random.PRNGKey(0), k=k, oversample=oversample,
            power_iters=power_iters)
        _MEMO[key] = probe_lowered("pcoa.fsvd_matfree", lowered, params)
    return _MEMO[key]


def probe_statistic(stat, batch: int = 32) -> Dict[str, ProbeRecord]:
    """Measure one statistic's engine entry points: the hoist program
    (``stats.engine.hoist_and_observe``) and one padded (B, n) tile of
    the per-batch program (``stats.engine.tile``)."""
    import jax
    import jax.numpy as jnp

    from repro.stats import engine

    records = {}
    records["stats.engine.hoist_and_observe"] = probe_lowered(
        "stats.engine.hoist_and_observe",
        engine.hoist_and_observe.lower(stat),
        {"stat": type(stat).__name__, "n": int(stat.n)})
    inv, _ = jax.eval_shape(engine.hoist_and_observe, stat)
    orders = jax.ShapeDtypeStruct((batch, int(stat.n)), jnp.int32)
    records["stats.engine.tile"] = probe_lowered(
        "stats.engine.tile",
        engine.tile_statistics.lower(stat, inv, orders),
        {"stat": type(stat).__name__, "n": int(stat.n), "batch": batch})
    return records


def probe_stream_pass(n: int) -> ProbeRecord:
    """Measure one elementwise fp32 pass over (n,) — the program
    ``tune.budget.calibrate()`` times; its compiled byte count is the
    probe-backed calibration's rate-constant feature."""
    import jax
    import jax.numpy as jnp

    params = {"n": n}
    key = _memo_key("tune.stream_pass", params)
    if key not in _MEMO:
        lowered = jax.jit(lambda a: a * 2.0 + 1.0).lower(
            jax.ShapeDtypeStruct((n,), jnp.float32))
        _MEMO[key] = probe_lowered("tune.stream_pass", lowered, params)
    return _MEMO[key]


# --------------------------------------------------------------------------
# Session-level front door
# --------------------------------------------------------------------------
def probe_session(ws, dimensions: int = 10) -> Dict[str, ProbeRecord]:
    """Measure the entry points a ``Workspace`` session executes, at the
    session's own resolved geometry (so sentinel signatures match and
    the drift sentinel reconciles like-for-like):

    * ``kernels.permute_reduce`` — always (every permutation test);
    * ``dist.panel_stats``       — feature-backed sessions (production);
    * ``kernels.center_matvec``  — square-backed Pallas-matvec sessions;
    * ``pcoa.fsvd_matfree``      — when the operator hoist is already
      cached (probing must not trigger builds mid-report).
    """
    cfg = ws.config
    tiles = ws.resolved_tiles()
    n = ws.n
    records: Dict[str, ProbeRecord] = {}
    records["kernels.permute_reduce"] = probe_permute_reduce(
        n, batch=tiles["batch_size"], s=1, chunk=tiles["chunk"],
        impl=cfg.kernel, interpret=cfg.interpret)
    if ws._features is not None:
        records["dist.panel_stats"] = probe_panel_stats(
            n, int(ws._features.shape[1]),
            block=tiles["block"] if isinstance(tiles["block"], int)
            else tiles["block_executed"],
            feature_block=tiles["feature_block_executed"]
            if isinstance(tiles["feature_block_executed"], int) else 128,
            metric=cfg.metric or "braycurtis",
            impl=cfg.pairwise_impl, interpret=cfg.interpret)
    elif cfg.matvec_impl == "pallas":
        records["kernels.center_matvec"] = probe_center_matvec(
            n, k=dimensions, interpret=cfg.interpret)
    if "operator" in ws.cache:
        op = ws.cache._store["operator"]      # peek — no counter perturbed
        records["pcoa.fsvd_matfree"] = probe_pcoa_matfree(op, k=dimensions)
    return records


def probe_table(records: Dict[str, ProbeRecord]) -> List[str]:
    """Aligned text rows for one measured section (README / examples)."""
    rows = []
    for name in sorted(records):
        r = records[name]
        scans = (",".join(f"x{v}" for v in r.scan_trips.values())
                 or "-")
        rows.append(f"{name:28s} {r.flops / 1e6:10.2f} Mflop  "
                    f"{r.bytes_corrected / 1e6:10.2f} MB "
                    f"({r.bytes_accessed / 1e6:.2f} raw, scan {scans})  "
                    f"peak {r.peak_bytes / 1e6:8.2f} MB")
    return rows
