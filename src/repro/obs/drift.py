"""Measured-vs-modeled reconciliation: the DriftSentinel.

``obs.probe`` reads what a compiled program actually moves;
``obs.ledger`` and ``tune.model`` say what the paper's streaming model
*prices*. This module closes the loop: every probe record is reconciled
against the analytic forms and judged against a per-backend tolerance
band, and the verdicts ride ``RunReport.drift`` — so "the model drifted
from the implementation" is a report field, not an archaeology project.

Two kinds of band, both calibrated against this container's XLA:CPU
(jax 0.4.37) and documented inline:

* **tight bands** where the closed form is exact — the scan-regime
  ``permute_reduce`` body (measured/modeled 0.93–0.98 across n=1024 and
  n=2048 and chunk sizes 8K–64K), the calibration stream pass (exactly
  1.0), and the peak-allocation models (argument + output + the known
  temp buffers);
* **envelope bands** where XLA's fusion policy picks the scale — below
  one chunk XLA may fuse an entire permute_reduce tile into one
  boundary-counted fusion (measured ≈ argument bytes, 0.14× the ledger
  floor at n=64) or materialize the gather stages (≈1.93× floor at
  n=128..256), and the production panel's metric intermediates either
  fuse (body ≈ n·d + 2·rb·n floats) or materialize at (rb, n, d)
  (body ≈ n·d + 4·rb·n·d). The verdict brackets measured between the
  cheapest and dearest known-good regime; anything outside — e.g. the
  square-gather permutation form at ~11× floor, or an accidental n×n
  materialization blowing the peak model — still fails loudly.

The ``ratio`` every verdict carries is measured / ledger-floor: the
implementation inflation factor over the paper's ideal streaming count.
On CPU the interesting ones are ~4.8 for the chunked permute_reduce
(HLO-level counting charges the permutation-index gathers and the
transposed gather output that the floor's per-element count does not)
and ceil(block/8)-flavored for the production panel (the XLA fallback
re-reads the full feature table once per 8-row sub-panel — exactly the
kind of fact a model-only report never surfaces).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

__all__ = ["DriftVerdict", "DriftSentinel", "reconcile"]

#: multiplicative slack applied to each envelope edge, per backend —
#: CPU edges were measured here; accelerator backends keep wider slack
#: until their fusion policies are calibrated the same way
_SLACK = {
    "cpu": (0.65, 1.35),
    "gpu": (0.5, 2.0),
    "tpu": (0.5, 2.0),
}
_DEFAULT_SLACK = (0.5, 2.0)

#: the dist XLA fallback's row sub-panel height (dist.driver._ROW_CHUNK)
_ROW_CHUNK_FALLBACK = 8


def _row_chunk() -> int:
    try:
        from repro.dist.driver import _ROW_CHUNK
        return int(_ROW_CHUNK)
    except Exception:
        return _ROW_CHUNK_FALLBACK


@dataclasses.dataclass(frozen=True)
class DriftVerdict:
    """One reconciled quantity for one probed entry point.

    ``floor`` is the analytic ideal (ledger traffic / modeled resident
    set); ``expected_lo``/``expected_hi`` the slack-adjusted envelope of
    known-good implementation regimes; ``ratio`` = measured / floor, the
    implementation inflation factor; ``within`` whether measured landed
    inside the envelope.
    """

    name: str
    quantity: str               # "bytes" | "peak"
    measured: float
    floor: float
    expected_lo: float
    expected_hi: float
    regime: str
    within: bool
    note: str = ""

    @property
    def ratio(self) -> float:
        return self.measured / self.floor if self.floor else float("inf")

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["ratio"] = self.ratio
        return d


class DriftSentinel:
    """Reconciles ``obs.probe`` records against the analytic models.

    ``reconcile(records)`` takes the ``{name: ProbeRecord}`` mapping
    ``probe_session`` returns and emits the ``RunReport.drift`` section.
    Entry points without a closed-form counterpart (the engine's fused
    statistic programs, whose traffic depends on the statistic's own
    hoist structure) stay measured-only: present in ``measured``, no
    verdict here.
    """

    def __init__(self, backend: Optional[str] = None,
                 slack: Optional[tuple] = None):
        if backend is None:
            import jax
            backend = jax.default_backend()
        self.backend = backend
        self.slack = (tuple(slack) if slack is not None
                      else _SLACK.get(backend, _DEFAULT_SLACK))

    # -- helpers -----------------------------------------------------------
    def _verdict(self, name: str, quantity: str, measured: float,
                 floor: float, lo: float, hi: float, regime: str,
                 note: str = "") -> DriftVerdict:
        slo, shi = self.slack
        lo, hi = lo * slo, hi * shi
        return DriftVerdict(name=name, quantity=quantity,
                            measured=float(measured), floor=float(floor),
                            expected_lo=lo, expected_hi=hi, regime=regime,
                            within=bool(lo <= measured <= hi), note=note)

    # -- permute_reduce ----------------------------------------------------
    def check_permute_reduce(self, rec) -> List[DriftVerdict]:
        from repro.obs.ledger import perm_traffic_floats

        p = rec.params
        n, B = int(p["n"]), int(p["batch"])
        s, ch = int(p.get("s", 1)), int(p["chunk"])
        m = n * (n - 1) // 2
        scan = m > ch
        m_pad = -(-m // ch) * ch if scan else m
        args = 4.0 * (m * (3 + s) + B * n)          # xc+ii+jj, ys, orders
        out = 4.0 * B * s
        floor = 4.0 * B * s * perm_traffic_floats(n, B)["condensed_fused"]
        if scan:
            # per-chunk boundary floats: xc-gather out B·c, two
            # permutation-index gathers 2·B·c, transposed gather out
            # B·c, dot reads B·c + s·c, ii/jj/ys slices (2+s)·c —
            # (5B + 3s + 2)·c per iteration; entry pre-chunks ii/jj/ys
            # and reads xc: m·(6 + 2s). Measured/modeled 0.93–0.98.
            eff = 4.0 * (m_pad * (5 * B + 3 * s + 2) + m * (6 + 2 * s))
            bv = self._verdict("kernels.permute_reduce", "bytes",
                               rec.bytes_corrected, floor, eff, eff,
                               "scan",
                               "tight: scan-regime boundary form")
            temp = 4.0 * (3 * m_pad + B * ch)       # chunked ii/jj/ys + tile
        else:
            # envelope: whole-tile fusion (boundary = args+out) up to
            # materialized gather stages (~2x the ledger floor)
            bv = self._verdict("kernels.permute_reduce", "bytes",
                               rec.bytes_corrected, floor, args + out,
                               2.0 * floor, "single-chunk",
                               "envelope: fused .. materialized gathers")
            temp = 4.0 * B * m                      # one (B, m) gather
        pv = self._verdict("kernels.permute_reduce", "peak",
                           rec.peak_bytes, args + out + temp, args + out,
                           args + out + temp,
                           "scan" if scan else "single-chunk",
                           "args+out .. +known temp buffers")
        return [bv, pv]

    # -- distance production panel ----------------------------------------
    def check_panel(self, rec) -> List[DriftVerdict]:
        from repro.obs.ledger import production_floats

        p = rec.params
        n, d, b = int(p["n"]), int(p["d"]), int(p["block"])
        rb = _row_chunk()
        trips = -(-b // rb)
        args = 4.0 * (b * d + n * d)
        acc = 4.0 * b * n                           # (trips, rb, n) carry
        floor = 4.0 * production_floats(n, d, b) / max(-(-n // b), 1)
        fused = 4.0 * (n * d + 2 * rb * n + rb * d)
        mater = 4.0 * (n * d + 4 * rb * n * d + 2 * rb * n)
        bv = self._verdict(
            "dist.panel_stats", "bytes", rec.bytes_corrected, floor,
            trips * fused + args, trips * mater + args + floor, "lax.map",
            f"envelope: fused .. materialized metric body; x re-read "
            f"once per {rb}-row sub-panel")
        pv = self._verdict("dist.panel_stats", "peak", rec.peak_bytes,
                           args + acc, args + acc, args + 5 * acc,
                           "lax.map", "args + 1..5 accumulator buffers")
        return [bv, pv]

    # -- fused center-matvec (Pallas) --------------------------------------
    def check_center_matvec(self, rec) -> List[DriftVerdict]:
        p = rec.params
        n, k = int(p["n"]), int(p["k"])
        floor = 4.0 * (n * n + 2 * n * k + 2 * n)   # D + x + out + vecs
        args = floor
        interp = p.get("interpret")
        emulated = interp is None and self.backend != "tpu" or bool(interp)
        if emulated:
            # the Pallas interpreter lowers grid steps to while+slice
            # copies; HLO traffic is emulation overhead, not the
            # kernel's DMA plan — bracket wide and say so
            bv = self._verdict("kernels.center_matvec", "bytes",
                               rec.bytes_corrected, floor, floor,
                               30.0 * floor, "interpret",
                               "envelope: Pallas interpreter emulation")
            pv = self._verdict("kernels.center_matvec", "peak",
                               rec.peak_bytes, args, args, 4.0 * args,
                               "interpret", "padded block copies")
        else:
            bv = self._verdict("kernels.center_matvec", "bytes",
                               rec.bytes_corrected, floor, floor,
                               2.0 * floor, "native", "tight: one D pass")
            pv = self._verdict("kernels.center_matvec", "peak",
                               rec.peak_bytes, args, args, 1.5 * args,
                               "native", "args + block scratch")
        return [bv, pv]

    # -- calibration stream pass -------------------------------------------
    def check_stream(self, rec) -> List[DriftVerdict]:
        nbytes = 8.0 * int(rec.params["n"])         # read + write fp32
        return [self._verdict("tune.stream_pass", "bytes",
                              rec.bytes_corrected, nbytes, nbytes, nbytes,
                              "stream", "tight: 2 passes exactly")]

    # -- front door --------------------------------------------------------
    _CHECKS = {
        "kernels.permute_reduce": "check_permute_reduce",
        "dist.panel_stats": "check_panel",
        "kernels.center_matvec": "check_center_matvec",
        "tune.stream_pass": "check_stream",
    }

    def reconcile(self, records: Dict[str, object]) -> dict:
        """``RunReport.drift`` section for a ``probe_session`` result."""
        verdicts: List[DriftVerdict] = []
        for name, rec in sorted(records.items()):
            method = self._CHECKS.get(name)
            if method is not None:
                verdicts.extend(getattr(self, method)(rec))
        return {
            "backend": self.backend,
            "slack": list(self.slack),
            "verdicts": [v.to_dict() for v in verdicts],
            "within_tolerance": all(v.within for v in verdicts),
        }


def reconcile(records: Dict[str, object],
              backend: Optional[str] = None) -> dict:
    """Module-level convenience: one-shot DriftSentinel reconcile."""
    return DriftSentinel(backend=backend).reconcile(records)
