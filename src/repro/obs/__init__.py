"""repro.obs — always-on observability for the analysis stack.

Four pieces, one discipline ("profile first, then trust the model" —
the SSD-profiling study's rule, applied to our own runtime):

* ``obs.trace``   — nested span tracer with phase tags
  (hoist | per_perm | production | solve | step), JSON + Chrome
  ``trace_event`` export, optional ``jax.profiler.TraceAnnotation``
  bridge, and a zero-overhead no-op fast path when disabled;
* ``obs.ledger``  — THE audited analytic-traffic registry (hoist pass
  tables, Mantel per-permutation models, production feature reads),
  shared by the benchmarks and charged live by the instrumented stack;
* ``obs.compile`` — the recompile sentinel: jit trace/program counts
  per wrapped entry point, with a runtime guard for the "one trace
  serves any K" invariant;
* ``obs.report``  — ``ObsSession`` (one run's tracer+ledger+sentinel
  window) and ``RunReport`` (the one-JSON-per-run artifact CI uploads).

Enable per session via ``ExecConfig(obs=ObsConfig(enabled=True))``;
read the result with ``Workspace.report()``.
"""

from repro.obs.compile import (CompileSentinel, RecompileError, note_trace,
                               sentinel)
from repro.obs.config import ObsConfig
from repro.obs.ledger import (FEATURE_HOIST_PASSES, HOIST_PASSES, Ledger,
                              LedgerEntry, hoist_floats, perm_traffic_floats,
                              production_floats)
from repro.obs.report import ObsSession, RunReport, build_report
from repro.obs.trace import (NULL_OBS, NULL_SPAN, PHASES, Span, Tracer,
                             current_obs)

__all__ = [
    "CompileSentinel", "RecompileError", "note_trace", "sentinel",
    "ObsConfig",
    "FEATURE_HOIST_PASSES", "HOIST_PASSES", "Ledger", "LedgerEntry",
    "hoist_floats", "perm_traffic_floats", "production_floats",
    "ObsSession", "RunReport", "build_report",
    "NULL_OBS", "NULL_SPAN", "PHASES", "Span", "Tracer", "current_obs",
]
