"""repro.obs — always-on observability for the analysis stack.

Seven pieces, one discipline ("profile first, then trust the model" —
the SSD-profiling study's rule, applied to our own runtime):

* ``obs.trace``   — nested span tracer with phase tags
  (hoist | per_perm | production | solve | step), JSON + Chrome
  ``trace_event`` export, optional ``jax.profiler.TraceAnnotation``
  bridge, and a zero-overhead no-op fast path when disabled;
* ``obs.ledger``  — THE audited analytic-traffic registry (hoist pass
  tables, Mantel per-permutation models, production feature reads),
  shared by the benchmarks and charged live by the instrumented stack;
* ``obs.compile`` — the recompile sentinel: jit trace/program counts
  per wrapped entry point, with a runtime guard for the "one trace
  serves any K" invariant;
* ``obs.report``  — ``ObsSession`` (one run's tracer+ledger+sentinel
  window) and ``RunReport`` (the one-JSON-per-run artifact CI uploads);
* ``obs.probe``   — the MEASURED half: AOT-compiled flop/byte/peak
  counts per jitted entry point (``cost_analysis`` + ``memory_analysis``
  + scan-corrected HLO byte counting, absorbed from the retired
  ``repro.roofline``);
* ``obs.drift``   — the ``DriftSentinel`` reconciling measured probes
  against the ledger/tune models with per-backend tolerance bands;
* ``obs.metrics`` — allocation-light ``Counter``/``Gauge``/``Histogram``
  primitives (JSON + Prometheus text export) behind the serve latency
  percentiles and the step monitor.

Enable per session via ``ExecConfig(obs=ObsConfig(enabled=True))``;
read the result with ``Workspace.report()``.
"""

from repro.obs.compile import (CompileSentinel, RecompileError, note_trace,
                               sentinel)
from repro.obs.config import ObsConfig
from repro.obs.drift import DriftSentinel, DriftVerdict
from repro.obs.ledger import (FEATURE_HOIST_PASSES, HOIST_PASSES, Ledger,
                              LedgerEntry, hoist_floats, perm_traffic_floats,
                              production_floats)
from repro.obs.metrics import (DEFAULT_LATENCY_BUCKETS, NULL_HISTOGRAM,
                               Counter, Gauge, Histogram, prometheus_text)
from repro.obs.probe import (ProbeRecord, probe_lowered, probe_session,
                             probe_table, scan_corrected_bytes)
from repro.obs.report import ObsSession, RunReport, build_report
from repro.obs.trace import (NULL_OBS, NULL_SPAN, PHASES, Span, Tracer,
                             current_obs)

__all__ = [
    "CompileSentinel", "RecompileError", "note_trace", "sentinel",
    "ObsConfig",
    "DriftSentinel", "DriftVerdict",
    "FEATURE_HOIST_PASSES", "HOIST_PASSES", "Ledger", "LedgerEntry",
    "hoist_floats", "perm_traffic_floats", "production_floats",
    "DEFAULT_LATENCY_BUCKETS", "NULL_HISTOGRAM", "Counter", "Gauge",
    "Histogram", "prometheus_text",
    "ProbeRecord", "probe_lowered", "probe_session", "probe_table",
    "scan_corrected_bytes",
    "ObsSession", "RunReport", "build_report",
    "NULL_OBS", "NULL_SPAN", "PHASES", "Span", "Tracer", "current_obs",
]
