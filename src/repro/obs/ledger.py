"""Analytic traffic ledger: ONE audited cost-term registry, charged live.

The repo's benchmark gates are analytic by policy (container wall-clock
is ±40% noise — ROADMAP), but until this module the audited tables
lived duplicated inside the benchmark scripts: ``bench_api._PASSES``,
``bench_dist._PASSES_FUSED``/``_PASSES_BASE``, and
``bench_mantel.perm_traffic_floats``. This module is now their single
home — the benchmarks import from here, a parity test pins the
published BENCH ratios (10.97x mantel, 11-vs-16 api passes) against the
registry, and the instrumented runtime (Workspace hoist builds, the
stats engine's permutation batches, the ``repro.dist`` production
sweep) charges a per-session ``Ledger`` with the same terms — so every
run carries its own traffic accounting instead of trusting a benchmark
that ran once.

Registry layout
---------------
* ``HOIST_PASSES`` — n²-sized fp32 passes per HoistCache artifact build
  on a **square-backed** session (reads + writes of n²-sized buffers).
* ``FEATURE_HOIST_PASSES`` — the same table for a **feature-backed**
  session (condensed production: the square never exists, so several
  builds get cheaper or free).
* ``perm_traffic_floats(n, batch)`` — audited fp32 floats moved PER
  PERMUTATION by each formulation of the Mantel-family inner loop.
* ``production_floats(n, d, block)`` — feature reads of the tiled
  distance production sweep (identical for fused and materialized
  modes, which is why the pass tables exclude it).

Costs are exact functions of (operation, n, d, K, B, block) — every
``Ledger`` entry records the operation, the floats moved, and the
parameters it was evaluated at, so a ``RunReport`` can be re-audited
offline.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

# --------------------------------------------------------------------------
# The audited registry
# --------------------------------------------------------------------------
#: Analytic n²-pass cost of building each HoistCache artifact on a
#: square-backed session (reads + writes of n²-sized fp32 buffers).
#: These mirror the implementations:
#:   operator    — row/global means of E = −½D∘D in ONE read of D (the
#:                 paper's hoist)
#:   gram        — fused centering: 2 reads + 2 writes (paper Alg. 2)
#:   condensed   — triangle extraction from the square: m-element gather
#:                 + m-element write ≈ 1 full pass (m = n(n−1)/2 ≈ ½n²)
#:   ranks       — O(m log m) sort of the cached condensed + condensed
#:                 rank write ≈ 1 pass (square-free since the
#:                 permute_reduce loop: no rank matrix is materialized)
#:   moments     — condensed read + centered-norm reduce ≈ ½ pass (O(m))
#:   coords      — the fsvd solve: 4 operator matvecs (range find +
#:                 2 power iterations + projection), each one read of D
#:   square      — the n² write of a materialized distance matrix
#:   dist_means  — rides the production sweep's running sums: free
HOIST_PASSES = {
    "operator": 1.0,
    "gram": 4.0,
    "condensed": 1.0,
    "ranks": 1.0,
    "moments": 0.5,
    "coords": 4.0,
    "square": 1.0,
    "dist_means": 0.0,
}

#: The same table for a feature-backed session (condensed production —
#: the square D never exists):
#:   condensed — the tiled production writes m ≈ ½n² entries once (its
#:               O(n·d) feature reads are ``production_floats``, charged
#:               as their own op since both modes pay them identically)
#:   operator  — wraps the production sweep's fused accumulators: free
#:   coords    — 4 fsvd matvecs, each reading condensed storage (½ pass)
FEATURE_HOIST_PASSES = dict(HOIST_PASSES,
                            condensed=0.5, operator=0.0, coords=2.0)


def hoist_floats(artifact: str, n: int, table: Optional[dict] = None
                 ) -> float:
    """fp32 floats moved building ``artifact`` once, per the registry
    (artifacts outside the table — ad-hoc cache keys — charge 0)."""
    t = HOIST_PASSES if table is None else table
    return t.get(artifact, 0.0) * float(n) * float(n)


def perm_traffic_floats(n: int, batch: int) -> dict:
    """Audited analytic fp32 floats moved PER PERMUTATION by each
    formulation of the Mantel-family inner loop (the ``BENCH_mantel``
    accounting — the 10.97x headline is
    ``square_gather / condensed_fused`` at n=2048, B=32):

    * ``original`` (paper Algorithm 3, eager): two materializing square
      gathers (4 n²-passes), the triangle condense (2m), and black-box
      pearsonr's multi-pass mean/center/norm/dot over both m-vectors
      (~8m) ⇒ 4n² + 10m floats;
    * ``square_gather`` (the pre-condensed engine loop): per
      permutation, ``x[order][:, order]`` lowers to two materialized n²
      gathers (read + write each) and the fused reduce reads the
      gathered Xp plus the square hoisted Ŷ ⇒ 6n² floats;
    * ``condensed_fused`` (the ``kernels.permute_reduce`` loop): one
      closed-form condensed gather (m) plus the per-permutation share
      of the tile streams — ŷ_c and the ii/jj triangle map, each
      fetched once per B-permutation tile (3m/B) — plus the (n,) order
      row ⇒ m(1 + 3/B) + n floats.
    """
    m = n * (n - 1) // 2
    return {
        "original": 4 * n * n + 10 * m,
        "square_gather": 6 * n * n,
        "condensed_fused": m * (1.0 + 3.0 / batch) + n,
    }


def production_floats(n: int, d: int, block: int) -> float:
    """Feature reads of the tiled pairwise production: each of the
    ⌈n/b⌉ row panels streams the full (n, d) table against its own
    (b, d) panel ⇒ ⌈n/b⌉·n·d + n·d floats. The m-element condensed
    write is the ``condensed`` hoist charge, not double-counted here."""
    b = max(min(block, n), 1)
    panels = -(-n // b)
    return float(panels) * n * d + float(n) * d


# --------------------------------------------------------------------------
# The runtime ledger
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LedgerEntry:
    """One charge: operation name, fp32 floats moved, and the parameter
    point ((n, d, K, B, block, …)) the cost term was evaluated at."""

    op: str
    floats: float
    params: dict

    @property
    def bytes(self) -> float:
        return 4.0 * self.floats

    def to_dict(self) -> dict:
        return {"op": self.op, "floats": self.floats, "bytes": self.bytes,
                "params": dict(self.params)}


class Ledger:
    """A session's running analytic traffic account.

    Charged by the instrumented call sites (HoistCache builds, the
    engine's permutation batches, the production sweep); ``totals()``
    is what ``RunReport`` embeds. Charges are analytic — exact
    functions of the documented parameters — never measured, so they
    are noise-free and reproducible offline.
    """

    def __init__(self):
        self.entries: list[LedgerEntry] = []

    # -- charging ----------------------------------------------------------
    def charge(self, op: str, floats: float, **params) -> LedgerEntry:
        e = LedgerEntry(op, float(floats), params)
        self.entries.append(e)
        return e

    def charge_hoist(self, artifact: str, n: int,
                     table: Optional[dict] = None) -> LedgerEntry:
        """One artifact build, per the pass registry (``table`` selects
        the square-backed vs feature-backed column)."""
        t = HOIST_PASSES if table is None else table
        passes = t.get(artifact, 0.0)
        return self.charge(f"hoist:{artifact}", passes * float(n) * n,
                           n=n, passes=passes)

    def charge_perm_batch(self, op: str, n: int, permutations: int,
                          batch: int, model: str = "condensed_fused",
                          **params) -> LedgerEntry:
        """One permutation run of ``permutations`` draws in B=``batch``
        tiles, per the audited per-permutation model."""
        per_perm = perm_traffic_floats(n, batch)[model]
        return self.charge(f"perm:{op}", per_perm * permutations, n=n,
                           permutations=permutations, batch=batch,
                           model=model, floats_per_perm=per_perm, **params)

    def charge_production(self, n: int, d: int, block: int,
                          **params) -> LedgerEntry:
        return self.charge("production", production_floats(n, d, block),
                           n=n, d=d, block=block, **params)

    # -- queries -----------------------------------------------------------
    def total_floats(self) -> float:
        return sum(e.floats for e in self.entries)

    def total_bytes(self) -> float:
        return 4.0 * self.total_floats()

    def hoist_passes(self) -> float:
        """Total n²-passes across every hoist charge — the quantity the
        ``BENCH_api`` 11-vs-16 session accounting tracks."""
        return sum(e.params.get("passes", 0.0) for e in self.entries
                   if e.op.startswith("hoist:"))

    def by_op(self) -> dict:
        out: dict = {}
        for e in self.entries:
            d = out.setdefault(e.op, {"count": 0, "floats": 0.0,
                                      "bytes": 0.0})
            d["count"] += 1
            d["floats"] += e.floats
            d["bytes"] += e.bytes
        return out

    def totals(self) -> dict:
        return {"by_op": self.by_op(),
                "total_floats": self.total_floats(),
                "total_bytes": self.total_bytes(),
                "hoist_passes": self.hoist_passes()}

    def to_dict(self) -> dict:
        d = self.totals()
        d["entries"] = [e.to_dict() for e in self.entries]
        return d
