"""Span tracer: nested wall-time spans with phase tags and cost attrs.

The paper's discipline is "account for every pass over the data"; the
ROADMAP's corollary is that container wall-clock is ±40% noise, so the
*structure* of a run — which phase ran, how often, against which backend,
with which analytic cost — is the trustworthy signal and the timing is
the informational overlay. A ``Span`` records both: host-side wall time
(``perf_counter``; note jax dispatch is async, so a span bounds the
host's dispatch+sync work, not device occupancy — use ``annotate_xla``
to line spans up inside an XLA profile for device truth) plus a phase
tag from the analysis stack's vocabulary:

* ``hoist``      — a permutation-invariant O(n²)/O(m) artifact build
  (the HoistCache miss path);
* ``per_perm``   — a Monte-Carlo permutation loop (the stats engine);
* ``production`` — the tiled feature-table → condensed-distance sweep
  (``repro.dist``);
* ``solve``      — an eigensolve / subspace iteration (``core.pcoa``);
* ``step``       — a training/serving step (``runtime.monitor``);
* ``serve``      — front-door work in ``repro.serve`` (admission, tile
  scheduling, request lifecycle).

Spans nest (a ``ws.permanova`` span contains its ``hoist:gram`` child
and the engine's ``per_perm`` span), export as plain dicts / JSON and as
Chrome ``trace_event`` JSON (load in ``chrome://tracing`` / Perfetto),
and optionally bridge into ``jax.profiler.TraceAnnotation``.

The no-op fast path is the contract that lets every hot call site stay
instrumented unconditionally: with no active session, ``current_obs()``
returns the shared ``NULL_OBS`` singleton whose ``span()`` returns the
shared ``NULL_SPAN`` singleton — no allocation, no branching beyond one
list check. ``tests/test_obs.py`` pins both the identity (no per-call
allocation) and a generous per-call time bound.

This module imports nothing from ``repro`` (jax only, lazily, for the
profiler bridge) so any layer can import it without cycles.
"""

from __future__ import annotations

import json
import time
from typing import Optional

#: the phase vocabulary — see the module docstring
PHASES = ("hoist", "per_perm", "production", "solve", "step", "serve")


class Span:
    """One timed, attributed, nestable region.

    Use as a context manager (``with tracer.span(...)``) or drive
    ``begin()``/``end()`` explicitly (the ``StepMonitor`` style). Attrs
    are free-form key→value pairs: impl/backend tags, analytic cost
    terms, shapes. ``add()`` attaches more after creation (e.g. a result
    computed inside the span).
    """

    __slots__ = ("name", "phase", "attrs", "t0", "duration", "children",
                 "_tracer", "_session", "_ann")

    def __init__(self, tracer: "Tracer", name: str,
                 phase: Optional[str] = None, session=None, **attrs):
        if phase is not None and phase not in PHASES:
            raise ValueError(f"unknown span phase {phase!r}; "
                             f"expected one of {PHASES} or None")
        self.name = name
        self.phase = phase
        self.attrs = attrs
        self.t0: Optional[float] = None
        self.duration: Optional[float] = None
        self.children: list = []
        self._tracer = tracer
        self._session = session
        self._ann = None

    # -- lifecycle ---------------------------------------------------------
    def begin(self) -> "Span":
        self.t0 = time.perf_counter()
        self._tracer._open(self)
        if self._session is not None:
            push_obs(self._session)
        if self._tracer.annotate_xla:
            try:                         # the profiler bridge is best-effort
                import jax
                self._ann = jax.profiler.TraceAnnotation(self.name)
                self._ann.__enter__()
            except Exception:
                self._ann = None
        return self

    def end(self) -> "Span":
        if self.t0 is None:
            raise RuntimeError(f"span {self.name!r} ended before begin()")
        if self._ann is not None:
            self._ann.__exit__(None, None, None)
            self._ann = None
        if self._session is not None:
            pop_obs(self._session)
        self.duration = time.perf_counter() - self.t0
        self._tracer._close(self)
        return self

    def __enter__(self) -> "Span":
        return self.begin()

    def __exit__(self, *exc) -> bool:
        self.end()
        return False

    def add(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    # -- export ------------------------------------------------------------
    def to_dict(self) -> dict:
        d = {"name": self.name, "phase": self.phase,
             "duration_s": self.duration, "attrs": dict(self.attrs)}
        if self.children:
            d["children"] = [c.to_dict() for c in self.children]
        return d

    def __repr__(self):
        dur = f"{self.duration:.4f}s" if self.duration is not None else "open"
        return f"Span({self.name!r}, phase={self.phase!r}, {dur})"


class Tracer:
    """Owns one run's span tree.

    ``spans`` holds the completed root spans in completion order;
    nesting is by begin/end bracketing (a span begun while another is
    open becomes its child). Not thread-safe — one tracer per session,
    like the HoistCache it instruments.
    """

    def __init__(self, annotate_xla: bool = False):
        self.annotate_xla = annotate_xla
        self.epoch = time.perf_counter()
        self.spans: list[Span] = []
        self._stack: list[Span] = []

    def span(self, name: str, phase: Optional[str] = None, session=None,
             **attrs) -> Span:
        """A new (unstarted) span — enter it (``with``) or ``begin()``."""
        return Span(self, name, phase, session=session, **attrs)

    def record(self, name: str, seconds: float,
               phase: Optional[str] = None, **attrs) -> Span:
        """Append a pre-timed span (no live begin/end window) — the
        ``StepMonitor.record`` path, where the caller measured the
        duration itself."""
        s = Span(self, name, phase, **attrs)
        s.t0 = time.perf_counter() - seconds
        s.duration = seconds
        self._close(s)
        return s

    # -- span plumbing -----------------------------------------------------
    def _open(self, span: Span) -> None:
        self._stack.append(span)

    def _close(self, span: Span) -> None:
        if self._stack and self._stack[-1] is span:
            self._stack.pop()
        parent = self._stack[-1] if self._stack else None
        if parent is not None:
            parent.children.append(span)
        else:
            self.spans.append(span)

    # -- queries -----------------------------------------------------------
    def _walk(self, spans=None):
        for s in (self.spans if spans is None else spans):
            yield s
            yield from self._walk(s.children)

    def count(self, phase: Optional[str] = None) -> int:
        return sum(1 for s in self._walk()
                   if phase is None or s.phase == phase)

    def total(self, phase: str) -> float:
        """Summed wall seconds of every span tagged ``phase`` (children
        of a same-phase parent still count — phases don't self-nest in
        the instrumented stack)."""
        return sum(s.duration or 0.0 for s in self._walk()
                   if s.phase == phase)

    # -- export ------------------------------------------------------------
    def to_dicts(self) -> list:
        return [s.to_dict() for s in self.spans]

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dicts(), indent=indent, default=str)

    def to_chrome_trace(self) -> list:
        """Chrome/Perfetto ``trace_event`` list (``ph="X"`` complete
        events, µs timebase) — dump with ``json.dump`` and load in
        ``chrome://tracing`` or https://ui.perfetto.dev."""
        events = []

        def emit(span: Span):
            if span.t0 is None or span.duration is None:
                return
            events.append({
                "name": span.name, "ph": "X", "pid": 0, "tid": 0,
                "cat": span.phase or "span",
                "ts": (span.t0 - self.epoch) * 1e6,
                "dur": span.duration * 1e6,
                "args": {k: str(v) for k, v in span.attrs.items()},
            })
            for c in span.children:
                emit(c)

        for s in self.spans:
            emit(s)
        return events

    def tree_lines(self, min_seconds: float = 0.0) -> list:
        """The span tree as indented text lines (the example's session
        epilogue printer)."""
        lines = []

        def walk(span: Span, depth: int):
            if span.duration is not None and span.duration < min_seconds:
                return
            dur = (f"{span.duration * 1e3:9.2f} ms"
                   if span.duration is not None else "     open")
            tag = f" [{span.phase}]" if span.phase else ""
            attrs = ", ".join(f"{k}={v}" for k, v in span.attrs.items()
                              if k in ("impl", "backend", "kernel", "method",
                                       "n", "permutations", "batch_size"))
            lines.append(f"{dur}  {'  ' * depth}{span.name}{tag}"
                         f"{'  (' + attrs + ')' if attrs else ''}")
            for c in span.children:
                walk(c, depth + 1)

        for s in self.spans:
            walk(s, 0)
        return lines


# --------------------------------------------------------------------------
# The no-op fast path + the ambient session stack
# --------------------------------------------------------------------------
class _NullSpan:
    """THE no-op span: one process-wide singleton, so the disabled path
    allocates nothing per call (pinned by tests/test_obs.py)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def begin(self):
        return self

    def end(self):
        return self

    def add(self, **attrs):
        return self


NULL_SPAN = _NullSpan()


class _NullObs:
    """THE no-op session: every instrumented call site talks to this when
    observability is off (or no session is ambient). Same method surface
    as ``obs.report.ObsSession``, all free."""

    __slots__ = ()
    enabled = False

    def span(self, name, phase=None, **attrs):
        return NULL_SPAN

    def charge(self, op, floats, **params):
        return None

    def charge_hoist(self, artifact, n, table=None):
        return None

    def charge_perm_batch(self, op, n, permutations, batch, **params):
        return None

    def charge_production(self, n, d, block, **params):
        return None


NULL_OBS = _NullObs()

# the ambient stack: a Workspace-level span pushes its session so free
# functions deeper in the stack (stats.engine, core.pcoa, dist.driver)
# attach their spans/charges to the session that invoked them. Plain
# list, not a contextvar: the analysis stack is synchronous.
_STACK: list = []


def current_obs():
    """The innermost active session, or ``NULL_OBS`` (the free path)."""
    return _STACK[-1] if _STACK else NULL_OBS


def push_obs(session) -> None:
    _STACK.append(session)


def pop_obs(session) -> None:
    if _STACK and _STACK[-1] is session:
        _STACK.pop()
    elif session in _STACK:              # unbalanced exit: drop it anyway
        _STACK.remove(session)
