"""Recompile sentinel: count jit traces per wrapped entry point, live.

The engine-hardening invariant of the padded ``per_batch`` path is "ONE
trace serves any K" — the orders are padded to full batch tiles so the
canonical 999-permutation run never traces a second trailing-block
program. Until now that was only a test-time property (a Python-side
counter inside a probe statistic); this module makes it an always-on
runtime counter with an assertable guard, so CI's smoke pass — and any
production session — fails loudly the day a shape leaks back into a
trace signature.

Mechanism: a jitted function's **Python body runs only at trace time**,
so a ``note_trace(name, signature)`` call placed inside the body is a
zero-cost-per-call trace counter (verified for nested jits too: an
inner jit's body runs once per distinct signature even across outer
retraces — jax caches the inner jaxpr by abstract values). Each note
records:

* ``traces``   — body executions: how many times jax traced this entry;
* ``programs`` — distinct signatures: how many separate compiled
  executables exist. A genuine recompile regression (e.g. the old
  trailing-block special case) shows up as a NEW signature; a
  legitimately different workload (another n, another batch size) does
  too — which is exactly what the signature tuple is for: the guard
  scopes to a window where the workload parameters that SHOULD be
  shape-stable actually are.

The sentinel is process-global because the jit caches it mirrors are
process-global; scope assertions with ``snapshot()``/``since()`` or the
``expect()`` context manager.
"""

from __future__ import annotations

import contextlib
from collections import Counter
from typing import Optional


class RecompileError(RuntimeError):
    """An entry point traced more distinct programs than its budget."""


class CompileSentinel:
    """Per-entry-point trace and program counters."""

    def __init__(self):
        self._traces: Counter = Counter()
        self._signatures: dict = {}          # name -> set of signatures

    # -- recording ---------------------------------------------------------
    def note(self, name: str, signature=None) -> None:
        """Record one trace of ``name`` (call from inside the jitted
        body — it only runs at trace time). ``signature`` is any
        hashable tuple of the shapes/statics that key the jit cache;
        ``None`` degrades to trace counting only."""
        self._traces[name] += 1
        if signature is not None:
            self._signatures.setdefault(name, set()).add(signature)

    # -- queries -----------------------------------------------------------
    def traces(self, name: str) -> int:
        return self._traces[name]

    def programs(self, name: str) -> int:
        return len(self._signatures.get(name, ()))

    def names(self):
        return sorted(set(self._traces) | set(self._signatures))

    def snapshot(self) -> dict:
        """{entry point: {"traces", "programs"}} — embed in a RunReport
        or diff later with ``since()``."""
        return {n: {"traces": self.traces(n), "programs": self.programs(n)}
                for n in self.names()}

    def since(self, snap: dict) -> dict:
        """Counter deltas vs an earlier ``snapshot()`` (entries with no
        new traces are omitted)."""
        out = {}
        for n in self.names():
            base = snap.get(n, {"traces": 0, "programs": 0})
            dt = self.traces(n) - base["traces"]
            dp = self.programs(n) - base["programs"]
            if dt or dp:
                out[n] = {"traces": dt, "programs": dp}
        return out

    # -- guards ------------------------------------------------------------
    @contextlib.contextmanager
    def expect(self, name: str, max_programs: int = 1,
               max_traces: Optional[int] = None):
        """Assert at runtime that the enclosed block traces ``name`` at
        most ``max_programs`` distinct programs (the "one trace serves
        any K" invariant: run two different K values inside the window
        and the padded path must not add a second program)."""
        base = self.snapshot()
        yield self
        delta = self.since(base).get(name, {"traces": 0, "programs": 0})
        if delta["programs"] > max_programs:
            raise RecompileError(
                f"{name}: {delta['programs']} distinct programs traced "
                f"in this window (budget: {max_programs}) — a shape or "
                f"static argument is leaking into the trace signature")
        if max_traces is not None and delta["traces"] > max_traces:
            raise RecompileError(
                f"{name}: {delta['traces']} traces in this window "
                f"(budget: {max_traces})")


#: THE process-global sentinel — jit caches are process-global, so their
#: mirror is too. Sessions embed ``snapshot()`` deltas in their reports.
sentinel = CompileSentinel()


def note_trace(name: str, signature=None) -> None:
    """Module-level shorthand the instrumented jit bodies call."""
    sentinel.note(name, signature)
